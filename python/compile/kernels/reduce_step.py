"""The fused owner step of Ring Reduce-Scatter (§3.1): hash-guard + add.

One kernel performs what the NetDAM device does at the chunk owner:
recompute the local block's hash, compare against the carried
`expect_hash`, and produce either the reduced block (guard passed) or
the unchanged local block (duplicate chain — idempotent). Fusing guard
and add into one VMEM pass avoids a second HBM read of the local block.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HASH_C1
from .simd_alu import LANES


def _guarded_kernel(payload_ref, local_ref, expect_ref, out_ref, wrote_ref):
    payload = payload_ref[...]
    local = local_ref[...]
    bits = local.view(jnp.uint32).reshape(-1)
    weights = 2 * jnp.arange(LANES, dtype=jnp.uint32) + 1
    h = jnp.sum((bits ^ jnp.uint32(HASH_C1)) * weights, dtype=jnp.uint32)
    ok = h == expect_ref[0]
    out_ref[...] = jnp.where(ok, payload + local, local)
    wrote_ref[...] = ok.astype(jnp.uint32).reshape(1)


@jax.jit
def guarded_reduce_pallas(payload, local, expect_hash):
    """Per-block guarded reduce.

    Args: `(blocks, LANES)` payload/local f32, `(blocks,)` u32 hashes.
    Returns `(new_block, wrote)` with shapes `(blocks, LANES)`/`(blocks,)`.
    """
    assert payload.shape == local.shape and payload.shape[1] == LANES
    blocks = payload.shape[0]
    tile = pl.BlockSpec((1, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1,), lambda i: (i,))
    return pl.pallas_call(
        _guarded_kernel,
        out_shape=(
            jax.ShapeDtypeStruct(payload.shape, jnp.float32),
            jax.ShapeDtypeStruct((blocks,), jnp.uint32),
        ),
        grid=(blocks,),
        in_specs=[tile, tile, scalar],
        out_specs=(tile, scalar),
        interpret=True,
    )(payload, local, expect_hash)
