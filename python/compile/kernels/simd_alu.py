"""The SIMD ALU kernel: one NetDAM instruction over blocks of 2048 lanes.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the FPGA datapath
streams a jumbo payload past 2048 parallel f32 ALUs; on TPU the analogue
is one (1, 2048) VMEM tile per grid step with the op vectorized on the
VPU. `BlockSpec` expresses the HBM→VMEM schedule the FPGA does with its
packet-buffer SRAM. No MXU involvement — the ISA is elementwise.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: The paper's SIMD width: 2048 × f32 = 8 KiB per instruction.
LANES = 2048


def _make_kernel(op: str):
    def kernel(a_ref, b_ref, o_ref):
        a = a_ref[...]
        b = b_ref[...]
        if op == "add":
            o_ref[...] = a + b
        elif op == "sub":
            o_ref[...] = a - b
        elif op == "mul":
            o_ref[...] = a * b
        elif op == "min":
            # Explicit NaN propagation: the HLO `minimum` op's NaN
            # behaviour is implementation-defined (xla_extension 0.5.1's
            # CPU backend returns the non-NaN operand), so spell it out —
            # the artifact must match jnp/rust semantics on every backend.
            nan = jnp.float32(jnp.nan)
            o_ref[...] = jnp.where(
                jnp.isnan(a) | jnp.isnan(b), nan, jnp.minimum(a, b)
            )
        elif op == "max":
            nan = jnp.float32(jnp.nan)
            o_ref[...] = jnp.where(
                jnp.isnan(a) | jnp.isnan(b), nan, jnp.maximum(a, b)
            )
        elif op == "xor":
            ai = a.view(jnp.uint32)
            bi = b.view(jnp.uint32)
            o_ref[...] = (ai ^ bi).view(jnp.float32)
        else:  # pragma: no cover - guarded by caller
            raise ValueError(op)

    return kernel


@functools.partial(jax.jit, static_argnames=("op",))
def simd_op_pallas(a: jnp.ndarray, b: jnp.ndarray, *, op: str = "add") -> jnp.ndarray:
    """Apply `op` lane-wise over `(blocks, LANES)` f32 arrays.

    One grid step = one block = one device instruction; the VMEM tile is
    exactly the paper's 8 KiB payload.
    """
    assert a.ndim == 2 and a.shape[1] == LANES, a.shape
    assert a.shape == b.shape
    blocks = a.shape[0]
    spec = pl.BlockSpec((1, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _make_kernel(op),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        grid=(blocks,),
        in_specs=[spec, spec],
        out_specs=spec,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)
