"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

These mirror, operation for operation, what the rust `alu::NativeAlu` and
`alu::hash` implement; `python/tests/` asserts kernel == ref, and the rust
integration tests assert NativeAlu == XlaAlu(artifact). The chain closes:

    pallas kernel  ==  jnp ref  ==  rust native  ==  PJRT-compiled HLO
"""

import jax.numpy as jnp

#: The SIMD extension ops of paper §2.4, opcode order matching rust
#: `isa::SimdOp`.
SIMD_OPS = ("add", "sub", "mul", "min", "max", "xor")

#: Lane-whitening constant of the block hash (must equal rust HASH_C1).
HASH_C1 = 0x9E37_79B9


def ref_simd(op: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Lane-wise `op` over two f32 arrays (NaN-propagating min/max)."""
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "min":
        return jnp.minimum(a, b)  # NaN-propagating, like the rust side
    if op == "max":
        return jnp.maximum(a, b)
    if op == "xor":
        return (a.view(jnp.uint32) ^ b.view(jnp.uint32)).view(jnp.float32)
    raise ValueError(f"unknown op {op!r}")


def ref_block_hash(x: jnp.ndarray) -> jnp.ndarray:
    """Weighted-sum block hash over the f32 bit patterns.

    ``h = Σ_i (bits(x_i) ^ C1) · (2i + 1)  (mod 2^32)`` — identical to
    rust ``alu::hash::block_hash_f32`` (known vector asserted in tests).
    """
    bits = x.reshape(-1).view(jnp.uint32)
    n = bits.shape[0]
    weights = 2 * jnp.arange(n, dtype=jnp.uint32) + 1
    terms = (bits ^ jnp.uint32(HASH_C1)) * weights
    return jnp.sum(terms, dtype=jnp.uint32)


def ref_guarded_reduce(payload, local, expect_hash):
    """The owner step of Ring Reduce-Scatter (§3.1).

    Returns ``(new_block, wrote)``: if ``hash(local) == expect_hash`` (the
    block is pristine) the reduced sum is produced and ``wrote=1``; else
    the local block passes through unchanged (``wrote=0``) — the
    idempotent last hop.
    """
    ok = ref_block_hash(local) == jnp.uint32(expect_hash)
    new_block = jnp.where(ok, payload + local, local)
    return new_block, ok.astype(jnp.uint32)
