"""L1 — Pallas kernels implementing the NetDAM ALU array.

The paper's device executes one SIMD instruction over a 9000 B jumbo
payload ≈ 2048 × f32 lanes. Each kernel here maps one block to one VMEM
tile (`BlockSpec((1, LANES))`), grids over blocks, and vectorizes on the
VPU — the TPU-shaped analogue of the FPGA's ALU array (see DESIGN.md
§Hardware-Adaptation). All kernels run `interpret=True` (the CPU PJRT
plugin cannot execute Mosaic custom-calls) and are verified against the
pure-jnp oracles in `ref.py`.
"""

from .block_hash import block_hash_pallas
from .ref import ref_block_hash, ref_guarded_reduce, ref_simd, HASH_C1, SIMD_OPS
from .reduce_step import guarded_reduce_pallas
from .simd_alu import simd_op_pallas, LANES

__all__ = [
    "LANES",
    "HASH_C1",
    "SIMD_OPS",
    "simd_op_pallas",
    "block_hash_pallas",
    "guarded_reduce_pallas",
    "ref_simd",
    "ref_block_hash",
    "ref_guarded_reduce",
]
