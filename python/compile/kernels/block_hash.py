"""The block-hash kernel (paper §3.1's idempotency guard).

Per block: ``h = Σ_i (bits(x_i) ^ C1) · (2i+1) mod 2^32``. The weighted
sum is a single vectorized pass — the form was chosen (over a serial FNV
chain) precisely so a 2048-lane datapath, a TPU VPU tile, and a rust loop
all compute it the same way in one sweep.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HASH_C1
from .simd_alu import LANES


def _hash_kernel(x_ref, o_ref):
    bits = x_ref[...].view(jnp.uint32).reshape(-1)
    weights = 2 * jnp.arange(LANES, dtype=jnp.uint32) + 1
    terms = (bits ^ jnp.uint32(HASH_C1)) * weights
    o_ref[...] = jnp.sum(terms, dtype=jnp.uint32).reshape(1)


@jax.jit
def block_hash_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Hash each `(blocks, LANES)` row to one u32: returns `(blocks,)`."""
    assert x.ndim == 2 and x.shape[1] == LANES, x.shape
    blocks = x.shape[0]
    return pl.pallas_call(
        _hash_kernel,
        out_shape=jax.ShapeDtypeStruct((blocks,), jnp.uint32),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        interpret=True,
    )(x)
