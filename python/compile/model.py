"""L2 — JAX compute graphs lowered to the AOT artifacts rust executes.

Three families:

* **ALU graphs** — thin jitted wrappers around the L1 Pallas kernels,
  one artifact per SIMD op (the rust `runtime::XlaAlu` backend executes
  these for the device ALU data path).
* **Guarded reduce** — the fused §3.1 owner step (hash guard + add).
* **MLP training step** — fwd/bwd of a small regression MLP for the
  data-parallel training example (`examples/train_dataparallel.rs`):
  workers run this artifact through PJRT, and the resulting gradients are
  allreduced through the simulated NetDAM fabric. The SGD update is
  expressed with the Pallas SIMD kernels (`sgd_apply`) so the paper's
  in-memory-compute path covers the optimizer too.

Everything here is shape-static: `aot.py` lowers each graph once per
(shape) configuration and writes HLO *text* (see /opt/xla-example:
serialized protos from jax ≥ 0.5 are rejected by xla_extension 0.5.1).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import simd_op_pallas, block_hash_pallas, guarded_reduce_pallas, LANES

# --------------------------------------------------------------- ALU ----


def simd_graph(op: str, blocks: int):
    """(blocks·LANES,) ⊕ (blocks·LANES,) — flat vectors for the rust side."""

    def fn(a, b):
        a2 = a.reshape(blocks, LANES)
        b2 = b.reshape(blocks, LANES)
        return (simd_op_pallas(a2, b2, op=op).reshape(-1),)

    return fn


def block_hash_graph(blocks: int):
    def fn(x):
        return (block_hash_pallas(x.reshape(blocks, LANES)),)

    return fn


def guarded_reduce_graph(blocks: int):
    def fn(payload, local, expect):
        out, wrote = guarded_reduce_pallas(
            payload.reshape(blocks, LANES), local.reshape(blocks, LANES), expect
        )
        return (out.reshape(-1), wrote)

    return fn


# --------------------------------------------------------------- MLP ----

#: Default MLP geometry for the training example (≈ 0.6 M params —
#: small enough for a CPU-interpret run, structured like the real thing).
MLP_IN, MLP_HIDDEN, MLP_OUT = 64, 512, 16


def mlp_init(seed: int = 0, d_in=MLP_IN, d_h=MLP_HIDDEN, d_out=MLP_OUT):
    """He-initialized parameters as a flat tuple (rust-friendly)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w1 = jax.random.normal(k1, (d_in, d_h), jnp.float32) * (2.0 / d_in) ** 0.5
    b1 = jnp.zeros((d_h,), jnp.float32)
    w2 = jax.random.normal(k2, (d_h, d_out), jnp.float32) * (2.0 / d_h) ** 0.5
    b2 = jnp.zeros((d_out,), jnp.float32)
    return w1, b1, w2, b2


def mlp_loss(params, x, y):
    w1, b1, w2, b2 = params
    h = jnp.tanh(x @ w1 + b1)
    pred = h @ w2 + b2
    return jnp.mean((pred - y) ** 2)


def mlp_grad_graph(batch: int, d_in=MLP_IN, d_h=MLP_HIDDEN, d_out=MLP_OUT):
    """(w1,b1,w2,b2,x,y) → (g1,gb1,g2,gb2,loss) — one worker's step."""

    def fn(w1, b1, w2, b2, x, y):
        loss, grads = jax.value_and_grad(mlp_loss)((w1, b1, w2, b2), x, y)
        g1, gb1, g2, gb2 = grads
        return g1, gb1, g2, gb2, loss.reshape(1)

    return fn


def sgd_apply_graph(blocks: int):
    """w ← w − lr·g over flat (blocks·LANES,) vectors, via the Pallas ALU.

    Composes two device instructions — MUL (g·(−lr) broadcast block) and
    ADD — exactly how an in-memory optimizer would run on NetDAM (§4's
    "in-memory optimizer" future work, realized).
    """

    def fn(w, g, neg_lr):
        w2 = w.reshape(blocks, LANES)
        g2 = g.reshape(blocks, LANES)
        step = simd_op_pallas(g2, jnp.broadcast_to(neg_lr, g2.shape), op="mul")
        return (simd_op_pallas(w2, step, op="add").reshape(-1),)

    return fn


# ------------------------------------------------------------ helpers ---


def mlp_init_graph(seed: int = 0):
    """() → (w1,b1,w2,b2): parameter initialization as an artifact so the
    rust runtime starts from the exact same weights as the oracle."""

    def fn():
        return mlp_init(seed)

    return fn


def mlp_batch_graph(batch: int, seed: int = 0):
    """(step:u32) → (x, y): the deterministic synthetic regression task.
    Same stream the python oracle uses, so rust and python train on
    identical data.

    NOTE: the task matrix `kw` is *recomputed inside the graph* rather
    than captured as a closure constant — XLA's HLO text printer elides
    large constants (`constant({...})`), which would silently round-trip
    as zeros through the text interchange (caught by the e2e oracle
    check). Keys are tiny constants and survive printing.
    """
    key = jax.random.PRNGKey(seed + 1)

    def fn(step):
        kw = jax.random.normal(key, (MLP_IN, MLP_OUT), jnp.float32)
        ks = jax.random.fold_in(key, step)
        x = jax.random.normal(ks, (batch, MLP_IN), jnp.float32)
        y = jnp.tanh(x @ kw)
        return x, y

    return fn


@functools.lru_cache(maxsize=None)
def reference_training_curve(steps: int = 50, batch: int = 256, seed: int = 0):
    """Pure-jax training loss curve — oracle for the rust e2e example.
    Uses exactly the graphs exported as artifacts (same init, same data,
    same lr) so the rust-side curve must match to float precision."""
    params = mlp_init(seed)
    gen = mlp_batch_graph(batch, seed)
    losses = []
    grad_fn = jax.jit(jax.value_and_grad(mlp_loss))
    gen_fn = jax.jit(gen)
    for s in range(steps):
        x, y = gen_fn(jnp.uint32(s))
        loss, grads = grad_fn(params, x, y)
        params = tuple(p - 0.05 * g for p, g in zip(params, grads))
        losses.append(float(loss))
    return losses
