"""AOT lowering: JAX graphs → HLO text artifacts for the rust runtime.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md). Lowered with
``return_tuple=True`` — the rust side unwraps with ``to_tuple``.

Usage:  cd python && python -m compile.aot --out ../artifacts
Writes one ``<name>.hlo.txt`` per graph plus ``manifest.txt`` describing
the argument shapes (parsed by ``rust/src/runtime``).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import LANES, SIMD_OPS

#: Block count per ALU artifact: rust chunks arbitrary vectors into this.
ALU_BLOCKS = 8
#: Batch per worker for the training-step artifact.
TRAIN_BATCH = 256


def to_hlo_text(fn, *specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def u32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.uint32)


def artifact_set():
    """name → (fn, arg specs). The manifest row format is
    ``name arg0xarg1x...:dtype ...`` with flat shapes."""
    n = ALU_BLOCKS * LANES
    arts = {}
    for op in SIMD_OPS:
        arts[f"simd_{op}"] = (model.simd_graph(op, ALU_BLOCKS), [f32(n), f32(n)])
    arts["block_hash"] = (model.block_hash_graph(ALU_BLOCKS), [f32(n)])
    arts["guarded_reduce"] = (
        model.guarded_reduce_graph(ALU_BLOCKS),
        [f32(n), f32(n), u32(ALU_BLOCKS)],
    )
    d_in, d_h, d_out = model.MLP_IN, model.MLP_HIDDEN, model.MLP_OUT
    arts["mlp_grad"] = (
        model.mlp_grad_graph(TRAIN_BATCH),
        [
            f32(d_in, d_h),
            f32(d_h),
            f32(d_h, d_out),
            f32(d_out),
            f32(TRAIN_BATCH, d_in),
            f32(TRAIN_BATCH, d_out),
        ],
    )
    # sgd_apply over the largest parameter block, rust pads smaller ones.
    sgd_blocks = (d_in * d_h + LANES - 1) // LANES
    arts["sgd_apply"] = (
        model.sgd_apply_graph(sgd_blocks),
        [f32(sgd_blocks * LANES), f32(sgd_blocks * LANES), f32(1, LANES)],
    )
    arts["mlp_init"] = (model.mlp_init_graph(0), [])
    arts["mlp_batch"] = (
        model.mlp_batch_graph(TRAIN_BATCH, 0),
        [jax.ShapeDtypeStruct((), jnp.uint32)],
    )
    return arts


def spec_str(s) -> str:
    shape = "x".join(str(d) for d in s.shape) or "scalar"
    return f"{shape}:{s.dtype}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="lower a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, (fn, specs) in artifact_set().items():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(fn, *specs)
        # Guard against the HLO text printer eliding large constants —
        # those round-trip as zeros through the text interchange.
        if "constant({...})" in text:
            raise RuntimeError(
                f"{name}: lowered HLO contains an elided large constant; "
                "move the array into the graph (compute it from a key) or "
                "pass it as an argument"
            )
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        row = f"{name} " + " ".join(spec_str(s) for s in specs)
        manifest.append(row)
        print(f"wrote {path} ({len(text)} chars)  [{row}]")

    if not args.only:
        with open(os.path.join(args.out, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest) + "\n")
        # Constants the rust side cross-checks at load time.
        with open(os.path.join(args.out, "abi.txt"), "w") as f:
            f.write(f"lanes {LANES}\nalu_blocks {ALU_BLOCKS}\n")
            f.write(f"train_batch {TRAIN_BATCH}\n")
            f.write(
                f"mlp {model.MLP_IN} {model.MLP_HIDDEN} {model.MLP_OUT}\n"
            )
        # Oracle loss curve for the rust e2e training example.
        curve = model.reference_training_curve(steps=50, batch=TRAIN_BATCH, seed=0)
        with open(os.path.join(args.out, "reference_curve.txt"), "w") as f:
            f.write("\n".join(f"{v:.9e}" for v in curve) + "\n")


if __name__ == "__main__":
    main()
