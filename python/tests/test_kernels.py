"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes and value regimes; dedicated cases pin the
special values (NaN/Inf/−0.0) and the cross-language hash vector.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    LANES,
    SIMD_OPS,
    block_hash_pallas,
    guarded_reduce_pallas,
    ref_block_hash,
    ref_guarded_reduce,
    ref_simd,
    simd_op_pallas,
)

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


def rand_blocks(seed: int, blocks: int, lo=-1e3, hi=1e3) -> jnp.ndarray:
    r = np.random.RandomState(seed)
    return jnp.asarray(r.uniform(lo, hi, size=(blocks, LANES)).astype("float32"))


@pytest.mark.parametrize("op", SIMD_OPS)
def test_ops_match_ref_basic(op):
    a = rand_blocks(1, 2)
    b = rand_blocks(2, 2)
    got = simd_op_pallas(a, b, op=op)
    want = ref_simd(op, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(
    op=st.sampled_from(SIMD_OPS),
    blocks=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-6, 1.0, 1e6, 3e38]),
)
def test_ops_match_ref_swept(op, blocks, seed, scale):
    a = rand_blocks(seed, blocks, -scale, scale)
    b = rand_blocks(seed + 1, blocks, -scale, scale)
    got = simd_op_pallas(a, b, op=op)
    want = ref_simd(op, a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("op", SIMD_OPS)
def test_ops_handle_specials(op):
    specials = np.array(
        [np.nan, np.inf, -np.inf, 0.0, -0.0, 1.0, np.float32(3.4e38)],
        dtype="float32",
    )
    a = np.tile(np.resize(specials, LANES), (1, 1)).astype("float32")
    b = a[:, ::-1].copy()
    got = np.asarray(simd_op_pallas(jnp.asarray(a), jnp.asarray(b), op=op))
    want = np.asarray(ref_simd(op, jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got.view("uint32"), want.view("uint32"))


def test_xor_is_involution():
    a = rand_blocks(5, 3)
    b = rand_blocks(6, 3)
    x = simd_op_pallas(a, b, op="xor")
    back = simd_op_pallas(x, b, op="xor")
    np.testing.assert_array_equal(np.asarray(back), np.asarray(a))


# ------------------------------------------------------------- hash ----


def test_hash_known_vector_matches_rust():
    # rust: alu::hash::tests::known_vector_matches_python_kernel
    xs = jnp.arange(8, dtype=jnp.float32)
    assert int(ref_block_hash(xs)) == 0xB5DE_6E40


def test_hash_kernel_matches_ref_per_block():
    x = rand_blocks(7, 4)
    got = np.asarray(block_hash_pallas(x))
    want = np.asarray(jnp.stack([ref_block_hash(x[i]) for i in range(4)]))
    np.testing.assert_array_equal(got, want)


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_hash_detects_single_lane_flip(seed):
    x = rand_blocks(seed, 1)
    h0 = int(block_hash_pallas(x)[0])
    lane = seed % LANES
    x2 = np.asarray(x).copy()
    x2[0, lane] += 1.0
    h1 = int(block_hash_pallas(jnp.asarray(x2))[0])
    assert h0 != h1


def test_hash_detects_permutation():
    x = rand_blocks(9, 1)
    perm = np.asarray(x).copy()
    perm[0, :2] = perm[0, [1, 0]]
    assert int(block_hash_pallas(x)[0]) != int(block_hash_pallas(jnp.asarray(perm))[0])


# --------------------------------------------------- guarded reduce ----


def test_guarded_reduce_pass_and_block():
    payload = rand_blocks(11, 2)
    local = rand_blocks(12, 2)
    good = block_hash_pallas(local)
    out, wrote = guarded_reduce_pallas(payload, local, good)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(payload + local))
    assert np.asarray(wrote).tolist() == [1, 1]

    bad = good + np.uint32(1)
    out2, wrote2 = guarded_reduce_pallas(payload, local, bad)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(local))
    assert np.asarray(wrote2).tolist() == [0, 0]


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_guarded_reduce_matches_ref(seed):
    payload = rand_blocks(seed, 1)
    local = rand_blocks(seed + 1, 1)
    expect = block_hash_pallas(local)
    out, wrote = guarded_reduce_pallas(payload, local, expect)
    ref_out, ref_wrote = ref_guarded_reduce(payload[0], local[0], expect[0])
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref_out))
    assert int(wrote[0]) == int(ref_wrote)


def test_mixed_guard_per_block():
    payload = rand_blocks(21, 3)
    local = rand_blocks(22, 3)
    h = np.asarray(block_hash_pallas(local)).copy()
    h[1] ^= 0xDEAD  # corrupt the middle block's guard
    out, wrote = guarded_reduce_pallas(payload, local, jnp.asarray(h))
    assert np.asarray(wrote).tolist() == [1, 0, 1]
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(local[1]))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(payload[0] + local[0]))
