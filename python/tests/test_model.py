"""L2 correctness: the MLP training graphs and the SGD-via-Pallas update."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import LANES


def test_mlp_shapes_and_finite_grads():
    params = model.mlp_init(0)
    w1, b1, w2, b2 = params
    assert w1.shape == (model.MLP_IN, model.MLP_HIDDEN)
    assert w2.shape == (model.MLP_HIDDEN, model.MLP_OUT)
    fn = model.mlp_grad_graph(batch=32)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, model.MLP_IN), jnp.float32)
    y = jax.random.normal(key, (32, model.MLP_OUT), jnp.float32)
    g1, gb1, g2, gb2, loss = jax.jit(fn)(w1, b1, w2, b2, x, y)
    assert g1.shape == w1.shape and g2.shape == w2.shape
    assert gb1.shape == b1.shape and gb2.shape == b2.shape
    for g in (g1, gb1, g2, gb2, loss):
        assert bool(jnp.all(jnp.isfinite(g)))
    assert float(loss[0]) > 0


def test_reference_curve_decreases():
    losses = model.reference_training_curve(steps=30, batch=128, seed=0)
    assert len(losses) == 30
    # Loss must drop substantially over 30 SGD steps on the synthetic task.
    assert losses[-1] < 0.5 * losses[0], losses[:5] + losses[-5:]


def test_sgd_apply_matches_dense_update():
    blocks = 4
    n = blocks * LANES
    r = np.random.RandomState(0)
    w = jnp.asarray(r.randn(n).astype("float32"))
    g = jnp.asarray(r.randn(n).astype("float32"))
    lr = 0.05
    neg_lr = jnp.full((1, LANES), -lr, jnp.float32)
    fn = model.sgd_apply_graph(blocks)
    (new_w,) = jax.jit(fn)(w, g, neg_lr)
    np.testing.assert_allclose(
        np.asarray(new_w), np.asarray(w) - lr * np.asarray(g), rtol=1e-6
    )


def test_grad_matches_finite_difference():
    params = model.mlp_init(1, d_in=8, d_h=16, d_out=4)
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (16, 8), jnp.float32)
    y = jax.random.normal(key, (16, 4), jnp.float32)
    loss0 = model.mlp_loss(params, x, y)
    grads = jax.grad(model.mlp_loss)(params, x, y)
    # Perturb one weight along its gradient; loss must drop linearly.
    eps = 1e-3
    w1 = params[0] - eps * grads[0]
    loss1 = model.mlp_loss((w1, *params[1:]), x, y)
    predicted_drop = eps * float(jnp.sum(grads[0] ** 2))
    actual_drop = float(loss0 - loss1)
    assert actual_drop > 0
    assert abs(actual_drop - predicted_drop) < 0.3 * predicted_drop + 1e-6
