"""AOT path: every artifact lowers to parseable HLO text with the ABI the
rust runtime expects (entry computation with the declared parameter count,
tuple root)."""

import re

import jax.numpy as jnp
import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts():
    return aot.artifact_set()


def test_artifact_set_is_complete(artifacts):
    names = set(artifacts)
    for op in ("add", "sub", "mul", "min", "max", "xor"):
        assert f"simd_{op}" in names
    for extra in ("block_hash", "guarded_reduce", "mlp_grad", "sgd_apply"):
        assert extra in names


@pytest.mark.parametrize(
    "name", ["simd_add", "block_hash", "guarded_reduce", "sgd_apply"]
)
def test_lowering_produces_entry_hlo(artifacts, name):
    fn, specs = artifacts[name]
    text = aot.to_hlo_text(fn, *specs)
    assert "ENTRY" in text
    # Parameter count in the ENTRY computation matches the manifest row
    # (nested computations — reducers, fusions — have their own params).
    entry = text[text.index("ENTRY"):]
    params = re.findall(r"parameter\(\d+\)", entry)
    assert len(set(params)) == len(specs), (name, sorted(set(params)))
    # Tuple root (return_tuple=True) — rust unwraps with to_tuple.
    assert re.search(r"ROOT .*tuple", text), name


def test_simd_artifact_executes_in_jax(artifacts):
    """The lowered graph, run through jax itself, matches a direct add —
    guards against lowering to a wrong-but-parseable module."""
    import jax

    fn, specs = artifacts["simd_add"]
    n = specs[0].shape[0]
    a = jnp.arange(n, dtype=jnp.float32)
    b = jnp.ones((n,), jnp.float32)
    (out,) = jax.jit(fn)(a, b)
    assert out.shape == (n,)
    assert float(out[5]) == 6.0


def test_spec_str_format():
    assert aot.spec_str(jnp.zeros((4, 8))) in ("4x8:float32",)
    assert aot.spec_str(jnp.zeros((16,), jnp.uint32)) == "16:uint32"
