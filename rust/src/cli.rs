//! A small argv parser (offline substitute for `clap`).
//!
//! Grammar: `netdam <subcommand> [--flag] [--key value] [--set a.b=c]...`
//! Subcommands register their options; `--help` renders usage from them.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed arguments for one subcommand invocation.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// `--set key=value` overrides, applied onto the experiment config.
    pub sets: Vec<(String, String)>,
    positional: Vec<String>,
}

impl Args {
    /// Parse everything after the subcommand name.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if name == "set" {
                    let Some(kv) = argv.get(i + 1) else {
                        bail!("--set requires key=value");
                    };
                    let Some((k, v)) = kv.split_once('=') else {
                        bail!("--set expects key=value, got {kv:?}");
                    };
                    a.sets.push((k.to_string(), v.to_string()));
                    i += 2;
                    continue;
                }
                // `--key value` unless next token is another option or end.
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        a.opts.insert(name.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        a.flags.push(name.to_string());
                        i += 1;
                    }
                }
            } else {
                a.positional.push(tok.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Comma-separated list option: `--algo ring,hd,bcast`. Empty items
    /// are dropped; `None` when the option is absent.
    pub fn opt_list(&self, name: &str) -> Option<Vec<String>> {
        self.opt(name).map(|s| {
            s.split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect()
        })
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(s) => Ok(s.replace('_', "").parse()?),
        }
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        Ok(self.opt_u64(name, default as u64)? as usize)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.opts.get(name) {
            None => Ok(default),
            Some(s) => Ok(s.parse()?),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn mixed_options_flags_positionals() {
        // NOTE: a valueless flag directly before a positional is ambiguous
        // in this grammar (`--verbose input.toml` reads as an option), so
        // positionals come first — the convention all netdam subcommands use.
        let a = Args::parse(&argv(&[
            "input.toml", "--nodes", "4", "--verbose", "--size", "1048576",
        ]))
        .unwrap();
        assert_eq!(a.opt("nodes"), Some("4"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["input.toml".to_string()]);
        assert_eq!(a.opt_u64("size", 0).unwrap(), 1_048_576);
        assert_eq!(a.opt_u64("missing", 9).unwrap(), 9);
    }

    #[test]
    fn set_overrides_collect() {
        let a = Args::parse(&argv(&["--set", "cluster.devices=8", "--set", "seed=1"])).unwrap();
        assert_eq!(
            a.sets,
            vec![
                ("cluster.devices".to_string(), "8".to_string()),
                ("seed".to_string(), "1".to_string())
            ]
        );
    }

    #[test]
    fn list_options_split_on_commas() {
        let a = Args::parse(&argv(&["--algo", "ring, hd,,bcast"])).unwrap();
        assert_eq!(
            a.opt_list("algo").unwrap(),
            vec!["ring".to_string(), "hd".to_string(), "bcast".to_string()]
        );
        assert_eq!(a.opt_list("missing"), None);
    }

    #[test]
    fn underscored_numbers_parse() {
        let a = Args::parse(&argv(&["--n", "536_870_912"])).unwrap();
        assert_eq!(a.opt_u64("n", 0).unwrap(), 536_870_912);
    }

    #[test]
    fn malformed_set_is_error() {
        assert!(Args::parse(&argv(&["--set", "novalue"])).is_err());
        assert!(Args::parse(&argv(&["--set"])).is_err());
    }

    #[test]
    fn trailing_option_becomes_flag() {
        let a = Args::parse(&argv(&["--timing-only"])).unwrap();
        assert!(a.flag("timing-only"));
    }
}
