//! Deterministic Zipf(θ) key sampler for the serving workload generator.
//!
//! Serving traffic is famously skewed — a handful of hot embedding rows /
//! KV keys absorb most requests — and the standard model is a Zipf
//! distribution: key rank `k` (1-based) drawn with probability
//! `P(k) ∝ k^{-θ}`. θ = 0 is uniform, θ ≈ 0.99 is the YCSB default, and
//! θ > 1 concentrates almost everything on the head.
//!
//! The sampler uses **rejection-inversion** (Hörmann & Derflinger 1996,
//! the algorithm behind Apache Commons' `RejectionInversionZipfSampler`):
//! invert the integral of the continuous envelope `h(x) = x^{-θ}` and
//! reject the thin sliver where the discrete pmf undercuts it. O(1) time
//! and memory per draw for *any* key-space size — no cdf table to build,
//! which matters when the pooled GVA space holds millions of rows — and
//! every draw is a pure function of the caller's [`Xoshiro256`] stream,
//! so serving runs stay bit-reproducible across DES cores.

use crate::util::rng::Xoshiro256;

/// Zipf(θ) sampler over `n` keys, returning **0-based** key indices.
#[derive(Debug, Clone)]
pub struct Zipf {
    n: u64,
    theta: f64,
    /// `H(1.5) - 1` — the left edge of the inversion interval.
    h_x1: f64,
    /// `H(n + 0.5)` — the right edge.
    h_n: f64,
    /// Acceptance shortcut: `x` within `s` of its rounded key is always
    /// accepted without evaluating the pmf bound.
    s: f64,
}

/// Antiderivative of the envelope: `H(x) = (x^{1-θ} - 1) / (1-θ)`,
/// degenerating to `ln x` at θ = 1. Written with `exp_m1` so the two
/// branches agree to machine precision as θ → 1.
fn h_integral(x: f64, theta: f64) -> f64 {
    let log_x = x.ln();
    if (theta - 1.0).abs() < 1e-12 {
        log_x
    } else {
        ((1.0 - theta) * log_x).exp_m1() / (1.0 - theta)
    }
}

/// The envelope itself: `h(x) = x^{-θ}`.
fn h(x: f64, theta: f64) -> f64 {
    (-theta * x.ln()).exp()
}

/// Inverse of [`h_integral`].
fn h_integral_inverse(y: f64, theta: f64) -> f64 {
    if (theta - 1.0).abs() < 1e-12 {
        y.exp()
    } else {
        // Clamp guards the log1p domain against rounding at the interval
        // edge (t can land an ulp below -1 for large θ).
        let t = (y * (1.0 - theta)).max(-1.0);
        (t.ln_1p() / (1.0 - theta)).exp()
    }
}

impl Zipf {
    /// Sampler over keys `0..n` with skew `theta ≥ 0`.
    ///
    /// # Panics
    /// If `n == 0` or `theta` is negative / non-finite.
    pub fn new(n: u64, theta: f64) -> Self {
        assert!(n > 0, "Zipf key space must be nonempty");
        assert!(
            theta.is_finite() && theta >= 0.0,
            "Zipf skew must be a finite nonnegative number, got {theta}"
        );
        let h_x1 = h_integral(1.5, theta) - 1.0;
        let h_n = h_integral(n as f64 + 0.5, theta);
        let s = 2.0 - h_integral_inverse(h_integral(2.5, theta) - h(2.0, theta), theta);
        Self {
            n,
            theta,
            h_x1,
            h_n,
            s,
        }
    }

    /// Number of keys.
    pub fn keys(&self) -> u64 {
        self.n
    }

    /// Skew exponent θ.
    pub fn theta(&self) -> f64 {
        self.theta
    }

    /// Draw one key index in `[0, n)`; rank 0 is the hottest key.
    pub fn sample(&self, rng: &mut Xoshiro256) -> u64 {
        loop {
            // u uniform in (h_x1, h_n] — note h_n < h_x1 for θ > 0, the
            // lerp below handles either orientation.
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = h_integral_inverse(u, self.theta);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            // Fast accept: x close enough to its key that the envelope
            // cannot undercut the pmf. Slow path: exact bound check.
            if k - x <= self.s
                || u >= h_integral(k + 0.5, self.theta) - h(k, self.theta)
            {
                return k as u64 - 1;
            }
        }
    }

    /// Exact pmf of 0-based key `k` — O(n), for tests and reports only.
    pub fn probability(&self, k: u64) -> f64 {
        assert!(k < self.n);
        let harmonic: f64 = (1..=self.n)
            .map(|i| (i as f64).powf(-self.theta))
            .sum();
        (k as f64 + 1.0).powf(-self.theta) / harmonic
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pearson chi-square of an observed histogram against the exact pmf.
    fn chi_square(zipf: &Zipf, seed: u64, draws: usize) -> f64 {
        let mut rng = Xoshiro256::seed_from(seed);
        let n = zipf.keys() as usize;
        let mut counts = vec![0u64; n];
        for _ in 0..draws {
            counts[zipf.sample(&mut rng) as usize] += 1;
        }
        (0..n)
            .map(|k| {
                let expect = zipf.probability(k as u64) * draws as f64;
                let d = counts[k] as f64 - expect;
                d * d / expect
            })
            .sum()
    }

    #[test]
    fn chi_square_fits_exact_pmf() {
        // 19 degrees of freedom: the χ² 0.001 critical value is ≈ 43.8.
        // A buggy sampler (off-by-one rank, wrong tail) lands in the
        // hundreds; a correct one stays comfortably below 45.
        for (theta, seed) in [(0.0, 11u64), (0.8, 12), (0.99, 13), (1.0, 14), (1.3, 15)] {
            let z = Zipf::new(20, theta);
            let x2 = chi_square(&z, seed, 200_000);
            assert!(x2 < 45.0, "theta={theta}: chi-square {x2:.1} too large");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let z = Zipf::new(1_000_000, 0.99);
        let draw = |seed| {
            let mut rng = Xoshiro256::seed_from(seed);
            (0..64).map(|_| z.sample(&mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(draw(7), draw(7));
        assert_ne!(draw(7), draw(8));
    }

    #[test]
    fn samples_stay_in_range_even_for_huge_key_spaces() {
        let z = Zipf::new(1 << 40, 1.1);
        let mut rng = Xoshiro256::seed_from(3);
        for _ in 0..10_000 {
            assert!(z.sample(&mut rng) < 1 << 40);
        }
    }

    #[test]
    fn theta_zero_is_uniform() {
        let z = Zipf::new(8, 0.0);
        let mut rng = Xoshiro256::seed_from(5);
        let mut counts = [0u64; 8];
        let draws = 80_000;
        for _ in 0..draws {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / draws as f64;
            assert!((frac - 0.125).abs() < 0.01, "uniform bucket at {frac}");
        }
    }

    #[test]
    fn higher_skew_concentrates_the_head() {
        let head_mass = |theta: f64| {
            let z = Zipf::new(1000, theta);
            let mut rng = Xoshiro256::seed_from(9);
            (0..50_000).filter(|_| z.sample(&mut rng) < 10).count()
        };
        let mild = head_mass(0.5);
        let hot = head_mass(1.2);
        assert!(
            hot > 2 * mild,
            "theta=1.2 head {hot} should dwarf theta=0.5 head {mild}"
        );
    }

    #[test]
    fn single_key_space() {
        let z = Zipf::new(1, 0.99);
        let mut rng = Xoshiro256::seed_from(1);
        for _ in 0..100 {
            assert_eq!(z.sample(&mut rng), 0);
        }
    }
}
