//! A miniature property-testing driver.
//!
//! `proptest` is not available in this offline build, so we provide the
//! 10% of it the test suite needs: run a property over many seeded random
//! cases, and on failure report the *seed and case index* so the exact
//! failing input can be replayed deterministically. There is no shrinking;
//! generators are encouraged to start small (sizes are drawn
//! log-uniformly, so small cases are tried often).

use crate::util::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Base seed; each case derives its own stream from `seed ^ case_index`.
    pub seed: u64,
    /// Number of random cases to run.
    pub cases: u32,
}

/// Default seed for all property runs ("NetDAM!1" in ASCII).
const NETDAM_DEFAULT_SEED: u64 = 0x4E65_7444_414D_2131;

impl Default for Config {
    fn default() -> Self {
        Self {
            seed: NETDAM_DEFAULT_SEED,
            cases: 128,
        }
    }
}

/// Run `property` for `cfg.cases` random cases. The property receives a
/// per-case RNG and the case index; it should panic (assert) on violation.
pub fn check_with<F: FnMut(&mut Xoshiro256, u32)>(cfg: Config, mut property: F) {
    for case in 0..cfg.cases {
        let mut rng = Xoshiro256::seed_from(cfg.seed ^ (case as u64).wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng, case)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{} (replay: seed={:#x}): {msg}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Run with the default config (128 cases, fixed seed).
pub fn check<F: FnMut(&mut Xoshiro256, u32)>(property: F) {
    check_with(
        Config {
            seed: NETDAM_DEFAULT_SEED,
            cases: 128,
        },
        property,
    )
}

/// Draw a size log-uniformly in `[1, max]` — biases coverage toward small
/// cases (where bugs reproduce quickly) while still exercising large ones.
pub fn log_size(rng: &mut Xoshiro256, max: usize) -> usize {
    debug_assert!(max >= 1);
    let bits = 64 - (max as u64).leading_zeros() as u64; // ceil(log2)+1-ish
    let b = rng.next_below(bits) + 1;
    let hi = (1u64 << b).min(max as u64);
    rng.range_u64(1, hi) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_with(Config { seed: 1, cases: 50 }, |_rng, _i| count += 1);
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        check_with(Config { seed: 1, cases: 50 }, |rng, _i| {
            let v = rng.next_below(10);
            assert!(v != 3, "hit the forbidden value");
        });
    }

    #[test]
    fn log_size_in_bounds_and_small_biased() {
        let mut rng = Xoshiro256::seed_from(2);
        let mut small = 0;
        for _ in 0..2000 {
            let s = log_size(&mut rng, 1 << 20);
            assert!((1..=(1 << 20)).contains(&s));
            if s <= 64 {
                small += 1;
            }
        }
        assert!(small > 200, "small sizes should be common, got {small}");
    }
}
