//! Scalar statistics helpers shared by metrics and the bench harness.

/// Online mean/variance (Welford) plus min/max — cheap enough for the DES
/// hot path, numerically stable for nanosecond-scale latencies.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold another accumulator into this one (Chan et al.'s parallel
    /// variance update) — used when per-shard metrics merge at the end of
    /// a sharded run.
    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let na = self.n as f64;
        let nb = other.n as f64;
        let d = other.mean - self.mean;
        let n = na + nb;
        self.mean += d * (nb / n);
        self.m2 += other.m2 + d * d * (na * nb / n);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exact percentile over a sorted copy — for bench-sized sample sets.
/// (The DES-side histogram in `metrics` handles the high-volume case.)
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Nearest-rank percentile over integer nanosecond samples. Unlike
/// [`percentile`] this never interpolates, so the result is always one of
/// the observed samples — which keeps reports carrying it `Eq`-comparable
/// (no float fields) and makes p99 read as "a latency that happened".
pub fn percentile_ns(samples: &[u64], p: f64) -> u64 {
    assert!((0.0..=100.0).contains(&p));
    if samples.is_empty() {
        return 0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.saturating_sub(1).min(v.len() - 1)]
}

/// Nearest-rank tail summary over whole-ns latency samples: p50 / p99 /
/// p99.9 / max from **one** sort instead of three `percentile_ns` passes.
/// All-integer, so reports carrying it stay `Eq`-comparable — the serving
/// isolation tests compare per-tenant tails bit-exactly across shard
/// counts.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct TailNs {
    pub count: usize,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
}

/// Summarise a latency sample set (empty in → all-zero summary out).
pub fn tail_ns(samples: &[u64]) -> TailNs {
    if samples.is_empty() {
        return TailNs::default();
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let at = |p: f64| {
        let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
        v[rank.saturating_sub(1).min(v.len() - 1)]
    };
    TailNs {
        count: v.len(),
        p50: at(50.0),
        p99: at(99.0),
        p999: at(99.9),
        max: *v.last().unwrap(),
    }
}

/// Jain's fairness index `(Σx)² / (n·Σx²)` — 1.0 when every flow gets the
/// same share, → 1/n when one flow takes everything. The incast bench
/// uses it to show DCQCN converging senders to equal goodput.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        // sample variance of the classic dataset is 32/7
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn merge_matches_single_stream() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut whole = Running::new();
        let mut a = Running::new();
        let mut b = Running::new();
        for (i, &x) in xs.iter().enumerate() {
            whole.push(x);
            if i < 3 {
                a.push(x);
            } else {
                b.push(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
        // Merging an empty accumulator is a no-op in both directions.
        let empty = Running::new();
        let before = a.mean();
        a.merge(&empty);
        assert_eq!(a.mean(), before);
        let mut e2 = Running::new();
        e2.merge(&whole);
        assert_eq!(e2.count(), whole.count());
    }

    #[test]
    fn empty_running_is_zeroes() {
        let r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.std_dev(), 0.0);
    }

    #[test]
    fn percentile_endpoints_and_median() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_ns_nearest_rank() {
        let xs = [50u64, 10, 40, 20, 30];
        assert_eq!(percentile_ns(&xs, 50.0), 30);
        assert_eq!(percentile_ns(&xs, 99.0), 50);
        assert_eq!(percentile_ns(&xs, 0.0), 10);
        assert_eq!(percentile_ns(&[], 99.0), 0);
        assert_eq!(percentile_ns(&[7], 50.0), 7);
    }

    #[test]
    fn percentile_ns_supports_p999() {
        // 1000 distinct samples: nearest rank for p99.9 is the 999th
        // order statistic — the second-largest value.
        let xs: Vec<u64> = (1..=1000).collect();
        assert_eq!(percentile_ns(&xs, 99.9), 999);
        assert_eq!(percentile_ns(&xs, 99.0), 990);
        // Below 1000 samples p99.9 collapses onto the max.
        let small: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile_ns(&small, 99.9), 100);
    }

    #[test]
    fn tail_summary_matches_percentile_ns() {
        let mut xs: Vec<u64> = (1..=2000).rev().collect();
        xs.push(5_000_000); // one outlier only the max should record
        let t = tail_ns(&xs);
        assert_eq!(t.count, xs.len());
        assert_eq!(t.p50, percentile_ns(&xs, 50.0));
        assert_eq!(t.p99, percentile_ns(&xs, 99.0));
        assert_eq!(t.p999, percentile_ns(&xs, 99.9));
        assert_eq!(t.max, 5_000_000);
        assert!(t.p50 <= t.p99 && t.p99 <= t.p999 && t.p999 <= t.max);
        // The outlier is invisible at p99 but the max records it.
        assert!(t.p99 < 5_000_000);
        assert_eq!(tail_ns(&[]), TailNs::default());
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain_fairness(&[5.0, 5.0, 5.0]), 1.0);
        let skew = jain_fairness(&[10.0, 0.0, 0.0, 0.0]);
        assert!((skew - 0.25).abs() < 1e-12);
        assert_eq!(jain_fairness(&[]), 1.0);
        assert_eq!(jain_fairness(&[0.0, 0.0]), 1.0);
    }
}
