//! Deterministic PRNGs for the simulator and the property-test driver.
//!
//! The DES must be bit-reproducible across runs (every experiment in
//! EXPERIMENTS.md records its seed), so we use small, well-known generators
//! rather than OS entropy: SplitMix64 for seeding/stateless streams and
//! xoshiro256** as the workhorse generator.

/// SplitMix64 — tiny, passes BigCrush, ideal for turning one u64 seed into
/// arbitrarily many decorrelated streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the simulator's main generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below bound must be positive");
        // 128-bit multiply keeps the distribution exactly uniform.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box-Muller (used by jitter models).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random f32 payload vector (used heavily in tests/benches).
    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * self.next_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values from the canonical splitmix64.c with seed 0.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn xoshiro_streams_differ_by_seed() {
        let mut a = Xoshiro256::seed_from(1);
        let mut b = Xoshiro256::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut r = Xoshiro256::seed_from(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::seed_from(3);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments_roughly_standard() {
        let mut r = Xoshiro256::seed_from(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Xoshiro256::seed_from(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = r.range_u64(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }
}
