//! CRC-32 (ISO-HDLC / IEEE 802.3, the `cksum`-family polynomial).
//!
//! The DPU offload library models the paper's "hash" offload with a real
//! CRC-32; this build is offline (no `crc32fast`), so the classic
//! reflected table-driven implementation lives here. Parameters:
//! polynomial `0xEDB88320` (reflected `0x04C11DB7`), init `0xFFFFFFFF`,
//! final xor `0xFFFFFFFF` — the variant whose check value over
//! `"123456789"` is `0xCBF43926`.

/// The reflected CRC-32 lookup table, built at compile time.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut bit = 0;
        while bit < 8 {
            crc = if crc & 1 != 0 {
                (crc >> 1) ^ 0xEDB8_8320
            } else {
                crc >> 1
            };
            bit += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

/// CRC-32 of `data` (one-shot).
pub fn hash(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = (crc >> 8) ^ TABLE[((crc ^ b as u32) & 0xFF) as usize];
    }
    crc ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_value() {
        // The canonical CRC-32 check value.
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_and_incremental_properties() {
        assert_eq!(hash(&[]), 0);
        // Deterministic, and sensitive to edits/truncation.
        let base = hash(b"netdam block");
        assert_eq!(base, hash(b"netdam block"));
        assert_ne!(base, hash(b"netdam block!"));
        assert_ne!(base, hash(b"netdam bloc"));
    }
}
