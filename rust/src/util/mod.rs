//! Small shared utilities: deterministic PRNGs, byte helpers, statistics,
//! and a miniature property-testing driver (`prop`) used because `proptest`
//! is unavailable in this offline build.

pub mod bytes;
pub mod crc32;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod zipf;

pub use rng::{SplitMix64, Xoshiro256};
pub use zipf::Zipf;
