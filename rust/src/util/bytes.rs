//! Byte-order helpers for the wire codec.
//!
//! Everything on the NetDAM wire is big-endian (network order), matching
//! the Ethernet/IP/UDP carriers. These helpers are the single place where
//! the codec touches raw bytes; `wire::*` builds on them.

use anyhow::{bail, Result};

/// A cursor for reading big-endian fields from a byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "wire underrun: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_be_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_be_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_be_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn slice(&mut self, n: usize) -> Result<&'a [u8]> {
        self.take(n)
    }

    pub fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }
}

/// A growable big-endian writer. Thin wrapper over `Vec<u8>` so the codec
/// reads symmetrically to [`Reader`].
#[derive(Debug, Default)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn with_capacity(n: usize) -> Self {
        Self {
            buf: Vec::with_capacity(n),
        }
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_be_bytes());
    }

    pub fn bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }
}

/// Reinterpret an f32 slice as bytes (little-endian host layout is fine for
/// payloads: the payload is opaque SIMD data and both ends of the simulated
/// wire share the representation; headers stay big-endian).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_bytes`]. Errors on ragged length.
pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        bail!("payload length {} is not a multiple of 4", b.len());
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_widths() {
        let mut w = Writer::default();
        w.u8(0xAB);
        w.u16(0xDEAD);
        w.u32(0xDEAD_BEEF);
        w.u64(0x0123_4567_89AB_CDEF);
        w.bytes(b"xyz");
        let v = w.into_vec();
        let mut r = Reader::new(&v);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u16().unwrap(), 0xDEAD);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.rest(), b"xyz");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn big_endian_on_wire() {
        let mut w = Writer::default();
        w.u32(1);
        assert_eq!(w.as_slice(), &[0, 0, 0, 1]);
    }

    #[test]
    fn underrun_is_error_not_panic() {
        let mut r = Reader::new(&[1, 2]);
        assert!(r.u32().is_err());
    }

    #[test]
    fn f32_payload_round_trip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MAX];
        let b = f32s_to_bytes(&xs);
        assert_eq!(bytes_to_f32s(&b).unwrap(), xs);
    }

    #[test]
    fn ragged_f32_payload_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
