//! User-defined instruction registry — the "programmable" in the
//! programmable ISA.
//!
//! The paper reserves the high opcode range for user-defined behaviour
//! ("user could define their own instructions for different computation
//! jobs": DPU offload would add compress/crypto/hash/LPM; NN training adds
//! SIMD and the collective steps). We model that with a registry of
//! [`UserInstruction`] handlers a device consults for any opcode `>=
//! USER_OPCODE_BASE`. Handlers see device memory through the [`MemAccess`]
//! trait and return an [`ExecOutcome`], and declare an execution *cost* so
//! the DES charges pipeline time for them.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::instr::Flags;
use super::opcode::USER_OPCODE_BASE;
use crate::sim::SimTime;

/// Device-memory access as seen by instruction handlers.
///
/// `read` returns an owned buffer because device memory is page-sparse
/// (2 GB HBM per device would not fit resident ×N devices); reads may
/// cross page boundaries.
pub trait MemAccess {
    fn capacity(&self) -> u64;
    fn read(&self, addr: u64, len: usize) -> Result<Vec<u8>>;
    fn write(&mut self, addr: u64, data: &[u8]) -> Result<()>;
}

/// What the device should do after executing a user instruction.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecOutcome {
    /// Nothing to send; packet is consumed.
    Consume,
    /// Reply to the source with a user instruction + payload.
    Reply {
        opcode: u16,
        a: u64,
        b: u64,
        c: u64,
        payload: Vec<u8>,
    },
    /// Replace the packet payload and continue along the SROU segment list
    /// (the chained-computation / DAG model of §2.2).
    Forward { payload: Vec<u8> },
    /// Drop silently (e.g. guard failed).
    Drop,
}

/// Execution context handed to a user instruction.
///
/// In a packet [`Program`](super::program::Program), user steps chain:
/// `payload` is the previous step's result payload and `fwd` carries the
/// previous user step's reply operands — the operand-forwarding
/// convention that lets e.g. `crypto_write → crc32` ride one packet.
pub struct ExecCtx<'a> {
    pub mem: &'a mut dyn MemAccess,
    pub payload: &'a [u8],
    pub a: u64,
    pub b: u64,
    pub c: u64,
    pub flags: Flags,
    /// `(a, b, c)` replied by the previous user step of the same program,
    /// if any. `None` outside programs or after non-user steps.
    pub fwd: Option<(u64, u64, u64)>,
}

/// A user-defined instruction implementation. `Send + Sync` so the
/// registry `Arc` shared by every device can cross shard-thread
/// boundaries (`execute` already takes `&self`; handlers are pure).
pub trait UserInstruction: Send + Sync {
    /// Human-readable name (for metrics and errors).
    fn name(&self) -> &'static str;
    /// Execute against device memory; pure function of (mem, packet).
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome>;
    /// Pipeline time charged by the DES. Default: ALU-array cost of one
    /// pass over the payload at 64 B/cycle, 250 MHz fabric clock (4 ns).
    fn cost_ns(&self, payload_len: usize) -> SimTime {
        4 * (payload_len as u64 / 64 + 1)
    }
    /// Whether blind re-execution is safe (drives retransmit policy).
    fn idempotent(&self) -> bool {
        false
    }
}

/// Opcode → handler table. One registry is shared by all devices in a
/// simulation (instructions are "flashed" into every NetDAM).
#[derive(Default)]
pub struct InstructionRegistry {
    handlers: HashMap<u16, Box<dyn UserInstruction>>,
}

impl InstructionRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a handler. Fails on opcodes below the user range or on
    /// double registration — both are deployment bugs worth surfacing.
    pub fn register(&mut self, opcode: u16, h: Box<dyn UserInstruction>) -> Result<()> {
        if opcode < USER_OPCODE_BASE {
            bail!(
                "opcode {opcode:#06x} is below the user range ({USER_OPCODE_BASE:#06x})"
            );
        }
        if self.handlers.contains_key(&opcode) {
            bail!("opcode {opcode:#06x} already registered");
        }
        self.handlers.insert(opcode, h);
        Ok(())
    }

    pub fn get(&self, opcode: u16) -> Option<&dyn UserInstruction> {
        self.handlers.get(&opcode).map(|b| b.as_ref())
    }

    pub fn len(&self) -> usize {
        self.handlers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.handlers.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy vector memory for handler tests.
    pub(crate) struct VecMem(pub Vec<u8>);

    impl MemAccess for VecMem {
        fn capacity(&self) -> u64 {
            self.0.len() as u64
        }
        fn read(&self, addr: u64, len: usize) -> Result<Vec<u8>> {
            let a = addr as usize;
            if a + len > self.0.len() {
                bail!("oob read");
            }
            Ok(self.0[a..a + len].to_vec())
        }
        fn write(&mut self, addr: u64, data: &[u8]) -> Result<()> {
            let a = addr as usize;
            if a + data.len() > self.0.len() {
                bail!("oob write");
            }
            self.0[a..a + data.len()].copy_from_slice(data);
            Ok(())
        }
    }

    /// Example user instruction: byte-wise XOR payload into memory
    /// (a stand-in for the paper's "crypto" DPU offload example).
    struct XorWrite;

    impl UserInstruction for XorWrite {
        fn name(&self) -> &'static str {
            "xor_write"
        }
        fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
            let cur = ctx.mem.read(ctx.a, ctx.payload.len())?;
            let mixed: Vec<u8> = cur.iter().zip(ctx.payload).map(|(m, p)| m ^ p).collect();
            ctx.mem.write(ctx.a, &mixed)?;
            Ok(ExecOutcome::Reply {
                opcode: 0x8002,
                a: ctx.a,
                b: 0,
                c: 0,
                payload: vec![],
            })
        }
    }

    #[test]
    fn register_and_execute() {
        let mut reg = InstructionRegistry::new();
        reg.register(0x8001, Box::new(XorWrite)).unwrap();
        assert_eq!(reg.len(), 1);
        let mut mem = VecMem(vec![0xFF; 16]);
        let payload = vec![0x0F; 4];
        let mut ctx = ExecCtx {
            mem: &mut mem,
            payload: &payload,
            a: 4,
            b: 0,
            c: 0,
            flags: Flags::default(),
            fwd: None,
        };
        let out = reg.get(0x8001).unwrap().execute(&mut ctx).unwrap();
        assert!(matches!(out, ExecOutcome::Reply { opcode: 0x8002, .. }));
        assert_eq!(&mem.0[4..8], &[0xF0; 4]);
        assert_eq!(&mem.0[0..4], &[0xFF; 4]);
    }

    #[test]
    fn rejects_core_range_and_duplicates() {
        let mut reg = InstructionRegistry::new();
        assert!(reg.register(0x0100, Box::new(XorWrite)).is_err());
        reg.register(0x8001, Box::new(XorWrite)).unwrap();
        assert!(reg.register(0x8001, Box::new(XorWrite)).is_err());
    }

    #[test]
    fn default_cost_scales_with_payload() {
        let x = XorWrite;
        assert!(x.cost_ns(9000) > x.cost_ns(64));
        assert!(x.cost_ns(0) > 0);
    }
}
