//! Opcode numbering and the SIMD ALU operation set.

use anyhow::{bail, Result};

/// Opcodes `>= USER_OPCODE_BASE` are the user-defined range the paper
/// reserves ("we reserve multiple bits in this field, user could define
/// their own instructions").
pub const USER_OPCODE_BASE: u16 = 0x8000;

/// One table drives the enum, the decoder, and the exhaustive test list —
/// adding an opcode in one place cannot drift from its `from_u16` arm.
macro_rules! define_opcodes {
    ($($(#[$meta:meta])* $name:ident = $val:literal,)+) => {
        /// Wire opcodes. The core template set is 0x00xx; SIMD extensions
        /// 0x01xx; collective extensions 0x02xx; pool/control 0x03xx;
        /// packet programs 0x04xx.
        #[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
        #[repr(u16)]
        pub enum Opcode {
            $($(#[$meta])* $name = $val,)+
        }

        impl Opcode {
            /// Every defined opcode, in table order.
            pub const ALL: &'static [Opcode] = &[$(Opcode::$name,)+];

            pub fn from_u16(v: u16) -> Result<Opcode> {
                match v {
                    $($val => Ok(Opcode::$name),)+
                    other => bail!("unknown opcode {other:#06x}"),
                }
            }
        }
    };
}

define_opcodes! {
    Nop = 0x0000,
    Read = 0x0001,
    ReadResp = 0x0002,
    Write = 0x0003,
    WriteAck = 0x0004,
    Cas = 0x0005,
    CasResp = 0x0006,
    Memcopy = 0x0007,
    Ack = 0x0008,
    Nack = 0x0009,

    Simd = 0x0100,
    SimdResp = 0x0101,
    BlockHash = 0x0102,
    BlockHashResp = 0x0103,
    WriteIfHash = 0x0104,

    /// Completion notification for a retired packet program (the old
    /// fused ReduceScatter/AllGather opcodes 0x0200/0x0201 are gone:
    /// those behaviours are now [`Program`](Opcode::Program)s).
    CollectiveDone = 0x0202,

    Malloc = 0x0300,
    MallocResp = 0x0301,
    Free = 0x0302,
    FreeResp = 0x0303,

    /// A bounded multi-instruction packet program (see
    /// [`crate::isa::program`]).
    Program = 0x0400,
}

/// The SIMD ALU operation set the paper lists for the neural-network case:
/// "user may define SIMD (ADD, SUB, MUL, XOR, MIN, MAX) and compute them
/// directly near the memory".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum SimdOp {
    Add = 0,
    Sub = 1,
    Mul = 2,
    Min = 3,
    Max = 4,
    Xor = 5,
}

impl SimdOp {
    pub const ALL: [SimdOp; 6] = [
        SimdOp::Add,
        SimdOp::Sub,
        SimdOp::Mul,
        SimdOp::Min,
        SimdOp::Max,
        SimdOp::Xor,
    ];

    pub fn from_u8(v: u8) -> Result<SimdOp> {
        Ok(match v {
            0 => SimdOp::Add,
            1 => SimdOp::Sub,
            2 => SimdOp::Mul,
            3 => SimdOp::Min,
            4 => SimdOp::Max,
            5 => SimdOp::Xor,
            other => bail!("unknown simd op {other}"),
        })
    }

    /// Apply to two f32 lanes (Xor operates on the raw bits, as the FPGA
    /// datapath would; useful for masks/checksums).
    #[inline]
    pub fn apply_f32(&self, a: f32, b: f32) -> f32 {
        match self {
            SimdOp::Add => a + b,
            SimdOp::Sub => a - b,
            SimdOp::Mul => a * b,
            SimdOp::Min => a.min(b),
            SimdOp::Max => a.max(b),
            SimdOp::Xor => f32::from_bits(a.to_bits() ^ b.to_bits()),
        }
    }

    /// True when the op is commutative+associative, i.e. safe under the
    /// paper's relaxed ordering / out-of-order execution rule (§2.3).
    pub fn commutative(&self) -> bool {
        !matches!(self, SimdOp::Sub)
    }

    pub fn name(&self) -> &'static str {
        match self {
            SimdOp::Add => "add",
            SimdOp::Sub => "sub",
            SimdOp::Mul => "mul",
            SimdOp::Min => "min",
            SimdOp::Max => "max",
            SimdOp::Xor => "xor",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn opcode_round_trip_whole_table() {
        // Opcode::ALL is generated from the same table as from_u16, so
        // this covers every opcode by construction — no hand list to
        // fall out of date.
        for &op in Opcode::ALL {
            assert_eq!(Opcode::from_u16(op as u16).unwrap(), op);
        }
        assert!(Opcode::ALL.len() >= 20);
    }

    #[test]
    fn unknown_opcode_rejected() {
        assert!(Opcode::from_u16(0x7FFF).is_err());
        // The retired fused-collective opcodes decode as unknown now.
        assert!(Opcode::from_u16(0x0200).is_err());
        assert!(Opcode::from_u16(0x0201).is_err());
    }

    #[test]
    fn simd_round_trip_and_semantics() {
        for op in SimdOp::ALL {
            assert_eq!(SimdOp::from_u8(op as u8).unwrap(), op);
        }
        assert_eq!(SimdOp::Add.apply_f32(2.0, 3.0), 5.0);
        assert_eq!(SimdOp::Sub.apply_f32(2.0, 3.0), -1.0);
        assert_eq!(SimdOp::Mul.apply_f32(2.0, 3.0), 6.0);
        assert_eq!(SimdOp::Min.apply_f32(2.0, 3.0), 2.0);
        assert_eq!(SimdOp::Max.apply_f32(2.0, 3.0), 3.0);
        assert_eq!(SimdOp::Xor.apply_f32(1.5, 1.5), 0.0);
    }

    #[test]
    fn only_sub_is_noncommutative() {
        for op in SimdOp::ALL {
            assert_eq!(op.commutative(), op != SimdOp::Sub, "{op:?}");
        }
    }
}
