//! DPU-offload instruction library (paper §2.4, §2.6).
//!
//! "For DPU offload case, compress, crypto, hash and longest prefix match
//! instruction could be added." and §2.6: "*encryption-write* and
//! *decryption-read* instruction could be added for secure computing."
//!
//! These are real [`UserInstruction`] implementations registered in the
//! user opcode range — they demonstrate (and test) the programmable-ISA
//! extension mechanism with the exact offloads the paper names:
//!
//! | opcode | instruction | semantics |
//! |---|---|---|
//! | `0x8001` | [`CryptoWrite`]  | XOR-keystream encrypt payload → memory |
//! | `0x8002` | [`CryptoRead`]   | decrypt `b` bytes at `a` → reply |
//! | `0x8010` | [`Crc32Region`]  | CRC-32 over `b` bytes at `a` → reply |
//! | `0x8020` | [`RleCompress`]  | run-length-encode region → store + reply len |
//! | `0x8030` | [`LpmLookup`]    | longest-prefix-match in an in-memory table |
//!
//! The "crypto" is a keyed XOR keystream (a toy cipher standing in for
//! AES-GCM hardware — the *offload structure* is what's modeled; swapping
//! in a real cipher changes none of the plumbing).

use anyhow::Result;

use super::registry::{ExecCtx, ExecOutcome, InstructionRegistry, UserInstruction};
use crate::sim::SimTime;

pub const OP_CRYPTO_WRITE: u16 = 0x8001;
pub const OP_CRYPTO_READ: u16 = 0x8002;
pub const OP_CRC32: u16 = 0x8010;
pub const OP_RLE_COMPRESS: u16 = 0x8020;
pub const OP_LPM_LOOKUP: u16 = 0x8030;

/// Register the whole library onto a registry.
pub fn register_dpu_instructions(reg: &mut InstructionRegistry, key: u64) -> Result<()> {
    reg.register(OP_CRYPTO_WRITE, Box::new(CryptoWrite { key }))?;
    reg.register(OP_CRYPTO_READ, Box::new(CryptoRead { key }))?;
    reg.register(OP_CRC32, Box::new(Crc32Region))?;
    reg.register(OP_RLE_COMPRESS, Box::new(RleCompress))?;
    reg.register(OP_LPM_LOOKUP, Box::new(LpmLookup))?;
    Ok(())
}

/// SplitMix-based XOR keystream seeded by (key, address) — position-bound
/// so identical plaintext at different addresses encrypts differently.
fn keystream(key: u64, addr: u64, len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    let mut s = crate::util::SplitMix64::new(key ^ addr.rotate_left(17));
    while out.len() < len {
        out.extend_from_slice(&s.next_u64().to_le_bytes());
    }
    out.truncate(len);
    out
}

/// `a` = destination address. Payload is plaintext; ciphertext lands in
/// memory. Idempotent (pure function of packet + address).
pub struct CryptoWrite {
    key: u64,
}

impl UserInstruction for CryptoWrite {
    fn name(&self) -> &'static str {
        "crypto_write"
    }
    fn idempotent(&self) -> bool {
        true
    }
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
        let ks = keystream(self.key, ctx.a, ctx.payload.len());
        let ct: Vec<u8> = ctx.payload.iter().zip(&ks).map(|(p, k)| p ^ k).collect();
        ctx.mem.write(ctx.a, &ct)?;
        Ok(ExecOutcome::Reply {
            opcode: OP_CRYPTO_WRITE,
            a: ctx.a,
            b: ct.len() as u64,
            c: 0,
            payload: vec![],
        })
    }
    fn cost_ns(&self, payload_len: usize) -> SimTime {
        // AES-GCM-class engine: ~64 B/cycle at 250 MHz + setup.
        20 + 4 * (payload_len as u64 / 64 + 1)
    }
}

/// `a` = source address, `b` = length. Replies with plaintext.
pub struct CryptoRead {
    key: u64,
}

impl UserInstruction for CryptoRead {
    fn name(&self) -> &'static str {
        "crypto_read"
    }
    fn idempotent(&self) -> bool {
        true
    }
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
        let ct = ctx.mem.read(ctx.a, ctx.b as usize)?;
        let ks = keystream(self.key, ctx.a, ct.len());
        let pt: Vec<u8> = ct.iter().zip(&ks).map(|(c, k)| c ^ k).collect();
        Ok(ExecOutcome::Reply {
            opcode: OP_CRYPTO_READ,
            a: ctx.a,
            b: pt.len() as u64,
            c: 0,
            payload: pt,
        })
    }
    fn cost_ns(&self, payload_len: usize) -> SimTime {
        20 + 4 * (payload_len as u64 / 64 + 1)
    }
}

/// `a` = address, `b` = length. Replies with the CRC-32 in operand `c`.
pub struct Crc32Region;

impl UserInstruction for Crc32Region {
    fn name(&self) -> &'static str {
        "crc32_region"
    }
    fn idempotent(&self) -> bool {
        true
    }
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
        // Chained form (`crypto_write → crc32` in one program): the
        // previous step's reply operands name the region it produced.
        let (addr, len) = match ctx.fwd {
            Some((a, b, _)) if ctx.b == 0 => (a, b),
            _ => (ctx.a, ctx.b),
        };
        let data = ctx.mem.read(addr, len as usize)?;
        let crc = crate::util::crc32::hash(&data);
        Ok(ExecOutcome::Reply {
            opcode: OP_CRC32,
            a: addr,
            b: len,
            c: crc as u64,
            payload: vec![],
        })
    }
}

/// `a` = source, `b` = length, `c` = destination. Byte-wise RLE
/// (`(count, byte)` pairs) written at `c`; replies with encoded length.
pub struct RleCompress;

pub fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let b = data[i];
        let mut run = 1usize;
        while i + run < data.len() && data[i + run] == b && run < 255 {
            run += 1;
        }
        out.push(run as u8);
        out.push(b);
        i += run;
    }
    out
}

pub fn rle_decode(enc: &[u8]) -> Result<Vec<u8>> {
    anyhow::ensure!(enc.len() % 2 == 0, "ragged RLE stream");
    let mut out = Vec::new();
    for pair in enc.chunks_exact(2) {
        anyhow::ensure!(pair[0] > 0, "zero-length run");
        out.extend(std::iter::repeat_n(pair[1], pair[0] as usize));
    }
    Ok(out)
}

impl UserInstruction for RleCompress {
    fn name(&self) -> &'static str {
        "rle_compress"
    }
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
        let data = ctx.mem.read(ctx.a, ctx.b as usize)?;
        let enc = rle_encode(&data);
        ctx.mem.write(ctx.c, &enc)?;
        Ok(ExecOutcome::Reply {
            opcode: OP_RLE_COMPRESS,
            a: ctx.c,
            b: enc.len() as u64,
            c: ctx.b,
            payload: vec![],
        })
    }
}

/// Longest-prefix match against a table stored in device memory at `a`:
/// `b` = entry count, `c` = the IPv4 address to look up. Table entries
/// are 12 bytes: `prefix:u32 | plen:u32 | next_hop:u32` (LE). Replies
/// with the best next hop in `c` (0 = no route).
pub struct LpmLookup;

impl UserInstruction for LpmLookup {
    fn name(&self) -> &'static str {
        "lpm_lookup"
    }
    fn idempotent(&self) -> bool {
        true
    }
    fn execute(&self, ctx: &mut ExecCtx) -> Result<ExecOutcome> {
        let n = ctx.b as usize;
        let table = ctx.mem.read(ctx.a, n * 12)?;
        let ip = ctx.c as u32;
        let mut best: Option<(u32, u32)> = None; // (plen, next_hop)
        for e in table.chunks_exact(12) {
            let prefix = u32::from_le_bytes(e[0..4].try_into().unwrap());
            let plen = u32::from_le_bytes(e[4..8].try_into().unwrap());
            let hop = u32::from_le_bytes(e[8..12].try_into().unwrap());
            if plen > 32 {
                continue;
            }
            let mask = if plen == 0 { 0 } else { u32::MAX << (32 - plen) };
            if ip & mask == prefix & mask && best.is_none_or(|(bl, _)| plen > bl) {
                best = Some((plen, hop));
            }
        }
        Ok(ExecOutcome::Reply {
            opcode: OP_LPM_LOOKUP,
            a: ctx.a,
            b: 0,
            c: best.map(|(_, h)| h as u64).unwrap_or(0),
            payload: vec![],
        })
    }
    fn cost_ns(&self, _payload_len: usize) -> SimTime {
        12 // TCAM-class lookup
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::registry::MemAccess;
    use crate::isa::Flags;

    struct VecMem(Vec<u8>);
    impl MemAccess for VecMem {
        fn capacity(&self) -> u64 {
            self.0.len() as u64
        }
        fn read(&self, addr: u64, len: usize) -> Result<Vec<u8>> {
            Ok(self.0[addr as usize..addr as usize + len].to_vec())
        }
        fn write(&mut self, addr: u64, data: &[u8]) -> Result<()> {
            self.0[addr as usize..addr as usize + data.len()].copy_from_slice(data);
            Ok(())
        }
    }

    fn ctx<'a>(mem: &'a mut VecMem, payload: &'a [u8], a: u64, b: u64, c: u64) -> ExecCtx<'a> {
        ExecCtx {
            mem,
            payload,
            a,
            b,
            c,
            flags: Flags::default(),
            fwd: None,
        }
    }

    #[test]
    fn crypto_write_read_round_trips() {
        let mut mem = VecMem(vec![0; 4096]);
        let plaintext = b"the paper's secure-computing story".to_vec();
        let w = CryptoWrite { key: 0xC0FFEE };
        let out = w
            .execute(&mut ctx(&mut mem, &plaintext, 128, 0, 0))
            .unwrap();
        assert!(matches!(out, ExecOutcome::Reply { .. }));
        // Ciphertext in memory differs from plaintext...
        assert_ne!(&mem.0[128..128 + plaintext.len()], &plaintext[..]);
        // ...and decrypt-read recovers it.
        let r = CryptoRead { key: 0xC0FFEE };
        let out = r
            .execute(&mut ctx(&mut mem, &[], 128, plaintext.len() as u64, 0))
            .unwrap();
        match out {
            ExecOutcome::Reply { payload, .. } => assert_eq!(payload, plaintext),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn crypto_is_address_bound() {
        let mut m1 = VecMem(vec![0; 256]);
        let mut m2 = VecMem(vec![0; 256]);
        let w = CryptoWrite { key: 7 };
        w.execute(&mut ctx(&mut m1, b"same", 0, 0, 0)).unwrap();
        w.execute(&mut ctx(&mut m2, b"same", 64, 0, 0)).unwrap();
        assert_ne!(&m1.0[..4], &m2.0[64..68], "same plaintext, different ct");
    }

    #[test]
    fn wrong_key_garbles() {
        let mut mem = VecMem(vec![0; 256]);
        CryptoWrite { key: 1 }
            .execute(&mut ctx(&mut mem, b"secret!!", 0, 0, 0))
            .unwrap();
        let out = CryptoRead { key: 2 }
            .execute(&mut ctx(&mut mem, &[], 0, 8, 0))
            .unwrap();
        match out {
            ExecOutcome::Reply { payload, .. } => assert_ne!(payload, b"secret!!"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn crc32_matches_library() {
        let mut mem = VecMem(b"123456789".to_vec());
        let out = Crc32Region.execute(&mut ctx(&mut mem, &[], 0, 9, 0)).unwrap();
        match out {
            // The canonical CRC-32 check value for "123456789".
            ExecOutcome::Reply { c, .. } => assert_eq!(c, 0xCBF4_3926),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rle_round_trips_and_compresses_runs() {
        let data = [b"AAAAAAAABBBCZZZZZZZZZZZZ".to_vec(), vec![7u8; 1000]].concat();
        let enc = rle_encode(&data);
        assert!(enc.len() < data.len() / 2);
        assert_eq!(rle_decode(&enc).unwrap(), data);
        // Through the instruction:
        let mut mem = VecMem(vec![0; 4096]);
        mem.write(0, &data).unwrap();
        let out = RleCompress
            .execute(&mut ctx(&mut mem, &[], 0, data.len() as u64, 2048))
            .unwrap();
        match out {
            ExecOutcome::Reply { a: 2048, b, .. } => {
                let stored = mem.read(2048, b as usize).unwrap();
                assert_eq!(rle_decode(&stored).unwrap(), data);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lpm_prefers_longest_prefix() {
        let mut table = Vec::new();
        let mut push = |prefix: [u8; 4], plen: u32, hop: u32| {
            table.extend_from_slice(&u32::from_be_bytes(prefix).to_le_bytes());
            table.extend_from_slice(&plen.to_le_bytes());
            table.extend_from_slice(&hop.to_le_bytes());
        };
        push([10, 0, 0, 0], 8, 1);
        push([10, 1, 0, 0], 16, 2);
        push([10, 1, 2, 0], 24, 3);
        push([0, 0, 0, 0], 0, 9); // default route
        let mut mem = VecMem(table);
        let lookup = |mem: &mut VecMem, ip: [u8; 4]| {
            let out = LpmLookup
                .execute(&mut ctx(mem, &[], 0, 4, u32::from_be_bytes(ip) as u64))
                .unwrap();
            match out {
                ExecOutcome::Reply { c, .. } => c,
                other => panic!("{other:?}"),
            }
        };
        assert_eq!(lookup(&mut mem, [10, 1, 2, 55]), 3);
        assert_eq!(lookup(&mut mem, [10, 1, 9, 1]), 2);
        assert_eq!(lookup(&mut mem, [10, 200, 0, 1]), 1);
        assert_eq!(lookup(&mut mem, [192, 168, 0, 1]), 9);
    }

    #[test]
    fn library_registers_cleanly() {
        let mut reg = InstructionRegistry::new();
        register_dpu_instructions(&mut reg, 42).unwrap();
        assert_eq!(reg.len(), 5);
        // Double registration is rejected.
        assert!(register_dpu_instructions(&mut reg, 42).is_err());
    }
}
