//! The NetDAM programmable ISA (paper §2.4).
//!
//! NetDAM instructions are RPC-like: a packet carries an instruction, the
//! memory address it operates on, and (for SIMD ops) a data payload of up
//! to 9000 B ≈ 2048 × f32 lanes. The "template" defines the basic memory
//! instructions (READ / WRITE / CAS / MEMCOPY); the instruction field
//! reserves an opcode range for *user-defined* instructions — modeled by
//! [`registry::InstructionRegistry`] and exercised by the DPU offload
//! library ([`dpu`]).
//!
//! Programmability goes beyond single opcodes: a packet may carry a
//! bounded, statically verified **program** ([`program::Program`]) — a
//! step sequence the devices on the SROU path execute hop-locally with
//! operand forwarding. The §3 fused allreduce chunk and chained DPU
//! offloads are programs, not bespoke opcodes; [`program::Program::verify`]
//! machine-checks the §2.3 relaxed-ordering rule (commutativity on
//! unordered paths, idempotency on lossy paths) before injection.

pub mod dpu;
mod instr;
mod opcode;
pub mod program;
pub mod registry;

pub use instr::{Flags, Instruction};
pub use opcode::{Opcode, SimdOp, USER_OPCODE_BASE};
pub use program::{
    Program, ProgramBuilder, ProgramError, Step, VerifyEnv, MAX_PROGRAM_STEPS, NO_COMPLETION,
};
