//! The NetDAM programmable ISA (paper §2.4).
//!
//! NetDAM instructions are RPC-like: a packet carries one instruction, the
//! memory address it operates on, and (for SIMD ops) a data payload of up to
//! 9000 B ≈ 2048 × f32 lanes. The "template" defines the basic memory
//! instructions (READ / WRITE / CAS / MEMCOPY); the instruction field
//! reserves an opcode range for *user-defined* instructions — we model that
//! extensibility with [`registry::InstructionRegistry`], and use it
//! ourselves to add the paper's SIMD ALU ops, the MPI collective steps
//! (Ring Reduce-Scatter / All-Gather), and the block-hash idempotency
//! guard, exactly as §3 describes.

pub mod dpu;
mod instr;
mod opcode;
pub mod registry;

pub use instr::{Flags, Instruction};
pub use opcode::{Opcode, SimdOp, USER_OPCODE_BASE};
