//! Instruction encoding: the typed [`Instruction`] enum and its wire codec.
//!
//! On the wire an instruction is `opcode:u16 | flags:u16 | operands...`
//! (operands are opcode-specific, always fixed-width so the FPGA pipeline
//! the paper describes could parse them in one cycle). The data payload is
//! *not* part of the instruction — it follows in the packet body.
//!
//! Fused behaviours (the §3 reduce-scatter → all-gather chain, DPU
//! offload chains) are **not** special-cased opcodes: they are
//! [`Program`]s — bounded step sequences built from the ordinary
//! instructions below (see [`super::program`]).

use std::sync::Arc;

use anyhow::{bail, Result};

use super::opcode::{Opcode, SimdOp, USER_OPCODE_BASE};
use super::program::Program;
use crate::util::bytes::{Reader, Writer};

/// Per-instruction flag bits (the paper's "reserved bits").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flags(pub u16);

impl Flags {
    /// Request an ACK / reliable delivery (reliability is *optional* in
    /// NetDAM; idempotent operators may simply re-transmit — §2.3).
    pub const RELIABLE: u16 = 1 << 0;
    /// Deliver through the receive reorder buffer (strict ordering).
    pub const ORDERED: u16 = 1 << 1;
    /// For SIMD: store the result to memory instead of replying with it.
    pub const STORE: u16 = 1 << 2;
    /// Marks the last packet of a multi-packet operation.
    pub const LAST: u16 = 1 << 3;
    /// Congestion-experienced mark set by a switch queue above its
    /// threshold (consumed by the RoCE baseline's DCQCN-lite).
    pub const ECN: u16 = 1 << 4;
    /// In-network aggregation mark (§2.5 "or in datacenter switch"):
    /// switches on the SROU path may fold this packet into an
    /// aggregation slot instead of forwarding it (see `net::aggregate`).
    pub const AGG: u16 = 1 << 5;

    pub fn reliable(self) -> bool {
        self.0 & Self::RELIABLE != 0
    }
    pub fn ordered(self) -> bool {
        self.0 & Self::ORDERED != 0
    }
    pub fn store(self) -> bool {
        self.0 & Self::STORE != 0
    }
    pub fn last(self) -> bool {
        self.0 & Self::LAST != 0
    }
    pub fn ecn(self) -> bool {
        self.0 & Self::ECN != 0
    }
    pub fn agg(self) -> bool {
        self.0 & Self::AGG != 0
    }
    pub fn with(self, bit: u16) -> Flags {
        Flags(self.0 | bit)
    }
}

/// A decoded NetDAM instruction. Operand meanings follow paper §2.2/§2.4.
#[derive(Debug, Clone, PartialEq)]
pub enum Instruction {
    Nop,
    /// Read `len` bytes at `addr`; device answers with `ReadResp` + data.
    Read { addr: u64, len: u32 },
    /// Response carrying the data payload for a `Read`.
    ReadResp { addr: u64 },
    /// Write the packet payload at `addr`; `WriteAck` if RELIABLE.
    Write { addr: u64 },
    WriteAck { addr: u64 },
    /// Compare-and-swap one u64 at `addr` — the paper's atomic, used to
    /// build idempotent operators.
    Cas { addr: u64, expected: u64, new: u64 },
    CasResp { addr: u64, old: u64, swapped: bool },
    /// Device-local DMA: copy `len` bytes from `src` to `dst`.
    Memcopy { src: u64, dst: u64, len: u32 },
    /// Transport-level acknowledgement of sequence `acked`.
    Ack { acked: u64 },
    Nack { acked: u64, reason: u8 },

    /// SIMD ALU op: payload lanes ⊕ mem[addr..addr+payload_len].
    /// Result goes to the reply (SimdResp) or to memory (STORE flag).
    Simd { op: SimdOp, addr: u64 },
    SimdResp { addr: u64 },
    /// Compute the block hash of `len` bytes at `addr` (idempotency guard).
    BlockHash { addr: u64, len: u32 },
    BlockHashResp { hash: u64 },
    /// Write payload at `addr` only if the current block hash equals
    /// `expect_hash` — the paper's idempotent last-hop WRITE (§3.1).
    WriteIfHash { addr: u64, expect_hash: u64 },

    /// Completion notification sent to the controller/leader when a
    /// packet [`Program`] retires with a completion id.
    CollectiveDone { block: u32 },

    /// Pool control plane (SDN controller as MMU, §2.6).
    Malloc { bytes: u64, tag: u32 },
    MallocResp { gva: u64, tag: u32 },
    Free { gva: u64 },
    FreeResp { gva: u64 },

    /// A bounded multi-instruction packet program executed hop-locally
    /// by the devices on the SROU path (see [`super::program`]). The §3
    /// fused allreduce chunk is one of these. `Arc`-shared so cloning a
    /// program-carrying packet (retransmit buffer, fan-out) is a
    /// refcount bump; the micro-executor copies-on-write when it
    /// advances the cursor (`Arc::make_mut`).
    Program(Arc<Program>),

    /// A user-defined instruction (opcode >= USER_OPCODE_BASE) with three
    /// raw operands; semantics come from the instruction registry.
    User { opcode: u16, a: u64, b: u64, c: u64 },
}

impl Instruction {
    /// The wire opcode for this instruction.
    pub fn opcode_u16(&self) -> u16 {
        use Instruction::*;
        match self {
            Nop => Opcode::Nop as u16,
            Read { .. } => Opcode::Read as u16,
            ReadResp { .. } => Opcode::ReadResp as u16,
            Write { .. } => Opcode::Write as u16,
            WriteAck { .. } => Opcode::WriteAck as u16,
            Cas { .. } => Opcode::Cas as u16,
            CasResp { .. } => Opcode::CasResp as u16,
            Memcopy { .. } => Opcode::Memcopy as u16,
            Ack { .. } => Opcode::Ack as u16,
            Nack { .. } => Opcode::Nack as u16,
            Simd { .. } => Opcode::Simd as u16,
            SimdResp { .. } => Opcode::SimdResp as u16,
            BlockHash { .. } => Opcode::BlockHash as u16,
            BlockHashResp { .. } => Opcode::BlockHashResp as u16,
            WriteIfHash { .. } => Opcode::WriteIfHash as u16,
            CollectiveDone { .. } => Opcode::CollectiveDone as u16,
            Malloc { .. } => Opcode::Malloc as u16,
            MallocResp { .. } => Opcode::MallocResp as u16,
            Free { .. } => Opcode::Free as u16,
            FreeResp { .. } => Opcode::FreeResp as u16,
            Program(_) => Opcode::Program as u16,
            User { opcode, .. } => *opcode,
        }
    }

    /// Encode `opcode | flags | operands` into `w`.
    pub fn encode(&self, flags: Flags, w: &mut Writer) {
        use Instruction::*;
        w.u16(self.opcode_u16());
        w.u16(flags.0);
        match self {
            Nop => {}
            Read { addr, len } => {
                w.u64(*addr);
                w.u32(*len);
            }
            ReadResp { addr } | Write { addr } | WriteAck { addr } | SimdResp { addr } => {
                w.u64(*addr);
            }
            Cas {
                addr,
                expected,
                new,
            } => {
                w.u64(*addr);
                w.u64(*expected);
                w.u64(*new);
            }
            CasResp { addr, old, swapped } => {
                w.u64(*addr);
                w.u64(*old);
                w.u8(*swapped as u8);
            }
            Memcopy { src, dst, len } => {
                w.u64(*src);
                w.u64(*dst);
                w.u32(*len);
            }
            Ack { acked } => w.u64(*acked),
            Nack { acked, reason } => {
                w.u64(*acked);
                w.u8(*reason);
            }
            Simd { op, addr } => {
                w.u8(*op as u8);
                w.u64(*addr);
            }
            BlockHash { addr, len } => {
                w.u64(*addr);
                w.u32(*len);
            }
            BlockHashResp { hash } => w.u64(*hash),
            WriteIfHash { addr, expect_hash } => {
                w.u64(*addr);
                w.u64(*expect_hash);
            }
            CollectiveDone { block } => w.u32(*block),
            Malloc { bytes, tag } => {
                w.u64(*bytes);
                w.u32(*tag);
            }
            MallocResp { gva, tag } => {
                w.u64(*gva);
                w.u32(*tag);
            }
            Free { gva } | FreeResp { gva } => w.u64(*gva),
            Program(p) => p.encode_body(w),
            User { opcode: _, a, b, c } => {
                w.u64(*a);
                w.u64(*b);
                w.u64(*c);
            }
        }
    }

    /// Decode from `r`; returns `(instruction, flags)`.
    pub fn decode(r: &mut Reader) -> Result<(Instruction, Flags)> {
        Self::decode_inner(r, true)
    }

    /// Decode a program *step*: identical wire format, but a nested
    /// `Program` opcode is rejected (bounds decode recursion at one).
    pub(crate) fn decode_step(r: &mut Reader) -> Result<(Instruction, Flags)> {
        Self::decode_inner(r, false)
    }

    fn decode_inner(r: &mut Reader, allow_program: bool) -> Result<(Instruction, Flags)> {
        let raw_op = r.u16()?;
        let flags = Flags(r.u16()?);
        if raw_op >= USER_OPCODE_BASE {
            return Ok((
                Instruction::User {
                    opcode: raw_op,
                    a: r.u64()?,
                    b: r.u64()?,
                    c: r.u64()?,
                },
                flags,
            ));
        }
        let op = Opcode::from_u16(raw_op)?;
        use Instruction as I;
        let instr = match op {
            Opcode::Nop => I::Nop,
            Opcode::Read => I::Read {
                addr: r.u64()?,
                len: r.u32()?,
            },
            Opcode::ReadResp => I::ReadResp { addr: r.u64()? },
            Opcode::Write => I::Write { addr: r.u64()? },
            Opcode::WriteAck => I::WriteAck { addr: r.u64()? },
            Opcode::Cas => I::Cas {
                addr: r.u64()?,
                expected: r.u64()?,
                new: r.u64()?,
            },
            Opcode::CasResp => {
                let addr = r.u64()?;
                let old = r.u64()?;
                let swapped = match r.u8()? {
                    0 => false,
                    1 => true,
                    v => bail!("bad bool {v} in CasResp"),
                };
                I::CasResp { addr, old, swapped }
            }
            Opcode::Memcopy => I::Memcopy {
                src: r.u64()?,
                dst: r.u64()?,
                len: r.u32()?,
            },
            Opcode::Ack => I::Ack { acked: r.u64()? },
            Opcode::Nack => I::Nack {
                acked: r.u64()?,
                reason: r.u8()?,
            },
            Opcode::Simd => I::Simd {
                op: SimdOp::from_u8(r.u8()?)?,
                addr: r.u64()?,
            },
            Opcode::SimdResp => I::SimdResp { addr: r.u64()? },
            Opcode::BlockHash => I::BlockHash {
                addr: r.u64()?,
                len: r.u32()?,
            },
            Opcode::BlockHashResp => I::BlockHashResp { hash: r.u64()? },
            Opcode::WriteIfHash => I::WriteIfHash {
                addr: r.u64()?,
                expect_hash: r.u64()?,
            },
            Opcode::CollectiveDone => I::CollectiveDone { block: r.u32()? },
            Opcode::Malloc => I::Malloc {
                bytes: r.u64()?,
                tag: r.u32()?,
            },
            Opcode::MallocResp => I::MallocResp {
                gva: r.u64()?,
                tag: r.u32()?,
            },
            Opcode::Free => I::Free { gva: r.u64()? },
            Opcode::FreeResp => I::FreeResp { gva: r.u64()? },
            Opcode::Program => {
                if !allow_program {
                    bail!("nested program rejected");
                }
                I::Program(Arc::new(Program::decode_body(r)?))
            }
        };
        Ok((instr, flags))
    }

    /// Is this instruction idempotent (safe to blindly re-execute)?
    /// §3.1: everything that only reads, or writes a value derived solely
    /// from the packet, is idempotent; accumulating into local memory
    /// (`Simd` with STORE) is not — hence `WriteIfHash`. A program is
    /// idempotent iff every step is.
    pub fn idempotent(&self, flags: Flags) -> bool {
        use Instruction::*;
        match self {
            Read { .. } | ReadResp { .. } | Write { .. } | WriteAck { .. } | Nop
            | BlockHash { .. } | BlockHashResp { .. } | WriteIfHash { .. }
            | Ack { .. } | Nack { .. } | SimdResp { .. } | MallocResp { .. }
            | CollectiveDone { .. } | FreeResp { .. } => true,
            // CAS is idempotent wrt retry only if expected != new.
            Cas { expected, new, .. } => expected != new,
            CasResp { .. } => true,
            Memcopy { src, dst, len } => {
                // Idempotent unless ranges overlap (self-clobbering copy).
                let (s, d, l) = (*src, *dst, *len as u64);
                s + l <= d || d + l <= s
            }
            Simd { .. } => !flags.store(),
            Program(p) => p.idempotent(),
            Malloc { .. } | Free { .. } => false,
            User { .. } => false, // unknown semantics: assume not
        }
    }

    /// Is this instruction safe to *retransmit* on the reliable path?
    /// Every idempotent instruction is; so is a top-level CAS, which is
    /// not idempotent but **replay-safe**: devices keep a response-dedupe
    /// cache keyed on `(src, seq)` and answer a retransmit of an
    /// already-executed CAS with the original `CasResp` instead of
    /// re-executing the swap. (CAS *inside a program* stays rejected by
    /// the §3.1 lossy-path verifier — program replays re-present the
    /// whole chain, and interim hops have no response to dedupe.)
    pub fn replay_safe(&self, flags: Flags) -> bool {
        matches!(self, Instruction::Cas { .. }) || self.idempotent(flags)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::ProgramBuilder;

    fn round_trip(i: &Instruction, f: Flags) {
        let mut w = Writer::default();
        i.encode(f, &mut w);
        let bytes = w.into_vec();
        let mut r = Reader::new(&bytes);
        let (j, g) = Instruction::decode(&mut r).unwrap();
        assert_eq!(&j, i);
        assert_eq!(g, f);
        assert_eq!(r.remaining(), 0, "codec consumed everything");
    }

    fn demo_program() -> Instruction {
        Instruction::Program(Arc::new(
            ProgramBuilder::new()
                .reduce(SimdOp::Add, 0x5000, 3)
                .guarded_write(0x5000, 9)
                .store(0x5000, 3)
                .on_retire(3)
                .build_unchecked(),
        ))
    }

    #[test]
    fn all_core_instructions_round_trip() {
        use Instruction::*;
        let cases = vec![
            Nop,
            Read { addr: 0x1000, len: 128 },
            ReadResp { addr: 0x1000 },
            Write { addr: u64::MAX },
            WriteAck { addr: 7 },
            Cas { addr: 8, expected: 1, new: 2 },
            CasResp { addr: 8, old: 1, swapped: true },
            Memcopy { src: 0, dst: 4096, len: 9000 },
            Ack { acked: 55 },
            Nack { acked: 56, reason: 2 },
            Simd { op: SimdOp::Add, addr: 0x2000 },
            SimdResp { addr: 0x2000 },
            BlockHash { addr: 0x3000, len: 8192 },
            BlockHashResp { hash: 0xDEAD_BEEF },
            WriteIfHash { addr: 0x4000, expect_hash: 42 },
            CollectiveDone { block: 2 },
            Malloc { bytes: 1 << 30, tag: 77 },
            MallocResp { gva: 0xA000_0000, tag: 77 },
            Free { gva: 0xA000_0000 },
            FreeResp { gva: 0xA000_0000 },
            demo_program(),
            User { opcode: 0x8001, a: 1, b: 2, c: 3 },
        ];
        for i in &cases {
            round_trip(i, Flags::default());
            round_trip(i, Flags(Flags::RELIABLE | Flags::STORE));
        }
    }

    #[test]
    fn mid_flight_program_round_trips() {
        // The executor cursor (pc / reps_done) travels on the wire.
        let Instruction::Program(mut p) = demo_program() else {
            unreachable!()
        };
        {
            let p = Arc::make_mut(&mut p);
            p.pc = 1;
            p.reps_done = 0;
        }
        round_trip(&Instruction::Program(p), Flags::default());
    }

    #[test]
    fn nested_program_rejected_by_decoder() {
        let inner = demo_program();
        let nested = Instruction::Program(Arc::new(
            ProgramBuilder::new().hop(inner).build_unchecked(),
        ));
        let mut w = Writer::default();
        nested.encode(Flags::default(), &mut w);
        let bytes = w.into_vec();
        let err = Instruction::decode(&mut Reader::new(&bytes)).unwrap_err();
        assert!(err.to_string().contains("nested"), "{err}");
    }

    #[test]
    fn flags_accessors() {
        let f = Flags::default()
            .with(Flags::RELIABLE)
            .with(Flags::ORDERED)
            .with(Flags::LAST);
        assert!(f.reliable() && f.ordered() && f.last() && !f.store());
    }

    #[test]
    fn idempotency_classification() {
        use Instruction::*;
        let f = Flags::default();
        assert!(Read { addr: 0, len: 4 }.idempotent(f));
        assert!(Write { addr: 0 }.idempotent(f));
        assert!(WriteIfHash { addr: 0, expect_hash: 1 }.idempotent(f));
        assert!(Simd { op: SimdOp::Add, addr: 0 }.idempotent(f));
        assert!(!Simd { op: SimdOp::Add, addr: 0 }.idempotent(Flags(Flags::STORE)));
        assert!(!Cas { addr: 0, expected: 3, new: 3 }.idempotent(f));
        assert!(Cas { addr: 0, expected: 0, new: 1 }.idempotent(f));
        // ...but top-level CAS is always replay-safe (device response
        // dedupe answers retransmits without re-executing the swap).
        assert!(Cas { addr: 0, expected: 3, new: 3 }.replay_safe(f));
        assert!(!Simd { op: SimdOp::Add, addr: 0 }.replay_safe(Flags(Flags::STORE)));
        // Overlapping memcopy is not idempotent.
        assert!(!Memcopy { src: 0, dst: 8, len: 64 }.idempotent(f));
        assert!(Memcopy { src: 0, dst: 64, len: 64 }.idempotent(f));
        // A program is as idempotent as its steps.
        assert!(demo_program().idempotent(f));
        let dirty = Instruction::Program(Arc::new(
            ProgramBuilder::new()
                .hop(Instruction::Cas { addr: 0, expected: 1, new: 1 })
                .build_unchecked(),
        ));
        assert!(!dirty.idempotent(f));
    }

    #[test]
    fn truncated_instruction_is_error() {
        let mut w = Writer::default();
        Instruction::Read { addr: 1, len: 2 }.encode(Flags::default(), &mut w);
        let bytes = w.into_vec();
        for cut in 1..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(Instruction::decode(&mut r).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn truncated_program_is_error() {
        let mut w = Writer::default();
        demo_program().encode(Flags::default(), &mut w);
        let bytes = w.into_vec();
        for cut in 1..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(Instruction::decode(&mut r).is_err(), "cut={cut}");
        }
    }
}
