//! Instruction encoding: the typed [`Instruction`] enum and its wire codec.
//!
//! On the wire an instruction is `opcode:u16 | flags:u16 | operands...`
//! (operands are opcode-specific, always fixed-width so the FPGA pipeline
//! the paper describes could parse them in one cycle). The data payload is
//! *not* part of the instruction — it follows in the packet body.

use anyhow::{bail, Result};

use super::opcode::{Opcode, SimdOp, USER_OPCODE_BASE};
use crate::util::bytes::{Reader, Writer};

/// Per-instruction flag bits (the paper's "reserved bits").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Flags(pub u16);

impl Flags {
    /// Request an ACK / reliable delivery (reliability is *optional* in
    /// NetDAM; idempotent operators may simply re-transmit — §2.3).
    pub const RELIABLE: u16 = 1 << 0;
    /// Deliver through the receive reorder buffer (strict ordering).
    pub const ORDERED: u16 = 1 << 1;
    /// For SIMD: store the result to memory instead of replying with it.
    pub const STORE: u16 = 1 << 2;
    /// Marks the last packet of a multi-packet operation.
    pub const LAST: u16 = 1 << 3;
    /// Congestion-experienced mark set by a switch queue above its
    /// threshold (consumed by the RoCE baseline's DCQCN-lite).
    pub const ECN: u16 = 1 << 4;

    pub fn reliable(self) -> bool {
        self.0 & Self::RELIABLE != 0
    }
    pub fn ordered(self) -> bool {
        self.0 & Self::ORDERED != 0
    }
    pub fn store(self) -> bool {
        self.0 & Self::STORE != 0
    }
    pub fn last(self) -> bool {
        self.0 & Self::LAST != 0
    }
    pub fn ecn(self) -> bool {
        self.0 & Self::ECN != 0
    }
    pub fn with(self, bit: u16) -> Flags {
        Flags(self.0 | bit)
    }
}

/// A decoded NetDAM instruction. Operand meanings follow paper §2.2/§2.4.
#[derive(Debug, Clone, PartialEq)]
pub enum Instruction {
    Nop,
    /// Read `len` bytes at `addr`; device answers with `ReadResp` + data.
    Read { addr: u64, len: u32 },
    /// Response carrying the data payload for a `Read`.
    ReadResp { addr: u64 },
    /// Write the packet payload at `addr`; `WriteAck` if RELIABLE.
    Write { addr: u64 },
    WriteAck { addr: u64 },
    /// Compare-and-swap one u64 at `addr` — the paper's atomic, used to
    /// build idempotent operators.
    Cas { addr: u64, expected: u64, new: u64 },
    CasResp { addr: u64, old: u64, swapped: bool },
    /// Device-local DMA: copy `len` bytes from `src` to `dst`.
    Memcopy { src: u64, dst: u64, len: u32 },
    /// Transport-level acknowledgement of sequence `acked`.
    Ack { acked: u64 },
    Nack { acked: u64, reason: u8 },

    /// SIMD ALU op: payload lanes ⊕ mem[addr..addr+payload_len].
    /// Result goes to the reply (SimdResp) or to memory (STORE flag).
    Simd { op: SimdOp, addr: u64 },
    SimdResp { addr: u64 },
    /// Compute the block hash of `len` bytes at `addr` (idempotency guard).
    BlockHash { addr: u64, len: u32 },
    BlockHashResp { hash: u64 },
    /// Write payload at `addr` only if the current block hash equals
    /// `expect_hash` — the paper's idempotent last-hop WRITE (§3.1).
    WriteIfHash { addr: u64, expect_hash: u64 },

    /// Ring Reduce-Scatter step: add payload into the accumulator carried
    /// in the packet buffer, then self-route to the next segment.
    /// `rs_left` counts reduce hops remaining *including this one*: at
    /// `rs_left == 1` this device is the chunk owner — it performs the
    /// hash-guarded reduced write (idempotent, §3.1) and, if the SROU
    /// stack continues, emits the fused All-Gather chain carrying the
    /// fully-reduced block (one instruction = whole MPI allreduce chunk).
    ReduceScatter {
        op: SimdOp,
        addr: u64,
        block: u32,
        rs_left: u8,
        expect_hash: u64,
    },
    /// Ring All-Gather step: write payload at `addr`, forward to next hop.
    AllGather { addr: u64, block: u32 },
    /// Completion notification sent to the controller/leader.
    CollectiveDone { block: u32 },

    /// Pool control plane (SDN controller as MMU, §2.6).
    Malloc { bytes: u64, tag: u32 },
    MallocResp { gva: u64, tag: u32 },
    Free { gva: u64 },
    FreeResp { gva: u64 },

    /// A user-defined instruction (opcode >= USER_OPCODE_BASE) with three
    /// raw operands; semantics come from the instruction registry.
    User { opcode: u16, a: u64, b: u64, c: u64 },
}

impl Instruction {
    /// The wire opcode for this instruction.
    pub fn opcode_u16(&self) -> u16 {
        use Instruction::*;
        match self {
            Nop => Opcode::Nop as u16,
            Read { .. } => Opcode::Read as u16,
            ReadResp { .. } => Opcode::ReadResp as u16,
            Write { .. } => Opcode::Write as u16,
            WriteAck { .. } => Opcode::WriteAck as u16,
            Cas { .. } => Opcode::Cas as u16,
            CasResp { .. } => Opcode::CasResp as u16,
            Memcopy { .. } => Opcode::Memcopy as u16,
            Ack { .. } => Opcode::Ack as u16,
            Nack { .. } => Opcode::Nack as u16,
            Simd { .. } => Opcode::Simd as u16,
            SimdResp { .. } => Opcode::SimdResp as u16,
            BlockHash { .. } => Opcode::BlockHash as u16,
            BlockHashResp { .. } => Opcode::BlockHashResp as u16,
            WriteIfHash { .. } => Opcode::WriteIfHash as u16,
            ReduceScatter { .. } => Opcode::ReduceScatter as u16,
            AllGather { .. } => Opcode::AllGather as u16,
            CollectiveDone { .. } => Opcode::CollectiveDone as u16,
            Malloc { .. } => Opcode::Malloc as u16,
            MallocResp { .. } => Opcode::MallocResp as u16,
            Free { .. } => Opcode::Free as u16,
            FreeResp { .. } => Opcode::FreeResp as u16,
            User { opcode, .. } => *opcode,
        }
    }

    /// Encode `opcode | flags | operands` into `w`.
    pub fn encode(&self, flags: Flags, w: &mut Writer) {
        use Instruction::*;
        w.u16(self.opcode_u16());
        w.u16(flags.0);
        match self {
            Nop => {}
            Read { addr, len } => {
                w.u64(*addr);
                w.u32(*len);
            }
            ReadResp { addr } | Write { addr } | WriteAck { addr } | SimdResp { addr } => {
                w.u64(*addr);
            }
            Cas {
                addr,
                expected,
                new,
            } => {
                w.u64(*addr);
                w.u64(*expected);
                w.u64(*new);
            }
            CasResp { addr, old, swapped } => {
                w.u64(*addr);
                w.u64(*old);
                w.u8(*swapped as u8);
            }
            Memcopy { src, dst, len } => {
                w.u64(*src);
                w.u64(*dst);
                w.u32(*len);
            }
            Ack { acked } => w.u64(*acked),
            Nack { acked, reason } => {
                w.u64(*acked);
                w.u8(*reason);
            }
            Simd { op, addr } => {
                w.u8(*op as u8);
                w.u64(*addr);
            }
            BlockHash { addr, len } => {
                w.u64(*addr);
                w.u32(*len);
            }
            BlockHashResp { hash } => w.u64(*hash),
            WriteIfHash { addr, expect_hash } => {
                w.u64(*addr);
                w.u64(*expect_hash);
            }
            ReduceScatter {
                op,
                addr,
                block,
                rs_left,
                expect_hash,
            } => {
                w.u8(*op as u8);
                w.u64(*addr);
                w.u32(*block);
                w.u8(*rs_left);
                w.u64(*expect_hash);
            }
            AllGather { addr, block } => {
                w.u64(*addr);
                w.u32(*block);
            }
            CollectiveDone { block } => w.u32(*block),
            Malloc { bytes, tag } => {
                w.u64(*bytes);
                w.u32(*tag);
            }
            MallocResp { gva, tag } => {
                w.u64(*gva);
                w.u32(*tag);
            }
            Free { gva } | FreeResp { gva } => w.u64(*gva),
            User { opcode: _, a, b, c } => {
                w.u64(*a);
                w.u64(*b);
                w.u64(*c);
            }
        }
    }

    /// Decode from `r`; returns `(instruction, flags)`.
    pub fn decode(r: &mut Reader) -> Result<(Instruction, Flags)> {
        let raw_op = r.u16()?;
        let flags = Flags(r.u16()?);
        if raw_op >= USER_OPCODE_BASE {
            return Ok((
                Instruction::User {
                    opcode: raw_op,
                    a: r.u64()?,
                    b: r.u64()?,
                    c: r.u64()?,
                },
                flags,
            ));
        }
        let op = Opcode::from_u16(raw_op)?;
        use Instruction as I;
        let instr = match op {
            Opcode::Nop => I::Nop,
            Opcode::Read => I::Read {
                addr: r.u64()?,
                len: r.u32()?,
            },
            Opcode::ReadResp => I::ReadResp { addr: r.u64()? },
            Opcode::Write => I::Write { addr: r.u64()? },
            Opcode::WriteAck => I::WriteAck { addr: r.u64()? },
            Opcode::Cas => I::Cas {
                addr: r.u64()?,
                expected: r.u64()?,
                new: r.u64()?,
            },
            Opcode::CasResp => {
                let addr = r.u64()?;
                let old = r.u64()?;
                let swapped = match r.u8()? {
                    0 => false,
                    1 => true,
                    v => bail!("bad bool {v} in CasResp"),
                };
                I::CasResp { addr, old, swapped }
            }
            Opcode::Memcopy => I::Memcopy {
                src: r.u64()?,
                dst: r.u64()?,
                len: r.u32()?,
            },
            Opcode::Ack => I::Ack { acked: r.u64()? },
            Opcode::Nack => I::Nack {
                acked: r.u64()?,
                reason: r.u8()?,
            },
            Opcode::Simd => I::Simd {
                op: SimdOp::from_u8(r.u8()?)?,
                addr: r.u64()?,
            },
            Opcode::SimdResp => I::SimdResp { addr: r.u64()? },
            Opcode::BlockHash => I::BlockHash {
                addr: r.u64()?,
                len: r.u32()?,
            },
            Opcode::BlockHashResp => I::BlockHashResp { hash: r.u64()? },
            Opcode::WriteIfHash => I::WriteIfHash {
                addr: r.u64()?,
                expect_hash: r.u64()?,
            },
            Opcode::ReduceScatter => I::ReduceScatter {
                op: SimdOp::from_u8(r.u8()?)?,
                addr: r.u64()?,
                block: r.u32()?,
                rs_left: r.u8()?,
                expect_hash: r.u64()?,
            },
            Opcode::AllGather => I::AllGather {
                addr: r.u64()?,
                block: r.u32()?,
            },
            Opcode::CollectiveDone => I::CollectiveDone { block: r.u32()? },
            Opcode::Malloc => I::Malloc {
                bytes: r.u64()?,
                tag: r.u32()?,
            },
            Opcode::MallocResp => I::MallocResp {
                gva: r.u64()?,
                tag: r.u32()?,
            },
            Opcode::Free => I::Free { gva: r.u64()? },
            Opcode::FreeResp => I::FreeResp { gva: r.u64()? },
        };
        Ok((instr, flags))
    }

    /// Is this instruction idempotent (safe to blindly re-execute)?
    /// §3.1: everything that only reads, or writes a value derived solely
    /// from the packet, is idempotent; accumulating into local memory
    /// (`Simd` with STORE) is not — hence `WriteIfHash`.
    pub fn idempotent(&self, flags: Flags) -> bool {
        use Instruction::*;
        match self {
            Read { .. } | ReadResp { .. } | Write { .. } | WriteAck { .. } | Nop
            | BlockHash { .. } | BlockHashResp { .. } | WriteIfHash { .. } | AllGather { .. }
            | Ack { .. } | Nack { .. } | SimdResp { .. } | MallocResp { .. }
            | CollectiveDone { .. } | FreeResp { .. } => true,
            // CAS is idempotent wrt retry only if expected != new.
            Cas { expected, new, .. } => expected != new,
            CasResp { .. } => true,
            Memcopy { src, dst, len } => {
                // Idempotent unless ranges overlap (self-clobbering copy).
                let (s, d, l) = (*src, *dst, *len as u64);
                s + l <= d || d + l <= s
            }
            Simd { .. } => !flags.store(),
            ReduceScatter { .. } => true, // interim hops: packet-buffer only;
            // last hop uses the hash guard — see device::exec.
            Malloc { .. } | Free { .. } => false,
            User { .. } => false, // unknown semantics: assume not
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(i: &Instruction, f: Flags) {
        let mut w = Writer::default();
        i.encode(f, &mut w);
        let bytes = w.into_vec();
        let mut r = Reader::new(&bytes);
        let (j, g) = Instruction::decode(&mut r).unwrap();
        assert_eq!(&j, i);
        assert_eq!(g, f);
        assert_eq!(r.remaining(), 0, "codec consumed everything");
    }

    #[test]
    fn all_core_instructions_round_trip() {
        use Instruction::*;
        let cases = vec![
            Nop,
            Read { addr: 0x1000, len: 128 },
            ReadResp { addr: 0x1000 },
            Write { addr: u64::MAX },
            WriteAck { addr: 7 },
            Cas { addr: 8, expected: 1, new: 2 },
            CasResp { addr: 8, old: 1, swapped: true },
            Memcopy { src: 0, dst: 4096, len: 9000 },
            Ack { acked: 55 },
            Nack { acked: 56, reason: 2 },
            Simd { op: SimdOp::Add, addr: 0x2000 },
            SimdResp { addr: 0x2000 },
            BlockHash { addr: 0x3000, len: 8192 },
            BlockHashResp { hash: 0xDEAD_BEEF },
            WriteIfHash { addr: 0x4000, expect_hash: 42 },
            ReduceScatter { op: SimdOp::Add, addr: 0x5000, block: 3, rs_left: 3, expect_hash: 9 },
            AllGather { addr: 0x6000, block: 1 },
            CollectiveDone { block: 2 },
            Malloc { bytes: 1 << 30, tag: 77 },
            MallocResp { gva: 0xA000_0000, tag: 77 },
            Free { gva: 0xA000_0000 },
            FreeResp { gva: 0xA000_0000 },
            User { opcode: 0x8001, a: 1, b: 2, c: 3 },
        ];
        for i in &cases {
            round_trip(i, Flags::default());
            round_trip(i, Flags(Flags::RELIABLE | Flags::STORE));
        }
    }

    #[test]
    fn flags_accessors() {
        let f = Flags::default()
            .with(Flags::RELIABLE)
            .with(Flags::ORDERED)
            .with(Flags::LAST);
        assert!(f.reliable() && f.ordered() && f.last() && !f.store());
    }

    #[test]
    fn idempotency_classification() {
        use Instruction::*;
        let f = Flags::default();
        assert!(Read { addr: 0, len: 4 }.idempotent(f));
        assert!(Write { addr: 0 }.idempotent(f));
        assert!(WriteIfHash { addr: 0, expect_hash: 1 }.idempotent(f));
        assert!(Simd { op: SimdOp::Add, addr: 0 }.idempotent(f));
        assert!(!Simd { op: SimdOp::Add, addr: 0 }.idempotent(Flags(Flags::STORE)));
        assert!(!Cas { addr: 0, expected: 3, new: 3 }.idempotent(f));
        assert!(Cas { addr: 0, expected: 0, new: 1 }.idempotent(f));
        // Overlapping memcopy is not idempotent.
        assert!(!Memcopy { src: 0, dst: 8, len: 64 }.idempotent(f));
        assert!(Memcopy { src: 0, dst: 64, len: 64 }.idempotent(f));
    }

    #[test]
    fn truncated_instruction_is_error() {
        let mut w = Writer::default();
        Instruction::Read { addr: 1, len: 2 }.encode(Flags::default(), &mut w);
        let bytes = w.into_vec();
        for cut in 1..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(Instruction::decode(&mut r).is_err(), "cut={cut}");
        }
    }
}
