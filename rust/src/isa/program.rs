//! Packet programs: verified multi-instruction NetDAM packets.
//!
//! The paper's headline is a *programmable* in-memory computing ISA, and
//! its killer application (§3) is a fused behaviour: one packet that
//! reduce-scatters around a ring and all-gathers the finished block back.
//! Instead of hardcoding each such fusion as a bespoke opcode, a packet
//! may carry a bounded **program**: a sequence of [`Step`]s the devices
//! on the SROU path execute hop-locally, with an operand-forwarding
//! convention — each step's result payload is the next step's input.
//!
//! * A [`Step`] wraps one ordinary [`Instruction`] plus placement:
//!   `repeat` spreads the step over that many consecutive SROU hops
//!   (forwarding the packet between executions), and `fused` pins the
//!   step to the device where the previous step finished (local
//!   chaining, e.g. `crypto_write → crc32` in one packet).
//! * A [`ProgramBuilder`] assembles programs; [`Program::verify`] is the
//!   static checker: bounded length, memory ranges against the device
//!   capacity, SROU hop-count consistency, and the paper's §2.3 relaxed-
//!   ordering rule as a *machine-checked property* — a non-commutative
//!   reduce on an unordered path, or a non-idempotent step on a lossy
//!   path, is rejected with a typed [`ProgramError`] before anything is
//!   injected.
//! * The micro-executor loop lives in `device::netdam` and charges
//!   per-step pipeline cost through the existing timing model.
//!
//! The §3 fused allreduce chunk is now literally
//! `reduce(op, addr) ×(N−1) → guarded_write(addr, hash) → store(addr)
//! ×(N−1)` — see `collectives::driver::lower_ring_chunk`.

use std::fmt;

use anyhow::{bail, Result};

use super::instr::{Flags, Instruction};
use super::opcode::SimdOp;
use super::registry::InstructionRegistry;
use crate::util::bytes::{Reader, Writer};

/// Hard bound on program length (the FPGA pipeline the paper describes
/// would unroll the step table into a fixed micro-sequencer).
pub const MAX_PROGRAM_STEPS: usize = 8;

/// `completion` sentinel: retire silently instead of emitting a
/// `CollectiveDone`.
pub const NO_COMPLETION: u32 = u32::MAX;

/// One program step: an instruction plus its placement on the SROU path.
#[derive(Debug, Clone, PartialEq)]
pub struct Step {
    pub instr: Instruction,
    /// Per-step flag bits (e.g. `STORE` for an accumulating SIMD step).
    pub flags: Flags,
    /// Number of consecutive SROU hops this step executes at (the packet
    /// is forwarded between executions). Must be >= 1.
    pub repeat: u8,
    /// Execute the first repetition at the device where the previous
    /// step finished (operand forwarding) instead of the next SROU hop.
    /// Must be false on the first step.
    pub fused: bool,
}

/// A bounded instruction sequence carried by one packet, plus its
/// execution cursor (`pc`/`reps_done` travel on the wire like the SROU
/// segments-left pointer).
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub steps: Vec<Step>,
    /// `CollectiveDone { block }` id emitted to the packet source when
    /// the program retires; [`NO_COMPLETION`] = silent retirement.
    pub completion: u32,
    /// Index of the step currently executing.
    pub pc: u8,
    /// Repetitions of the current step already performed.
    pub reps_done: u8,
}

/// What the verifier knows about the path a program will take. Built by
/// the planner (see `collectives::driver`) from the live fabric.
/// (No `Debug` derive: the registry holds opaque handler objects.)
#[derive(Clone)]
pub struct VerifyEnv<'a> {
    /// Device memory capacity in bytes (range checks).
    pub capacity: u64,
    /// Payload length the packet is injected with.
    pub payload_len: usize,
    /// Strict in-order delivery (`Flags::ORDERED` path). When false, the
    /// §2.3 rule applies: reduce steps must be commutative.
    pub ordered: bool,
    /// No loss, duplication, or timeout-retransmit on the path. When
    /// false, every step must be idempotent (blind re-execution safe).
    pub lossless: bool,
    /// Segments in the SROU header the program will ride.
    pub srou_hops: usize,
    /// Resolve user opcodes (existence + idempotency). `None` = reject
    /// user steps on lossy paths conservatively.
    pub registry: Option<&'a InstructionRegistry>,
}

/// Typed rejection from [`Program::verify`].
#[derive(Debug, Clone, PartialEq)]
pub enum ProgramError {
    /// A program must have at least one step.
    Empty,
    /// More than [`MAX_PROGRAM_STEPS`] steps.
    TooLong { steps: usize },
    /// A step declared `repeat == 0`.
    ZeroRepeat { pc: usize },
    /// The first step cannot be fused (there is no previous step).
    LeadingFusion,
    /// Programs cannot nest.
    NestedProgram { pc: usize },
    /// The instruction kind cannot run as a program step.
    UnsupportedStep { pc: usize, opcode: u16 },
    /// A step touches memory outside the device capacity.
    OutOfRange {
        pc: usize,
        addr: u64,
        len: u64,
        capacity: u64,
    },
    /// §2.3: a non-commutative reduce is illegal on an unordered path.
    NonCommutativeReduce { pc: usize, op: SimdOp },
    /// §3.1: a non-idempotent step is illegal where blind retransmission
    /// or duplication can replay it.
    NonIdempotentStep { pc: usize, opcode: u16 },
    /// An unregistered user opcode.
    UnknownUserOpcode { pc: usize, opcode: u16 },
    /// Program hop count does not match the SROU segment list.
    HopMismatch { program: usize, srou: usize },
}

impl fmt::Display for ProgramError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProgramError::Empty => write!(f, "program has no steps"),
            ProgramError::TooLong { steps } => {
                write!(f, "program has {steps} steps (max {MAX_PROGRAM_STEPS})")
            }
            ProgramError::ZeroRepeat { pc } => write!(f, "step {pc} has repeat 0"),
            ProgramError::LeadingFusion => {
                write!(f, "first step cannot be fused to a previous step")
            }
            ProgramError::NestedProgram { pc } => {
                write!(f, "step {pc} nests a program inside a program")
            }
            ProgramError::UnsupportedStep { pc, opcode } => {
                write!(f, "step {pc}: opcode {opcode:#06x} cannot run as a program step")
            }
            ProgramError::OutOfRange {
                pc,
                addr,
                len,
                capacity,
            } => write!(
                f,
                "step {pc}: [{addr:#x}, +{len}) exceeds device capacity {capacity:#x}"
            ),
            ProgramError::NonCommutativeReduce { pc, op } => write!(
                f,
                "step {pc}: non-commutative reduce {:?} on an unordered path (§2.3)",
                op
            ),
            ProgramError::NonIdempotentStep { pc, opcode } => write!(
                f,
                "step {pc}: opcode {opcode:#06x} is not idempotent but the path can replay it (§3.1)"
            ),
            ProgramError::UnknownUserOpcode { pc, opcode } => {
                write!(f, "step {pc}: user opcode {opcode:#06x} is not registered")
            }
            ProgramError::HopMismatch { program, srou } => write!(
                f,
                "program needs {program} SROU hops but the header carries {srou}"
            ),
        }
    }
}

impl std::error::Error for ProgramError {}

impl Program {
    /// SROU segments the program consumes: every repetition travels one
    /// hop except fused first-repetitions (which stay on the device where
    /// the previous step finished).
    pub fn hops(&self) -> usize {
        self.steps
            .iter()
            .enumerate()
            .map(|(i, s)| s.repeat as usize - usize::from(i > 0 && s.fused))
            .sum()
    }

    /// Are all steps safe to blindly re-execute? Drives the transport's
    /// retransmit policy, like [`Instruction::idempotent`].
    pub fn idempotent(&self) -> bool {
        self.steps.iter().all(|s| s.instr.idempotent(s.flags))
    }

    /// The static checker — see the module docs for the property list.
    pub fn verify(&self, env: &VerifyEnv<'_>) -> Result<(), ProgramError> {
        use Instruction as I;
        if self.steps.is_empty() {
            return Err(ProgramError::Empty);
        }
        if self.steps.len() > MAX_PROGRAM_STEPS {
            return Err(ProgramError::TooLong {
                steps: self.steps.len(),
            });
        }
        if self.steps[0].fused {
            return Err(ProgramError::LeadingFusion);
        }
        if self.hops() != env.srou_hops {
            return Err(ProgramError::HopMismatch {
                program: self.hops(),
                srou: env.srou_hops,
            });
        }
        // Payload length as it flows through the steps (operand
        // forwarding): Read/BlockHash replace it, User makes it unknown
        // (handler-defined), the rest preserve it. Unknown lengths skip
        // the static range check — the executor still bounds-checks at
        // runtime.
        let mut cur_len = Some(env.payload_len as u64);
        for (pc, s) in self.steps.iter().enumerate() {
            if s.repeat == 0 {
                return Err(ProgramError::ZeroRepeat { pc });
            }
            let opcode = s.instr.opcode_u16();
            let check_range = |addr: u64, len: Option<u64>| -> Result<(), ProgramError> {
                let Some(len) = len else { return Ok(()) };
                if addr.checked_add(len).is_none_or(|end| end > env.capacity) {
                    return Err(ProgramError::OutOfRange {
                        pc,
                        addr,
                        len,
                        capacity: env.capacity,
                    });
                }
                Ok(())
            };
            match &s.instr {
                I::Program(_) => return Err(ProgramError::NestedProgram { pc }),
                I::Read { addr, len } => {
                    check_range(*addr, Some(*len as u64))?;
                    cur_len = Some(*len as u64);
                }
                I::Write { addr } => check_range(*addr, cur_len)?,
                I::Memcopy { src, dst, len } => {
                    check_range(*src, Some(*len as u64))?;
                    check_range(*dst, Some(*len as u64))?;
                }
                I::Simd { op, addr } => {
                    check_range(*addr, cur_len)?;
                    if !env.ordered && !op.commutative() {
                        return Err(ProgramError::NonCommutativeReduce { pc, op: *op });
                    }
                }
                I::BlockHash { addr, len } => {
                    check_range(*addr, Some(*len as u64))?;
                    cur_len = Some(8);
                }
                I::WriteIfHash { addr, .. } => check_range(*addr, cur_len)?,
                I::User { opcode, .. } => {
                    if let Some(reg) = env.registry {
                        if reg.get(*opcode).is_none() {
                            return Err(ProgramError::UnknownUserOpcode {
                                pc,
                                opcode: *opcode,
                            });
                        }
                    }
                    cur_len = None; // handler-defined result length
                }
                _ => return Err(ProgramError::UnsupportedStep { pc, opcode }),
            }
            if !env.lossless {
                let safe = match &s.instr {
                    I::User { opcode, .. } => env
                        .registry
                        .and_then(|r| r.get(*opcode))
                        .is_some_and(|h| h.idempotent()),
                    other => other.idempotent(s.flags),
                };
                if !safe {
                    return Err(ProgramError::NonIdempotentStep { pc, opcode });
                }
            }
        }
        Ok(())
    }

    /// Peephole optimizer (build-time, `pc == 0`): merge adjacent steps
    /// that provably perform the same work in fewer table entries —
    /// verified programs only *shrink*, never change meaning:
    ///
    /// * two adjacent non-fused `Write`s of the carried payload at the
    ///   same address collapse into one step with the summed `repeat`
    ///   (the shape chained `store()` calls produce);
    /// * a `Memcopy` followed by a fused `Memcopy` over the contiguous
    ///   next ranges collapses into one longer copy on the same device.
    ///
    /// Both rewrites preserve [`hops`](Self::hops), flags, idempotency
    /// and per-hop semantics, so a program verified before optimization
    /// verifies identically after. Returns the number of merges.
    pub fn peephole(&mut self) -> usize {
        debug_assert_eq!((self.pc, self.reps_done), (0, 0), "optimize before launch");
        let mut merged = 0;
        let mut i = 0;
        while i + 1 < self.steps.len() {
            enum Rewrite {
                WriteRepeat(u8),
                CopyLen(u32),
            }
            let rewrite = {
                let a = &self.steps[i];
                let b = &self.steps[i + 1];
                if a.flags != b.flags {
                    None
                } else {
                    match (&a.instr, &b.instr) {
                        (Instruction::Write { addr: x }, Instruction::Write { addr: y })
                            if x == y
                                && !b.fused
                                && a.repeat as u16 + b.repeat as u16 <= u8::MAX as u16 =>
                        {
                            Some(Rewrite::WriteRepeat(a.repeat + b.repeat))
                        }
                        (
                            Instruction::Memcopy {
                                src: s1,
                                dst: d1,
                                len: l1,
                            },
                            Instruction::Memcopy {
                                src: s2,
                                dst: d2,
                                len: l2,
                            },
                        ) if b.fused
                            && a.repeat == 1
                            && b.repeat == 1
                            && *s2 == s1 + *l1 as u64
                            && *d2 == d1 + *l1 as u64
                            && l1.checked_add(*l2).is_some()
                            && {
                                // The merged copy must itself stay
                                // non-overlapping: two shift-style copies
                                // (dst of the first = src of the second)
                                // are each idempotent, but their fusion
                                // would self-overlap — different bytes
                                // AND a §3.1 idempotency break.
                                let total = (*l1 + *l2) as u64;
                                s1.checked_add(total).is_some_and(|e| e <= *d1)
                                    || d1.checked_add(total).is_some_and(|e| e <= *s1)
                            } =>
                        {
                            Some(Rewrite::CopyLen(l1 + l2))
                        }
                        _ => None,
                    }
                }
            };
            match rewrite {
                Some(Rewrite::WriteRepeat(r)) => {
                    self.steps[i].repeat = r;
                    self.steps.remove(i + 1);
                    merged += 1; // stay at i: further writes may cascade
                }
                Some(Rewrite::CopyLen(len)) => {
                    if let Instruction::Memcopy { len: l, .. } = &mut self.steps[i].instr {
                        *l = len;
                    }
                    self.steps.remove(i + 1);
                    merged += 1;
                }
                None => i += 1,
            }
        }
        merged
    }

    // ----------------------------------------------------------- codec

    /// Encode the program body (everything after `opcode|flags`):
    /// `completion:u32 | pc:u8 | reps_done:u8 | n:u8 | steps...` where a
    /// step is `fused:u8 | repeat:u8 | instruction`.
    pub(crate) fn encode_body(&self, w: &mut Writer) {
        w.u32(self.completion);
        w.u8(self.pc);
        w.u8(self.reps_done);
        w.u8(self.steps.len() as u8);
        for s in &self.steps {
            w.u8(s.fused as u8);
            w.u8(s.repeat);
            s.instr.encode(s.flags, w);
        }
    }

    /// Decode the program body. Steps are decoded through the
    /// nesting-rejecting entry point, bounding recursion depth at one.
    pub(crate) fn decode_body(r: &mut Reader) -> Result<Program> {
        let completion = r.u32()?;
        let pc = r.u8()?;
        let reps_done = r.u8()?;
        let n = r.u8()? as usize;
        if n == 0 || n > MAX_PROGRAM_STEPS {
            bail!("program step count {n} out of range");
        }
        if pc as usize > n {
            bail!("program pc {pc} exceeds step count {n}");
        }
        let mut steps = Vec::with_capacity(n);
        for i in 0..n {
            let fused = match r.u8()? {
                0 => false,
                1 => true,
                v => bail!("bad fused flag {v} in step {i}"),
            };
            let repeat = r.u8()?;
            if repeat == 0 {
                bail!("step {i} has repeat 0");
            }
            let (instr, flags) = Instruction::decode_step(r)?;
            steps.push(Step {
                instr,
                flags,
                repeat,
                fused,
            });
        }
        Ok(Program {
            steps,
            completion,
            pc,
            reps_done,
        })
    }
}

/// Typed assembler for [`Program`]s. Semantic helpers cover the lowered
/// collective shapes; [`hop`](ProgramBuilder::hop) /
/// [`then`](ProgramBuilder::then) add arbitrary steps.
#[derive(Debug)]
pub struct ProgramBuilder {
    steps: Vec<Step>,
    completion: u32,
}

impl Default for ProgramBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self {
            steps: Vec::new(),
            completion: NO_COMPLETION,
        }
    }

    fn push(mut self, instr: Instruction, flags: Flags, repeat: u8, fused: bool) -> Self {
        // The first step always rides the first SROU segment.
        let fused = fused && !self.steps.is_empty();
        self.steps.push(Step {
            instr,
            flags,
            repeat,
            fused,
        });
        self
    }

    /// Add a step executing at the next `1` SROU hop.
    pub fn hop(self, instr: Instruction) -> Self {
        self.push(instr, Flags::default(), 1, false)
    }

    /// Add a step fused to the device where the previous step finished
    /// (operand forwarding: it sees the previous step's result payload).
    pub fn then(self, instr: Instruction) -> Self {
        self.push(instr, Flags::default(), 1, true)
    }

    /// Reduce step: payload lanes `⊕=` local memory at `addr`, spread
    /// over `hops` consecutive ring hops (packet-buffer only — no local
    /// side effects, idempotent by construction).
    pub fn reduce(self, op: SimdOp, addr: u64, hops: u8) -> Self {
        if hops == 0 {
            return self;
        }
        self.push(Instruction::Simd { op, addr }, Flags::default(), hops, false)
    }

    /// Hash-guarded write at the device where the reduce chain ended —
    /// §3.1's exactly-once trick. After the step the payload is the
    /// block re-read from memory, so a retransmitted chain forwards the
    /// already-reduced block instead of double-adding.
    pub fn guarded_write(self, addr: u64, expect_hash: u64) -> Self {
        self.push(
            Instruction::WriteIfHash { addr, expect_hash },
            Flags::default(),
            1,
            true,
        )
    }

    /// Plain idempotent writes of the carried payload at the next `hops`
    /// ring hops (the all-gather / broadcast shape).
    pub fn store(self, addr: u64, hops: u8) -> Self {
        if hops == 0 {
            return self;
        }
        self.push(Instruction::Write { addr }, Flags::default(), hops, false)
    }

    /// Emit `CollectiveDone { block: done_id }` to the source on retire.
    pub fn on_retire(mut self, done_id: u32) -> Self {
        self.completion = done_id;
        self
    }

    /// Verify against `env`, then peephole-optimize (verified programs
    /// only shrink — the merges preserve hops, flags and semantics, so
    /// the optimized program still satisfies `verify`).
    pub fn build(self, env: &VerifyEnv<'_>) -> Result<Program, ProgramError> {
        let mut p = self.build_unchecked();
        p.verify(env)?;
        p.peephole();
        debug_assert!(p.verify(env).is_ok(), "peephole broke verification");
        Ok(p)
    }

    /// Skip verification (tests and executor-error paths only).
    pub fn build_unchecked(self) -> Program {
        Program {
            steps: self.steps,
            completion: self.completion,
            pc: 0,
            reps_done: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(hops: usize) -> VerifyEnv<'static> {
        VerifyEnv {
            capacity: 1 << 20,
            payload_len: 8192,
            ordered: false,
            lossless: true,
            srou_hops: hops,
            registry: None,
        }
    }

    fn ring_program(n: usize, fused: bool) -> ProgramBuilder {
        let mut b = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0x1000, (n - 1) as u8)
            .guarded_write(0x1000, 42);
        if fused {
            b = b.store(0x1000, (n - 1) as u8);
        }
        b.on_retire(7)
    }

    #[test]
    fn fused_ring_shape_and_hops() {
        let p = ring_program(4, true).build(&env(6)).unwrap();
        assert_eq!(p.steps.len(), 3);
        assert_eq!(p.hops(), 6, "2(N-1) hops for N=4");
        assert_eq!(p.completion, 7);
        assert!(p.idempotent(), "whole fused chain is §3.1-safe");
        // Reduce-scatter only: N-1 hops.
        let p = ring_program(4, false).build(&env(3)).unwrap();
        assert_eq!(p.hops(), 3);
    }

    #[test]
    fn two_rank_ring_has_no_interim_reduce() {
        // N=2: reduce spans 1 hop (the owner), guarded write fused there.
        let p = ring_program(2, true).build(&env(2)).unwrap();
        assert_eq!(p.steps.len(), 3);
        assert_eq!(p.hops(), 2);
    }

    #[test]
    fn hop_mismatch_is_typed() {
        let err = ring_program(4, true).build(&env(5)).unwrap_err();
        assert_eq!(err, ProgramError::HopMismatch { program: 6, srou: 5 });
    }

    #[test]
    fn noncommutative_reduce_rejected_on_unordered_path() {
        let err = ProgramBuilder::new()
            .reduce(SimdOp::Sub, 0, 2)
            .guarded_write(0, 1)
            .build(&env(2))
            .unwrap_err();
        assert_eq!(
            err,
            ProgramError::NonCommutativeReduce {
                pc: 0,
                op: SimdOp::Sub
            }
        );
        // The same program is legal on a strictly ordered path.
        let mut ordered = env(2);
        ordered.ordered = true;
        assert!(ProgramBuilder::new()
            .reduce(SimdOp::Sub, 0, 2)
            .guarded_write(0, 1)
            .build(&ordered)
            .is_ok());
    }

    #[test]
    fn nonidempotent_step_rejected_on_lossy_path() {
        let mut lossy = env(1);
        lossy.lossless = false;
        // STORE'd SIMD accumulates into memory: replay would double-add.
        let err = ProgramBuilder::new()
            .push_test(
                Instruction::Simd {
                    op: SimdOp::Add,
                    addr: 0,
                },
                Flags(Flags::STORE),
            )
            .build(&lossy)
            .unwrap_err();
        assert!(matches!(err, ProgramError::NonIdempotentStep { pc: 0, .. }));
        // The guarded-write version of the same intent is accepted.
        assert!(ProgramBuilder::new()
            .reduce(SimdOp::Add, 0, 1)
            .guarded_write(0, 9)
            .build(&VerifyEnv {
                lossless: false,
                srou_hops: 1,
                ..env(1)
            })
            .is_ok());
    }

    #[test]
    fn range_and_shape_errors() {
        assert_eq!(
            ProgramBuilder::new().build(&env(0)).unwrap_err(),
            ProgramError::Empty
        );
        let mut b = ProgramBuilder::new();
        for _ in 0..(MAX_PROGRAM_STEPS + 1) {
            b = b.hop(Instruction::Write { addr: 0 });
        }
        assert!(matches!(
            b.build(&env(MAX_PROGRAM_STEPS + 1)).unwrap_err(),
            ProgramError::TooLong { .. }
        ));
        let err = ProgramBuilder::new()
            .hop(Instruction::Write { addr: (1 << 20) - 4 })
            .build(&env(1))
            .unwrap_err();
        assert!(matches!(err, ProgramError::OutOfRange { pc: 0, .. }), "{err}");
        // Unsupported step kind (a response opcode).
        let err = ProgramBuilder::new()
            .hop(Instruction::Ack { acked: 1 })
            .build(&env(1))
            .unwrap_err();
        assert!(matches!(err, ProgramError::UnsupportedStep { .. }));
    }

    #[test]
    fn read_updates_flowing_payload_length() {
        // Read replaces the payload: the following Write is checked
        // against the *read* length, not the injected payload length.
        let p = ProgramBuilder::new()
            .hop(Instruction::Read { addr: 0, len: 64 })
            .then(Instruction::Write { addr: (1 << 20) - 64 })
            .build(&env(1));
        assert!(p.is_ok(), "{p:?}");
        let err = ProgramBuilder::new()
            .hop(Instruction::Read { addr: 0, len: 128 })
            .then(Instruction::Write { addr: (1 << 20) - 64 })
            .build(&env(1))
            .unwrap_err();
        assert!(matches!(err, ProgramError::OutOfRange { pc: 1, .. }));
    }

    #[test]
    fn user_step_makes_payload_length_unknown() {
        // A user handler's result length is handler-defined, so a
        // following Write cannot be statically range-checked — it must
        // not be rejected against the stale injected length (the
        // executor still bounds-checks at runtime).
        let p = ProgramBuilder::new()
            .hop(Instruction::User {
                opcode: 0x8001,
                a: 0,
                b: 0,
                c: 0,
            })
            .then(Instruction::Write { addr: (1 << 20) - 4 })
            .build(&env(1));
        assert!(p.is_ok(), "{p:?}");
    }

    #[test]
    fn unknown_user_opcode_rejected_when_registry_known() {
        let reg = InstructionRegistry::new();
        let mut e = env(1);
        e.registry = Some(&reg);
        let err = ProgramBuilder::new()
            .hop(Instruction::User {
                opcode: 0x9999,
                a: 0,
                b: 0,
                c: 0,
            })
            .build(&e)
            .unwrap_err();
        assert_eq!(
            err,
            ProgramError::UnknownUserOpcode {
                pc: 0,
                opcode: 0x9999
            }
        );
    }

    #[test]
    fn peephole_merges_adjacent_store_chains() {
        // Two chained store() calls at the same address collapse into one
        // step with the summed repeat; hops are preserved.
        let p = ProgramBuilder::new()
            .store(0x100, 2)
            .store(0x100, 3)
            .on_retire(1)
            .build(&env(5))
            .unwrap();
        assert_eq!(p.steps.len(), 1);
        assert_eq!(p.steps[0].repeat, 5);
        assert_eq!(p.hops(), 5);
        // Cascades across three fragments too.
        let mut p = ProgramBuilder::new()
            .store(0x100, 1)
            .store(0x100, 1)
            .store(0x100, 1)
            .build_unchecked();
        assert_eq!(p.peephole(), 2);
        assert_eq!(p.steps.len(), 1);
        assert_eq!(p.steps[0].repeat, 3);
    }

    #[test]
    fn peephole_merges_contiguous_fused_memcopies() {
        let p = ProgramBuilder::new()
            .hop(Instruction::Memcopy {
                src: 0,
                dst: 0x4000,
                len: 64,
            })
            .then(Instruction::Memcopy {
                src: 64,
                dst: 0x4040,
                len: 32,
            })
            .build(&env(1))
            .unwrap();
        assert_eq!(p.steps.len(), 1);
        assert_eq!(
            p.steps[0].instr,
            Instruction::Memcopy {
                src: 0,
                dst: 0x4000,
                len: 96
            }
        );
        assert_eq!(p.hops(), 1);
    }

    #[test]
    fn peephole_leaves_unmergeable_steps_alone() {
        // Different addresses: no merge.
        let mut p = ProgramBuilder::new()
            .store(0x100, 1)
            .store(0x200, 1)
            .build_unchecked();
        assert_eq!(p.peephole(), 0);
        assert_eq!(p.steps.len(), 2);
        // Non-contiguous copies: no merge.
        let mut p = ProgramBuilder::new()
            .hop(Instruction::Memcopy {
                src: 0,
                dst: 0x4000,
                len: 64,
            })
            .then(Instruction::Memcopy {
                src: 128,
                dst: 0x4080,
                len: 64,
            })
            .build_unchecked();
        assert_eq!(p.peephole(), 0);
        // Shift-style copies (dst of the first = src of the second) are
        // each idempotent, but the fused copy would self-overlap: both
        // a semantic change and a §3.1 idempotency break — no merge.
        let mut p = ProgramBuilder::new()
            .hop(Instruction::Memcopy {
                src: 0,
                dst: 64,
                len: 64,
            })
            .then(Instruction::Memcopy {
                src: 64,
                dst: 128,
                len: 64,
            })
            .build_unchecked();
        assert_eq!(p.peephole(), 0);
        assert!(p.idempotent(), "pair stays idempotent un-merged");
        // The full fused-ring shape is already minimal.
        let mut p = ring_program(4, true).build_unchecked();
        assert_eq!(p.peephole(), 0);
        assert_eq!(p.steps.len(), 3);
    }

    #[test]
    fn error_display_is_informative() {
        let e = ProgramError::NonCommutativeReduce {
            pc: 2,
            op: SimdOp::Sub,
        };
        let s = e.to_string();
        assert!(s.contains("non-commutative") && s.contains("§2.3"), "{s}");
    }

    impl ProgramBuilder {
        /// Test-only: push a step with explicit flags.
        fn push_test(self, instr: Instruction, flags: Flags) -> Self {
            self.push(instr, flags, 1, false)
        }
    }
}
