//! Go-back-N sender state (one RC queue pair, simplified).
//!
//! RoCE RC transports retransmit from the first unacknowledged PSN on a
//! NAK or timeout — everything after the loss is resent even if it
//! arrived. This is the behaviour that makes RoCE demand lossless
//! Ethernet (PFC), and the contrast with NetDAM's idempotent-retransmit
//! model (E5): under the same loss rate, go-back-N wastes a window per
//! drop where NetDAM re-sends exactly the lost operation.

use std::collections::VecDeque;

/// What the sender should put on the wire next.
#[derive(Debug, Clone, PartialEq)]
pub enum TxEvent {
    /// Transmit PSN (fresh or retransmit).
    Send { psn: u64, retransmit: bool },
    /// Window full / nothing to do.
    Idle,
}

#[derive(Debug)]
pub struct GoBackN {
    /// Next fresh PSN to send.
    next_psn: u64,
    /// Lowest unacked PSN.
    base: u64,
    /// Total PSNs to send (message length in packets).
    total: u64,
    /// Send window (packets).
    window: u64,
    /// Rewind queue after a NAK/timeout: PSNs to resend in order.
    rewind: VecDeque<u64>,
    pub retransmitted: u64,
}

impl GoBackN {
    pub fn new(total: u64, window: u64) -> Self {
        assert!(window > 0);
        Self {
            next_psn: 0,
            base: 0,
            total,
            window,
            rewind: VecDeque::new(),
            retransmitted: 0,
        }
    }

    /// Ask for the next transmission opportunity.
    pub fn next_tx(&mut self) -> TxEvent {
        if let Some(psn) = self.rewind.pop_front() {
            self.retransmitted += 1;
            return TxEvent::Send {
                psn,
                retransmit: true,
            };
        }
        if self.next_psn < self.total && self.next_psn < self.base + self.window {
            let psn = self.next_psn;
            self.next_psn += 1;
            return TxEvent::Send {
                psn,
                retransmit: false,
            };
        }
        TxEvent::Idle
    }

    /// Cumulative ACK up to and including `psn`.
    pub fn ack(&mut self, psn: u64) {
        if psn >= self.base {
            self.base = psn + 1;
        }
    }

    /// NAK at `psn` (receiver saw a gap): rewind — resend `psn..next_psn`.
    pub fn nak(&mut self, psn: u64) {
        if psn < self.base {
            return; // stale
        }
        self.rewind.clear();
        for p in psn..self.next_psn {
            self.rewind.push_back(p);
        }
    }

    /// Timeout with nothing acked: rewind the whole window.
    pub fn timeout(&mut self) {
        self.nak(self.base);
    }

    pub fn done(&self) -> bool {
        self.base >= self.total
    }

    pub fn in_flight(&self) -> u64 {
        self.next_psn - self.base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_sends(q: &mut GoBackN, n: usize) -> Vec<u64> {
        let mut out = Vec::new();
        for _ in 0..n {
            match q.next_tx() {
                TxEvent::Send { psn, .. } => out.push(psn),
                TxEvent::Idle => break,
            }
        }
        out
    }

    #[test]
    fn window_limits_in_flight() {
        let mut q = GoBackN::new(100, 4);
        assert_eq!(drain_sends(&mut q, 10), vec![0, 1, 2, 3]);
        assert_eq!(q.next_tx(), TxEvent::Idle);
        q.ack(1);
        assert_eq!(drain_sends(&mut q, 10), vec![4, 5]);
    }

    #[test]
    fn completes_in_order() {
        let mut q = GoBackN::new(3, 8);
        drain_sends(&mut q, 3);
        q.ack(2);
        assert!(q.done());
        assert_eq!(q.next_tx(), TxEvent::Idle);
    }

    #[test]
    fn nak_rewinds_everything_after_loss() {
        let mut q = GoBackN::new(10, 8);
        drain_sends(&mut q, 6); // sent 0..6
        q.ack(1); // 0,1 acked
        q.nak(3); // 3 lost: must resend 3,4,5
        let resent = drain_sends(&mut q, 3);
        assert_eq!(resent, vec![3, 4, 5]);
        assert_eq!(q.retransmitted, 3);
        // Then fresh ones continue.
        match q.next_tx() {
            TxEvent::Send { psn: 6, retransmit: false } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn timeout_rewinds_window() {
        let mut q = GoBackN::new(5, 8);
        drain_sends(&mut q, 5);
        q.timeout();
        assert_eq!(drain_sends(&mut q, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn stale_nak_ignored() {
        let mut q = GoBackN::new(5, 8);
        drain_sends(&mut q, 5);
        q.ack(4);
        q.nak(2);
        assert!(q.done());
        assert_eq!(q.next_tx(), TxEvent::Idle);
    }

    #[test]
    fn goback_n_wastes_a_window_vs_selective() {
        // The E5 contrast quantified: 1 loss in a 64-window costs ~window
        // retransmissions for go-back-N vs exactly 1 for NetDAM's
        // idempotent re-send.
        let mut q = GoBackN::new(128, 64);
        drain_sends(&mut q, 64);
        q.ack(30);
        q.nak(32); // one loss at 32
        let mut resent = 0;
        loop {
            match q.next_tx() {
                TxEvent::Send { retransmit: true, .. } => resent += 1,
                _ => break,
            }
        }
        assert_eq!(resent, 32); // 32..64 all resent for one drop
    }
}
