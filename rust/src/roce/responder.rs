//! A RoCE responder: a host whose NIC serves remote READ/WRITE through
//! the PCIe/DRAM path. The E1 comparison runs the same READ workload
//! against a [`RoceResponder`] and a NetDAM device and contrasts the
//! latency distributions.

use crate::host::{HostConfig, HostModel};
use crate::isa::Instruction;
use crate::net::{App, AppCtx};
use crate::wire::{Packet, Payload, SrouHeader};

/// Timer tokens carry an index into the pending-reply queue.
pub struct RoceResponder {
    host: HostModel,
    pending: Vec<Packet>,
    pub served: u64,
}

impl RoceResponder {
    pub fn new(seed: u64) -> Self {
        Self {
            host: HostModel::new(HostConfig::paper_default(), seed),
            pending: Vec::new(),
            served: 0,
        }
    }
}

impl App for RoceResponder {
    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        match pkt.instr {
            Instruction::Read { addr, len } => {
                // NIC-terminated READ: DMA the data up over PCIe, then reply.
                let service = self.host.nic_read_ns(len as usize);
                let resp = Packet::new(
                    ctx.self_ip,
                    pkt.seq,
                    SrouHeader::direct(pkt.src),
                    Instruction::ReadResp { addr },
                )
                .with_payload(Payload::phantom(len as usize));
                let token = self.pending.len() as u64;
                self.pending.push(resp);
                ctx.timer(service, token);
            }
            Instruction::Write { addr } => {
                let service = self.host.nic_write_ns(pkt.payload.len());
                if pkt.flags.reliable() {
                    let ack = Packet::new(
                        ctx.self_ip,
                        pkt.seq,
                        SrouHeader::direct(pkt.src),
                        Instruction::WriteAck { addr },
                    );
                    let token = self.pending.len() as u64;
                    self.pending.push(ack);
                    ctx.timer(service, token);
                }
            }
            _ => {}
        }
    }

    fn on_timer(&mut self, token: u64, ctx: &mut AppCtx) {
        let resp = self.pending[token as usize].clone();
        self.served += 1;
        ctx.send(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{Cluster, LinkConfig, NodeId, Switch};
    use crate::sim::Engine;
    use crate::wire::DeviceIp;

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    fn setup() -> (Cluster, NodeId, NodeId) {
        let mut cl = Cluster::new(11);
        let sw = cl.add_switch(Switch::tor(None));
        let client = cl.add_host(ip(100), None);
        let server = cl.add_host(ip(50), Some(Box::new(RoceResponder::new(50))));
        cl.connect(sw, client, LinkConfig::dc_100g());
        cl.connect(sw, server, LinkConfig::dc_100g());
        cl.compute_routes();
        (cl, client, server)
    }

    #[test]
    fn read_served_through_host_path() {
        let (mut cl, client, _server) = setup();
        let mut eng: Engine<Cluster> = Engine::new();
        let seq = cl.alloc_seq(client);
        let req = Packet::new(
            ip(100),
            seq,
            SrouHeader::direct(ip(50)),
            Instruction::Read { addr: 0, len: 128 },
        );
        cl.inject(&mut eng, client, req);
        eng.run(&mut cl);
        let mailbox = &cl.host_mut(client).mailbox;
        assert_eq!(mailbox.len(), 1);
        let (t, resp) = &mailbox[0];
        assert!(matches!(resp.instr, Instruction::ReadResp { .. }));
        // RoCE RTT must exceed the NetDAM RTT for the same fabric (~3.2us
        // measured in net::cluster tests) by the PCIe margin.
        assert!(*t > 3_800, "roce rtt {t}");
    }

    #[test]
    fn roce_read_slower_than_netdam_same_fabric() {
        // Run both against identical fabrics and compare.
        let (mut cl, client, _) = setup();
        let d = cl.add_device(crate::device::DeviceConfig::paper_default(ip(1)));
        cl.connect(0, d, LinkConfig::dc_100g()); // node 0 is the switch
        cl.compute_routes();
        let mut eng: Engine<Cluster> = Engine::new();
        for target in [ip(50), ip(1)] {
            for _ in 0..50 {
                let seq = cl.alloc_seq(client);
                let req = Packet::new(
                    ip(100),
                    seq,
                    SrouHeader::direct(target),
                    Instruction::Read { addr: 0, len: 128 },
                );
                cl.inject(&mut eng, client, req);
            }
        }
        eng.run(&mut cl);
        let mailbox = std::mem::take(&mut cl.host_mut(client).mailbox);
        assert_eq!(mailbox.len(), 100);
        // (Responses interleave; identify by src ip.)
        let mean = |ip_: DeviceIp| {
            let v: Vec<f64> = mailbox
                .iter()
                .filter(|(_, p)| p.src == ip_)
                .map(|(t, _)| *t as f64)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        // Means of *completion times* under identical injection times →
        // compare service+queue; RoCE must be visibly slower.
        assert!(mean(ip(50)) > mean(ip(1)) + 500.0);
    }
}
