//! The RoCEv2 baseline (paper §3.3's comparison platform).
//!
//! RoCE's cost structure is what NetDAM eliminates, so the baseline models
//! it explicitly:
//!
//! * the **host path** — PCIe doorbells/DMA, DRAM, interrupt jitter —
//!   comes from [`crate::host::HostModel`];
//! * **go-back-N** ([`qp::GoBackN`]) — RoCE's loss recovery, which is why
//!   it wants lossless Ethernet/PFC: one drop rewinds the window;
//! * **DCQCN-lite** ([`dcqcn::RateController`]) — ECN-driven rate control
//!   (reference [14]), the congestion machinery NetDAM's deterministic
//!   latency + receiver-paced READs make unnecessary;
//! * [`responder::RoceResponder`] — a host app serving remote READ/WRITE
//!   like an RDMA NIC would, for the E1 latency comparison.

pub mod dcqcn;
pub mod qp;
pub mod responder;

pub use dcqcn::{DcqcnConfig, RateController};
pub use qp::{GoBackN, TxEvent};
pub use responder::RoceResponder;
