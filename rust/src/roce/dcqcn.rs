//! DCQCN-lite rate controller (Zhu et al., SIGCOMM'15 — paper ref [14]).
//!
//! The shape that matters for the comparison: multiplicative decrease on
//! CNP (ECN feedback), then fast-recovery toward the rate before the cut,
//! then additive probing. We keep the canonical α-EWMA form with the
//! byte-counter stages folded into time-based recovery — enough fidelity
//! to show throttling under incast (E3's "complex congestion control"
//! arm) without modeling every QP timer of the real spec.

use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct DcqcnConfig {
    pub line_gbps: f64,
    /// α EWMA gain.
    pub g: f64,
    /// Additive increase per recovery period (Gbps).
    pub ai_gbps: f64,
    /// Recovery/probe period.
    pub period_ns: SimTime,
    /// Minimum rate floor (Gbps).
    pub min_gbps: f64,
    /// Token-bucket depth when the controller drives a pacer (bytes) —
    /// how much a slot may burst ahead of its sustained rate.
    pub burst_bytes: usize,
}

impl Default for DcqcnConfig {
    fn default() -> Self {
        Self {
            line_gbps: 100.0,
            g: 1.0 / 16.0,
            ai_gbps: 5.0,
            period_ns: 55_000, // ≈ DCQCN's 55 us rate timer
            min_gbps: 1.0,
            burst_bytes: 18_000, // two jumbo frames of headroom
        }
    }
}

#[derive(Debug)]
pub struct RateController {
    cfg: DcqcnConfig,
    /// Current sending rate (Gbps).
    rate: f64,
    /// Target rate remembered from before the last cut.
    target: f64,
    /// α — EWMA congestion estimate.
    alpha: f64,
    last_update: SimTime,
    pub cnps: u64,
}

impl RateController {
    pub fn new(cfg: DcqcnConfig) -> Self {
        let line = cfg.line_gbps;
        Self {
            cfg,
            rate: line,
            target: line,
            alpha: 1.0,
            last_update: 0,
            cnps: 0,
        }
    }

    /// Congestion notification received (an ECN-echo).
    pub fn on_cnp(&mut self, now: SimTime) {
        self.advance(now);
        self.cnps += 1;
        self.alpha = (1.0 - self.cfg.g) * self.alpha + self.cfg.g;
        self.target = self.rate;
        self.rate = (self.rate * (1.0 - self.alpha / 2.0)).max(self.cfg.min_gbps);
    }

    /// Time-based recovery: α decays; rate climbs toward target, then
    /// probes additively past it.
    fn advance(&mut self, now: SimTime) {
        while now.saturating_sub(self.last_update) >= self.cfg.period_ns {
            self.last_update += self.cfg.period_ns;
            self.alpha *= 1.0 - self.cfg.g;
            if self.rate < self.target {
                // fast recovery: halfway to target
                self.rate = (self.rate + self.target) / 2.0;
            } else {
                // additive probe
                self.target += self.cfg.ai_gbps;
                self.rate = ((self.rate + self.target) / 2.0).min(self.cfg.line_gbps);
                self.target = self.target.min(self.cfg.line_gbps);
            }
        }
    }

    /// Current rate (Gbps) at `now`.
    pub fn rate_gbps(&mut self, now: SimTime) -> f64 {
        self.advance(now);
        self.rate
    }

    /// Inter-packet gap for `bytes` at the current rate.
    pub fn pacing_ns(&mut self, now: SimTime, bytes: usize) -> SimTime {
        let r = self.rate_gbps(now);
        ((bytes as f64 * 8.0) / r).ceil() as SimTime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_line_rate() {
        let mut rc = RateController::new(DcqcnConfig::default());
        assert_eq!(rc.rate_gbps(0), 100.0);
        assert_eq!(rc.pacing_ns(0, 1250), 100); // 1250B at 100G = 100ns
    }

    #[test]
    fn cnp_cuts_rate_multiplicatively() {
        let mut rc = RateController::new(DcqcnConfig::default());
        rc.on_cnp(1000);
        // First CNP with α=1: cut toward half.
        assert!(rc.rate_gbps(1000) < 55.0);
        let r1 = rc.rate_gbps(1000);
        rc.on_cnp(2000);
        assert!(rc.rate_gbps(2000) < r1);
    }

    #[test]
    fn recovers_after_quiet_period() {
        let mut rc = RateController::new(DcqcnConfig::default());
        rc.on_cnp(0);
        let cut = rc.rate_gbps(0);
        // 2 ms without CNPs → substantial recovery.
        let later = rc.rate_gbps(2_000_000);
        assert!(later > cut * 1.5, "cut {cut}, later {later}");
        // 50 ms → essentially line rate again.
        assert!(rc.rate_gbps(50_000_000) > 95.0);
    }

    #[test]
    fn sustained_cnps_pin_near_floor() {
        let mut rc = RateController::new(DcqcnConfig::default());
        let mut now = 0;
        for _ in 0..200 {
            rc.on_cnp(now);
            now += 10_000;
        }
        assert!(rc.rate_gbps(now) < 10.0);
        assert_eq!(rc.cnps, 200);
    }

    #[test]
    fn rate_never_exceeds_line_or_drops_below_floor() {
        let mut rc = RateController::new(DcqcnConfig::default());
        let mut now = 0;
        let mut rng = crate::util::Xoshiro256::seed_from(4);
        for _ in 0..2000 {
            now += rng.next_below(100_000);
            if rng.chance(0.3) {
                rc.on_cnp(now);
            }
            let r = rc.rate_gbps(now);
            assert!((1.0..=100.0).contains(&r), "rate {r}");
        }
    }
}
