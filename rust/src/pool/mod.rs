//! The global memory pool (paper §2.5, Figure 5).
//!
//! "multiple NetDAM device with switch construct a big memory pool with
//! multi-terabytes memory capacity with multi-terabits bandwidth. [...]
//! The global memory pool could be operated in block interleaved mode,
//! thus many-to-one communication could be equally load balance to
//! multiple NetDAM device [and] the incast problem can be easily avoid."
//!
//! * [`interleave::InterleaveMap`] — the GVA ↔ (device, local) bijection.
//! * [`controller::SdnController`] — the SDN-controller-as-MMU of §2.6:
//!   malloc/free over the pool, access-control lists, address translation.
//!   `malloc_mapped`/`free_mapped`/`grant_host` *program the fabric*: each
//!   lease becomes per-device IOMMU mappings (through [`IommuDirectory`],
//!   implemented by `net::Cluster`), so enforcement happens on the device
//!   and denials surface as wire-level NAKs.
//!
//! The host-side data plane over this pool is [`crate::mem::MemClient`].

pub mod controller;
pub mod interleave;

pub use controller::{AllocError, Allocation, IommuDirectory, SdnController, TenantId};
pub use interleave::{Extent, InterleaveMap};
