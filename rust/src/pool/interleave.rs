//! Block-interleaved global addressing.
//!
//! GVA block `i` lives on device `i mod N` at local block `i div N`.
//! A linear GVA write therefore sprays round-robin across all devices —
//! that is the incast-avoidance mechanism of §2.5 (experiment E3).

use crate::wire::DeviceIp;

/// One contiguous piece of a GVA range on a single device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Extent {
    pub device: DeviceIp,
    pub local_addr: u64,
    /// Offset of this extent within the original GVA range.
    pub range_off: u64,
    pub len: u64,
}

/// The GVA ↔ (device, local address) bijection.
#[derive(Debug, Clone)]
pub struct InterleaveMap {
    devices: Vec<DeviceIp>,
    block: u64,
    /// Local base offset where pool blocks start on every device.
    base: u64,
}

impl InterleaveMap {
    pub fn new(devices: Vec<DeviceIp>, block_bytes: u64, local_base: u64) -> Self {
        assert!(!devices.is_empty());
        assert!(block_bytes.is_power_of_two(), "block size must be 2^k");
        Self {
            devices,
            block: block_bytes,
            base: local_base,
        }
    }

    /// The paper's natural block: 2048 × f32 = 8 KiB.
    pub fn paper_default(devices: Vec<DeviceIp>) -> Self {
        Self::new(devices, 8192, 0)
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// The participating devices, in interleave order.
    pub fn devices(&self) -> &[DeviceIp] {
        &self.devices
    }

    pub fn block_bytes(&self) -> u64 {
        self.block
    }

    /// Translate one GVA to its device + local address.
    pub fn translate(&self, gva: u64) -> (DeviceIp, u64) {
        let n = self.devices.len() as u64;
        let blk = gva / self.block;
        let off = gva % self.block;
        let dev = self.devices[(blk % n) as usize];
        let local = self.base + (blk / n) * self.block + off;
        (dev, local)
    }

    /// Inverse: (device, local) → GVA.
    pub fn inverse(&self, dev: DeviceIp, local: u64) -> Option<u64> {
        let idx = self.devices.iter().position(|&d| d == dev)? as u64;
        let rel = local.checked_sub(self.base)?;
        let lblk = rel / self.block;
        let off = rel % self.block;
        let n = self.devices.len() as u64;
        Some((lblk * n + idx) * self.block + off)
    }

    /// Split a linear GVA range into per-device extents, in range order.
    pub fn scatter(&self, gva: u64, len: u64) -> Vec<Extent> {
        let mut out = Vec::new();
        let mut off = 0;
        while off < len {
            let a = gva + off;
            let in_block = a % self.block;
            let chunk = (self.block - in_block).min(len - off);
            let (device, local_addr) = self.translate(a);
            out.push(Extent {
                device,
                local_addr,
                range_off: off,
                len: chunk,
            });
            off += chunk;
        }
        out
    }

    /// Per-device contiguous local runs covering `[gva, gva+len)`.
    ///
    /// Consecutive blocks of a linear GVA range land on the same device
    /// exactly every `n` blocks, and their local addresses then advance by
    /// exactly one block — so each device's share of a linear range is one
    /// contiguous local run. This is what the SDN controller programs into
    /// each device IOMMU per lease (one `map_leased` per device).
    pub fn device_runs(&self, gva: u64, len: u64) -> Vec<(DeviceIp, u64, u64)> {
        let mut runs: Vec<(DeviceIp, u64, u64)> = Vec::new();
        for e in self.scatter(gva, len) {
            if let Some(r) = runs.iter_mut().rev().find(|r| r.0 == e.device) {
                if r.1 + r.2 == e.local_addr {
                    r.2 += e.len;
                    continue;
                }
            }
            runs.push((e.device, e.local_addr, e.len));
        }
        runs
    }

    /// Total pool capacity given per-device capacity.
    pub fn pool_capacity(&self, per_device: u64) -> u64 {
        per_device.saturating_sub(self.base) * self.devices.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn map() -> InterleaveMap {
        InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect())
    }

    #[test]
    fn round_robin_blocks() {
        let m = map();
        assert_eq!(m.translate(0).0, DeviceIp::lan(1));
        assert_eq!(m.translate(8192).0, DeviceIp::lan(2));
        assert_eq!(m.translate(3 * 8192).0, DeviceIp::lan(4));
        assert_eq!(m.translate(4 * 8192), (DeviceIp::lan(1), 8192));
    }

    #[test]
    fn translate_inverse_is_bijective() {
        let m = map();
        prop::check(|rng, _| {
            let gva = rng.next_below(1 << 40);
            let (d, local) = m.translate(gva);
            assert_eq!(m.inverse(d, local), Some(gva));
        });
    }

    #[test]
    fn scatter_covers_range_exactly_once() {
        let m = map();
        prop::check(|rng, _| {
            let gva = rng.next_below(1 << 30);
            let len = 1 + rng.next_below(200_000);
            let extents = m.scatter(gva, len);
            // Coverage: extents tile [0, len) in order.
            let mut expect_off = 0;
            for e in &extents {
                assert_eq!(e.range_off, expect_off);
                assert!(e.len > 0 && e.len <= m.block_bytes());
                // Each extent translates consistently.
                let (d, l) = m.translate(gva + e.range_off);
                assert_eq!((e.device, e.local_addr), (d, l));
                expect_off += e.len;
            }
            assert_eq!(expect_off, len);
        });
    }

    #[test]
    fn aligned_scatter_balances_perfectly() {
        let m = map();
        // 64 aligned blocks over 4 devices → exactly 16 each.
        let extents = m.scatter(0, 64 * 8192);
        let mut per: std::collections::HashMap<DeviceIp, u64> = Default::default();
        for e in extents {
            *per.entry(e.device).or_insert(0) += e.len;
        }
        assert_eq!(per.len(), 4);
        assert!(per.values().all(|&v| v == 16 * 8192));
    }

    #[test]
    fn device_runs_merge_to_one_run_per_device() {
        let m = map();
        prop::check(|rng, _| {
            let gva = rng.next_below(1 << 28) / 8192 * 8192;
            let len = (1 + rng.next_below(64)) * 8192;
            let runs = m.device_runs(gva, len);
            // At most one run per device, and they tile the range.
            let devs: std::collections::HashSet<_> = runs.iter().map(|r| r.0).collect();
            assert_eq!(devs.len(), runs.len(), "one contiguous run per device");
            assert_eq!(runs.iter().map(|r| r.2).sum::<u64>(), len);
            for (dev, local, rlen) in &runs {
                assert_eq!(local % 8192, 0);
                assert_eq!(rlen % 8192, 0);
                // Every block of the run translates back into the range.
                for b in 0..rlen / 8192 {
                    let gva_back = m.inverse(*dev, local + b * 8192).unwrap();
                    assert!(gva_back >= gva && gva_back < gva + len);
                }
            }
        });
    }

    #[test]
    fn local_base_offsets_pool_region() {
        let m = InterleaveMap::new(vec![DeviceIp::lan(1)], 4096, 1 << 20);
        let (_, local) = m.translate(0);
        assert_eq!(local, 1 << 20);
        assert_eq!(m.inverse(DeviceIp::lan(1), (1 << 20) - 1), None);
    }
}
