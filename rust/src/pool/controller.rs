//! The SDN controller as pool MMU (paper §2.6).
//!
//! "SDN controller could act as a MMU to simply apply malloc/free request
//! and translate request to access-control-list and apply to each NetDAM
//! or in datacenter switch."
//!
//! The controller owns the GVA space: tenants `malloc`/`free` ranges, get
//! back GVAs, and every data-plane access is checked against the ACL
//! (tenant, range, rw) before translation. A first-fit free-list keeps the
//! allocator simple and deterministic.

use std::collections::BTreeMap;
use std::fmt;

use crate::wire::DeviceIp;

use super::interleave::{Extent, InterleaveMap};

pub type TenantId = u32;

#[derive(Debug, PartialEq)]
pub enum AllocError {
    Exhausted { requested: u64, largest: u64 },
    NotOwned(u64),
    Denied { tenant: TenantId, gva: u64, len: u64 },
    Zero,
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::Exhausted { requested, largest } => write!(
                f,
                "pool exhausted: requested {requested} bytes, largest hole {largest}"
            ),
            AllocError::NotOwned(gva) => {
                write!(f, "gva {gva:#x} is not an allocation of this tenant")
            }
            AllocError::Denied { tenant, gva, len } => {
                write!(f, "access [{gva:#x}..+{len}) denied for tenant {tenant}")
            }
            AllocError::Zero => write!(f, "zero-byte allocation"),
        }
    }
}

impl std::error::Error for AllocError {}

#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub gva: u64,
    pub len: u64,
    pub tenant: TenantId,
    pub writable: bool,
}

/// Controller state: allocations + free list over the GVA space.
#[derive(Debug)]
pub struct SdnController {
    map: InterleaveMap,
    capacity: u64,
    /// start → hole length.
    holes: BTreeMap<u64, u64>,
    /// start → allocation.
    allocs: BTreeMap<u64, Allocation>,
    /// Allocation granularity (whole blocks so extents stay aligned).
    granule: u64,
}

impl SdnController {
    pub fn new(map: InterleaveMap, per_device_capacity: u64) -> Self {
        let capacity = map.pool_capacity(per_device_capacity);
        let granule = map.block_bytes();
        let mut holes = BTreeMap::new();
        holes.insert(0, capacity);
        Self {
            map,
            capacity,
            holes,
            allocs: BTreeMap::new(),
            granule,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn allocated_bytes(&self) -> u64 {
        self.allocs.values().map(|a| a.len).sum()
    }

    pub fn map(&self) -> &InterleaveMap {
        &self.map
    }

    /// First-fit malloc, rounded up to the block granule.
    pub fn malloc(
        &mut self,
        tenant: TenantId,
        bytes: u64,
        writable: bool,
    ) -> Result<Allocation, AllocError> {
        if bytes == 0 {
            return Err(AllocError::Zero);
        }
        let len = bytes.div_ceil(self.granule) * self.granule;
        let mut chosen = None;
        let mut largest = 0;
        for (&start, &hole) in &self.holes {
            largest = largest.max(hole);
            if hole >= len {
                chosen = Some((start, hole));
                break;
            }
        }
        let Some((start, hole)) = chosen else {
            return Err(AllocError::Exhausted {
                requested: len,
                largest,
            });
        };
        self.holes.remove(&start);
        if hole > len {
            self.holes.insert(start + len, hole - len);
        }
        let alloc = Allocation {
            gva: start,
            len,
            tenant,
            writable,
        };
        self.allocs.insert(start, alloc.clone());
        Ok(alloc)
    }

    /// Free a previous allocation (must be owned by `tenant`).
    pub fn free(&mut self, tenant: TenantId, gva: u64) -> Result<(), AllocError> {
        match self.allocs.get(&gva) {
            Some(a) if a.tenant == tenant => {}
            _ => return Err(AllocError::NotOwned(gva)),
        }
        let a = self.allocs.remove(&gva).unwrap();
        // Insert hole and coalesce with neighbors.
        let mut start = a.gva;
        let mut len = a.len;
        if let Some((&ps, &pl)) = self.holes.range(..start).next_back() {
            if ps + pl == start {
                self.holes.remove(&ps);
                start = ps;
                len += pl;
            }
        }
        if let Some(&nl) = self.holes.get(&(a.gva + a.len)) {
            self.holes.remove(&(a.gva + a.len));
            len += nl;
        }
        self.holes.insert(start, len);
        Ok(())
    }

    /// ACL check + translation for a data-plane access.
    pub fn access(
        &self,
        tenant: TenantId,
        gva: u64,
        len: u64,
        write: bool,
    ) -> Result<Vec<Extent>, AllocError> {
        let denied = AllocError::Denied { tenant, gva, len };
        let Some((_, a)) = self.allocs.range(..=gva).next_back() else {
            return Err(denied);
        };
        let inside = gva >= a.gva && gva + len <= a.gva + a.len;
        if !inside || a.tenant != tenant || (write && !a.writable) {
            return Err(denied);
        }
        Ok(self.map.scatter(gva, len))
    }

    /// Which device holds the GVA (no ACL; controller-internal use).
    pub fn locate(&self, gva: u64) -> (DeviceIp, u64) {
        self.map.translate(gva)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> SdnController {
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        SdnController::new(map, 1 << 20) // 1 MiB per device → 4 MiB pool
    }

    #[test]
    fn malloc_rounds_to_blocks_and_translates() {
        let mut c = ctl();
        let a = c.malloc(1, 100, true).unwrap();
        assert_eq!(a.len, 8192);
        let ext = c.access(1, a.gva, 100, true).unwrap();
        assert_eq!(ext.len(), 1);
        assert_eq!(ext[0].device, DeviceIp::lan(1));
    }

    #[test]
    fn distinct_allocations_dont_overlap() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        let b = c.malloc(2, 8192, true).unwrap();
        assert!(a.gva + a.len <= b.gva || b.gva + b.len <= a.gva);
    }

    #[test]
    fn acl_denies_foreign_and_readonly() {
        let mut c = ctl();
        let a = c.malloc(1, 16384, false).unwrap();
        // Wrong tenant.
        assert!(matches!(
            c.access(2, a.gva, 8, false),
            Err(AllocError::Denied { .. })
        ));
        // Read-only allocation rejects writes, allows reads.
        assert!(c.access(1, a.gva, 8, false).is_ok());
        assert!(c.access(1, a.gva, 8, true).is_err());
        // Out-of-bounds tail.
        assert!(c.access(1, a.gva + a.len - 4, 8, false).is_err());
    }

    #[test]
    fn free_coalesces_holes() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        let b = c.malloc(1, 8192, true).unwrap();
        let d = c.malloc(1, 8192, true).unwrap();
        // Free middle then neighbors; a full-size alloc must fit again.
        c.free(1, b.gva).unwrap();
        c.free(1, a.gva).unwrap();
        c.free(1, d.gva).unwrap();
        let whole = c.capacity();
        let big = c.malloc(9, whole, true).unwrap();
        assert_eq!(big.len, whole);
    }

    #[test]
    fn exhaustion_reports_largest_hole() {
        let mut c = ctl();
        let cap = c.capacity();
        c.malloc(1, cap, true).unwrap();
        match c.malloc(1, 8192, true) {
            Err(AllocError::Exhausted { largest, .. }) => assert_eq!(largest, 0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn double_free_rejected() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        c.free(1, a.gva).unwrap();
        assert_eq!(c.free(1, a.gva), Err(AllocError::NotOwned(a.gva)));
        // Freeing someone else's allocation rejected too.
        let b = c.malloc(2, 8192, true).unwrap();
        assert_eq!(c.free(1, b.gva), Err(AllocError::NotOwned(b.gva)));
    }

    #[test]
    fn alloc_spreads_over_all_devices() {
        let mut c = ctl();
        let a = c.malloc(1, 8 * 8192, true).unwrap();
        let ext = c.access(1, a.gva, a.len, true).unwrap();
        let devs: std::collections::HashSet<_> = ext.iter().map(|e| e.device).collect();
        assert_eq!(devs.len(), 4, "interleaving uses the whole pool");
    }
}
