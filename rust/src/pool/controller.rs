//! The SDN controller as pool MMU (paper §2.6).
//!
//! "SDN controller could act as a MMU to simply apply malloc/free request
//! and translate request to access-control-list and apply to each NetDAM
//! or in datacenter switch."
//!
//! The controller owns the GVA space: tenants `malloc`/`free` ranges and
//! get back GVAs. A first-fit free-list keeps the allocator simple and
//! deterministic. Control-plane decisions are *applied to the devices*:
//! [`SdnController::malloc_mapped`] translates each new lease into
//! per-device IOMMU programs (map + R/W perms + tenant lease) through an
//! [`IommuDirectory`], and [`SdnController::grant_host`] installs the
//! requester-to-tenant ACL binding on every pool device — so the data
//! plane is enforced by the device IOMMUs (wire-level NAKs), not by
//! in-process checks. [`SdnController::access`] remains as the host-side
//! *planning* translation (the same ACL, evaluated early so clients can
//! compile scatter-gather plans without a round trip).

use std::collections::BTreeMap;
use std::fmt;

use crate::iommu::{Iommu, Perms};
use crate::wire::DeviceIp;

use super::interleave::{Extent, InterleaveMap};

pub use crate::iommu::TenantId;

/// The controller's window onto the fabric's device IOMMUs — implemented
/// by `net::Cluster` (and by test doubles). Keeps `pool` independent of
/// the fabric layer.
pub trait IommuDirectory {
    /// Mutable access to the IOMMU of the device addressed `dev`.
    fn device_iommu(&mut self, dev: DeviceIp) -> Option<&mut Iommu>;
    /// Program the device-side ACL: requests sourced from `host` are
    /// attributed to `tenant` on device `dev`.
    fn bind_tenant(&mut self, dev: DeviceIp, host: DeviceIp, tenant: TenantId);
}

#[derive(Debug, PartialEq)]
pub enum AllocError {
    Exhausted { requested: u64, largest: u64 },
    NotOwned(u64),
    Denied { tenant: TenantId, gva: u64, len: u64 },
    Zero,
    /// A device IOMMU refused the lease mapping (e.g. it already holds
    /// foreign-granule mappings). The allocation was rolled back.
    MapFailed { device: DeviceIp, gva: u64 },
}

impl fmt::Display for AllocError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AllocError::Exhausted { requested, largest } => write!(
                f,
                "pool exhausted: requested {requested} bytes, largest hole {largest}"
            ),
            AllocError::NotOwned(gva) => {
                write!(f, "gva {gva:#x} is not an allocation of this tenant")
            }
            AllocError::Denied { tenant, gva, len } => {
                write!(f, "access [{gva:#x}..+{len}) denied for tenant {tenant}")
            }
            AllocError::Zero => write!(f, "zero-byte allocation"),
            AllocError::MapFailed { device, gva } => write!(
                f,
                "device {device} IOMMU refused the lease at gva {gva:#x} (rolled back)"
            ),
        }
    }
}

impl std::error::Error for AllocError {}

#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    pub gva: u64,
    pub len: u64,
    pub tenant: TenantId,
    pub writable: bool,
}

/// Controller state: allocations + free list over the GVA space.
#[derive(Debug)]
pub struct SdnController {
    map: InterleaveMap,
    capacity: u64,
    /// start → hole length.
    holes: BTreeMap<u64, u64>,
    /// start → allocation.
    allocs: BTreeMap<u64, Allocation>,
    /// Allocation granularity (whole blocks so extents stay aligned).
    granule: u64,
}

impl SdnController {
    pub fn new(map: InterleaveMap, per_device_capacity: u64) -> Self {
        let capacity = map.pool_capacity(per_device_capacity);
        let granule = map.block_bytes();
        let mut holes = BTreeMap::new();
        holes.insert(0, capacity);
        Self {
            map,
            capacity,
            holes,
            allocs: BTreeMap::new(),
            granule,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn allocated_bytes(&self) -> u64 {
        self.allocs.values().map(|a| a.len).sum()
    }

    pub fn map(&self) -> &InterleaveMap {
        &self.map
    }

    /// First-fit malloc, rounded up to the block granule.
    pub fn malloc(
        &mut self,
        tenant: TenantId,
        bytes: u64,
        writable: bool,
    ) -> Result<Allocation, AllocError> {
        if bytes == 0 {
            return Err(AllocError::Zero);
        }
        let len = bytes.div_ceil(self.granule) * self.granule;
        let mut chosen = None;
        let mut largest = 0;
        for (&start, &hole) in &self.holes {
            largest = largest.max(hole);
            if hole >= len {
                chosen = Some((start, hole));
                break;
            }
        }
        let Some((start, hole)) = chosen else {
            // Report what the caller asked for, not the granule-rounded
            // internal length (the rounded number reads as a corruption).
            return Err(AllocError::Exhausted {
                requested: bytes,
                largest,
            });
        };
        self.holes.remove(&start);
        if hole > len {
            self.holes.insert(start + len, hole - len);
        }
        let alloc = Allocation {
            gva: start,
            len,
            tenant,
            writable,
        };
        self.allocs.insert(start, alloc.clone());
        Ok(alloc)
    }

    /// Free a previous allocation (must be owned by `tenant`).
    pub fn free(&mut self, tenant: TenantId, gva: u64) -> Result<(), AllocError> {
        match self.allocs.get(&gva) {
            Some(a) if a.tenant == tenant => {}
            _ => return Err(AllocError::NotOwned(gva)),
        }
        let a = self.allocs.remove(&gva).unwrap();
        // Insert hole and coalesce with neighbors.
        let mut start = a.gva;
        let mut len = a.len;
        if let Some((&ps, &pl)) = self.holes.range(..start).next_back() {
            if ps + pl == start {
                self.holes.remove(&ps);
                start = ps;
                len += pl;
            }
        }
        if let Some(&nl) = self.holes.get(&(a.gva + a.len)) {
            self.holes.remove(&(a.gva + a.len));
            len += nl;
        }
        self.holes.insert(start, len);
        Ok(())
    }

    // --------------------------------------------- device-programmed path

    /// Install the requester ACL for `host` on every pool device: packets
    /// sourced from `host` are attributed to `tenant` when the device
    /// IOMMU checks a lease.
    pub fn grant_host(&self, dir: &mut dyn IommuDirectory, tenant: TenantId, host: DeviceIp) {
        for &dev in self.map.devices() {
            dir.bind_tenant(dev, host, tenant);
        }
    }

    /// Malloc *and program the fabric*: the lease's per-device local runs
    /// are mapped (identity PA, lease perms, tenant-fenced) into each
    /// device's IOMMU, so out-of-lease or permission-violating accesses
    /// fault **on the device** and surface as wire NAKs. If any device
    /// refuses the mapping (e.g. its IOMMU already holds foreign-granule
    /// mappings the controller does not own), the whole operation rolls
    /// back — already-programmed devices are unmapped and the GVA range
    /// is released — and a typed [`AllocError::MapFailed`] is returned.
    pub fn malloc_mapped(
        &mut self,
        dir: &mut dyn IommuDirectory,
        tenant: TenantId,
        bytes: u64,
        writable: bool,
    ) -> Result<Allocation, AllocError> {
        let a = self.malloc(tenant, bytes, writable)?;
        let perms = if writable { Perms::RW } else { Perms::RO };
        let page_bits = self.granule.trailing_zeros();
        let runs = self.map.device_runs(a.gva, a.len);
        for (idx, &(dev, local, len)) in runs.iter().enumerate() {
            let mapped = match dir.device_iommu(dev) {
                Some(mmu) => {
                    if mmu.is_identity() {
                        // First lease on this device: adopt the granule.
                        let _ = mmu.set_page_bits(page_bits);
                    }
                    mmu.page_size() == self.granule
                        && mmu.map_leased(local, local, len, perms, Some(tenant)).is_ok()
                }
                // Device absent from this fabric view: nothing to program.
                None => true,
            };
            if !mapped {
                for &(dev2, local2, len2) in &runs[..idx] {
                    if let Some(mmu) = dir.device_iommu(dev2) {
                        let _ = mmu.unmap(local2, len2);
                    }
                }
                self.free(tenant, a.gva).expect("fresh allocation is owned");
                return Err(AllocError::MapFailed { device: dev, gva: a.gva });
            }
        }
        Ok(a)
    }

    /// Free a lease and unmap it from every device IOMMU it touched.
    /// Unmap failures (a device vanished or was reprogrammed out-of-band)
    /// are tolerated: the GVA range is released either way.
    pub fn free_mapped(
        &mut self,
        dir: &mut dyn IommuDirectory,
        tenant: TenantId,
        gva: u64,
    ) -> Result<(), AllocError> {
        let runs = match self.allocs.get(&gva) {
            Some(a) if a.tenant == tenant => self.map.device_runs(a.gva, a.len),
            _ => return Err(AllocError::NotOwned(gva)),
        };
        self.free(tenant, gva)?;
        for (dev, local, len) in runs {
            if let Some(mmu) = dir.device_iommu(dev) {
                let _ = mmu.unmap(local, len);
            }
        }
        Ok(())
    }

    /// ACL check + translation for a data-plane access (host-side plan
    /// compilation; the device IOMMU re-enforces the same decision).
    pub fn access(
        &self,
        tenant: TenantId,
        gva: u64,
        len: u64,
        write: bool,
    ) -> Result<Vec<Extent>, AllocError> {
        let denied = AllocError::Denied { tenant, gva, len };
        let Some((_, a)) = self.allocs.range(..=gva).next_back() else {
            return Err(denied);
        };
        let inside = gva >= a.gva && gva + len <= a.gva + a.len;
        if !inside || a.tenant != tenant || (write && !a.writable) {
            return Err(denied);
        }
        Ok(self.map.scatter(gva, len))
    }

    /// Which device holds the GVA (no ACL; controller-internal use).
    pub fn locate(&self, gva: u64) -> (DeviceIp, u64) {
        self.map.translate(gva)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn ctl() -> SdnController {
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        SdnController::new(map, 1 << 20) // 1 MiB per device → 4 MiB pool
    }

    /// Test double: an IOMMU per device, no fabric.
    #[derive(Default)]
    struct FakeFabric {
        iommus: HashMap<DeviceIp, Iommu>,
        bindings: Vec<(DeviceIp, DeviceIp, TenantId)>,
    }

    impl IommuDirectory for FakeFabric {
        fn device_iommu(&mut self, dev: DeviceIp) -> Option<&mut Iommu> {
            Some(self.iommus.entry(dev).or_default())
        }
        fn bind_tenant(&mut self, dev: DeviceIp, host: DeviceIp, tenant: TenantId) {
            self.bindings.push((dev, host, tenant));
        }
    }

    #[test]
    fn malloc_rounds_to_blocks_and_translates() {
        let mut c = ctl();
        let a = c.malloc(1, 100, true).unwrap();
        assert_eq!(a.len, 8192);
        let ext = c.access(1, a.gva, 100, true).unwrap();
        assert_eq!(ext.len(), 1);
        assert_eq!(ext[0].device, DeviceIp::lan(1));
    }

    #[test]
    fn distinct_allocations_dont_overlap() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        let b = c.malloc(2, 8192, true).unwrap();
        assert!(a.gva + a.len <= b.gva || b.gva + b.len <= a.gva);
    }

    #[test]
    fn acl_denies_foreign_and_readonly() {
        let mut c = ctl();
        let a = c.malloc(1, 16384, false).unwrap();
        // Wrong tenant.
        assert!(matches!(
            c.access(2, a.gva, 8, false),
            Err(AllocError::Denied { .. })
        ));
        // Read-only allocation rejects writes, allows reads.
        assert!(c.access(1, a.gva, 8, false).is_ok());
        assert!(c.access(1, a.gva, 8, true).is_err());
        // Out-of-bounds tail.
        assert!(c.access(1, a.gva + a.len - 4, 8, false).is_err());
    }

    #[test]
    fn free_coalesces_holes() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        let b = c.malloc(1, 8192, true).unwrap();
        let d = c.malloc(1, 8192, true).unwrap();
        // Free middle then neighbors; a full-size alloc must fit again.
        c.free(1, b.gva).unwrap();
        c.free(1, a.gva).unwrap();
        c.free(1, d.gva).unwrap();
        let whole = c.capacity();
        let big = c.malloc(9, whole, true).unwrap();
        assert_eq!(big.len, whole);
    }

    #[test]
    fn exhaustion_reports_callers_request_and_largest_hole() {
        let mut c = ctl();
        let cap = c.capacity();
        c.malloc(1, cap, true).unwrap();
        match c.malloc(1, 100, true) {
            Err(AllocError::Exhausted { requested, largest }) => {
                assert_eq!(requested, 100, "caller bytes, not granule-rounded");
                assert_eq!(largest, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn double_free_rejected() {
        let mut c = ctl();
        let a = c.malloc(1, 8192, true).unwrap();
        c.free(1, a.gva).unwrap();
        assert_eq!(c.free(1, a.gva), Err(AllocError::NotOwned(a.gva)));
        // Freeing someone else's allocation rejected too.
        let b = c.malloc(2, 8192, true).unwrap();
        assert_eq!(c.free(1, b.gva), Err(AllocError::NotOwned(b.gva)));
    }

    #[test]
    fn alloc_spreads_over_all_devices() {
        let mut c = ctl();
        let a = c.malloc(1, 8 * 8192, true).unwrap();
        let ext = c.access(1, a.gva, a.len, true).unwrap();
        let devs: std::collections::HashSet<_> = ext.iter().map(|e| e.device).collect();
        assert_eq!(devs.len(), 4, "interleaving uses the whole pool");
    }

    #[test]
    fn malloc_mapped_programs_every_touched_device() {
        let mut c = ctl();
        let mut fab = FakeFabric::default();
        let a = c.malloc_mapped(&mut fab, 5, 8 * 8192, true).unwrap();
        assert_eq!(fab.iommus.len(), 4);
        for (dev, local, len) in c.map().device_runs(a.gva, a.len) {
            let mmu = fab.iommus.get(&dev).unwrap();
            assert_eq!(mmu.page_size(), 8192, "pool granule adopted");
            use crate::iommu::Access;
            // The lease translates for its tenant, identity-mapped...
            assert_eq!(
                mmu.translate_req(local, len as usize, Access::Write, Some(5)),
                Ok(local)
            );
            // ...and fences everyone else.
            assert!(mmu.translate_req(local, 8, Access::Read, Some(6)).is_err());
        }
        // Free unmaps: the old lease faults afterwards.
        c.free_mapped(&mut fab, 5, a.gva).unwrap();
        for (dev, local, _) in c.map().device_runs(a.gva, a.len) {
            let mmu = fab.iommus.get(&dev).unwrap();
            use crate::iommu::Access;
            assert!(mmu.is_identity() || mmu.translate_req(local, 8, Access::Read, Some(5)).is_err());
        }
    }

    #[test]
    fn readonly_lease_maps_ro_pages() {
        let mut c = ctl();
        let mut fab = FakeFabric::default();
        let a = c.malloc_mapped(&mut fab, 3, 8192, false).unwrap();
        let (dev, local) = c.locate(a.gva);
        let mmu = fab.iommus.get(&dev).unwrap();
        use crate::iommu::Access;
        assert!(mmu.translate_req(local, 8, Access::Read, Some(3)).is_ok());
        assert!(mmu.translate_req(local, 8, Access::Write, Some(3)).is_err());
    }

    #[test]
    fn foreign_granule_iommu_fails_typed_and_rolls_back() {
        let mut c = ctl();
        let mut fab = FakeFabric::default();
        // Device 2's IOMMU already holds a user mapping at the default
        // 2 MiB granule — the controller does not own it.
        use crate::iommu::IOMMU_PAGE_SIZE;
        fab.iommus
            .entry(DeviceIp::lan(2))
            .or_default()
            .map(0, 0, IOMMU_PAGE_SIZE, crate::iommu::Perms::RW)
            .unwrap();
        let err = c.malloc_mapped(&mut fab, 1, 8 * 8192, true).unwrap_err();
        assert!(
            matches!(err, AllocError::MapFailed { device, .. } if device == DeviceIp::lan(2)),
            "{err:?}"
        );
        // Rolled back: no bytes held, device 1's trial mapping undone,
        // and the full pool is allocatable again once dev 2 is excluded.
        assert_eq!(c.allocated_bytes(), 0);
        use crate::iommu::Access;
        assert!(fab
            .iommus
            .get(&DeviceIp::lan(1))
            .unwrap()
            .translate_req(0, 8, Access::Read, Some(1))
            .is_err());
    }

    #[test]
    fn grant_host_binds_on_every_device() {
        let c = ctl();
        let mut fab = FakeFabric::default();
        c.grant_host(&mut fab, 9, DeviceIp::lan(101));
        assert_eq!(fab.bindings.len(), 4);
        assert!(fab
            .bindings
            .iter()
            .all(|&(_, host, t)| host == DeviceIp::lan(101) && t == 9));
    }
}
