//! Transport-layer mechanisms (paper §2.3).
//!
//! NetDAM's transport choices are deliberately *à la carte*:
//!
//! * **Reliable transmit is optional** — idempotent operators simply
//!   retransmit on timeout ([`reliability::ReliabilityTable`]); there is
//!   no go-back-N and no lossless-Ethernet/PFC requirement.
//! * **Relaxed ordering by default** — commutative SIMD ops execute
//!   out-of-order; an optional receive-side [`reorder::ReorderBuffer`]
//!   restores sequence order for flows that set `Flags::ORDERED`.
//! * **Rate-limited READ pull** ([`rate::TokenBucket`]) — the receiver
//!   paces its own reads from the block-interleaved pool, which is how
//!   the paper dissolves incast without a congestion-control protocol
//!   (§2.5, experiment E3).

pub mod rate;
pub mod reliability;
pub mod reorder;

pub use rate::TokenBucket;
pub use reliability::{PendingKey, ReliabilityTable, RetryVerdict};
pub use reorder::ReorderBuffer;
