//! Transport-layer mechanisms (paper §2.3).
//!
//! NetDAM's transport choices are deliberately *à la carte*:
//!
//! * **Reliable transmit is optional** — idempotent operators simply
//!   retransmit on timeout ([`reliability::ReliabilityTable`]); there is
//!   no go-back-N and no lossless-Ethernet/PFC requirement.
//! * **Relaxed ordering by default** — commutative SIMD ops execute
//!   out-of-order; an optional receive-side [`reorder::ReorderBuffer`]
//!   restores sequence order for flows that set `Flags::ORDERED`.
//! * **Rate-limited READ pull** ([`rate::TokenBucket`]) — the receiver
//!   paces its own reads from the block-interleaved pool, which is how
//!   the paper dissolves incast without a congestion-control protocol
//!   (§2.5, experiment E3).
//! * **One windowed engine** ([`engine::WindowEngine`]) — the shared
//!   reliable-injection/completion-refill state machine under both the
//!   collective driver and the pooled-memory client: per-slot
//!   self-clocked windows, completion keying generic over done-id vs
//!   sequence, NAK surfacing with per-plan cancellation, and token-bucket
//!   paced refill (global or per-slot). Its multi-plan front
//!   ([`engine::EngineSession`]) lets concurrent tenants — communicator
//!   collectives and pooled-memory batches from one fabric — multiplex
//!   onto a single completion hook (see [`crate::comm`]).
//! * **Closed-loop DCQCN** ([`engine::CcMode::Dcqcn`]) — when static
//!   budgets aren't enough (mixed tenants, unknown fan-in), each window
//!   slot gets a [`crate::roce::RateController`] actuating its bucket via
//!   [`rate::TokenBucket::set_rate`]: CE-marked completions act as CNPs
//!   (multiplicative cut + α-EWMA), fast recovery and additive probing
//!   restore the rate between marks.

pub mod engine;
pub mod rate;
pub mod reliability;
pub mod reorder;

pub use engine::{
    CcMode, CompletionKey, EngineSession, NakRecord, PlanId, PlanOutcome, Retired, WindowEngine,
    WindowOutcome, WindowedOp,
};
pub use rate::TokenBucket;
pub use reliability::{PendingKey, ReliabilityTable, RetryVerdict};
pub use reorder::ReorderBuffer;
