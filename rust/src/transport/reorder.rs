//! Optional receive-side reorder buffer.
//!
//! "we provide sequence field in the packet, user could add optional
//! reorder module in programming logic for ordering execution" (§2.3).
//! Flows that set `Flags::ORDERED` are buffered per (src → dst) pair and
//! released strictly in sequence. Flows start at sequence 1 by convention
//! (asserted by the injection helpers).

use std::collections::{BTreeMap, HashMap};

use crate::wire::{DeviceIp, Packet};

/// Per-flow state.
#[derive(Debug)]
struct FlowBuf {
    next: u64,
    held: BTreeMap<u64, Packet>,
}

#[derive(Debug, Default)]
pub struct ReorderBuffer {
    flows: HashMap<DeviceIp, FlowBuf>,
    /// Duplicates of already-released sequences, dropped.
    pub dup_drops: u64,
    /// High-water mark of held packets across all flows.
    pub max_held: usize,
}

impl ReorderBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Offer a packet from `src`; returns every packet now releasable in
    /// order (possibly empty if there is a gap).
    pub fn offer(&mut self, src: DeviceIp, pkt: Packet) -> Vec<Packet> {
        let flow = self.flows.entry(src).or_insert(FlowBuf {
            next: 1,
            held: BTreeMap::new(),
        });
        if pkt.seq < flow.next || flow.held.contains_key(&pkt.seq) {
            self.dup_drops += 1;
            return Vec::new();
        }
        flow.held.insert(pkt.seq, pkt);
        let mut out = Vec::new();
        while let Some(p) = flow.held.remove(&flow.next) {
            flow.next += 1;
            out.push(p);
        }
        let held: usize = self.flows.values().map(|f| f.held.len()).sum();
        self.max_held = self.max_held.max(held);
        out
    }

    /// Packets currently parked waiting for a gap to fill.
    pub fn held(&self) -> usize {
        self.flows.values().map(|f| f.held.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;
    use crate::wire::SrouHeader;

    fn pkt(seq: u64) -> Packet {
        Packet::new(
            DeviceIp::lan(1),
            seq,
            SrouHeader::direct(DeviceIp::lan(2)),
            Instruction::Nop,
        )
    }

    fn seqs(v: &[Packet]) -> Vec<u64> {
        v.iter().map(|p| p.seq).collect()
    }

    #[test]
    fn in_order_passes_through() {
        let mut rb = ReorderBuffer::new();
        assert_eq!(seqs(&rb.offer(DeviceIp::lan(1), pkt(1))), vec![1]);
        assert_eq!(seqs(&rb.offer(DeviceIp::lan(1), pkt(2))), vec![2]);
    }

    #[test]
    fn gap_holds_then_releases_in_order() {
        let mut rb = ReorderBuffer::new();
        assert!(rb.offer(DeviceIp::lan(1), pkt(3)).is_empty());
        assert!(rb.offer(DeviceIp::lan(1), pkt(2)).is_empty());
        assert_eq!(rb.held(), 2);
        assert_eq!(seqs(&rb.offer(DeviceIp::lan(1), pkt(1))), vec![1, 2, 3]);
        assert_eq!(rb.held(), 0);
    }

    #[test]
    fn duplicates_dropped() {
        let mut rb = ReorderBuffer::new();
        rb.offer(DeviceIp::lan(1), pkt(1));
        assert!(rb.offer(DeviceIp::lan(1), pkt(1)).is_empty());
        rb.offer(DeviceIp::lan(1), pkt(3));
        assert!(rb.offer(DeviceIp::lan(1), pkt(3)).is_empty());
        assert_eq!(rb.dup_drops, 2);
    }

    #[test]
    fn flows_are_independent() {
        let mut rb = ReorderBuffer::new();
        assert!(rb.offer(DeviceIp::lan(1), pkt(2)).is_empty());
        // Same seq from another src is its own flow.
        assert_eq!(seqs(&rb.offer(DeviceIp::lan(9), pkt(1))), vec![1]);
    }
}
