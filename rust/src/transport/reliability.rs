//! Timeout-retransmit reliability for idempotent operations.
//!
//! The table tracks outstanding request packets keyed by (origin node,
//! sequence). A completion delivered back to the origin clears the entry;
//! a timer firing on a still-pending entry yields the packet to re-send.
//! Because every retried instruction is idempotent (plain WRITE, READ,
//! hash-guarded WRITE, interim reduce-scatter), duplicates are harmless —
//! that is the paper's §3.1 argument, and test E5 injects loss to prove it.

use std::collections::HashMap;

use crate::net::NodeId;
use crate::sim::{SimTime, TimerId};
use crate::wire::Packet;

/// (origin node, sequence number).
pub type PendingKey = (NodeId, u64);

#[derive(Debug)]
struct Pending {
    pkt: Packet,
    retries: u32,
    /// Epoch guard: timers from before the latest (re)send are stale.
    /// Still used by the sharded core, where retransmit timers live on the
    /// shard heap and cannot be cancelled.
    epoch: u32,
    /// Timer-wheel slot of the live retransmit timer (classic engine path).
    /// A completion hands it back so the caller can cancel in O(1) instead
    /// of leaving a tombstone to skip.
    timer: Option<TimerId>,
}

/// What to do when a retransmit timer fires.
#[derive(Debug, PartialEq)]
pub enum RetryVerdict {
    /// Already acked (or stale timer) — nothing to do.
    Done,
    /// Re-send this packet and re-arm the timer.
    Resend(Packet),
    /// Gave up after max retries.
    Failed,
}

#[derive(Debug)]
pub struct ReliabilityTable {
    pending: HashMap<PendingKey, Pending>,
    pub timeout_ns: SimTime,
    pub max_retries: u32,
    // --- counters ---
    pub retransmits: u64,
    pub failures: u64,
    pub completed: u64,
}

impl ReliabilityTable {
    pub fn new(timeout_ns: SimTime, max_retries: u32) -> Self {
        Self {
            pending: HashMap::new(),
            timeout_ns,
            max_retries,
            retransmits: 0,
            failures: 0,
            completed: 0,
        }
    }

    /// Track an injected packet. Returns the epoch to stamp on the timer.
    /// The packet's heavy parts (payload, program, agg meta) are Arc-shared,
    /// so keeping a copy here costs a header memcpy, not a deep clone.
    pub fn track(&mut self, origin: NodeId, pkt: Packet) -> u32 {
        let key = (origin, pkt.seq);
        let e = self.pending.entry(key).or_insert(Pending {
            pkt,
            retries: 0,
            epoch: 0,
            timer: None,
        });
        e.epoch
    }

    /// Record the live retransmit timer for a pending entry (classic path).
    pub fn set_timer(&mut self, origin: NodeId, seq: u64, id: TimerId) {
        if let Some(p) = self.pending.get_mut(&(origin, seq)) {
            p.timer = Some(id);
        }
    }

    /// A completion with `seq` arrived at `origin`. On a hit, returns the
    /// pending retransmit timer (if one was registered) so the caller can
    /// cancel it; a miss (duplicate completion) returns `None`.
    pub fn complete(&mut self, origin: NodeId, seq: u64) -> Option<TimerId> {
        match self.pending.remove(&(origin, seq)) {
            Some(p) => {
                self.completed += 1;
                p.timer
            }
            None => None,
        }
    }

    /// Did a completion for (origin, seq) already land?
    pub fn is_pending(&self, origin: NodeId, seq: u64) -> bool {
        self.pending.contains_key(&(origin, seq))
    }

    /// Retransmit timer for (origin, seq) at `epoch` fired.
    pub fn on_timeout(&mut self, origin: NodeId, seq: u64, epoch: u32) -> RetryVerdict {
        let key = (origin, seq);
        let Some(p) = self.pending.get_mut(&key) else {
            return RetryVerdict::Done;
        };
        if p.epoch != epoch {
            return RetryVerdict::Done; // stale timer from an older send
        }
        if p.retries >= self.max_retries {
            self.pending.remove(&key);
            self.failures += 1;
            return RetryVerdict::Failed;
        }
        p.retries += 1;
        p.epoch += 1;
        p.timer = None; // the timer that just fired is spent
        self.retransmits += 1;
        RetryVerdict::Resend(p.pkt.clone())
    }

    /// Epoch of a pending entry (for arming the follow-up timer).
    pub fn epoch(&self, origin: NodeId, seq: u64) -> Option<u32> {
        self.pending.get(&(origin, seq)).map(|p| p.epoch)
    }

    pub fn outstanding(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;
    use crate::wire::{DeviceIp, SrouHeader};

    fn pkt(seq: u64) -> Packet {
        Packet::new(
            DeviceIp::lan(1),
            seq,
            SrouHeader::direct(DeviceIp::lan(2)),
            Instruction::Write { addr: 0 },
        )
    }

    #[test]
    fn ack_before_timeout_completes() {
        let mut t = ReliabilityTable::new(1000, 3);
        let epoch = t.track(0, pkt(7));
        assert!(t.is_pending(0, 7));
        t.complete(0, 7);
        assert_eq!(t.on_timeout(0, 7, epoch), RetryVerdict::Done);
        assert_eq!(t.completed, 1);
        assert_eq!(t.outstanding(), 0);
    }

    #[test]
    fn timeout_resends_until_max() {
        let mut t = ReliabilityTable::new(1000, 2);
        let mut epoch = t.track(0, pkt(9));
        for _ in 0..2 {
            match t.on_timeout(0, 9, epoch) {
                RetryVerdict::Resend(p) => assert_eq!(p.seq, 9),
                v => panic!("expected resend, got {v:?}"),
            }
            epoch = t.epoch(0, 9).unwrap();
        }
        assert_eq!(t.on_timeout(0, 9, epoch), RetryVerdict::Failed);
        assert_eq!(t.retransmits, 2);
        assert_eq!(t.failures, 1);
    }

    #[test]
    fn stale_timer_is_ignored() {
        let mut t = ReliabilityTable::new(1000, 5);
        let epoch = t.track(0, pkt(3));
        // First timeout bumps the epoch...
        assert!(matches!(t.on_timeout(0, 3, epoch), RetryVerdict::Resend(_)));
        // ...so the original timer firing again is stale.
        assert_eq!(t.on_timeout(0, 3, epoch), RetryVerdict::Done);
        assert_eq!(t.retransmits, 1);
    }

    #[test]
    fn same_seq_different_origin_is_distinct() {
        let mut t = ReliabilityTable::new(1000, 3);
        t.track(0, pkt(5));
        t.track(1, pkt(5));
        t.complete(0, 5);
        assert_eq!(t.completed, 1);
        assert_eq!(t.outstanding(), 1);
        t.complete(0, 5);
        assert_eq!(t.completed, 1, "double complete is a no-op");
    }

    #[test]
    fn completion_hands_back_the_registered_timer() {
        use crate::sim::TimerWheel;
        // Mint a real TimerId from a wheel so the handshake is end-to-end.
        let mut wheel: TimerWheel<u32> = TimerWheel::new();
        let id = wheel.arm(5_000, 0, 42);

        let mut t = ReliabilityTable::new(1000, 3);
        t.track(0, pkt(11));
        t.set_timer(0, 11, id);
        let got = t.complete(0, 11);
        assert_eq!(got, Some(id));
        assert!(wheel.cancel(got.unwrap()), "timer cancels exactly once");

        // A resend consumes the stored timer: nothing left to cancel.
        let e = t.track(0, pkt(12));
        t.set_timer(0, 12, wheel.arm(6_000, 1, 43));
        assert!(matches!(t.on_timeout(0, 12, e), RetryVerdict::Resend(_)));
        assert_eq!(t.complete(0, 12), None);
    }
}
