//! Token-bucket pacing for receiver-driven READ pull (§2.5).
//!
//! "the receiving host could pull them back from global memory pool based
//! sequencing and rate-limited READ command, the incast problem can be
//! easily avoid without complex congestion control mechanism."
//!
//! The bucket is expressed in bytes so the puller can pace to a fraction
//! of its line rate regardless of packet size mix.

use crate::sim::SimTime;

#[derive(Debug, Clone)]
pub struct TokenBucket {
    /// Fill rate in bytes per ns.
    rate: f64,
    /// Burst capacity in bytes.
    burst: f64,
    tokens: f64,
    last_ns: SimTime,
}

impl TokenBucket {
    /// `gbps` fill rate with `burst` bytes of depth.
    pub fn new(gbps: f64, burst: usize) -> Self {
        Self {
            rate: gbps / 8.0,
            burst: burst as f64,
            tokens: burst as f64,
            last_ns: 0,
        }
    }

    fn refill(&mut self, now: SimTime) {
        let dt = now.saturating_sub(self.last_ns);
        self.tokens = (self.tokens + dt as f64 * self.rate).min(self.burst);
        self.last_ns = now;
    }

    /// Try to spend `bytes` at `now`. On failure returns the time at which
    /// the bucket will have enough tokens (callers re-arm a timer there).
    pub fn try_take(&mut self, now: SimTime, bytes: usize) -> Result<(), SimTime> {
        self.refill(now);
        let need = bytes as f64;
        if self.tokens >= need {
            self.tokens -= need;
            Ok(())
        } else {
            let wait = ((need - self.tokens) / self.rate).ceil() as SimTime;
            Err(now + wait.max(1))
        }
    }

    /// Unconditionally debit `bytes` at `now` — the balance may go
    /// negative — and return the earliest time the paced send may be
    /// released. Back-to-back reservations serialize at exactly the fill
    /// rate, which is what the window engine's paced refill needs: it
    /// commits to the injection when a completion frees the slot and
    /// defers the wire release to the bucket's schedule.
    pub fn reserve(&mut self, now: SimTime, bytes: usize) -> SimTime {
        debug_assert!(self.rate > 0.0, "reserve on a zero-rate bucket");
        self.refill(now);
        self.tokens -= bytes as f64;
        if self.tokens >= 0.0 {
            now
        } else {
            now + ((-self.tokens) / self.rate).ceil() as SimTime
        }
    }

    /// Retarget the fill rate at `now`, settling the accrual under the old
    /// rate first so the release envelope stays `burst + ∫rate(t)dt` —
    /// tokens earned before the change are earned at the old rate, tokens
    /// after at the new one. This is the actuator half of DCQCN: the
    /// [`crate::roce::RateController`] decides the rate, `set_rate` makes
    /// the bucket enforce it.
    pub fn set_rate(&mut self, now: SimTime, gbps: f64) {
        self.refill(now);
        self.rate = (gbps / 8.0).max(f64::MIN_POSITIVE);
    }

    /// Current fill rate in Gbit/s.
    pub fn rate_gbps(&self) -> f64 {
        self.rate * 8.0
    }

    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_then_paced() {
        // 80 Gbps = 10 B/ns, burst 9000.
        let mut tb = TokenBucket::new(80.0, 9000);
        assert!(tb.try_take(0, 9000).is_ok());
        // Immediately again: need 9000 bytes = 900ns of refill.
        match tb.try_take(0, 9000) {
            Err(at) => assert_eq!(at, 900),
            Ok(()) => panic!("should have paced"),
        }
        assert!(tb.try_take(900, 9000).is_ok());
    }

    #[test]
    fn reserve_serializes_at_the_fill_rate() {
        // 80 Gbps = 10 B/ns, burst 9000.
        let mut tb = TokenBucket::new(80.0, 9000);
        assert_eq!(tb.reserve(0, 9000), 0, "burst releases immediately");
        // Debt: each further 9000 B releases 900 ns after the previous.
        assert_eq!(tb.reserve(0, 9000), 900);
        assert_eq!(tb.reserve(0, 9000), 1800);
        // Refill repays debt before new reservations.
        assert_eq!(tb.reserve(2700, 9000), 2700);
    }

    #[test]
    fn tokens_cap_at_burst() {
        let mut tb = TokenBucket::new(80.0, 1000);
        assert!(tb.try_take(1_000_000, 1000).is_ok());
        assert!(tb.try_take(1_000_001, 1000).is_err(), "no over-accumulation");
    }

    #[test]
    fn set_rate_settles_old_accrual_first() {
        // 8 Gbps = 1 B/ns, burst 1000, drained at t=0.
        let mut tb = TokenBucket::new(8.0, 1000);
        assert!(tb.try_take(0, 1000).is_ok());
        // 500 ns at 1 B/ns banks 500 tokens, then drop to 0.8 Gbps.
        tb.set_rate(500, 0.8);
        assert!((tb.tokens() - 500.0).abs() < 1e-9, "old-rate accrual kept");
        assert!(tb.try_take(500, 500).is_ok());
        // From here refill runs at 0.1 B/ns: 400 B needs 4000 ns.
        match tb.try_take(500, 400) {
            Err(at) => assert_eq!(at, 4500),
            Ok(()) => panic!("should pace at the new rate"),
        }
        assert_eq!(tb.rate_gbps(), 0.8);
    }

    #[test]
    fn steady_state_matches_rate() {
        let mut tb = TokenBucket::new(8.0, 1500); // 1 B/ns
        let mut now = 0;
        let mut sent = 0usize;
        while now < 1_000_000 {
            match tb.try_take(now, 1500) {
                Ok(()) => sent += 1500,
                Err(at) => now = at,
            }
        }
        let rate = sent as f64 / 1_000_000.0;
        assert!((rate - 1.0).abs() < 0.01, "achieved {rate} B/ns");
    }
}
