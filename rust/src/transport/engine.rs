//! The windowed transport engine — one reliable-injection/completion-
//! refill state machine for *every* host-side data path.
//!
//! Before this module existed, `collectives::Driver::run` and
//! `mem::MemClient::run_plan` each owned a copy of the same loop:
//! per-peer FIFO queues, a self-clocked in-flight window, reliable
//! injection, an `on_completion` hook that retires one op and refills
//! the window, and (on the mem side) NAK surfacing. The paper's core
//! claim is that one programmable memory-attached datapath serves both
//! collectives (§3) and pooled-memory access (§2.5/§2.6) — so the host
//! side gets one transport engine too.
//!
//! Since the session API landed (`netdam::comm`), the engine has two
//! fronts:
//!
//! * [`EngineSession`] — the long-lived, multi-tenant front. Plans
//!   (batches of [`WindowedOp`]s) are **submitted incrementally** and
//!   multiplex onto one completion hook: concurrent collectives from
//!   several communicators and pooled-memory plans from the same fabric
//!   are all in flight together, each windowed on its own slots.
//!   Per-plan outcomes ([`PlanOutcome`]) are redeemed by [`PlanId`].
//! * [`WindowEngine`] — the classic single-plan front: `run` opens a
//!   session, submits one plan, drives the DES until quiet, and tears
//!   the session down. All pre-session callers (the collective driver,
//!   standalone `MemBatch::run`) still use this.
//!
//! Shared semantics, regardless of front:
//!
//! * **Windowing** — ops are queued per *slot* (a collective rank, a
//!   pool device — whatever the caller windows over) and at most
//!   `window` ops per slot are in flight; each retirement refills from
//!   that slot's queue (self-clocking). Sessions give every plan its
//!   own slots, so one tenant's window never starves another's.
//! * **Completion keying** — generic over the two flavors in the tree:
//!   [`CompletionKey::DoneId`] matches a `CollectiveDone { block }`
//!   (collective chains retire at the far end of a multi-hop program),
//!   [`CompletionKey::Seq`] matches any response echoing the request's
//!   sequence number at the op's origin (RDMA-PSN-style request/response
//!   correlation). Duplicate completions (retransmitted chains re-emit
//!   their Done) are counted and ignored: every op retires exactly once.
//! * **Reliability** — reliable ops are injected through the cluster's
//!   timeout-retransmit table; the retirement path clears the pending
//!   entry (via `note_completion`), so a drained run leaves no dangling
//!   timers.
//! * **NAK surfacing + cancel** — a wire `Nack` matching an in-flight op
//!   records the typed denial and cancels *that plan's* remaining queue:
//!   no further ops of the NAK'd plan are injected, its in-flight ops
//!   drain normally, and every other plan keeps running untouched (a bad
//!   lease in one job must not take the fabric down for its neighbors).
//! * **Paced refill** — with [`WindowEngine::paced`] /
//!   [`EngineSession::paced`], every injection first reserves the op's
//!   `pace_bytes` from a [`TokenBucket`] and is released only when the
//!   bucket allows (the §2.5 "sequencing and rate-limited READ" incast
//!   cure). `paced_per_slot` gives each slot its **own** bucket cloned
//!   from the template — per-destination pacing for communicator
//!   fan-out, where one global bucket would serialize independent
//!   destinations. Pacing composes with windowing: injection time is
//!   the later of the completion that freed the slot and the bucket
//!   release.
//!
//! The session installs the cluster's completion hook for its lifetime
//! and removes it on [`EngineSession::close`]; `WindowEngine::run` does
//! both internally — callers never touch `Cluster::on_completion`
//! themselves.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::isa::Instruction;
use crate::net::{Cluster, CompletionRecord, InjectCmd, NodeId};
use crate::roce::dcqcn::{DcqcnConfig, RateController};
use crate::sim::{Engine, SimTime};
use crate::wire::{DeviceIp, Packet};

use super::rate::TokenBucket;

/// Upper bound on window slots (sanity guard against caller bugs).
const MAX_SLOTS: usize = 65_536;

/// Congestion-control mode for a session or fabric — the public switch
/// behind [`EngineSession::with_congestion_control`] and
/// `FabricBuilder::with_congestion_control`.
#[derive(Debug, Clone, Default)]
pub enum CcMode {
    /// Keep whatever static pacing (or none) the caller configured.
    #[default]
    Static,
    /// Closed-loop DCQCN: each window slot gets its own
    /// [`RateController`] actuating a [`TokenBucket`]; CE-marked
    /// completions arriving at the origin act as CNPs for the owning
    /// slot (multiplicative cut + α-EWMA), and the paced-refill decision
    /// reads the controller's *current* rate.
    Dcqcn(DcqcnConfig),
}

impl CcMode {
    /// Parse a CLI-style mode name (`dcqcn` | `static`).
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "dcqcn" => Ok(CcMode::Dcqcn(DcqcnConfig::default())),
            "static" => Ok(CcMode::Static),
            other => anyhow::bail!("unknown cc mode {other:?} (want dcqcn|static)"),
        }
    }
}

/// How one op recognises its completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompletionKey {
    /// A `CollectiveDone { block }` carrying this id (collective chains
    /// retire wherever the packet program's last hop runs).
    DoneId(u32),
    /// Any response echoing this sequence number at the op's origin
    /// (reads, write acks, CAS responses, NAKs).
    Seq(u64),
}

/// One windowed op: a packet plus how to window and retire it.
pub struct WindowedOp {
    /// Window slot (a rank, a device index — the caller's peer notion).
    /// Slots are plan-local: two plans submitted into one session may
    /// both use slot 0 and still get independent windows.
    pub slot: usize,
    /// Node that injects the packet and receives its completion.
    pub origin: NodeId,
    pub key: CompletionKey,
    /// Caller cookie carried through to [`Retired`] / [`NakRecord`]
    /// (e.g. the GVA a mem op targets).
    pub tag: u64,
    pub reliable: bool,
    /// Bytes this op charges the pacer — the data it *moves* (a READ's
    /// response payload, a WRITE's wire bytes), not necessarily its
    /// request size. Ignored when the engine is unpaced.
    pub pace_bytes: usize,
    pub pkt: Packet,
}

/// A retired op's completion, recorded when response recording is on.
#[derive(Debug, Clone)]
pub struct Retired {
    pub key: CompletionKey,
    pub tag: u64,
    pub instr: Instruction,
    pub time: SimTime,
}

/// The first wire NAK matched to an in-flight op of one plan.
#[derive(Debug, Clone, Copy)]
pub struct NakRecord {
    /// Device that denied the access.
    pub from: DeviceIp,
    /// The NAK'd op's caller cookie.
    pub tag: u64,
    /// Typed reason byte (see [`crate::iommu::NakReason`]).
    pub reason: u8,
    pub key: CompletionKey,
}

/// What one engine run produced (the single-plan [`WindowEngine`] view).
#[derive(Debug)]
pub struct WindowOutcome {
    /// Ops submitted.
    pub ops: usize,
    /// Ops retired (each exactly once). `< ops` means unrecovered loss
    /// or a NAK cancellation — callers decide whether that is an error.
    pub done: usize,
    /// Time of the last retirement (run start time if nothing retired).
    pub last_done: SimTime,
    pub nak: Option<NakRecord>,
    /// Queued ops dropped by NAK cancellation (never injected).
    pub cancelled: usize,
    /// Max ops simultaneously in flight on any one slot (≤ window).
    pub max_inflight: usize,
    /// Completions that matched an already-retired key (retransmit
    /// echoes) — ignored, counted for diagnostics.
    pub duplicate_completions: usize,
    /// Paced release log `(release_time, pace_bytes)`, empty when
    /// unpaced. With a global bucket, cumulative bytes released by time
    /// `t` never exceed `burst + rate·t`; with per-slot buckets the
    /// bound holds per slot (see [`WindowOutcome::releases_per_slot`]).
    pub releases: Vec<(SimTime, usize)>,
    /// Like `releases`, but tagged with the releasing slot.
    pub releases_per_slot: Vec<(usize, SimTime, usize)>,
    /// Retired completions (only when [`WindowEngine::record_responses()`]
    /// is on; `CollectiveDone` floods would be noise for collectives).
    pub responses: Vec<Retired>,
    /// Per-op completion latency (wire release → retirement, ns).
    pub latencies: Vec<SimTime>,
}

/// Handle to one plan submitted into an [`EngineSession`].
///
/// Generational: [`EngineSession::release`] recycles the plan's slab slot
/// and bumps its generation, so a stale id held after release is detected
/// instead of silently reading a successor plan's state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanId {
    idx: usize,
    gen: u32,
}

/// Per-plan outcome, redeemed from a session by [`PlanId`].
#[derive(Debug)]
pub struct PlanOutcome {
    /// Ops this plan submitted.
    pub ops: usize,
    /// Ops retired exactly once.
    pub done: usize,
    /// Simulated time the plan was submitted.
    pub submitted_at: SimTime,
    /// Time of the plan's last retirement (submit time if none).
    pub last_done: SimTime,
    pub nak: Option<NakRecord>,
    /// Queued ops of *this plan* dropped by its NAK cancellation.
    pub cancelled: usize,
    /// Retired completions, when the plan was submitted recording.
    pub responses: Vec<Retired>,
    /// Per-op completion latency (wire release → retirement, ns) — the
    /// p50/p99 latency-under-load lens. Moves out with the outcome.
    pub latencies: Vec<SimTime>,
}

impl PlanOutcome {
    /// Every op retired (no loss, no cancellation).
    pub fn complete(&self) -> bool {
        self.done == self.ops
    }
}

/// Internal completion key: seq matches are scoped to the origin node so
/// independent origins may reuse sequence numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Key {
    Done(u32),
    Seq(NodeId, u64),
}

struct QueuedOp {
    key: Key,
    pub_key: CompletionKey,
    plan: usize,
    tag: u64,
    origin: NodeId,
    reliable: bool,
    pace_bytes: usize,
    pkt: Packet,
}

struct InflightOp {
    slot: usize,
    plan: usize,
    tag: u64,
    pub_key: CompletionKey,
    /// Wire-release time (injection commit plus any pacing delay) — the
    /// zero point for this op's completion latency.
    issued_at: SimTime,
}

/// Per-slot DCQCN state: the controller decides the rate, the bucket
/// enforces it on the paced-refill path.
struct SlotCc {
    ctl: RateController,
    bucket: TokenBucket,
}

impl SlotCc {
    fn new(cfg: &DcqcnConfig) -> Self {
        Self {
            ctl: RateController::new(cfg.clone()),
            bucket: TokenBucket::new(cfg.line_gbps, cfg.burst_bytes),
        }
    }
}

/// Per-plan bookkeeping inside the session state.
struct PlanState {
    ops: usize,
    done: usize,
    inflight: usize,
    /// Session slots this plan owns (returned to the free list once the
    /// plan settles).
    slots: Vec<usize>,
    /// This plan's completion keys (pruned from the session sets at
    /// reclaim time so a long-lived session doesn't grow forever).
    keys: Vec<Key>,
    reclaimed: bool,
    submitted_at: SimTime,
    last_done: SimTime,
    nak: Option<NakRecord>,
    cancelled: usize,
    record_responses: bool,
    responses: Vec<Retired>,
    /// Per-op completion latency (wire release → retirement), the
    /// latency-under-load lens the p50/p99 report columns read.
    latencies: Vec<SimTime>,
    /// Plan-private token bucket (paced submits, e.g. a paced pooled-
    /// memory batch). Overrides the session's [`PaceMode`] for this
    /// plan's injections only.
    pacer: Option<TokenBucket>,
}

/// One slot of the plan slab: the live state (if any) plus a generation
/// counter that invalidates released [`PlanId`]s.
struct PlanSlot {
    gen: u32,
    state: Option<PlanState>,
}

/// How injections are paced.
#[derive(Clone)]
enum PaceMode {
    None,
    /// One bucket paces every slot together (E3's single-receiver cure).
    Global(TokenBucket),
    /// Each slot gets its own bucket cloned from this template —
    /// per-destination pacing for communicator fan-out.
    PerSlot(TokenBucket),
    /// Closed-loop DCQCN: each slot gets its own [`SlotCc`] built from
    /// this config, fed CNPs by CE-marked completions (see
    /// [`CcMode::Dcqcn`]).
    Dcqcn(DcqcnConfig),
}

struct State {
    queues: Vec<VecDeque<QueuedOp>>,
    inflight: HashMap<Key, InflightOp>,
    retired: HashSet<Key>,
    /// Every live key (duplicate-submission guard; pruned per plan at
    /// reclaim time).
    keys: HashSet<Key>,
    inflight_per_slot: Vec<usize>,
    /// Slots whose owning plan settled — reused by later submits so a
    /// long-lived session's slot space stays bounded by its concurrency,
    /// not its history.
    free_slots: Vec<usize>,
    max_inflight: usize,
    duplicates: usize,
    /// Plan slab: released plans leave `None` holes that `free_plans`
    /// recycles, keeping a long-lived session's plan bookkeeping
    /// O(concurrently live plans) instead of O(plans ever submitted).
    plans: Vec<PlanSlot>,
    free_plans: Vec<usize>,
    /// Plans with ≥ 1 op in flight right now / the high-water mark —
    /// the multi-tenant overlap statistic the comm tests assert on.
    active_plans: usize,
    max_concurrent_plans: usize,
    pace: PaceMode,
    slot_pacers: Vec<Option<TokenBucket>>,
    /// Per-slot DCQCN controller + actuator bucket (Dcqcn mode only;
    /// reset with the slot at reclaim time, like `slot_pacers`).
    slot_cc: Vec<Option<SlotCc>>,
    releases: Vec<(usize, SimTime, usize)>,
    /// Rate trajectory under DCQCN: `(slot, time, rate_bits)` appended at
    /// every CNP delivery (`f64::to_bits` of the post-cut rate). Between
    /// entries the rate evolves by the deterministic recovery formula, so
    /// this log *is* the trajectory — the sharded-determinism tests
    /// compare it bit-for-bit across shard counts.
    rate_log: Vec<(usize, SimTime, u64)>,
}

impl State {
    /// Live plan state at slab index `idx` (internal references from
    /// queued/in-flight ops are only created while the plan is live).
    fn plan(&self, idx: usize) -> &PlanState {
        self.plans[idx].state.as_ref().expect("live plan")
    }

    fn plan_mut(&mut self, idx: usize) -> &mut PlanState {
        self.plans[idx].state.as_mut().expect("live plan")
    }

    /// Resolve a public [`PlanId`], panicking on a stale (released) id.
    fn checked(&self, id: PlanId) -> &PlanState {
        let slot = &self.plans[id.idx];
        assert_eq!(slot.gen, id.gen, "stale plan id (already released)");
        slot.state.as_ref().expect("released plan")
    }

    fn checked_mut(&mut self, id: PlanId) -> &mut PlanState {
        let slot = &mut self.plans[id.idx];
        assert_eq!(slot.gen, id.gen, "stale plan id (already released)");
        slot.state.as_mut().expect("released plan")
    }

    /// Pace an injection on `slot` at `now`: reserve from the bucket the
    /// op's plan (first) or the session mode selects and return the
    /// release delay (0 when unpaced).
    fn pace_delay(&mut self, plan: usize, slot: usize, now: SimTime, bytes: usize) -> SimTime {
        if let Some(tb) = self.plans[plan]
            .state
            .as_mut()
            .and_then(|p| p.pacer.as_mut())
        {
            let release = tb.reserve(now, bytes);
            self.releases.push((slot, release, bytes));
            return release.saturating_sub(now);
        }
        let release = match &mut self.pace {
            PaceMode::None => return 0,
            PaceMode::Global(tb) => tb.reserve(now, bytes),
            PaceMode::PerSlot(template) => {
                if self.slot_pacers.len() <= slot {
                    self.slot_pacers.resize_with(slot + 1, || None);
                }
                self.slot_pacers[slot]
                    .get_or_insert_with(|| template.clone())
                    .reserve(now, bytes)
            }
            PaceMode::Dcqcn(cfg) => {
                if self.slot_cc.len() <= slot {
                    self.slot_cc.resize_with(slot + 1, || None);
                }
                let cc = self.slot_cc[slot].get_or_insert_with(|| SlotCc::new(cfg));
                // Read the controller's *current* rate (time-based fast
                // recovery + additive probing run inside `rate_gbps`),
                // retarget the bucket, then reserve on the new schedule.
                let gbps = cc.ctl.rate_gbps(now);
                cc.bucket.set_rate(now, gbps);
                cc.bucket.reserve(now, bytes)
            }
        };
        self.releases.push((slot, release, bytes));
        release.saturating_sub(now)
    }

    /// Pop the next op off `slot`'s queue and turn it into an injection
    /// command (possibly pace-delayed). `None` when the queue is dry.
    /// Callers guarantee the slot has window room.
    fn next_cmd(&mut self, slot: usize, now: SimTime) -> Option<InjectCmd> {
        let op = self.queues[slot].pop_front()?;
        let plan = op.plan;
        let delay = self.pace_delay(plan, slot, now, op.pace_bytes);
        self.inflight.insert(
            op.key,
            InflightOp {
                slot,
                plan,
                tag: op.tag,
                pub_key: op.pub_key,
                issued_at: now + delay,
            },
        );
        self.inflight_per_slot[slot] += 1;
        self.max_inflight = self.max_inflight.max(self.inflight_per_slot[slot]);
        let newly_active = {
            let p = self.plan_mut(plan);
            let newly = p.inflight == 0;
            p.inflight += 1;
            newly
        };
        if newly_active {
            self.active_plans += 1;
            self.max_concurrent_plans = self.max_concurrent_plans.max(self.active_plans);
        }
        Some(InjectCmd {
            origin: op.origin,
            pkt: op.pkt,
            reliable: op.reliable,
            delay,
        })
    }

    /// Handle one completion record; returns follow-up injections.
    fn on_completion(&mut self, rec: &CompletionRecord) -> Vec<InjectCmd> {
        let candidate = match &rec.instr {
            Instruction::CollectiveDone { block } => {
                let k = Key::Done(*block);
                if self.inflight.contains_key(&k) || self.retired.contains(&k) {
                    k
                } else {
                    Key::Seq(rec.node, rec.seq)
                }
            }
            _ => Key::Seq(rec.node, rec.seq),
        };
        let Some(info) = self.inflight.remove(&candidate) else {
            if self.retired.contains(&candidate) {
                self.duplicates += 1; // retransmit echo — already retired
            }
            return Vec::new(); // foreign completion
        };
        self.retired.insert(candidate);
        self.inflight_per_slot[info.slot] -= 1;
        let latency = rec.time.saturating_sub(info.issued_at);
        let now_idle = {
            let p = self.plan_mut(info.plan);
            p.inflight -= 1;
            p.done += 1;
            p.last_done = rec.time;
            p.latencies.push(latency);
            p.inflight == 0
        };
        if now_idle {
            self.active_plans -= 1;
        }
        // CE-marked completion → CNP for the owning slot's controller:
        // multiplicative cut now, so the refill below already paces at
        // the reduced rate. Fired here (not in `deliver`) because the
        // sharded core replays completions at barriers in global key
        // order — which is exactly what keeps the rate trajectory
        // bit-identical across shard counts.
        if rec.ecn {
            if let PaceMode::Dcqcn(cfg) = &self.pace {
                if self.slot_cc.len() <= info.slot {
                    self.slot_cc.resize_with(info.slot + 1, || None);
                }
                let cc = self.slot_cc[info.slot].get_or_insert_with(|| SlotCc::new(cfg));
                cc.ctl.on_cnp(rec.time);
                let gbps = cc.ctl.rate_gbps(rec.time);
                cc.bucket.set_rate(rec.time, gbps);
                self.rate_log.push((info.slot, rec.time, gbps.to_bits()));
            }
        }
        if let Instruction::Nack { reason, .. } = &rec.instr {
            if self.plan(info.plan).nak.is_none() {
                // Cancel the rest of *this plan only*: its lease is bad,
                // so hammering the device with its remaining window
                // would just be more NAKs — but other tenants' plans on
                // the session are healthy and keep running. One sweep,
                // over the plan's own slots, on the first NAK (the
                // remaining in-flight ops drain to their own NAKs).
                let nak = NakRecord {
                    from: rec.from,
                    tag: info.tag,
                    reason: *reason,
                    key: info.pub_key,
                };
                let slots = {
                    let p = self.plan_mut(info.plan);
                    p.nak = Some(nak);
                    p.slots.clone()
                };
                let mut dropped = 0usize;
                for slot in slots {
                    let q = &mut self.queues[slot];
                    let before = q.len();
                    q.retain(|op| op.plan != info.plan);
                    dropped += before - q.len();
                }
                self.plan_mut(info.plan).cancelled += dropped;
            }
        }
        if self.plan(info.plan).record_responses {
            let retired = Retired {
                key: info.pub_key,
                tag: info.tag,
                instr: rec.instr.clone(),
                time: rec.time,
            };
            self.plan_mut(info.plan).responses.push(retired);
        }
        let cmds = match self.next_cmd(info.slot, rec.time) {
            Some(cmd) => vec![cmd],
            None => Vec::new(),
        };
        self.reclaim_if_settled(info.plan);
        cmds
    }

    /// Once a plan has fully settled (every op retired or cancelled,
    /// nothing in flight), return its slots to the free list and prune
    /// its keys — a long-lived session stays bounded by concurrency.
    /// Late retransmit echoes for a reclaimed plan simply read as
    /// foreign completions and are ignored.
    fn reclaim_if_settled(&mut self, plan: usize) {
        {
            let p = self.plan(plan);
            if p.reclaimed || p.inflight > 0 || p.done + p.cancelled < p.ops {
                return;
            }
        }
        let (slots, keys) = {
            let p = self.plan_mut(plan);
            p.reclaimed = true;
            p.pacer = None;
            (std::mem::take(&mut p.slots), std::mem::take(&mut p.keys))
        };
        for k in keys {
            self.keys.remove(&k);
            self.retired.remove(&k);
        }
        for slot in slots {
            debug_assert!(self.queues[slot].is_empty());
            debug_assert_eq!(self.inflight_per_slot[slot], 0);
            if self.slot_pacers.len() > slot {
                // A reused slot starts with a fresh bucket.
                self.slot_pacers[slot] = None;
            }
            if self.slot_cc.len() > slot {
                // ... and a fresh DCQCN controller: rate state is
                // per-origin-slot, and the slot's owner is gone.
                self.slot_cc[slot] = None;
            }
            self.free_slots.push(slot);
        }
    }
}

/// The long-lived multi-plan front of the transport engine (see the
/// module docs). A session owns the cluster's completion hook from the
/// first [`submit`](Self::submit) until [`close`](Self::close); plans
/// from any number of tenants multiplex onto it.
pub struct EngineSession {
    window: usize,
    state: Rc<RefCell<State>>,
    hooked: bool,
}

impl EngineSession {
    /// Session whose plans default to `window` ops in flight per slot
    /// (minimum 1); [`submit`](Self::submit) takes each plan's actual
    /// window.
    pub fn new(window: usize) -> Self {
        Self {
            window: window.max(1),
            state: Rc::new(RefCell::new(State {
                queues: Vec::new(),
                inflight: HashMap::new(),
                retired: HashSet::new(),
                keys: HashSet::new(),
                inflight_per_slot: Vec::new(),
                free_slots: Vec::new(),
                max_inflight: 0,
                duplicates: 0,
                plans: Vec::new(),
                free_plans: Vec::new(),
                active_plans: 0,
                max_concurrent_plans: 0,
                pace: PaceMode::None,
                slot_pacers: Vec::new(),
                slot_cc: Vec::new(),
                releases: Vec::new(),
                rate_log: Vec::new(),
            })),
            hooked: false,
        }
    }

    /// Pace every injection through one shared `bucket`.
    pub fn paced(self, bucket: TokenBucket) -> Self {
        self.state.borrow_mut().pace = PaceMode::Global(bucket);
        self
    }

    /// Pace each slot through its own clone of `bucket` — per-
    /// destination pacing (the ROADMAP's communicator fan-out item).
    pub fn paced_per_slot(self, bucket: TokenBucket) -> Self {
        self.state.borrow_mut().pace = PaceMode::PerSlot(bucket);
        self
    }

    /// Apply a congestion-control mode: [`CcMode::Dcqcn`] replaces the
    /// session's pacing with per-slot closed-loop rate control (plan-
    /// private pacers still win for their own plans);
    /// [`CcMode::Static`] leaves the configured pacing untouched.
    pub fn with_congestion_control(self, mode: CcMode) -> Self {
        if let CcMode::Dcqcn(cfg) = mode {
            self.state.borrow_mut().pace = PaceMode::Dcqcn(cfg);
        }
        self
    }

    /// Submit one plan with its own per-slot `window`: map the plan's
    /// local slot space onto session slots (reusing slots of settled
    /// plans), enqueue its ops, install the completion hook if this is
    /// the first plan, and kick every touched slot's window. The ops
    /// start flowing on the next [`drive`](Self::drive).
    pub fn submit(
        &mut self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<WindowedOp>,
        record_responses: bool,
        window: usize,
    ) -> Result<PlanId> {
        self.submit_with_pacer(cl, eng, ops, record_responses, window, None)
    }

    /// [`submit`](Self::submit) with a plan-private token bucket: every
    /// injection of *this plan* reserves its `pace_bytes` from `bucket`
    /// before release, independent of the session's pacing mode and of
    /// every other plan. This is how a paced pooled-memory batch rides a
    /// shared fabric session without rate-limiting its neighbors.
    pub fn submit_paced(
        &mut self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<WindowedOp>,
        record_responses: bool,
        window: usize,
        bucket: TokenBucket,
    ) -> Result<PlanId> {
        self.submit_with_pacer(cl, eng, ops, record_responses, window, Some(bucket))
    }

    fn submit_with_pacer(
        &mut self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<WindowedOp>,
        record_responses: bool,
        window: usize,
        pacer: Option<TokenBucket>,
    ) -> Result<PlanId> {
        let window = window.max(1);
        if !self.hooked {
            ensure!(
                cl.on_completion.is_none(),
                "cluster already has a completion hook installed"
            );
            let hook_state = Rc::clone(&self.state);
            cl.on_completion = Some(Box::new(move |rec: &CompletionRecord| {
                hook_state.borrow_mut().on_completion(rec)
            }));
            self.hooked = true;
        }
        let plan_id;
        let plan_gen;
        let mut kicks = Vec::new();
        {
            let mut st = self.state.borrow_mut();
            // Map the plan's local slots onto session slots: every plan
            // windows independently even when two tenants name the same
            // peer.
            let mut slot_map: HashMap<usize, usize> = HashMap::new();
            let mut touched: Vec<usize> = Vec::new();
            let n_ops = ops.len();
            // Validate keys AND slot capacity up front so a rejected
            // submit leaves no partial queue state behind.
            let mut fresh: Vec<Key> = Vec::with_capacity(n_ops);
            let mut fresh_set: HashSet<Key> = HashSet::with_capacity(n_ops);
            let mut distinct_slots: HashSet<usize> = HashSet::new();
            for op in &ops {
                let key = match op.key {
                    CompletionKey::DoneId(b) => Key::Done(b),
                    CompletionKey::Seq(s) => Key::Seq(op.origin, s),
                };
                ensure!(
                    !st.keys.contains(&key) && fresh_set.insert(key),
                    "duplicate completion key {:?}",
                    op.key
                );
                fresh.push(key);
                distinct_slots.insert(op.slot);
            }
            let new_slots = distinct_slots
                .len()
                .saturating_sub(st.free_slots.len());
            ensure!(
                st.queues.len() + new_slots <= MAX_SLOTS,
                "window engine slot space exhausted"
            );
            // Validation passed — allocate the plan's slab slot (recycling
            // a released one when available).
            plan_id = match st.free_plans.pop() {
                Some(idx) => idx,
                None => {
                    st.plans.push(PlanSlot {
                        gen: 0,
                        state: None,
                    });
                    st.plans.len() - 1
                }
            };
            plan_gen = st.plans[plan_id].gen;
            st.keys.extend(fresh_set);
            for (op, key) in ops.into_iter().zip(fresh.iter().copied()) {
                let slot = match slot_map.get(&op.slot) {
                    Some(&s) => s,
                    None => {
                        let s = match st.free_slots.pop() {
                            Some(s) => s,
                            None => {
                                let s = st.queues.len();
                                st.queues.push(VecDeque::new());
                                st.inflight_per_slot.push(0);
                                s
                            }
                        };
                        slot_map.insert(op.slot, s);
                        touched.push(s);
                        s
                    }
                };
                st.queues[slot].push_back(QueuedOp {
                    key,
                    pub_key: op.key,
                    plan: plan_id,
                    tag: op.tag,
                    origin: op.origin,
                    reliable: op.reliable,
                    pace_bytes: op.pace_bytes,
                    pkt: op.pkt,
                });
            }
            st.plans[plan_id].state = Some(PlanState {
                ops: n_ops,
                done: 0,
                inflight: 0,
                slots: touched.clone(),
                keys: fresh,
                reclaimed: false,
                submitted_at: eng.now(),
                last_done: eng.now(),
                nak: None,
                cancelled: 0,
                record_responses,
                responses: Vec::new(),
                latencies: Vec::new(),
                pacer,
            });
            // Kick the plan's initial windows.
            let now = eng.now();
            for slot in touched {
                while st.inflight_per_slot[slot] < window {
                    match st.next_cmd(slot, now) {
                        Some(cmd) => kicks.push(cmd),
                        None => break,
                    }
                }
            }
        }
        for cmd in kicks {
            cl.inject_cmd(eng, cmd);
        }
        Ok(PlanId {
            idx: plan_id,
            gen: plan_gen,
        })
    }

    /// Run the DES until it drains. Every submitted plan makes progress
    /// concurrently; plans that can complete do.
    pub fn drive(&mut self, cl: &mut Cluster, eng: &mut Engine<Cluster>) {
        eng.run(cl);
    }

    /// Has every op of `plan` retired?
    pub fn is_complete(&self, plan: PlanId) -> bool {
        let st = self.state.borrow();
        let p = st.checked(plan);
        p.done == p.ops
    }

    /// Has `plan` stopped (all retired, or NAK-cancelled and drained)?
    pub fn is_settled(&self, plan: PlanId) -> bool {
        let st = self.state.borrow();
        let p = st.checked(plan);
        p.done + p.cancelled == p.ops && p.inflight == 0
    }

    /// Lightweight progress probe: `(done, ops, last_done)` for `plan`
    /// without consuming its recorded responses.
    pub fn progress(&self, plan: PlanId) -> (usize, usize, SimTime) {
        let st = self.state.borrow();
        let p = st.checked(plan);
        (p.done, p.ops, p.last_done)
    }

    /// Redeem a plan's outcome (recorded responses move out — redeem a
    /// given plan once).
    pub fn outcome(&mut self, plan: PlanId) -> PlanOutcome {
        let mut st = self.state.borrow_mut();
        let p = st.checked_mut(plan);
        PlanOutcome {
            ops: p.ops,
            done: p.done,
            submitted_at: p.submitted_at,
            last_done: p.last_done,
            nak: p.nak,
            cancelled: p.cancelled,
            responses: std::mem::take(&mut p.responses),
            latencies: std::mem::take(&mut p.latencies),
        }
    }

    /// Move out a plan's per-op completion latencies without redeeming
    /// the full outcome (the fabric folds these incrementally before
    /// releasing each phase's plan).
    pub fn take_latencies(&mut self, plan: PlanId) -> Vec<SimTime> {
        let mut st = self.state.borrow_mut();
        std::mem::take(&mut st.checked_mut(plan).latencies)
    }

    /// Drop a settled plan's bookkeeping and recycle its slab slot. After
    /// this the id is stale: further accessor calls with it panic, and a
    /// fresh submit may reuse the slot under a bumped generation. Errors
    /// if the plan still has ops queued or in flight (release after
    /// [`is_settled`](Self::is_settled), typically after redeeming
    /// [`outcome`](Self::outcome)), or if the id is already stale.
    pub fn release(&mut self, plan: PlanId) -> Result<()> {
        let mut st = self.state.borrow_mut();
        ensure!(
            st.plans
                .get(plan.idx)
                .is_some_and(|s| s.gen == plan.gen && s.state.is_some()),
            "stale plan id (already released)"
        );
        {
            let p = st.plan(plan.idx);
            ensure!(
                p.inflight == 0 && p.done + p.cancelled == p.ops,
                "plan not settled; cannot release"
            );
        }
        // Frees slots/keys if the plan never went through the completion
        // path (e.g. an empty plan).
        st.reclaim_if_settled(plan.idx);
        let slot = &mut st.plans[plan.idx];
        slot.state = None;
        slot.gen = slot.gen.wrapping_add(1);
        st.free_plans.push(plan.idx);
        Ok(())
    }

    /// Slab length (live + recyclable holes) — the memory-compaction
    /// regression tests assert this stays bounded on long sessions.
    pub fn plan_slab_len(&self) -> usize {
        self.state.borrow().plans.len()
    }

    /// Plans currently holding live bookkeeping (not yet released).
    pub fn live_plans(&self) -> usize {
        self.state
            .borrow()
            .plans
            .iter()
            .filter(|s| s.state.is_some())
            .count()
    }

    /// High-water mark of plans simultaneously in flight — ≥ 2 proves
    /// two tenants' ops coexisted on the shared engine.
    pub fn max_concurrent_plans(&self) -> usize {
        self.state.borrow().max_concurrent_plans
    }

    /// Max ops simultaneously in flight on any one slot (≤ window).
    pub fn max_inflight(&self) -> usize {
        self.state.borrow().max_inflight
    }

    /// Completions that matched an already-retired key (retransmit
    /// echoes).
    pub fn duplicate_completions(&self) -> usize {
        self.state.borrow().duplicates
    }

    /// Ops currently queued but not yet injected (all plans).
    pub fn queued(&self) -> usize {
        self.state.borrow().queues.iter().map(|q| q.len()).sum()
    }

    /// Nothing queued or in flight anywhere on the session.
    pub fn idle(&self) -> bool {
        let st = self.state.borrow();
        st.inflight.is_empty() && st.queues.iter().all(|q| q.is_empty())
    }

    /// Paced release log `(slot, release_time, bytes)`.
    pub fn releases(&self) -> Vec<(usize, SimTime, usize)> {
        self.state.borrow().releases.clone()
    }

    /// DCQCN rate trajectory `(slot, time, rate_bits)` — one entry per
    /// CNP delivered, `rate_bits = f64::to_bits(post-cut Gbps)`. Empty
    /// unless the session runs [`CcMode::Dcqcn`]. The sharded-
    /// determinism suite compares this bit-for-bit across shard counts.
    pub fn rate_log(&self) -> Vec<(usize, SimTime, u64)> {
        self.state.borrow().rate_log.clone()
    }

    /// Total CNPs delivered to slot controllers (Dcqcn mode only).
    pub fn cnps(&self) -> usize {
        self.state.borrow().rate_log.len()
    }

    /// Uninstall the completion hook. The session keeps its bookkeeping
    /// (outcomes stay redeemable) but accepts no more traffic.
    pub fn close(&mut self, cl: &mut Cluster) {
        if self.hooked {
            cl.on_completion = None;
            self.hooked = false;
        }
    }

    /// The default per-slot in-flight window for this session's plans.
    pub fn window(&self) -> usize {
        self.window
    }
}

/// The classic single-plan front: construct with [`Self::new`],
/// optionally add pacing/recording, then [`Self::run`] a batch of ops.
pub struct WindowEngine {
    window: usize,
    pacer: Option<TokenBucket>,
    per_slot: bool,
    cc: CcMode,
    record_responses: bool,
}

impl WindowEngine {
    /// Engine with `window` ops in flight per slot (minimum 1).
    pub fn new(window: usize) -> Self {
        Self {
            window: window.max(1),
            pacer: None,
            per_slot: false,
            cc: CcMode::Static,
            record_responses: false,
        }
    }

    /// Pace every injection through one shared `bucket` (see module docs).
    pub fn paced(mut self, bucket: TokenBucket) -> Self {
        self.pacer = Some(bucket);
        self.per_slot = false;
        self
    }

    /// Pace each slot through its own clone of `bucket` (per-destination
    /// pacing — see module docs).
    pub fn paced_per_slot(mut self, bucket: TokenBucket) -> Self {
        self.pacer = Some(bucket);
        self.per_slot = true;
        self
    }

    /// Closed-loop DCQCN pacing (see [`CcMode::Dcqcn`]): per-slot rate
    /// controllers replace any static bucket for this run.
    pub fn with_congestion_control(mut self, mode: CcMode) -> Self {
        self.cc = mode;
        self
    }

    /// Record each retired op's completion instruction into the outcome.
    pub fn record_responses(mut self, on: bool) -> Self {
        self.record_responses = on;
        self
    }

    /// Drive `ops` to completion (or to NAK cancellation / retry
    /// exhaustion): open a one-plan session, kick the initial windows,
    /// run the DES until quiet, tear the hook down, and report.
    pub fn run(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<WindowedOp>,
    ) -> Result<WindowOutcome> {
        let n_ops = ops.len();
        if n_ops == 0 {
            return Ok(WindowOutcome {
                ops: 0,
                done: 0,
                last_done: eng.now(),
                nak: None,
                cancelled: 0,
                max_inflight: 0,
                duplicate_completions: 0,
                releases: Vec::new(),
                releases_per_slot: Vec::new(),
                responses: Vec::new(),
                latencies: Vec::new(),
            });
        }
        let mut session = EngineSession::new(self.window);
        if let Some(tb) = &self.pacer {
            session = if self.per_slot {
                session.paced_per_slot(tb.clone())
            } else {
                session.paced(tb.clone())
            };
        }
        session = session.with_congestion_control(self.cc.clone());
        let plan = match session.submit(cl, eng, ops, self.record_responses, self.window) {
            Ok(p) => p,
            Err(e) => {
                // A rejected submit (duplicate key) must not leave the
                // hook installed.
                session.close(cl);
                return Err(e);
            }
        };
        session.drive(cl, eng);
        session.close(cl);
        let out = session.outcome(plan);
        let releases_per_slot = session.releases();
        Ok(WindowOutcome {
            ops: out.ops,
            done: out.done,
            last_done: out.last_done,
            nak: out.nak,
            cancelled: out.cancelled,
            max_inflight: session.max_inflight(),
            duplicate_completions: session.duplicate_completions(),
            releases: releases_per_slot.iter().map(|&(_, at, b)| (at, b)).collect(),
            releases_per_slot,
            responses: out.responses,
            latencies: out.latencies,
        })
    }
}
