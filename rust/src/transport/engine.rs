//! The windowed transport engine — one reliable-injection/completion-
//! refill state machine for *every* host-side data path.
//!
//! Before this module existed, `collectives::Driver::run` and
//! `mem::MemClient::run_plan` each owned a copy of the same loop:
//! per-peer FIFO queues, a self-clocked in-flight window, reliable
//! injection, an `on_completion` hook that retires one op and refills
//! the window, and (on the mem side) NAK surfacing. The paper's core
//! claim is that one programmable memory-attached datapath serves both
//! collectives (§3) and pooled-memory access (§2.5/§2.6) — so the host
//! side gets one transport engine too.
//!
//! [`WindowEngine::run`] drives a batch of [`WindowedOp`]s to
//! completion:
//!
//! * **Windowing** — ops are queued per *slot* (a collective rank, a
//!   pool device — whatever the caller windows over) and at most
//!   `window` ops per slot are in flight; each retirement refills from
//!   that slot's queue (self-clocking).
//! * **Completion keying** — generic over the two flavors in the tree:
//!   [`CompletionKey::DoneId`] matches a `CollectiveDone { block }`
//!   (collective chains retire at the far end of a multi-hop program),
//!   [`CompletionKey::Seq`] matches any response echoing the request's
//!   sequence number at the op's origin (RDMA-PSN-style request/response
//!   correlation). Duplicate completions (retransmitted chains re-emit
//!   their Done) are counted and ignored: every op retires exactly once.
//! * **Reliability** — reliable ops are injected through the cluster's
//!   timeout-retransmit table; the retirement path clears the pending
//!   entry (via `note_completion`), so a drained run leaves no dangling
//!   timers.
//! * **NAK surfacing + cancel** — a wire `Nack` matching an in-flight op
//!   records the typed denial and *cancels the remaining queues*: no
//!   further ops are injected, in-flight ops drain normally, and the
//!   caller gets the first NAK plus the count of cancelled ops.
//! * **Paced refill** — with [`WindowEngine::paced`], every injection
//!   first reserves the op's `pace_bytes` from a [`TokenBucket`] and is
//!   released only when the bucket allows (the §2.5 "sequencing and
//!   rate-limited READ" incast cure). Pacing composes with windowing:
//!   injection time is the later of the completion that freed the slot
//!   and the bucket release.
//!
//! The engine installs the cluster's completion hook for the duration of
//! one `run` and always removes it before returning — callers never
//! touch `Cluster::on_completion` themselves.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::isa::Instruction;
use crate::net::{Cluster, CompletionRecord, InjectCmd, NodeId};
use crate::sim::{Engine, SimTime};
use crate::wire::{DeviceIp, Packet};

use super::rate::TokenBucket;

/// Upper bound on window slots (sanity guard against caller bugs).
const MAX_SLOTS: usize = 65_536;

/// How one op recognises its completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CompletionKey {
    /// A `CollectiveDone { block }` carrying this id (collective chains
    /// retire wherever the packet program's last hop runs).
    DoneId(u32),
    /// Any response echoing this sequence number at the op's origin
    /// (reads, write acks, CAS responses, NAKs).
    Seq(u64),
}

/// One windowed op: a packet plus how to window and retire it.
pub struct WindowedOp {
    /// Window slot (a rank, a device index — the caller's peer notion).
    pub slot: usize,
    /// Node that injects the packet and receives its completion.
    pub origin: NodeId,
    pub key: CompletionKey,
    /// Caller cookie carried through to [`Retired`] / [`NakRecord`]
    /// (e.g. the GVA a mem op targets).
    pub tag: u64,
    pub reliable: bool,
    /// Bytes this op charges the pacer — the data it *moves* (a READ's
    /// response payload, a WRITE's wire bytes), not necessarily its
    /// request size. Ignored when the engine is unpaced.
    pub pace_bytes: usize,
    pub pkt: Packet,
}

/// A retired op's completion, recorded when response recording is on.
#[derive(Debug, Clone)]
pub struct Retired {
    pub key: CompletionKey,
    pub tag: u64,
    pub instr: Instruction,
    pub time: SimTime,
}

/// The first wire NAK matched to an in-flight op.
#[derive(Debug, Clone, Copy)]
pub struct NakRecord {
    /// Device that denied the access.
    pub from: DeviceIp,
    /// The NAK'd op's caller cookie.
    pub tag: u64,
    /// Typed reason byte (see [`crate::iommu::NakReason`]).
    pub reason: u8,
    pub key: CompletionKey,
}

/// What one engine run produced.
#[derive(Debug)]
pub struct WindowOutcome {
    /// Ops submitted.
    pub ops: usize,
    /// Ops retired (each exactly once). `< ops` means unrecovered loss
    /// or a NAK cancellation — callers decide whether that is an error.
    pub done: usize,
    /// Time of the last retirement (run start time if nothing retired).
    pub last_done: SimTime,
    pub nak: Option<NakRecord>,
    /// Queued ops dropped by NAK cancellation (never injected).
    pub cancelled: usize,
    /// Max ops simultaneously in flight on any one slot (≤ window).
    pub max_inflight: usize,
    /// Completions that matched an already-retired key (retransmit
    /// echoes) — ignored, counted for diagnostics.
    pub duplicate_completions: usize,
    /// Paced release log `(release_time, pace_bytes)`, empty when
    /// unpaced. By construction cumulative bytes released by time `t`
    /// never exceed `burst + rate·t`.
    pub releases: Vec<(SimTime, usize)>,
    /// Retired completions (only when [`WindowEngine::record_responses()`]
    /// is on; `CollectiveDone` floods would be noise for collectives).
    pub responses: Vec<Retired>,
}

/// Internal completion key: seq matches are scoped to the origin node so
/// independent origins may reuse sequence numbers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Key {
    Done(u32),
    Seq(NodeId, u64),
}

struct QueuedOp {
    key: Key,
    pub_key: CompletionKey,
    tag: u64,
    origin: NodeId,
    reliable: bool,
    pace_bytes: usize,
    pkt: Packet,
}

struct InflightOp {
    slot: usize,
    tag: u64,
    pub_key: CompletionKey,
}

struct State {
    queues: Vec<VecDeque<QueuedOp>>,
    inflight: HashMap<Key, InflightOp>,
    retired: HashSet<Key>,
    inflight_per_slot: Vec<usize>,
    max_inflight: usize,
    done: usize,
    duplicates: usize,
    last_done: SimTime,
    nak: Option<NakRecord>,
    cancelled: usize,
    record_responses: bool,
    responses: Vec<Retired>,
    pacer: Option<TokenBucket>,
    releases: Vec<(SimTime, usize)>,
}

impl State {
    /// Pop the next op off `slot`'s queue and turn it into an injection
    /// command (possibly pace-delayed). `None` when the queue is dry.
    fn next_cmd(&mut self, slot: usize, now: SimTime) -> Option<InjectCmd> {
        let op = self.queues[slot].pop_front()?;
        self.inflight.insert(
            op.key,
            InflightOp {
                slot,
                tag: op.tag,
                pub_key: op.pub_key,
            },
        );
        self.inflight_per_slot[slot] += 1;
        self.max_inflight = self.max_inflight.max(self.inflight_per_slot[slot]);
        let delay = match &mut self.pacer {
            Some(tb) => {
                let release = tb.reserve(now, op.pace_bytes);
                self.releases.push((release, op.pace_bytes));
                release.saturating_sub(now)
            }
            None => 0,
        };
        Some(InjectCmd {
            origin: op.origin,
            pkt: op.pkt,
            reliable: op.reliable,
            delay,
        })
    }
}

/// The shared windowed transport engine. Construct with [`Self::new`],
/// optionally add pacing/recording, then [`Self::run`] a batch of ops.
pub struct WindowEngine {
    window: usize,
    pacer: Option<TokenBucket>,
    record_responses: bool,
}

impl WindowEngine {
    /// Engine with `window` ops in flight per slot (minimum 1).
    pub fn new(window: usize) -> Self {
        Self {
            window: window.max(1),
            pacer: None,
            record_responses: false,
        }
    }

    /// Pace every injection through `bucket` (see module docs).
    pub fn paced(mut self, bucket: TokenBucket) -> Self {
        self.pacer = Some(bucket);
        self
    }

    /// Record each retired op's completion instruction into the outcome.
    pub fn record_responses(mut self, on: bool) -> Self {
        self.record_responses = on;
        self
    }

    /// Drive `ops` to completion (or to NAK cancellation / retry
    /// exhaustion): install the completion hook, kick the initial
    /// windows, run the DES until quiet, tear the hook down, and report.
    pub fn run(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<WindowedOp>,
    ) -> Result<WindowOutcome> {
        let n_ops = ops.len();
        if n_ops == 0 {
            return Ok(WindowOutcome {
                ops: 0,
                done: 0,
                last_done: eng.now(),
                nak: None,
                cancelled: 0,
                max_inflight: 0,
                duplicate_completions: 0,
                releases: Vec::new(),
                responses: Vec::new(),
            });
        }
        let n_slots = ops.iter().map(|o| o.slot + 1).max().unwrap_or(1);
        ensure!(
            n_slots <= MAX_SLOTS,
            "window engine slot index {} out of range",
            n_slots - 1
        );
        let mut queues: Vec<VecDeque<QueuedOp>> =
            (0..n_slots).map(|_| VecDeque::new()).collect();
        let mut seen: HashSet<Key> = HashSet::with_capacity(n_ops);
        for op in ops {
            let key = match op.key {
                CompletionKey::DoneId(b) => Key::Done(b),
                CompletionKey::Seq(s) => Key::Seq(op.origin, s),
            };
            ensure!(seen.insert(key), "duplicate completion key {:?}", op.key);
            queues[op.slot].push_back(QueuedOp {
                key,
                pub_key: op.key,
                tag: op.tag,
                origin: op.origin,
                reliable: op.reliable,
                pace_bytes: op.pace_bytes,
                pkt: op.pkt,
            });
        }
        let state = Rc::new(RefCell::new(State {
            queues,
            inflight: HashMap::with_capacity(n_ops.min(n_slots * self.window)),
            retired: HashSet::with_capacity(n_ops),
            inflight_per_slot: vec![0; n_slots],
            max_inflight: 0,
            done: 0,
            duplicates: 0,
            last_done: eng.now(),
            nak: None,
            cancelled: 0,
            record_responses: self.record_responses,
            responses: Vec::new(),
            pacer: self.pacer.clone(),
            releases: Vec::new(),
        }));

        let hook_state = Rc::clone(&state);
        cl.on_completion = Some(Box::new(move |rec: &CompletionRecord| {
            let mut st = hook_state.borrow_mut();
            let candidate = match &rec.instr {
                Instruction::CollectiveDone { block } => {
                    let k = Key::Done(*block);
                    if st.inflight.contains_key(&k) || st.retired.contains(&k) {
                        k
                    } else {
                        Key::Seq(rec.node, rec.seq)
                    }
                }
                _ => Key::Seq(rec.node, rec.seq),
            };
            let Some(info) = st.inflight.remove(&candidate) else {
                if st.retired.contains(&candidate) {
                    st.duplicates += 1; // retransmit echo — already retired
                }
                return Vec::new(); // foreign completion
            };
            st.retired.insert(candidate);
            st.inflight_per_slot[info.slot] -= 1;
            st.done += 1;
            st.last_done = rec.time;
            if let Instruction::Nack { reason, .. } = &rec.instr {
                if st.nak.is_none() {
                    st.nak = Some(NakRecord {
                        from: rec.from,
                        tag: info.tag,
                        reason: *reason,
                        key: info.pub_key,
                    });
                }
                // Cancel the remaining plan: drain in-flight ops, inject
                // nothing more (the lease is bad — hammering it with the
                // rest of the window would just be more NAKs).
                let queued: usize = st.queues.iter().map(|q| q.len()).sum();
                st.cancelled += queued;
                for q in &mut st.queues {
                    q.clear();
                }
            }
            if st.record_responses {
                st.responses.push(Retired {
                    key: info.pub_key,
                    tag: info.tag,
                    instr: rec.instr.clone(),
                    time: rec.time,
                });
            }
            match st.next_cmd(info.slot, rec.time) {
                Some(cmd) => vec![cmd],
                None => Vec::new(),
            }
        }));

        // Kick the initial per-slot windows.
        let mut kicks = Vec::new();
        {
            let mut st = state.borrow_mut();
            let now = eng.now();
            for slot in 0..n_slots {
                for _ in 0..self.window {
                    match st.next_cmd(slot, now) {
                        Some(cmd) => kicks.push(cmd),
                        None => break,
                    }
                }
            }
        }
        for cmd in kicks {
            cl.inject_cmd(eng, cmd);
        }
        eng.run(cl);
        cl.on_completion = None;
        let st = Rc::try_unwrap(state)
            .ok()
            .expect("completion hook released")
            .into_inner();
        Ok(WindowOutcome {
            ops: n_ops,
            done: st.done,
            last_done: st.last_done,
            nak: st.nak,
            cancelled: st.cancelled,
            max_inflight: st.max_inflight,
            duplicate_completions: st.duplicates,
            releases: st.releases,
            responses: st.responses,
        })
    }
}
