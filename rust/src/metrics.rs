//! Metrics: counters, log-bucketed latency histograms, and table rendering.
//!
//! The DES produces millions of latency samples; storing them all is
//! wasteful, so the histogram is HDR-style: log2 major buckets with linear
//! sub-buckets, giving <4% relative error across ns..s while staying O(1)
//! per record. Exact min/max/mean/stddev are tracked on the side (the paper
//! reports avg / jitter / max — experiment E1 needs those exactly).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::stats::Running;

const SUB_BITS: u32 = 5; // 32 linear sub-buckets per power of two
const SUB: usize = 1 << SUB_BITS;

/// Log-bucketed histogram of u64 values (nanoseconds, bytes, ...).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    run: Running,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            // 64 majors × 32 subs covers the whole u64 range.
            buckets: vec![0; 64 * SUB],
            run: Running::new(),
        }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let major = 63 - v.leading_zeros() as usize; // floor(log2 v), >= SUB_BITS
        let sub = ((v >> (major as u32 - SUB_BITS)) - SUB as u64) as usize;
        (major - SUB_BITS as usize) * SUB + SUB + sub
    }

    /// Representative (lower-bound) value of bucket `i` — inverse of `index`.
    fn bucket_low(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        let major = (i - SUB) / SUB + SUB_BITS as usize;
        let sub = (i - SUB) % SUB;
        (1u64 << major) + ((sub as u64) << (major as u32 - SUB_BITS))
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.run.push(v as f64);
    }

    /// Fold another histogram into this one (bucket-wise add plus a
    /// parallel merge of the exact side stats). Used to combine per-shard
    /// metrics after a sharded run.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.run.merge(&other.run);
    }

    pub fn count(&self) -> u64 {
        self.run.count()
    }

    pub fn mean(&self) -> f64 {
        self.run.mean()
    }

    /// Standard deviation — the paper's "jitter" metric for E1.
    pub fn jitter(&self) -> f64 {
        self.run.std_dev()
    }

    pub fn min(&self) -> u64 {
        self.run.min() as u64
    }

    pub fn max(&self) -> u64 {
        self.run.max() as u64
    }

    /// Approximate percentile (bucket lower bound; ≤4% low).
    pub fn percentile(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_low(i);
            }
        }
        self.max()
    }
}

/// A named collection of counters and histograms, rendered as a table.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    hists: BTreeMap<String, Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &str, v: u64) {
        // Fast path: bumping an existing counter must not allocate (hot
        // DES events count through here); the `to_string` is paid once
        // per counter name, not once per event.
        if let Some(c) = self.counters.get_mut(name) {
            *c += v;
        } else {
            self.counters.insert(name.to_string(), v);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn record(&mut self, name: &str, v: u64) {
        // Same allocation-free fast path as `add`.
        if let Some(h) = self.hists.get_mut(name) {
            h.record(v);
        } else {
            self.hists.entry(name.to_string()).or_default().record(v);
        }
    }

    pub fn hist(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    pub fn counters(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Fold another `Metrics` into this one: counters add exactly,
    /// histograms merge bucket-wise. Counter totals are order-independent;
    /// histogram mean/jitter are floating-point and merge in caller order
    /// (the sharded runtime always merges in shard order, so a given shard
    /// count is still bit-reproducible).
    pub fn merge(&mut self, other: &Metrics) {
        for (k, &v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k.clone()).or_default().merge(h);
        }
    }

    /// Render a markdown summary (used by the CLI and EXPERIMENTS.md).
    pub fn render(&self) -> String {
        let mut out = String::new();
        if !self.counters.is_empty() {
            let _ = writeln!(out, "| counter | value |");
            let _ = writeln!(out, "|---|---|");
            for (k, v) in &self.counters {
                let _ = writeln!(out, "| {k} | {v} |");
            }
        }
        if !self.hists.is_empty() {
            let _ = writeln!(out, "| histogram | n | mean | p50 | p99 | max | jitter |");
            let _ = writeln!(out, "|---|---|---|---|---|---|---|");
            for (k, h) in &self.hists {
                let _ = writeln!(
                    out,
                    "| {k} | {} | {:.1} | {} | {} | {} | {:.1} |",
                    h.count(),
                    h.mean(),
                    h.percentile(50.0),
                    h.percentile(99.0),
                    h.max(),
                    h.jitter()
                );
            }
        }
        out
    }
}

/// A fixed-width, markdown-compatible table printer for bench output.
#[derive(Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        fn line(cells: &[String], widths: &[usize], out: &mut String) {
            let _ = write!(out, "|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(out, " {c:<w$} |");
            }
            let _ = writeln!(out);
        }
        let mut out = String::new();
        line(&self.headers, &widths, &mut out);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &widths, &mut out);
        for r in &self.rows {
            line(r, &widths, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_exact_small_values() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 3, 3, 31] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 31);
        assert_eq!(h.percentile(50.0), 3);
    }

    #[test]
    fn histogram_relative_error_bounded() {
        for v in [618u64, 920, 1000, 123_456, 5_000_000_000] {
            let i = Histogram::index(v);
            let low = Histogram::bucket_low(i);
            let next = Histogram::bucket_low(i + 1);
            assert!(low <= v && v < next, "v={v} low={low} next={next}");
            let err = (v - low) as f64 / v as f64;
            assert!(err < 0.04, "err {err} for {v}");
        }
    }

    #[test]
    fn histogram_mean_and_jitter() {
        let mut h = Histogram::new();
        for v in [600u64, 620, 640] {
            h.record(v);
        }
        assert!((h.mean() - 620.0).abs() < 1e-9);
        assert!((h.jitter() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_monotone() {
        let mut h = Histogram::new();
        let mut rng = crate::util::Xoshiro256::seed_from(1);
        for _ in 0..10_000 {
            h.record(rng.range_u64(100, 1_000_000));
        }
        let mut last = 0;
        for p in [1.0, 10.0, 50.0, 90.0, 99.0, 99.9] {
            let v = h.percentile(p);
            assert!(v >= last, "p{p}: {v} < {last}");
            last = v;
        }
    }

    #[test]
    fn metrics_merge_combines_counters_and_hists() {
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.add("pkts", 3);
        b.add("pkts", 4);
        b.add("drops", 1);
        a.record("lat_ns", 100);
        b.record("lat_ns", 300);
        b.record("svc_ns", 50);
        a.merge(&b);
        assert_eq!(a.counter("pkts"), 7);
        assert_eq!(a.counter("drops"), 1);
        let h = a.hist("lat_ns").unwrap();
        assert_eq!(h.count(), 2);
        assert!((h.mean() - 200.0).abs() < 1e-9);
        assert_eq!(h.min(), 100);
        assert_eq!(h.max(), 300);
        assert_eq!(a.hist("svc_ns").unwrap().count(), 1);
    }

    #[test]
    fn metrics_counters_and_render() {
        let mut m = Metrics::new();
        m.inc("pkts");
        m.add("pkts", 2);
        m.record("lat_ns", 618);
        assert_eq!(m.counter("pkts"), 3);
        let s = m.render();
        assert!(s.contains("pkts"));
        assert!(s.contains("lat_ns"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("| long-name | 22"));
        assert_eq!(s.lines().count(), 4);
    }
}
