//! Per-stage pipeline costs and the full device configuration.
//!
//! Calibration (experiment E1): the paper measures a wire-to-wire SIMD
//! READ of 32 × f32 at **618 ns average, 39 ns jitter, 920 ns max**. The
//! budget below reproduces it:
//!
//! ```text
//!   rx_mac 90 + parse 50 + iommu 25           = 165 ns
//!   HBM access 339 ± 34 (+128 B stream ≈ 0.3) ≈ 339 ns
//!   route 25 + tx_mac 86 + alu 0              = 111 ns  (READ skips ALU)
//!   refresh collision (+210 ns, p = 1.5%)     ≈ 3 ns mean, sets the max
//!   total                                     ≈ 618 ns ± ~36, max ≈ 920
//! ```

use crate::alu::AluCostModel;
use crate::sim::SimTime;
use crate::wire::DeviceIp;

use super::hbm::HbmConfig;

/// Fixed per-stage costs of the packet pipeline.
#[derive(Debug, Clone)]
pub struct PipelineCosts {
    /// RX MAC/PHY + packet-buffer landing.
    pub rx_mac_ns: SimTime,
    /// Header parse / instruction decode.
    pub parse_ns: SimTime,
    /// IOMMU lookup (VA→PA).
    pub iommu_ns: SimTime,
    /// SROU processing + next-hop selection.
    pub route_ns: SimTime,
    /// TX MAC/PHY.
    pub tx_mac_ns: SimTime,
}

impl PipelineCosts {
    pub fn paper_default() -> Self {
        Self {
            rx_mac_ns: 90,
            parse_ns: 50,
            iommu_ns: 25,
            route_ns: 25,
            tx_mac_ns: 86,
        }
    }

    /// Fixed cost excluding memory/ALU (both directions of the MAC).
    pub fn fixed_ns(&self) -> SimTime {
        self.rx_mac_ns + self.parse_ns + self.iommu_ns + self.route_ns + self.tx_mac_ns
    }
}

/// Everything needed to instantiate one NetDAM device.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    pub ip: DeviceIp,
    pub pipeline: PipelineCosts,
    pub hbm: HbmConfig,
    pub alu: AluCostModel,
    /// Store payload contents (false = timing-only phantom device).
    pub data_bearing: bool,
    /// RNG stream id (mixed with the cluster seed).
    pub seed: u64,
}

impl DeviceConfig {
    /// The paper's prototype device at address `ip`.
    pub fn paper_default(ip: DeviceIp) -> Self {
        Self {
            ip,
            pipeline: PipelineCosts::paper_default(),
            hbm: HbmConfig::paper_default(),
            alu: AluCostModel::paper_default(),
            data_bearing: true,
            seed: ip.0 as u64,
        }
    }

    pub fn timing_only(mut self) -> Self {
        self.data_bearing = false;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_budget_sums_to_paper_mean() {
        // The static parts of the E1 budget (everything but jitter):
        // fixed pipeline + HBM access + 128B stream time.
        let p = PipelineCosts::paper_default();
        let h = HbmConfig::paper_default();
        let static_ns = p.fixed_ns() + h.access_ns + (128.0 / h.bytes_per_ns).round() as SimTime;
        let expected_mean = static_ns as f64 + h.refresh_p * h.refresh_ns as f64;
        assert!(
            (expected_mean - 618.0).abs() < 15.0,
            "budget drifted: {expected_mean} ns"
        );
    }

    #[test]
    fn timing_only_flag() {
        let c = DeviceConfig::paper_default(DeviceIp::lan(1)).timing_only();
        assert!(!c.data_bearing);
    }
}
