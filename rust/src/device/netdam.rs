//! The NetDAM device: instruction execution in the fixed pipeline.

use std::sync::Arc;

use anyhow::Result;

use crate::alu::{block_hash, AluBackend, NativeAlu};
use crate::iommu::{Access, Iommu};
use crate::isa::registry::{ExecCtx, ExecOutcome, InstructionRegistry, MemAccess};
use crate::isa::{Instruction, USER_OPCODE_BASE};
use crate::sim::SimTime;
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::Xoshiro256;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};

use super::hbm::Hbm;
use super::pipeline::DeviceConfig;

/// A packet the device wants to transmit, `delay` ns after the packet
/// that triggered it *arrived* (the delay covers the full pipeline).
#[derive(Debug)]
pub struct Emit {
    pub delay: SimTime,
    pub pkt: Packet,
}

/// One NetDAM device.
pub struct NetDamDevice {
    cfg: DeviceConfig,
    hbm: Hbm,
    iommu: Iommu,
    alu: Box<dyn AluBackend>,
    registry: Arc<InstructionRegistry>,
    rng: Xoshiro256,
    /// Next sequence number for device-originated packets.
    seq: u64,
    /// Completion queue ("memif" side): packets addressed to this device
    /// that carry responses/completions, for the attached host to drain.
    completions: Vec<(SimTime, Packet)>,
    /// Counters for metrics.
    pub pkts_in: u64,
    pub pkts_out: u64,
    pub drops_hash_guard: u64,
    pub exec_errors: u64,
}

impl NetDamDevice {
    pub fn new(cfg: DeviceConfig, registry: Arc<InstructionRegistry>) -> Self {
        let hbm = if cfg.data_bearing {
            Hbm::new(cfg.hbm.clone())
        } else {
            Hbm::new_phantom(cfg.hbm.clone())
        };
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xDA_DE_71CE);
        Self {
            cfg,
            hbm,
            iommu: Iommu::identity(),
            alu: Box::new(NativeAlu::new()),
            registry,
            rng,
            seq: 1,
            completions: Vec::new(),
            pkts_in: 0,
            pkts_out: 0,
            drops_hash_guard: 0,
            exec_errors: 0,
        }
    }

    pub fn ip(&self) -> DeviceIp {
        self.cfg.ip
    }

    pub fn cfg(&self) -> &DeviceConfig {
        &self.cfg
    }

    /// Direct host-side memory access (memif): bypasses the network but
    /// not the HBM. Used by examples and the pool controller.
    pub fn mem(&mut self) -> &mut Hbm {
        &mut self.hbm
    }

    pub fn mem_ref(&self) -> &Hbm {
        &self.hbm
    }

    pub fn iommu_mut(&mut self) -> &mut Iommu {
        &mut self.iommu
    }

    /// Swap in a different ALU backend (e.g. `runtime::XlaAlu`).
    pub fn set_alu(&mut self, alu: Box<dyn AluBackend>) {
        self.alu = alu;
    }

    /// Drain the completion queue (host poll-mode driver).
    pub fn drain_completions(&mut self) -> Vec<(SimTime, Packet)> {
        std::mem::take(&mut self.completions)
    }

    pub fn next_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    /// Process an arriving packet. `now` is the arrival time; returned
    /// emits are relative to it. Malformed packets count as exec_errors
    /// and are dropped (the hardware would raise an error CQE).
    pub fn handle_packet(&mut self, now: SimTime, pkt: Packet) -> Vec<Emit> {
        self.pkts_in += 1;
        match self.execute(now, pkt) {
            Ok(emits) => {
                self.pkts_out += emits.len() as u64;
                emits
            }
            Err(_) => {
                self.exec_errors += 1;
                Vec::new()
            }
        }
    }

    /// Fixed pipeline cost excluding memory/ALU.
    fn fixed_ns(&self) -> SimTime {
        self.cfg.pipeline.fixed_ns()
    }

    fn mem_ns(&mut self, len: usize) -> SimTime {
        self.hbm.access_ns(len, &mut self.rng)
    }

    fn alu_ns(&self, lanes: usize) -> SimTime {
        self.cfg.alu.exec_ns(lanes)
    }

    /// Build a reply routed straight back to `dst`, echoing the request's
    /// sequence number (responses correlate to requests RDMA-PSN-style;
    /// the reliability table keys on it).
    fn reply_seq(&mut self, dst: DeviceIp, seq: u64, instr: Instruction) -> Packet {
        Packet::new(self.cfg.ip, seq, SrouHeader::direct(dst), instr)
    }

    fn reply(&mut self, dst: DeviceIp, seq: u64, instr: Instruction, payload: Payload) -> Packet {
        self.reply_seq(dst, seq, instr).with_payload(payload)
    }

    fn execute(&mut self, now: SimTime, mut pkt: Packet) -> Result<Vec<Emit>> {
        let flags = pkt.flags;
        let src = pkt.src;
        let mut emits = Vec::new();
        let fixed = self.fixed_ns();

        // Raw user-defined opcode? Dispatch through the registry.
        if let Instruction::User { opcode, a, b, c } = pkt.instr {
            return self.execute_user(now, pkt, opcode, a, b, c);
        }

        match pkt.instr.clone() {
            Instruction::Nop => {}

            Instruction::Read { addr, len } => {
                let pa = self.iommu.translate(addr, len as usize, Access::Read)?;
                let t = fixed + self.mem_ns(len as usize);
                let payload = if self.hbm.is_phantom() {
                    Payload::phantom(len as usize)
                } else {
                    Payload::from_bytes(self.hbm.read(pa, len as usize)?)
                };
                let resp = self.reply(src, pkt.seq, Instruction::ReadResp { addr }, payload);
                emits.push(Emit { delay: t, pkt: resp });
            }

            Instruction::Write { addr } => {
                let len = pkt.payload.len();
                let pa = self.iommu.translate(addr, len, Access::Write)?;
                let t = fixed + self.mem_ns(len);
                if let Some(bytes) = pkt.payload.bytes() {
                    self.hbm.write(pa, bytes)?;
                }
                if flags.reliable() {
                    let ack = self.reply_seq(src, pkt.seq, Instruction::WriteAck { addr });
                    emits.push(Emit { delay: t, pkt: ack });
                }
            }

            Instruction::Cas {
                addr,
                expected,
                new,
            } => {
                let pa = self.iommu.translate(addr, 8, Access::Write)?;
                let t = fixed + self.mem_ns(8);
                let cur = u64::from_le_bytes(self.hbm.read(pa, 8)?.try_into().unwrap());
                let swapped = cur == expected;
                if swapped {
                    self.hbm.write(pa, &new.to_le_bytes())?;
                }
                let resp = self.reply_seq(
                    src,
                    pkt.seq,
                    Instruction::CasResp {
                        addr,
                        old: cur,
                        swapped,
                    },
                );
                emits.push(Emit { delay: t, pkt: resp });
            }

            Instruction::Memcopy { src: s, dst, len } => {
                let ps = self.iommu.translate(s, len as usize, Access::Read)?;
                let pd = self.iommu.translate(dst, len as usize, Access::Write)?;
                // Two bursts: read + write.
                let t = fixed + self.mem_ns(len as usize) + self.mem_ns(len as usize);
                let data = self.hbm.read(ps, len as usize)?;
                self.hbm.write(pd, &data)?;
                if flags.reliable() {
                    let ack = self.reply_seq(src, pkt.seq, Instruction::Ack { acked: pkt.seq });
                    emits.push(Emit { delay: t, pkt: ack });
                }
            }

            Instruction::Simd { op, addr } => {
                let len = pkt.payload.len();
                let lanes = len / 4;
                let access = if flags.store() { Access::Write } else { Access::Read };
                let pa = self.iommu.translate(addr, len, access)?;
                let t = fixed + self.mem_ns(len) + self.alu_ns(lanes)
                    + if flags.store() { self.mem_ns(len) } else { 0 };
                let result = match pkt.payload.bytes() {
                    Some(bytes) => {
                        let mut acc = bytes_to_f32s(bytes)?;
                        let operand = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
                        self.alu.apply(op, &mut acc, &operand);
                        Payload::from_bytes(f32s_to_bytes(&acc))
                    }
                    None => Payload::phantom(len),
                };
                if flags.store() {
                    if let Some(bytes) = result.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                    if flags.reliable() {
                        let ack = self.reply_seq(src, pkt.seq, Instruction::SimdResp { addr });
                        emits.push(Emit { delay: t, pkt: ack });
                    }
                } else {
                    let resp = self.reply(src, pkt.seq, Instruction::SimdResp { addr }, result);
                    emits.push(Emit { delay: t, pkt: resp });
                }
            }

            Instruction::BlockHash { addr, len } => {
                let pa = self.iommu.translate(addr, len as usize, Access::Read)?;
                let t = fixed + self.mem_ns(len as usize) + self.alu_ns(len as usize / 4);
                let hash = block_hash(&self.hbm.read(pa, len as usize)?);
                let resp = self.reply_seq(src, pkt.seq, Instruction::BlockHashResp { hash });
                emits.push(Emit { delay: t, pkt: resp });
            }

            Instruction::WriteIfHash { addr, expect_hash } => {
                let len = pkt.payload.len();
                let pa = self.iommu.translate(addr, len, Access::Write)?;
                let t = fixed + self.mem_ns(len) * 2 + self.alu_ns(len / 4);
                let ok = if self.hbm.is_phantom() {
                    true // timing mode: guard always passes (documented)
                } else {
                    block_hash(&self.hbm.read(pa, len)?) == expect_hash
                };
                if ok {
                    if let Some(bytes) = pkt.payload.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                    if flags.reliable() {
                        let ack = self.reply_seq(src, pkt.seq, Instruction::WriteAck { addr });
                        emits.push(Emit { delay: t, pkt: ack });
                    }
                } else {
                    self.drops_hash_guard += 1;
                }
            }

            Instruction::ReduceScatter {
                op,
                addr,
                block,
                rs_left,
                expect_hash,
            } => {
                let len = pkt.payload.len();
                let lanes = len / 4;
                let owner = rs_left <= 1;
                let access = if owner { Access::Write } else { Access::Read };
                let pa = self.iommu.translate(addr, len, access)?;
                if !owner {
                    // Interim hop: payload ⊕= local contribution, forward.
                    // No side effect on local memory — idempotent (§3.1).
                    let t = fixed + self.mem_ns(len) + self.alu_ns(lanes);
                    let new_payload = match pkt.payload.bytes() {
                        Some(bytes) => {
                            let mut acc = bytes_to_f32s(bytes)?;
                            let local = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
                            self.alu.apply(op, &mut acc, &local);
                            Payload::from_bytes(f32s_to_bytes(&acc))
                        }
                        None => Payload::phantom(len),
                    };
                    pkt.srou.advance();
                    pkt.instr = Instruction::ReduceScatter {
                        op,
                        addr,
                        block,
                        rs_left: rs_left - 1,
                        expect_hash,
                    };
                    pkt.payload = new_payload;
                    emits.push(Emit { delay: t, pkt });
                } else {
                    // Chunk owner: add local contribution, hash-guarded
                    // write (exactly-once under retransmission), then if
                    // the SROU stack continues, emit the fused All-Gather
                    // chain carrying the fully-reduced block.
                    let t = fixed + self.mem_ns(len) * 2 + self.alu_ns(lanes) * 2;
                    let pristine_ok = if self.hbm.is_phantom() {
                        true
                    } else {
                        let local = self.hbm.read(pa, len)?;
                        block_hash(&local) == expect_hash
                    };
                    let reduced: Payload = if let Some(bytes) = pkt.payload.bytes() {
                        if pristine_ok {
                            let mut acc = bytes_to_f32s(bytes)?;
                            let local = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
                            self.alu.apply(op, &mut acc, &local);
                            self.hbm.write(pa, &f32s_to_bytes(&acc))?;
                            Payload::from_bytes(self.hbm.read(pa, len)?)
                        } else {
                            // Duplicate chain (retransmit): memory already
                            // holds the reduced block; replay the gather
                            // from it so end-to-end retries still finish.
                            self.drops_hash_guard += 1;
                            Payload::from_bytes(self.hbm.read(pa, len)?)
                        }
                    } else {
                        Payload::phantom(len)
                    };
                    match pkt.srou.advance() {
                        Some(_) => {
                            pkt.instr = Instruction::AllGather { addr, block };
                            pkt.payload = reduced;
                            emits.push(Emit { delay: t, pkt });
                        }
                        None => {
                            let done = self.reply_seq(
                                src,
                                pkt.seq,
                                Instruction::CollectiveDone { block },
                            );
                            emits.push(Emit { delay: t, pkt: done });
                        }
                    }
                }
            }

            Instruction::AllGather { addr, block } => {
                let len = pkt.payload.len();
                let pa = self.iommu.translate(addr, len, Access::Write)?;
                let t = fixed + self.mem_ns(len);
                if let Some(bytes) = pkt.payload.bytes() {
                    self.hbm.write(pa, bytes)?; // plain write: idempotent
                }
                if pkt.srou.at_last_hop() {
                    let done = self.reply_seq(src, pkt.seq, Instruction::CollectiveDone { block });
                    emits.push(Emit { delay: t, pkt: done });
                } else {
                    pkt.srou.advance();
                    emits.push(Emit { delay: t, pkt });
                }
            }

            // Responses / completions: land in the completion queue for the
            // attached host (memif poll-mode driver).
            Instruction::ReadResp { .. }
            | Instruction::WriteAck { .. }
            | Instruction::CasResp { .. }
            | Instruction::SimdResp { .. }
            | Instruction::BlockHashResp { .. }
            | Instruction::CollectiveDone { .. }
            | Instruction::Ack { .. }
            | Instruction::Nack { .. }
            | Instruction::MallocResp { .. }
            | Instruction::FreeResp { .. } => {
                let t = fixed; // parse + land in CQ
                let _ = t;
                self.completions.push((now, pkt));
            }

            // Pool control is handled by the SDN controller (pool module),
            // not by devices; receiving one here is a misdelivery.
            Instruction::Malloc { .. } | Instruction::Free { .. } => {
                anyhow::bail!("pool control packet delivered to a device");
            }

            Instruction::User { .. } => unreachable!("handled above"),
        }
        Ok(emits)
    }

    fn execute_user(
        &mut self,
        _now: SimTime,
        mut pkt: Packet,
        opcode: u16,
        a: u64,
        b: u64,
        c: u64,
    ) -> Result<Vec<Emit>> {
        debug_assert!(opcode >= USER_OPCODE_BASE);
        let registry = Arc::clone(&self.registry);
        let Some(handler) = registry.get(opcode) else {
            anyhow::bail!("no handler for user opcode {opcode:#06x}");
        };
        let empty: &[u8] = &[];
        let payload_bytes = pkt.payload.bytes().unwrap_or(empty).to_vec();
        let cost = handler.cost_ns(pkt.payload.len());
        let t = self.fixed_ns() + self.mem_ns(pkt.payload.len().max(8)) + cost;
        let mut ctx = ExecCtx {
            mem: &mut self.hbm,
            payload: &payload_bytes,
            a,
            b,
            c,
            flags: pkt.flags,
        };
        let outcome = handler.execute(&mut ctx)?;
        let mut emits = Vec::new();
        match outcome {
            ExecOutcome::Consume | ExecOutcome::Drop => {}
            ExecOutcome::Reply {
                opcode,
                a,
                b,
                c,
                payload,
            } => {
                let resp = self.reply(
                    pkt.src,
                    pkt.seq,
                    Instruction::User { opcode, a, b, c },
                    Payload::from_bytes(payload),
                );
                emits.push(Emit { delay: t, pkt: resp });
            }
            ExecOutcome::Forward { payload } => {
                pkt.srou.advance();
                if pkt.srou.current().is_some() {
                    pkt.payload = Payload::from_bytes(payload);
                    emits.push(Emit { delay: t, pkt });
                }
            }
        }
        Ok(emits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Flags, SimdOp};
    use crate::wire::Segment;

    fn dev(ip: u8) -> NetDamDevice {
        NetDamDevice::new(
            DeviceConfig::paper_default(DeviceIp::lan(ip)),
            Arc::new(InstructionRegistry::new()),
        )
    }

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    fn direct(src: u8, dst: u8, instr: Instruction) -> Packet {
        Packet::new(ip(src), 1, SrouHeader::direct(ip(dst)), instr)
    }

    #[test]
    fn read_returns_data_with_pipeline_delay() {
        let mut d = dev(2);
        d.mem().write(0x100, &[9u8; 128]).unwrap();
        let emits = d.handle_packet(0, direct(1, 2, Instruction::Read { addr: 0x100, len: 128 }));
        assert_eq!(emits.len(), 1);
        let e = &emits[0];
        assert!(matches!(e.pkt.instr, Instruction::ReadResp { addr: 0x100 }));
        assert_eq!(e.pkt.dst().unwrap(), ip(1));
        assert_eq!(e.pkt.payload.bytes().unwrap(), &[9u8; 128][..]);
        // E1 envelope: fixed + HBM, should be in the paper's ballpark.
        assert!(e.delay > 400 && e.delay < 1000, "delay {}", e.delay);
    }

    #[test]
    fn write_is_silent_unless_reliable() {
        let mut d = dev(2);
        let w = direct(1, 2, Instruction::Write { addr: 0 })
            .with_payload(Payload::from_bytes(vec![5; 16]));
        assert!(d.handle_packet(0, w).is_empty());
        assert_eq!(d.mem().read(0, 16).unwrap(), vec![5; 16]);

        let w = direct(1, 2, Instruction::Write { addr: 32 })
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_bytes(vec![7; 4]));
        let emits = d.handle_packet(0, w);
        assert!(matches!(emits[0].pkt.instr, Instruction::WriteAck { addr: 32 }));
    }

    #[test]
    fn cas_swaps_exactly_once() {
        let mut d = dev(2);
        d.mem().write(8, &42u64.to_le_bytes()).unwrap();
        let cas = |exp, new| direct(1, 2, Instruction::Cas { addr: 8, expected: exp, new });
        let e1 = d.handle_packet(0, cas(42, 100));
        assert!(matches!(
            e1[0].pkt.instr,
            Instruction::CasResp { swapped: true, old: 42, .. }
        ));
        let e2 = d.handle_packet(0, cas(42, 200));
        assert!(matches!(
            e2[0].pkt.instr,
            Instruction::CasResp { swapped: false, old: 100, .. }
        ));
    }

    #[test]
    fn simd_add_against_memory() {
        let mut d = dev(2);
        let local: Vec<f32> = vec![10.0, 20.0, 30.0];
        d.mem().write(0, &f32s_to_bytes(&local)).unwrap();
        let pkt = direct(1, 2, Instruction::Simd { op: SimdOp::Add, addr: 0 })
            .with_payload(Payload::from_f32s(&[1.0, 2.0, 3.0]));
        let emits = d.handle_packet(0, pkt);
        let got = emits[0].pkt.payload.f32s().unwrap().unwrap();
        assert_eq!(got, vec![11.0, 22.0, 33.0]);
        // Memory unchanged without STORE.
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            local
        );
    }

    #[test]
    fn simd_store_writes_back() {
        let mut d = dev(2);
        d.mem().write(0, &f32s_to_bytes(&[1.0, 1.0])).unwrap();
        let pkt = direct(1, 2, Instruction::Simd { op: SimdOp::Mul, addr: 0 })
            .with_flags(Flags(Flags::STORE))
            .with_payload(Payload::from_f32s(&[3.0, 4.0]));
        let emits = d.handle_packet(0, pkt);
        assert!(emits.is_empty()); // not reliable → silent
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 8).unwrap()).unwrap(),
            vec![3.0, 4.0]
        );
    }

    #[test]
    fn write_if_hash_guards_duplicates() {
        let mut d = dev(2);
        let pristine: Vec<f32> = vec![4.0, 5.0, 6.0];
        d.mem().write(0, &f32s_to_bytes(&pristine)).unwrap();
        let guard = block_hash(&f32s_to_bytes(&pristine));
        let mk = || {
            direct(1, 2, Instruction::WriteIfHash { addr: 0, expect_hash: guard })
                .with_payload(Payload::from_f32s(&[7.0, 8.0, 9.0]))
        };
        d.handle_packet(0, mk());
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            vec![7.0, 8.0, 9.0]
        );
        // Duplicate (retransmit): hash no longer matches → dropped.
        d.handle_packet(0, mk());
        assert_eq!(d.drops_hash_guard, 1);
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            vec![7.0, 8.0, 9.0]
        );
    }

    #[test]
    fn reduce_scatter_interim_hop_accumulates_and_forwards() {
        let mut d = dev(2);
        d.mem().write(0, &f32s_to_bytes(&[10.0, 10.0])).unwrap();
        let srou = SrouHeader::through(vec![Segment::to(ip(2)), Segment::to(ip(3))]);
        let pkt = Packet::new(
            ip(1),
            1,
            srou,
            Instruction::ReduceScatter {
                op: SimdOp::Add,
                addr: 0,
                block: 0,
                rs_left: 2,
                expect_hash: 0,
            },
        )
        .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits.len(), 1);
        let fwd = &emits[0].pkt;
        assert_eq!(fwd.dst().unwrap(), ip(3), "self-routed to next segment");
        assert_eq!(
            fwd.payload.f32s().unwrap().unwrap(),
            vec![11.0, 12.0],
            "payload accumulated in packet buffer"
        );
        // Local memory untouched: interim hop is idempotent.
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 8).unwrap()).unwrap(),
            vec![10.0, 10.0]
        );
    }

    #[test]
    fn reduce_scatter_last_hop_writes_with_guard() {
        let mut d = dev(4);
        let local = vec![100.0f32, 200.0];
        d.mem().write(64, &f32s_to_bytes(&local)).unwrap();
        let guard = block_hash(&f32s_to_bytes(&local));
        let mk = || {
            Packet::new(
                ip(3),
                9,
                SrouHeader::direct(ip(4)),
                Instruction::ReduceScatter {
                    op: SimdOp::Add,
                    addr: 64,
                    block: 5,
                    rs_left: 1,
                    expect_hash: guard,
                },
            )
            .with_payload(Payload::from_f32s(&[1.0, 2.0]))
        };
        let emits = d.handle_packet(0, mk());
        assert!(matches!(
            emits[0].pkt.instr,
            Instruction::CollectiveDone { block: 5 }
        ));
        assert_eq!(
            bytes_to_f32s(&d.mem().read(64, 8).unwrap()).unwrap(),
            vec![101.0, 202.0]
        );
        // Retransmit: guard fails, memory stable; the Done is *re-emitted*
        // (the retry may exist because the original Done was lost).
        let emits = d.handle_packet(0, mk());
        assert!(matches!(
            emits[0].pkt.instr,
            Instruction::CollectiveDone { block: 5 }
        ));
        assert_eq!(d.drops_hash_guard, 1);
        assert_eq!(
            bytes_to_f32s(&d.mem().read(64, 8).unwrap()).unwrap(),
            vec![101.0, 202.0]
        );
    }

    #[test]
    fn all_gather_writes_and_chains() {
        let mut d = dev(2);
        let srou = SrouHeader::through(vec![Segment::to(ip(2)), Segment::to(ip(3))]);
        let pkt = Packet::new(ip(1), 1, srou, Instruction::AllGather { addr: 0, block: 1 })
            .with_payload(Payload::from_f32s(&[5.0]));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits[0].pkt.dst().unwrap(), ip(3));
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 4).unwrap()).unwrap(),
            vec![5.0]
        );
    }

    #[test]
    fn responses_land_in_completion_queue() {
        let mut d = dev(1);
        let resp = direct(2, 1, Instruction::ReadResp { addr: 0 })
            .with_payload(Payload::from_bytes(vec![1, 2, 3]));
        assert!(d.handle_packet(77, resp).is_empty());
        let comps = d.drain_completions();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].0, 77);
        assert!(d.drain_completions().is_empty());
    }

    #[test]
    fn unknown_user_opcode_is_counted_error() {
        let mut d = dev(2);
        let pkt = direct(1, 2, Instruction::User { opcode: 0x9999, a: 0, b: 0, c: 0 });
        assert!(d.handle_packet(0, pkt).is_empty());
        assert_eq!(d.exec_errors, 1);
    }

    #[test]
    fn phantom_device_charges_time_without_data() {
        let mut d = NetDamDevice::new(
            DeviceConfig::paper_default(DeviceIp::lan(2)).timing_only(),
            Arc::new(InstructionRegistry::new()),
        );
        let pkt = direct(1, 2, Instruction::Read { addr: 0, len: 8192 });
        let emits = d.handle_packet(0, pkt);
        assert!(emits[0].pkt.payload.is_phantom());
        assert_eq!(emits[0].pkt.payload.len(), 8192);
        assert!(emits[0].delay > 400);
    }
}
