//! The NetDAM device: instruction execution in the fixed pipeline.
//!
//! Single instructions execute exactly as before; packets carrying an
//! [`Instruction::Program`] run through the **micro-executor loop**
//! (`execute_program`): each step executes hop-locally
//! against HBM with per-step cost accounting, fused steps chain on the
//! same device with operand forwarding, and `repeat` steps self-route
//! along the SROU segment list — the §3 fused allreduce and chained DPU
//! offloads without any bespoke opcode.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::alu::{block_hash, AluBackend, NativeAlu};
use crate::iommu::{Access, Iommu, IommuFault, TenantId};
use crate::isa::registry::{ExecCtx, ExecOutcome, InstructionRegistry, MemAccess};
use crate::isa::{Flags, Instruction, Program, Step, NO_COMPLETION, USER_OPCODE_BASE};
use crate::sim::SimTime;
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::Xoshiro256;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};

use super::hbm::Hbm;
use super::pipeline::DeviceConfig;

/// A packet the device wants to transmit, `delay` ns after the packet
/// that triggered it *arrived* (the delay covers the full pipeline).
#[derive(Debug)]
pub struct Emit {
    pub delay: SimTime,
    pub pkt: Packet,
}

/// Bound on the response-dedupe cache (entries; FIFO eviction). Sized to
/// comfortably outlive any retransmit window: a retried request arrives
/// within `timeout × max_retries` of the original, during which a host
/// issues far fewer than this many non-idempotent ops.
const RESP_CACHE_CAP: usize = 4096;

/// Bound on the aggregation-group seen-set (groups; FIFO eviction) — the
/// root-collector analog of [`RESP_CACHE_CAP`]: a retransmitted
/// contribution lands within its retry window, during which far fewer
/// than this many aggregation groups terminate at one device.
const AGG_GROUPS_CAP: usize = 4096;

/// Side channel out of one program step.
enum StepNote {
    /// Nothing beyond the payload transformation.
    None,
    /// A user handler produced a reply (emitted if the program retires
    /// on this step and carries no completion id).
    Reply {
        opcode: u16,
        a: u64,
        b: u64,
        c: u64,
        payload: Vec<u8>,
    },
    /// A user handler dropped the packet (guard failed): abort silently.
    Halt,
}

/// One NetDAM device.
pub struct NetDamDevice {
    cfg: DeviceConfig,
    hbm: Hbm,
    iommu: Iommu,
    /// Requester ACL programmed by the SDN controller (§2.6): which
    /// tenant a packet source is attributed to for IOMMU lease checks.
    tenant_acl: HashMap<DeviceIp, TenantId>,
    /// Tenant attribution of the packet currently executing.
    req_tenant: Option<TenantId>,
    /// Typed fault captured by the last failed translation (consumed by
    /// `handle_packet` to emit the wire NAK).
    last_fault: Option<IommuFault>,
    alu: Box<dyn AluBackend>,
    registry: Arc<InstructionRegistry>,
    rng: Xoshiro256,
    /// Next sequence number for device-originated packets.
    seq: u64,
    /// Completion queue ("memif" side): packets addressed to this device
    /// that carry responses/completions, for the attached host to drain.
    completions: Vec<(SimTime, Packet)>,
    /// Response-dedupe cache for non-idempotent ops (CAS), keyed on
    /// `(src, seq)`: a reliable retransmit of an already-executed request
    /// replays the original response instead of re-executing — the
    /// replay-safety half of §3.1 that hash guards cannot provide for
    /// read-modify-write atomics.
    resp_cache: HashMap<(DeviceIp, u64), Instruction>,
    resp_cache_fifo: VecDeque<(DeviceIp, u64)>,
    /// Root-collector state for in-network aggregation (PR 7): which
    /// contribution identities have already been folded, per
    /// `(tenant, group)`. Makes replayed manifests re-emit completions
    /// instead of double-folding.
    agg_seen: HashMap<(u32, u32), HashSet<(DeviceIp, u64)>>,
    agg_seen_fifo: VecDeque<(u32, u32)>,
    /// Counters for metrics.
    pub pkts_in: u64,
    pub pkts_out: u64,
    pub drops_hash_guard: u64,
    pub exec_errors: u64,
    /// Translations denied by the IOMMU and NAK'd back on the wire.
    pub iommu_naks: u64,
    /// Program steps executed locally (micro-executor throughput).
    pub prog_steps: u64,
    /// Retransmits answered from the response-dedupe cache (replays that
    /// would otherwise have re-executed a non-idempotent op).
    pub resp_cache_hits: u64,
    /// Aggregated contributions folded into memory (root collector).
    pub agg_folds: u64,
    /// Fully-seen manifests whose completions were re-emitted.
    pub agg_replays: u64,
    /// Manifests dropped because they mixed folded and unfolded
    /// contributions (the unfolded ones arrive again unmerged).
    pub agg_mixed_drops: u64,
}

impl NetDamDevice {
    pub fn new(cfg: DeviceConfig, registry: Arc<InstructionRegistry>) -> Self {
        let hbm = if cfg.data_bearing {
            Hbm::new(cfg.hbm.clone())
        } else {
            Hbm::new_phantom(cfg.hbm.clone())
        };
        let rng = Xoshiro256::seed_from(cfg.seed ^ 0xDA_DE_71CE);
        Self {
            cfg,
            hbm,
            iommu: Iommu::identity(),
            tenant_acl: HashMap::new(),
            req_tenant: None,
            last_fault: None,
            alu: Box::new(NativeAlu::new()),
            registry,
            rng,
            seq: 1,
            completions: Vec::new(),
            resp_cache: HashMap::new(),
            resp_cache_fifo: VecDeque::new(),
            agg_seen: HashMap::new(),
            agg_seen_fifo: VecDeque::new(),
            pkts_in: 0,
            pkts_out: 0,
            drops_hash_guard: 0,
            exec_errors: 0,
            iommu_naks: 0,
            prog_steps: 0,
            resp_cache_hits: 0,
            agg_folds: 0,
            agg_replays: 0,
            agg_mixed_drops: 0,
        }
    }

    pub fn ip(&self) -> DeviceIp {
        self.cfg.ip
    }

    pub fn cfg(&self) -> &DeviceConfig {
        &self.cfg
    }

    /// Direct host-side memory access (memif): bypasses the network but
    /// not the HBM. Used by examples and the pool controller.
    pub fn mem(&mut self) -> &mut Hbm {
        &mut self.hbm
    }

    pub fn mem_ref(&self) -> &Hbm {
        &self.hbm
    }

    pub fn iommu_mut(&mut self) -> &mut Iommu {
        &mut self.iommu
    }

    pub fn iommu_ref(&self) -> &Iommu {
        &self.iommu
    }

    /// Program the requester ACL: packets sourced from `host` are
    /// attributed to `tenant` when the IOMMU checks leases. Installed by
    /// the SDN controller (`pool::SdnController::grant_host`).
    pub fn bind_tenant(&mut self, host: DeviceIp, tenant: TenantId) {
        self.tenant_acl.insert(host, tenant);
    }

    /// Translate through the IOMMU with the current packet's tenant
    /// attribution, capturing the typed fault for the NAK path.
    fn xlate(&mut self, addr: u64, len: usize, access: Access) -> Result<u64> {
        match self.iommu.translate_req(addr, len, access, self.req_tenant) {
            Ok(pa) => Ok(pa),
            Err(fault) => {
                self.last_fault = Some(fault);
                Err(fault.into())
            }
        }
    }

    /// Swap in a different ALU backend (e.g. `runtime::XlaAlu`).
    pub fn set_alu(&mut self, alu: Box<dyn AluBackend>) {
        self.alu = alu;
    }

    /// Drain the completion queue (host poll-mode driver).
    pub fn drain_completions(&mut self) -> Vec<(SimTime, Packet)> {
        std::mem::take(&mut self.completions)
    }

    pub fn next_seq(&mut self) -> u64 {
        let s = self.seq;
        self.seq += 1;
        s
    }

    /// Process an arriving packet. `now` is the arrival time; returned
    /// emits are relative to it. Convenience wrapper over
    /// [`Self::handle_packet_into`] (tests, simple drivers).
    pub fn handle_packet(&mut self, now: SimTime, pkt: Packet) -> Vec<Emit> {
        let mut out = Vec::new();
        self.handle_packet_into(now, pkt, &mut out);
        out
    }

    /// Process an arriving packet, appending emissions to `out` (the DES
    /// hot path reuses one buffer across calls, so steady-state execution
    /// performs no per-packet allocation). A translation denied by the
    /// IOMMU is NAK'd back on the wire with the fault's typed reason
    /// (§2.6 — the device enforces the controller's ACL); other malformed
    /// packets count as exec_errors and are dropped (the hardware would
    /// raise an error CQE).
    pub fn handle_packet_into(&mut self, now: SimTime, pkt: Packet, out: &mut Vec<Emit>) {
        self.pkts_in += 1;
        self.last_fault = None;
        let (src, seq) = (pkt.src, pkt.seq);
        // A CE mark on the request must be echoed into everything this
        // device emits for it (replies travel the uncongested reverse
        // path, so without the echo the origin never sees congestion —
        // this is the CNP half of the DCQCN loop; forwarded program hops
        // keep the mark like the same IP packet would).
        let ce = pkt.flags.ecn();
        let start = out.len();
        match self.execute(now, pkt, out) {
            Ok(()) => {
                self.pkts_out += (out.len() - start) as u64;
            }
            Err(_) => {
                out.truncate(start); // discard partial emissions
                match self.last_fault.take() {
                    Some(fault) => {
                        self.iommu_naks += 1;
                        let delay = self.fixed_ns();
                        let nak = self.reply_seq(
                            src,
                            seq,
                            Instruction::Nack {
                                acked: seq,
                                reason: fault.reason() as u8,
                            },
                        );
                        self.pkts_out += 1;
                        out.push(Emit { delay, pkt: nak });
                    }
                    None => {
                        self.exec_errors += 1;
                    }
                }
            }
        }
        if ce {
            for e in &mut out[start..] {
                e.pkt.flags = e.pkt.flags.with(Flags::ECN);
            }
        }
    }

    /// Fixed pipeline cost excluding memory/ALU.
    fn fixed_ns(&self) -> SimTime {
        self.cfg.pipeline.fixed_ns()
    }

    fn mem_ns(&mut self, len: usize) -> SimTime {
        self.hbm.access_ns(len, &mut self.rng)
    }

    fn alu_ns(&self, lanes: usize) -> SimTime {
        self.cfg.alu.exec_ns(lanes)
    }

    /// Build a reply routed straight back to `dst`, echoing the request's
    /// sequence number (responses correlate to requests RDMA-PSN-style;
    /// the reliability table keys on it).
    fn reply_seq(&mut self, dst: DeviceIp, seq: u64, instr: Instruction) -> Packet {
        Packet::new(self.cfg.ip, seq, SrouHeader::direct(dst), instr)
    }

    fn reply(&mut self, dst: DeviceIp, seq: u64, instr: Instruction, payload: Payload) -> Packet {
        self.reply_seq(dst, seq, instr).with_payload(payload)
    }

    /// Bounded FIFO insert into the response-dedupe cache.
    fn cache_response(&mut self, src: DeviceIp, seq: u64, resp: Instruction) {
        if self.resp_cache.len() >= RESP_CACHE_CAP {
            if let Some(old) = self.resp_cache_fifo.pop_front() {
                self.resp_cache.remove(&old);
            }
        }
        if self.resp_cache.insert((src, seq), resp).is_none() {
            self.resp_cache_fifo.push_back((src, seq));
        }
    }

    fn execute(&mut self, now: SimTime, pkt: Packet, out: &mut Vec<Emit>) -> Result<()> {
        let flags = pkt.flags;
        let src = pkt.src;
        // Attribute the request to a tenant for IOMMU lease checks (the
        // §2.6 ACL the controller programmed; None = unattributed).
        self.req_tenant = self.tenant_acl.get(&src).copied();
        let emits = out;
        let fixed = self.fixed_ns();

        // Raw user-defined opcode? Dispatch through the registry.
        if let Instruction::User { opcode, a, b, c } = pkt.instr {
            return self.execute_user(now, pkt, opcode, a, b, c, emits);
        }
        // Packet program? Run the micro-executor loop. The program is
        // moved out (the `Arc` travels with the packet, copy-on-write at
        // cursor updates) — no per-hop deep clone on the collective path.
        if matches!(pkt.instr, Instruction::Program(_)) {
            let mut pkt = pkt;
            let Instruction::Program(prog) = std::mem::replace(&mut pkt.instr, Instruction::Nop)
            else {
                unreachable!()
            };
            return self.execute_program(pkt, prog, emits);
        }
        // Terminal hop of an in-network aggregation tree? The root folds
        // the switch-combined contribution and answers the manifest.
        if flags.agg() {
            return self.execute_agg(pkt, emits);
        }

        match pkt.instr.clone() {
            Instruction::Nop => {}

            Instruction::Read { addr, len } => {
                let pa = self.xlate(addr, len as usize, Access::Read)?;
                let t = fixed + self.mem_ns(len as usize);
                let payload = if self.hbm.is_phantom() {
                    Payload::phantom(len as usize)
                } else {
                    Payload::from_bytes(self.hbm.read(pa, len as usize)?)
                };
                let resp = self.reply(src, pkt.seq, Instruction::ReadResp { addr }, payload);
                emits.push(Emit { delay: t, pkt: resp });
            }

            Instruction::Write { addr } => {
                let len = pkt.payload.len();
                let pa = self.xlate(addr, len, Access::Write)?;
                let t = fixed + self.mem_ns(len);
                if let Some(bytes) = pkt.payload.bytes() {
                    self.hbm.write(pa, bytes)?;
                }
                if flags.reliable() {
                    let ack = self.reply_seq(src, pkt.seq, Instruction::WriteAck { addr });
                    emits.push(Emit { delay: t, pkt: ack });
                }
            }

            Instruction::Cas {
                addr,
                expected,
                new,
            } => {
                // Replay-safe CAS: if this (src, seq) already executed,
                // the request is a retransmit whose *response* was lost —
                // re-executing would swap-fail and lie `swapped=false` to
                // the winner. Replay the cached original outcome instead.
                let cached = self.resp_cache.get(&(src, pkt.seq)).cloned();
                if let Some(replay) = cached {
                    self.resp_cache_hits += 1;
                    let resp = self.reply_seq(src, pkt.seq, replay);
                    emits.push(Emit { delay: fixed, pkt: resp });
                } else {
                    let pa = self.xlate(addr, 8, Access::Write)?;
                    let t = fixed + self.mem_ns(8);
                    let cur = u64::from_le_bytes(self.hbm.read(pa, 8)?.try_into().unwrap());
                    let swapped = cur == expected;
                    if swapped {
                        self.hbm.write(pa, &new.to_le_bytes())?;
                    }
                    let outcome = Instruction::CasResp {
                        addr,
                        old: cur,
                        swapped,
                    };
                    self.cache_response(src, pkt.seq, outcome.clone());
                    let resp = self.reply_seq(src, pkt.seq, outcome);
                    emits.push(Emit { delay: t, pkt: resp });
                }
            }

            Instruction::Memcopy { src: s, dst, len } => {
                let ps = self.xlate(s, len as usize, Access::Read)?;
                let pd = self.xlate(dst, len as usize, Access::Write)?;
                // Two bursts: read + write.
                let t = fixed + self.mem_ns(len as usize) + self.mem_ns(len as usize);
                let data = self.hbm.read(ps, len as usize)?;
                self.hbm.write(pd, &data)?;
                if flags.reliable() {
                    let ack = self.reply_seq(src, pkt.seq, Instruction::Ack { acked: pkt.seq });
                    emits.push(Emit { delay: t, pkt: ack });
                }
            }

            Instruction::Simd { op, addr } => {
                let len = pkt.payload.len();
                let lanes = len / 4;
                let access = if flags.store() { Access::Write } else { Access::Read };
                let pa = self.xlate(addr, len, access)?;
                let t = fixed + self.mem_ns(len) + self.alu_ns(lanes)
                    + if flags.store() { self.mem_ns(len) } else { 0 };
                let result = match pkt.payload.bytes() {
                    Some(bytes) => {
                        let mut acc = bytes_to_f32s(bytes)?;
                        let operand = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
                        self.alu.apply(op, &mut acc, &operand);
                        Payload::from_bytes(f32s_to_bytes(&acc))
                    }
                    None => Payload::phantom(len),
                };
                if flags.store() {
                    if let Some(bytes) = result.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                    if flags.reliable() {
                        let ack = self.reply_seq(src, pkt.seq, Instruction::SimdResp { addr });
                        emits.push(Emit { delay: t, pkt: ack });
                    }
                } else {
                    let resp = self.reply(src, pkt.seq, Instruction::SimdResp { addr }, result);
                    emits.push(Emit { delay: t, pkt: resp });
                }
            }

            Instruction::BlockHash { addr, len } => {
                let pa = self.xlate(addr, len as usize, Access::Read)?;
                let t = fixed + self.mem_ns(len as usize) + self.alu_ns(len as usize / 4);
                let hash = block_hash(&self.hbm.read(pa, len as usize)?);
                let resp = self.reply_seq(src, pkt.seq, Instruction::BlockHashResp { hash });
                emits.push(Emit { delay: t, pkt: resp });
            }

            Instruction::WriteIfHash { addr, expect_hash } => {
                let len = pkt.payload.len();
                let pa = self.xlate(addr, len, Access::Write)?;
                let t = fixed + self.mem_ns(len) * 2 + self.alu_ns(len / 4);
                let ok = if self.hbm.is_phantom() {
                    true // timing mode: guard always passes (documented)
                } else {
                    block_hash(&self.hbm.read(pa, len)?) == expect_hash
                };
                if ok {
                    if let Some(bytes) = pkt.payload.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                    if flags.reliable() {
                        let ack = self.reply_seq(src, pkt.seq, Instruction::WriteAck { addr });
                        emits.push(Emit { delay: t, pkt: ack });
                    }
                } else {
                    self.drops_hash_guard += 1;
                }
            }

            // Responses / completions: land in the completion queue for the
            // attached host (memif poll-mode driver).
            Instruction::ReadResp { .. }
            | Instruction::WriteAck { .. }
            | Instruction::CasResp { .. }
            | Instruction::SimdResp { .. }
            | Instruction::BlockHashResp { .. }
            | Instruction::CollectiveDone { .. }
            | Instruction::Ack { .. }
            | Instruction::Nack { .. }
            | Instruction::MallocResp { .. }
            | Instruction::FreeResp { .. } => {
                let t = fixed; // parse + land in CQ
                let _ = t;
                self.completions.push((now, pkt));
            }

            // Pool control is handled by the SDN controller (pool module),
            // not by devices; receiving one here is a misdelivery.
            Instruction::Malloc { .. } | Instruction::Free { .. } => {
                bail!("pool control packet delivered to a device");
            }

            Instruction::Program(_) | Instruction::User { .. } => unreachable!("handled above"),
        }
        Ok(())
    }

    // ------------------------------------------------- program executor

    /// The micro-executor loop: run the current step (and any fused
    /// successors) locally, then either forward the packet along the
    /// SROU path with the updated cursor, or retire the program.
    /// Terminal point of an in-network aggregation tree (paper §2.5, PR
    /// 7): fold the (possibly switch-combined) SIMD contribution into
    /// memory, then fan one `CollectiveDone` back to *every* contributor
    /// named in the manifest — each echoing that contributor's own
    /// sequence number so its reliability-table slot clears.
    ///
    /// Exactly-once under loss/duplication/eviction: a per-
    /// `(tenant, group)` seen-set records folded contribution identities.
    /// A manifest whose entries are all seen is a replay — the dones are
    /// re-emitted without touching memory. A manifest mixing seen and
    /// unseen entries is dropped: folding it would double-count the seen
    /// part, and the unseen contributions will retransmit and arrive
    /// unmerged (the switch remembers completed groups and passes late
    /// traffic through).
    fn execute_agg(&mut self, pkt: Packet, out: &mut Vec<Emit>) -> Result<()> {
        // `Arc` bump, not a manifest deep-copy.
        let Some(meta) = pkt.agg.clone() else {
            bail!("aggregation-marked packet without a manifest");
        };
        let Instruction::Simd { op, addr } = pkt.instr else {
            bail!("aggregation mark on non-SIMD instruction {:?}", pkt.instr);
        };
        let fixed = self.fixed_ns();
        let key = (meta.tenant, meta.group);
        let seen_n = self.agg_seen.get(&key).map_or(0, |s| {
            meta.entries
                .iter()
                .filter(|e| s.contains(&(e.src, e.seq)))
                .count()
        });
        if seen_n == meta.entries.len() {
            // Pure replay: the fold already happened; the contributor(s)
            // just never saw their completion. Re-emit it.
            self.agg_replays += 1;
            for e in &meta.entries {
                let done =
                    self.reply_seq(e.src, e.seq, Instruction::CollectiveDone { block: e.done_id });
                out.push(Emit { delay: fixed, pkt: done });
            }
            return Ok(());
        }
        if seen_n > 0 {
            self.agg_mixed_drops += 1;
            return Ok(());
        }
        // Same cost shape as a stored `Simd`: read the resident block,
        // one ALU pass, write the folded block back.
        let len = pkt.payload.len();
        let lanes = len / 4;
        let pa = self.xlate(addr, len, Access::Write)?;
        let t = fixed + self.mem_ns(len) + self.alu_ns(lanes) + self.mem_ns(len);
        if let Some(bytes) = pkt.payload.bytes() {
            let mut acc = bytes_to_f32s(bytes)?;
            let operand = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
            self.alu.apply(op, &mut acc, &operand);
            self.hbm.write(pa, &f32s_to_bytes(&acc))?;
        }
        if !self.agg_seen.contains_key(&key) {
            if self.agg_seen.len() >= AGG_GROUPS_CAP {
                if let Some(old) = self.agg_seen_fifo.pop_front() {
                    self.agg_seen.remove(&old);
                }
            }
            self.agg_seen_fifo.push_back(key);
        }
        let seen = self.agg_seen.entry(key).or_default();
        for e in &meta.entries {
            seen.insert((e.src, e.seq));
        }
        self.agg_folds += 1;
        for e in &meta.entries {
            let done =
                self.reply_seq(e.src, e.seq, Instruction::CollectiveDone { block: e.done_id });
            out.push(Emit { delay: t, pkt: done });
        }
        Ok(())
    }

    fn execute_program(
        &mut self,
        mut pkt: Packet,
        mut prog: Arc<Program>,
        out: &mut Vec<Emit>,
    ) -> Result<()> {
        let mut t = self.fixed_ns();
        let mut fwd: Option<(u64, u64, u64)> = None;
        loop {
            let pc = prog.pc as usize;
            ensure!(pc < prog.steps.len(), "program pc {pc} out of range");
            let payload = std::mem::replace(&mut pkt.payload, Payload::empty());
            let (cost, new_payload, note) = {
                let step = &prog.steps[pc];
                ensure!(step.repeat >= 1, "program step with repeat 0");
                self.exec_step(step, payload, &mut fwd)?
            };
            self.prog_steps += 1;
            t += cost;
            pkt.payload = new_payload;
            if matches!(note, StepNote::Halt) {
                return Ok(());
            }
            // Cursor updates go through `make_mut`: unique in steady state
            // (free), copy-on-write when a retransmit buffer still shares
            // the program.
            {
                let p = Arc::make_mut(&mut prog);
                p.reps_done = p.reps_done.saturating_add(1);
            }
            if prog.reps_done < prog.steps[pc].repeat {
                // Same step again at the next hop.
                ensure!(
                    pkt.srou.advance().is_some(),
                    "program ran out of SROU segments mid-step"
                );
                pkt.instr = Instruction::Program(prog);
                out.push(Emit { delay: t, pkt });
                return Ok(());
            }
            {
                let p = Arc::make_mut(&mut prog);
                p.pc += 1;
                p.reps_done = 0;
            }
            if prog.pc as usize >= prog.steps.len() {
                // Program retires at this device: completion id wins,
                // otherwise a final user reply, otherwise an Ack when the
                // sender asked for reliability.
                if prog.completion != NO_COMPLETION {
                    let done = self.reply_seq(
                        pkt.src,
                        pkt.seq,
                        Instruction::CollectiveDone {
                            block: prog.completion,
                        },
                    );
                    out.push(Emit { delay: t, pkt: done });
                    return Ok(());
                }
                if let StepNote::Reply {
                    opcode,
                    a,
                    b,
                    c,
                    payload,
                } = note
                {
                    let resp = self.reply(
                        pkt.src,
                        pkt.seq,
                        Instruction::User { opcode, a, b, c },
                        Payload::from_bytes(payload),
                    );
                    out.push(Emit { delay: t, pkt: resp });
                    return Ok(());
                }
                if pkt.flags.reliable() {
                    let ack = self.reply_seq(pkt.src, pkt.seq, Instruction::Ack { acked: pkt.seq });
                    out.push(Emit { delay: t, pkt: ack });
                    return Ok(());
                }
                return Ok(());
            }
            if !prog.steps[prog.pc as usize].fused {
                ensure!(
                    pkt.srou.advance().is_some(),
                    "program ran out of SROU segments between steps"
                );
                pkt.instr = Instruction::Program(prog);
                out.push(Emit { delay: t, pkt });
                return Ok(());
            }
            // Fused successor: keep executing on this device, with the
            // step's result payload as input (operand forwarding).
        }
    }

    /// Execute one program step against local memory. Returns the charged
    /// pipeline time, the step's result payload (the next step's input),
    /// and any side note.
    fn exec_step(
        &mut self,
        step: &Step,
        payload: Payload,
        fwd: &mut Option<(u64, u64, u64)>,
    ) -> Result<(SimTime, Payload, StepNote)> {
        use Instruction as I;
        let flags = step.flags;
        match &step.instr {
            I::Read { addr, len } => {
                let len = *len as usize;
                let pa = self.xlate(*addr, len, Access::Read)?;
                let t = self.mem_ns(len);
                let out = if self.hbm.is_phantom() {
                    Payload::phantom(len)
                } else {
                    Payload::from_bytes(self.hbm.read(pa, len)?)
                };
                *fwd = None;
                Ok((t, out, StepNote::None))
            }
            I::Write { addr } => {
                let len = payload.len();
                let pa = self.xlate(*addr, len, Access::Write)?;
                let t = self.mem_ns(len);
                if let Some(bytes) = payload.bytes() {
                    self.hbm.write(pa, bytes)?;
                }
                *fwd = None;
                Ok((t, payload, StepNote::None))
            }
            I::Memcopy { src, dst, len } => {
                let len = *len as usize;
                let ps = self.xlate(*src, len, Access::Read)?;
                let pd = self.xlate(*dst, len, Access::Write)?;
                let t = self.mem_ns(len) + self.mem_ns(len);
                let data = self.hbm.read(ps, len)?;
                self.hbm.write(pd, &data)?;
                *fwd = None;
                Ok((t, payload, StepNote::None))
            }
            I::Simd { op, addr } => {
                let len = payload.len();
                let lanes = len / 4;
                let access = if flags.store() { Access::Write } else { Access::Read };
                let pa = self.xlate(*addr, len, access)?;
                let mut t = self.mem_ns(len) + self.alu_ns(lanes);
                let out = match payload.bytes() {
                    Some(bytes) => {
                        let mut acc = bytes_to_f32s(bytes)?;
                        let operand = bytes_to_f32s(&self.hbm.read(pa, len)?)?;
                        self.alu.apply(*op, &mut acc, &operand);
                        Payload::from_bytes(f32s_to_bytes(&acc))
                    }
                    None => Payload::phantom(len),
                };
                if flags.store() {
                    t += self.mem_ns(len);
                    if let Some(bytes) = out.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                }
                *fwd = None;
                Ok((t, out, StepNote::None))
            }
            I::BlockHash { addr, len } => {
                let len = *len as usize;
                let pa = self.xlate(*addr, len, Access::Read)?;
                let t = self.mem_ns(len) + self.alu_ns(len / 4);
                let hash = block_hash(&self.hbm.read(pa, len)?);
                *fwd = None;
                Ok((t, Payload::from_u64(hash), StepNote::None))
            }
            I::WriteIfHash { addr, expect_hash } => {
                // Guarded write + read-back: on first delivery the payload
                // lands and reads back unchanged; on a replayed chain the
                // guard fails and the read-back substitutes the already-
                // written block, so downstream hops still see the truth.
                let len = payload.len();
                let pa = self.xlate(*addr, len, Access::Write)?;
                let t = self.mem_ns(len) * 2 + self.alu_ns(len / 4);
                if payload.is_phantom() {
                    *fwd = None;
                    return Ok((t, Payload::phantom(len), StepNote::None));
                }
                let ok = if self.hbm.is_phantom() {
                    true
                } else {
                    block_hash(&self.hbm.read(pa, len)?) == *expect_hash
                };
                if ok {
                    if let Some(bytes) = payload.bytes() {
                        self.hbm.write(pa, bytes)?;
                    }
                } else {
                    self.drops_hash_guard += 1;
                }
                let back = if self.hbm.is_phantom() {
                    Payload::phantom(len)
                } else {
                    Payload::from_bytes(self.hbm.read(pa, len)?)
                };
                *fwd = None;
                Ok((t, back, StepNote::None))
            }
            I::User { opcode, a, b, c } => {
                ensure!(*opcode >= USER_OPCODE_BASE, "user opcode below range");
                let registry = Arc::clone(&self.registry);
                let Some(handler) = registry.get(*opcode) else {
                    bail!("no handler for user opcode {opcode:#06x}");
                };
                let empty: &[u8] = &[];
                let payload_bytes = payload.bytes().unwrap_or(empty).to_vec();
                let t = self.mem_ns(payload_bytes.len().max(8)) + handler.cost_ns(payload_bytes.len());
                let mut ctx = ExecCtx {
                    mem: &mut self.hbm,
                    payload: &payload_bytes,
                    a: *a,
                    b: *b,
                    c: *c,
                    flags,
                    fwd: *fwd,
                };
                let outcome = handler.execute(&mut ctx)?;
                match outcome {
                    ExecOutcome::Consume => {
                        *fwd = None;
                        Ok((t, Payload::empty(), StepNote::None))
                    }
                    ExecOutcome::Drop => Ok((t, payload, StepNote::Halt)),
                    ExecOutcome::Forward { payload } => {
                        *fwd = None;
                        Ok((t, Payload::from_bytes(payload), StepNote::None))
                    }
                    ExecOutcome::Reply {
                        opcode,
                        a,
                        b,
                        c,
                        payload,
                    } => {
                        *fwd = Some((a, b, c));
                        Ok((
                            t,
                            Payload::from_bytes(payload.clone()),
                            StepNote::Reply {
                                opcode,
                                a,
                                b,
                                c,
                                payload,
                            },
                        ))
                    }
                }
            }
            other => bail!(
                "instruction {:#06x} cannot run as a program step",
                other.opcode_u16()
            ),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_user(
        &mut self,
        _now: SimTime,
        mut pkt: Packet,
        opcode: u16,
        a: u64,
        b: u64,
        c: u64,
        out: &mut Vec<Emit>,
    ) -> Result<()> {
        debug_assert!(opcode >= USER_OPCODE_BASE);
        let registry = Arc::clone(&self.registry);
        let Some(handler) = registry.get(opcode) else {
            bail!("no handler for user opcode {opcode:#06x}");
        };
        let empty: &[u8] = &[];
        let payload_bytes = pkt.payload.bytes().unwrap_or(empty).to_vec();
        let cost = handler.cost_ns(pkt.payload.len());
        let t = self.fixed_ns() + self.mem_ns(pkt.payload.len().max(8)) + cost;
        let mut ctx = ExecCtx {
            mem: &mut self.hbm,
            payload: &payload_bytes,
            a,
            b,
            c,
            flags: pkt.flags,
            fwd: None,
        };
        let outcome = handler.execute(&mut ctx)?;
        match outcome {
            ExecOutcome::Consume | ExecOutcome::Drop => {}
            ExecOutcome::Reply {
                opcode,
                a,
                b,
                c,
                payload,
            } => {
                let resp = self.reply(
                    pkt.src,
                    pkt.seq,
                    Instruction::User { opcode, a, b, c },
                    Payload::from_bytes(payload),
                );
                out.push(Emit { delay: t, pkt: resp });
            }
            ExecOutcome::Forward { payload } => {
                pkt.srou.advance();
                if pkt.srou.current().is_some() {
                    pkt.payload = Payload::from_bytes(payload);
                    out.push(Emit { delay: t, pkt });
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::dpu::{register_dpu_instructions, OP_CRC32, OP_CRYPTO_WRITE};
    use crate::isa::{Flags, ProgramBuilder, SimdOp};
    use crate::wire::Segment;

    fn dev(ip: u8) -> NetDamDevice {
        NetDamDevice::new(
            DeviceConfig::paper_default(DeviceIp::lan(ip)),
            Arc::new(InstructionRegistry::new()),
        )
    }

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    fn direct(src: u8, dst: u8, instr: Instruction) -> Packet {
        Packet::new(ip(src), 1, SrouHeader::direct(ip(dst)), instr)
    }

    #[test]
    fn read_returns_data_with_pipeline_delay() {
        let mut d = dev(2);
        d.mem().write(0x100, &[9u8; 128]).unwrap();
        let emits = d.handle_packet(0, direct(1, 2, Instruction::Read { addr: 0x100, len: 128 }));
        assert_eq!(emits.len(), 1);
        let e = &emits[0];
        assert!(matches!(e.pkt.instr, Instruction::ReadResp { addr: 0x100 }));
        assert_eq!(e.pkt.dst().unwrap(), ip(1));
        assert_eq!(e.pkt.payload.bytes().unwrap(), &[9u8; 128][..]);
        // E1 envelope: fixed + HBM, should be in the paper's ballpark.
        assert!(e.delay > 400 && e.delay < 1000, "delay {}", e.delay);
    }

    #[test]
    fn write_is_silent_unless_reliable() {
        let mut d = dev(2);
        let w = direct(1, 2, Instruction::Write { addr: 0 })
            .with_payload(Payload::from_bytes(vec![5; 16]));
        assert!(d.handle_packet(0, w).is_empty());
        assert_eq!(d.mem().read(0, 16).unwrap(), vec![5; 16]);

        let w = direct(1, 2, Instruction::Write { addr: 32 })
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_bytes(vec![7; 4]));
        let emits = d.handle_packet(0, w);
        assert!(matches!(emits[0].pkt.instr, Instruction::WriteAck { addr: 32 }));
    }

    #[test]
    fn cas_swaps_exactly_once() {
        let mut d = dev(2);
        d.mem().write(8, &42u64.to_le_bytes()).unwrap();
        // Distinct ops carry distinct sequence numbers — a repeated
        // (src, seq) is by definition a retransmit and hits the dedupe
        // cache instead (see cas_retransmit_replays_original_response).
        let cas = |seq, exp, new| {
            Packet::new(
                ip(1),
                seq,
                SrouHeader::direct(ip(2)),
                Instruction::Cas { addr: 8, expected: exp, new },
            )
        };
        let e1 = d.handle_packet(0, cas(1, 42, 100));
        assert!(matches!(
            e1[0].pkt.instr,
            Instruction::CasResp { swapped: true, old: 42, .. }
        ));
        let e2 = d.handle_packet(0, cas(2, 42, 200));
        assert!(matches!(
            e2[0].pkt.instr,
            Instruction::CasResp { swapped: false, old: 100, .. }
        ));
    }

    /// The replay-safe CAS contract: a retransmit (same src, same seq)
    /// after a lost response returns the *original* outcome from the
    /// dedupe cache — the swap executes exactly once and the winner is
    /// never told `swapped=false` by its own retry.
    #[test]
    fn cas_retransmit_replays_original_response() {
        let mut d = dev(2);
        let mk = || direct(1, 2, Instruction::Cas { addr: 8, expected: 0, new: 42 });
        let e1 = d.handle_packet(0, mk());
        assert!(matches!(
            e1[0].pkt.instr,
            Instruction::CasResp { swapped: true, old: 0, .. }
        ));
        // The response was lost; the reliable layer re-presents (src, seq).
        let e2 = d.handle_packet(0, mk());
        assert!(
            matches!(
                e2[0].pkt.instr,
                Instruction::CasResp { swapped: true, old: 0, .. }
            ),
            "retransmit must replay the original swapped=true, got {:?}",
            e2[0].pkt.instr
        );
        assert_eq!(d.resp_cache_hits, 1);
        // Memory swapped exactly once.
        assert_eq!(d.mem().read(8, 8).unwrap(), 42u64.to_le_bytes());
        // A *new* CAS (fresh seq) executes normally against the new value.
        let p = Packet::new(
            ip(1),
            2,
            SrouHeader::direct(ip(2)),
            Instruction::Cas { addr: 8, expected: 0, new: 7 },
        );
        let e3 = d.handle_packet(0, p);
        assert!(matches!(
            e3[0].pkt.instr,
            Instruction::CasResp { swapped: false, old: 42, .. }
        ));
        assert_eq!(d.resp_cache_hits, 1, "fresh seq is not a replay");
    }

    #[test]
    fn simd_add_against_memory() {
        let mut d = dev(2);
        let local: Vec<f32> = vec![10.0, 20.0, 30.0];
        d.mem().write(0, &f32s_to_bytes(&local)).unwrap();
        let pkt = direct(1, 2, Instruction::Simd { op: SimdOp::Add, addr: 0 })
            .with_payload(Payload::from_f32s(&[1.0, 2.0, 3.0]));
        let emits = d.handle_packet(0, pkt);
        let got = emits[0].pkt.payload.f32s().unwrap().unwrap();
        assert_eq!(got, vec![11.0, 22.0, 33.0]);
        // Memory unchanged without STORE.
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            local
        );
    }

    #[test]
    fn simd_store_writes_back() {
        let mut d = dev(2);
        d.mem().write(0, &f32s_to_bytes(&[1.0, 1.0])).unwrap();
        let pkt = direct(1, 2, Instruction::Simd { op: SimdOp::Mul, addr: 0 })
            .with_flags(Flags(Flags::STORE))
            .with_payload(Payload::from_f32s(&[3.0, 4.0]));
        let emits = d.handle_packet(0, pkt);
        assert!(emits.is_empty()); // not reliable → silent
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 8).unwrap()).unwrap(),
            vec![3.0, 4.0]
        );
    }

    #[test]
    fn write_if_hash_guards_duplicates() {
        let mut d = dev(2);
        let pristine: Vec<f32> = vec![4.0, 5.0, 6.0];
        d.mem().write(0, &f32s_to_bytes(&pristine)).unwrap();
        let guard = block_hash(&f32s_to_bytes(&pristine));
        let mk = || {
            direct(1, 2, Instruction::WriteIfHash { addr: 0, expect_hash: guard })
                .with_payload(Payload::from_f32s(&[7.0, 8.0, 9.0]))
        };
        d.handle_packet(0, mk());
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            vec![7.0, 8.0, 9.0]
        );
        // Duplicate (retransmit): hash no longer matches → dropped.
        d.handle_packet(0, mk());
        assert_eq!(d.drops_hash_guard, 1);
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 12).unwrap()).unwrap(),
            vec![7.0, 8.0, 9.0]
        );
    }

    /// The §3 reduce chain as a program: interim hop accumulates into the
    /// packet buffer and self-routes onward, pc/reps advancing on the wire.
    #[test]
    fn program_reduce_hop_accumulates_and_forwards() {
        let mut d = dev(2);
        d.mem().write(0, &f32s_to_bytes(&[10.0, 10.0])).unwrap();
        let srou = SrouHeader::through(vec![Segment::to(ip(2)), Segment::to(ip(3))]);
        let prog = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0, 2)
            .guarded_write(0, 0)
            .build_unchecked();
        let pkt = Packet::new(ip(1), 1, srou, Instruction::Program(Arc::new(prog)))
            .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits.len(), 1);
        let fwd = &emits[0].pkt;
        assert_eq!(fwd.dst().unwrap(), ip(3), "self-routed to next segment");
        assert_eq!(
            fwd.payload.f32s().unwrap().unwrap(),
            vec![11.0, 12.0],
            "payload accumulated in packet buffer"
        );
        let Instruction::Program(p) = &fwd.instr else {
            panic!("still a program");
        };
        assert_eq!((p.pc, p.reps_done), (0, 1), "cursor travels on the wire");
        // Local memory untouched: interim hop is idempotent.
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 8).unwrap()).unwrap(),
            vec![10.0, 10.0]
        );
        assert_eq!(d.prog_steps, 1);
    }

    /// Chain owner: fused guarded write retires the program with a
    /// CollectiveDone; a replayed chain is absorbed by the guard but the
    /// Done is re-emitted (the retry may exist because it was lost).
    #[test]
    fn program_owner_writes_with_guard_and_completes() {
        let mut d = dev(4);
        let local = vec![100.0f32, 200.0];
        d.mem().write(64, &f32s_to_bytes(&local)).unwrap();
        let guard = block_hash(&f32s_to_bytes(&local));
        let mk = || {
            let prog = ProgramBuilder::new()
                .reduce(SimdOp::Add, 64, 1)
                .guarded_write(64, guard)
                .on_retire(5)
                .build_unchecked();
            Packet::new(
                ip(3),
                9,
                SrouHeader::direct(ip(4)),
                Instruction::Program(Arc::new(prog)),
            )
            .with_payload(Payload::from_f32s(&[1.0, 2.0]))
        };
        let emits = d.handle_packet(0, mk());
        assert!(matches!(
            emits[0].pkt.instr,
            Instruction::CollectiveDone { block: 5 }
        ));
        assert_eq!(
            bytes_to_f32s(&d.mem().read(64, 8).unwrap()).unwrap(),
            vec![101.0, 202.0]
        );
        // Retransmit: guard fails, memory stable; the Done is re-emitted.
        let emits = d.handle_packet(0, mk());
        assert!(matches!(
            emits[0].pkt.instr,
            Instruction::CollectiveDone { block: 5 }
        ));
        assert_eq!(d.drops_hash_guard, 1);
        assert_eq!(
            bytes_to_f32s(&d.mem().read(64, 8).unwrap()).unwrap(),
            vec![101.0, 202.0]
        );
    }

    /// The all-gather tail as a program store chain.
    #[test]
    fn program_store_chain_writes_and_forwards() {
        let mut d = dev(2);
        let srou = SrouHeader::through(vec![Segment::to(ip(2)), Segment::to(ip(3))]);
        let prog = ProgramBuilder::new().store(0, 2).on_retire(1).build_unchecked();
        let pkt = Packet::new(ip(1), 1, srou, Instruction::Program(Arc::new(prog)))
            .with_payload(Payload::from_f32s(&[5.0]));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits[0].pkt.dst().unwrap(), ip(3));
        assert_eq!(
            bytes_to_f32s(&d.mem().read(0, 4).unwrap()).unwrap(),
            vec![5.0]
        );
    }

    /// Chained DPU offload in one packet: encrypt-write then CRC the
    /// ciphertext region, operands forwarded between the fused steps.
    #[test]
    fn program_chains_dpu_offloads_with_operand_forwarding() {
        let mut reg = InstructionRegistry::new();
        register_dpu_instructions(&mut reg, 0xC0FFEE).unwrap();
        let mut d = NetDamDevice::new(
            DeviceConfig::paper_default(ip(2)),
            Arc::new(reg),
        );
        let plaintext = b"one packet, two offloads".to_vec();
        let prog = ProgramBuilder::new()
            .hop(Instruction::User {
                opcode: OP_CRYPTO_WRITE,
                a: 256,
                b: 0,
                c: 0,
            })
            .then(Instruction::User {
                opcode: OP_CRC32,
                a: 0,
                b: 0,
                c: 0,
            })
            .build_unchecked();
        let pkt = direct(1, 2, Instruction::Program(Arc::new(prog)))
            .with_payload(Payload::from_bytes(plaintext.clone()));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits.len(), 1);
        let Instruction::User { opcode, a, b, c } = emits[0].pkt.instr else {
            panic!("expected a user reply, got {:?}", emits[0].pkt.instr);
        };
        assert_eq!(opcode, OP_CRC32);
        assert_eq!((a, b), (256, plaintext.len() as u64));
        // The CRC covers the *ciphertext* the first step wrote.
        let ct = d.mem().read(256, plaintext.len()).unwrap();
        assert_ne!(ct, plaintext);
        assert_eq!(c, crate::util::crc32::hash(&ct) as u64);
        assert_eq!(d.prog_steps, 2);
    }

    #[test]
    fn program_without_segments_is_exec_error() {
        let mut d = dev(2);
        // Two travelling steps but a single-segment SROU header.
        let prog = ProgramBuilder::new().store(0, 2).build_unchecked();
        let pkt = direct(1, 2, Instruction::Program(Arc::new(prog)))
            .with_payload(Payload::from_f32s(&[1.0]));
        assert!(d.handle_packet(0, pkt).is_empty());
        assert_eq!(d.exec_errors, 1);
    }

    /// §2.6 enforcement point: a denied translation is a *wire NAK* with
    /// the fault's typed reason, not a silent in-process drop.
    #[test]
    fn iommu_denial_naks_on_the_wire() {
        use crate::iommu::{NakReason, Perms};
        let mut d = dev(2);
        // One 8 KiB read-only page leased to tenant 7; host ip(1) → 7.
        d.iommu_mut().set_page_bits(13).unwrap();
        d.iommu_mut()
            .map_leased(0, 0, 8192, Perms::RO, Some(7))
            .unwrap();
        d.bind_tenant(ip(1), 7);
        // In-lease read passes through the lease.
        let emits = d.handle_packet(0, direct(1, 2, Instruction::Read { addr: 0, len: 64 }));
        assert!(matches!(emits[0].pkt.instr, Instruction::ReadResp { .. }));
        // Write to the RO lease → WriteDenied NAK back to the source.
        let w = direct(1, 2, Instruction::Write { addr: 0 })
            .with_payload(Payload::from_bytes(vec![1; 8]));
        let emits = d.handle_packet(0, w);
        assert_eq!(emits.len(), 1);
        let Instruction::Nack { acked, reason } = emits[0].pkt.instr else {
            panic!("expected Nack, got {:?}", emits[0].pkt.instr);
        };
        assert_eq!(acked, 1, "NAK echoes the request sequence");
        assert_eq!(emits[0].pkt.dst().unwrap(), ip(1));
        assert_eq!(NakReason::from_u8(reason), NakReason::WriteDenied);
        // Unattributed source → foreign-lease NAK.
        let r = Packet::new(
            ip(3),
            9,
            SrouHeader::direct(ip(2)),
            Instruction::Read { addr: 0, len: 8 },
        );
        let emits = d.handle_packet(0, r);
        let Instruction::Nack { reason, .. } = emits[0].pkt.instr else {
            panic!("expected Nack, got {:?}", emits[0].pkt.instr);
        };
        assert_eq!(NakReason::from_u8(reason), NakReason::ForeignLease);
        // Out-of-lease address → Unmapped NAK.
        let emits = d.handle_packet(
            0,
            direct(1, 2, Instruction::Read { addr: 1 << 20, len: 8 }),
        );
        let Instruction::Nack { reason, .. } = emits[0].pkt.instr else {
            panic!("expected Nack, got {:?}", emits[0].pkt.instr);
        };
        assert_eq!(NakReason::from_u8(reason), NakReason::Unmapped);
        assert_eq!(d.iommu_naks, 3);
        assert_eq!(d.exec_errors, 0, "IOMMU faults are NAKs, not exec errors");
    }

    /// Program steps translate through the same lease checks: a fault
    /// mid-program NAKs instead of silently killing the chain.
    #[test]
    fn program_step_fault_naks_too() {
        use crate::iommu::{NakReason, Perms};
        let mut d = dev(2);
        d.iommu_mut().set_page_bits(13).unwrap();
        d.iommu_mut()
            .map_leased(0, 0, 8192, Perms::RO, Some(4))
            .unwrap();
        d.bind_tenant(ip(1), 4);
        let prog = ProgramBuilder::new().store(0, 1).build_unchecked();
        let pkt = direct(1, 2, Instruction::Program(Arc::new(prog)))
            .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        let emits = d.handle_packet(0, pkt);
        assert_eq!(emits.len(), 1);
        let Instruction::Nack { reason, .. } = emits[0].pkt.instr else {
            panic!("expected Nack, got {:?}", emits[0].pkt.instr);
        };
        assert_eq!(NakReason::from_u8(reason), NakReason::WriteDenied);
        assert_eq!(d.iommu_naks, 1);
    }

    #[test]
    fn responses_land_in_completion_queue() {
        let mut d = dev(1);
        let resp = direct(2, 1, Instruction::ReadResp { addr: 0 })
            .with_payload(Payload::from_bytes(vec![1, 2, 3]));
        assert!(d.handle_packet(77, resp).is_empty());
        let comps = d.drain_completions();
        assert_eq!(comps.len(), 1);
        assert_eq!(comps[0].0, 77);
        assert!(d.drain_completions().is_empty());
    }

    #[test]
    fn unknown_user_opcode_is_counted_error() {
        let mut d = dev(2);
        let pkt = direct(1, 2, Instruction::User { opcode: 0x9999, a: 0, b: 0, c: 0 });
        assert!(d.handle_packet(0, pkt).is_empty());
        assert_eq!(d.exec_errors, 1);
    }

    #[test]
    fn phantom_device_charges_time_without_data() {
        let mut d = NetDamDevice::new(
            DeviceConfig::paper_default(DeviceIp::lan(2)).timing_only(),
            Arc::new(InstructionRegistry::new()),
        );
        let pkt = direct(1, 2, Instruction::Read { addr: 0, len: 8192 });
        let emits = d.handle_packet(0, pkt);
        assert!(emits[0].pkt.payload.is_phantom());
        assert_eq!(emits[0].pkt.payload.len(), 8192);
        assert!(emits[0].delay > 400);
    }
}
