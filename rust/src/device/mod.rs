//! The NetDAM device model (paper §2.1–§2.5, Figure 1).
//!
//! A NetDAM device is HBM + an Ethernet MAC + a **fixed** packet pipeline:
//!
//! ```text
//!   RX MAC → parse → IOMMU → execute (HBM ⊕ ALU array) → route → TX MAC
//! ```
//!
//! The fixed pipeline is the paper's central latency claim: no PCIe DMA,
//! no cache-coherency snooping, so wire-to-wire service time is a narrow
//! distribution (618 ns ± 39 ns for a 32×f32 READ on the FPGA prototype).
//! [`DeviceConfig::paper_default`] carries the calibrated per-stage costs
//! that reproduce those numbers (experiment E1).
//!
//! The device is *pure* with respect to the network: [`NetDamDevice::
//! handle_packet`] consumes a packet and returns [`Emit`]s (delay +
//! packet); the [`crate::net::Cluster`] owns actual link scheduling.

mod hbm;
mod netdam;
mod pipeline;

pub use hbm::{Hbm, HbmConfig};
pub use netdam::{Emit, NetDamDevice};
pub use pipeline::{DeviceConfig, PipelineCosts};
