//! The device-attached HBM model.
//!
//! * **Storage** — page-sparse (64 KiB pages, allocate on first touch):
//!   a 2 GB device that only ever touches a few MB costs a few MB of host
//!   RAM, and a full 4-device cluster at paper scale stays resident.
//! * **Timing** — first-access latency + streaming bandwidth, with bank
//!   jitter and an occasional refresh penalty. HBM2 on the Alveo U55N:
//!   ~400 GB/s per stack, a few hundred ns load-to-use through the AXI
//!   fabric.
//! * **Phantom mode** — no backing pages at all; reads return zeros.
//!   Timing-only experiments (paper-scale E2) run the same code path.

use anyhow::{bail, Result};
use std::collections::HashMap;

use crate::isa::registry::MemAccess;
use crate::sim::SimTime;
use crate::util::Xoshiro256;

const PAGE_BITS: u32 = 16;
const PAGE_SIZE: usize = 1 << PAGE_BITS; // 64 KiB

/// Timing parameters of the HBM stack + AXI path.
#[derive(Debug, Clone)]
pub struct HbmConfig {
    pub capacity: u64,
    /// Fixed load-to-use latency through the memory controller (ns).
    pub access_ns: SimTime,
    /// Streaming bandwidth (bytes per ns; 400 GB/s = 400 B/ns).
    pub bytes_per_ns: f64,
    /// Gaussian bank-conflict jitter (σ, ns), clamped at ±3σ.
    pub bank_jitter_ns: f64,
    /// Probability an access collides with a refresh cycle...
    pub refresh_p: f64,
    /// ...and the extra latency it costs (ns).
    pub refresh_ns: SimTime,
}

impl HbmConfig {
    /// One Alveo U55N NetDAM device: 2 GB HBM @ ~400 GB/s.
    /// `access_ns` is calibrated so E1 reproduces the paper's 618 ns mean
    /// (see `DeviceConfig::paper_default` for the full budget).
    pub fn paper_default() -> Self {
        Self {
            capacity: 2 << 30,
            access_ns: 339,
            bytes_per_ns: 400.0,
            bank_jitter_ns: 34.0,
            refresh_p: 0.015,
            refresh_ns: 210,
        }
    }
}

/// The memory itself.
pub struct Hbm {
    cfg: HbmConfig,
    /// `None` = phantom (timing-only) storage.
    pages: Option<HashMap<u64, Box<[u8]>>>,
}

impl Hbm {
    pub fn new(cfg: HbmConfig) -> Self {
        Self {
            cfg,
            pages: Some(HashMap::new()),
        }
    }

    /// Timing-only HBM: reads return zeros, writes are discarded.
    pub fn new_phantom(cfg: HbmConfig) -> Self {
        Self { cfg, pages: None }
    }

    pub fn is_phantom(&self) -> bool {
        self.pages.is_none()
    }

    pub fn cfg(&self) -> &HbmConfig {
        &self.cfg
    }

    fn check_range(&self, addr: u64, len: usize) -> Result<()> {
        if addr.checked_add(len as u64).is_none_or(|end| end > self.cfg.capacity) {
            bail!(
                "HBM access [{addr:#x}..+{len}) out of range (capacity {:#x})",
                self.cfg.capacity
            );
        }
        Ok(())
    }

    /// Access time for `len` bytes (one burst). Deterministic given `rng`.
    pub fn access_ns(&self, len: usize, rng: &mut Xoshiro256) -> SimTime {
        let stream = (len as f64 / self.cfg.bytes_per_ns).round() as SimTime;
        let jitter = (rng.next_gaussian() * self.cfg.bank_jitter_ns)
            .clamp(-3.0 * self.cfg.bank_jitter_ns, 3.0 * self.cfg.bank_jitter_ns);
        let refresh = if rng.chance(self.cfg.refresh_p) {
            self.cfg.refresh_ns
        } else {
            0
        };
        let base = self.cfg.access_ns as f64 + jitter;
        base.max(1.0) as SimTime + stream + refresh
    }

    /// Resident bytes (for memory accounting in § Perf).
    pub fn resident_bytes(&self) -> usize {
        self.pages.as_ref().map_or(0, |p| p.len() * PAGE_SIZE)
    }
}

impl MemAccess for Hbm {
    fn capacity(&self) -> u64 {
        self.cfg.capacity
    }

    fn read(&self, addr: u64, len: usize) -> Result<Vec<u8>> {
        self.check_range(addr, len)?;
        let mut out = vec![0u8; len];
        let Some(pages) = &self.pages else {
            return Ok(out); // phantom: zeros
        };
        let mut off = 0usize;
        while off < len {
            let a = addr + off as u64;
            let page = a >> PAGE_BITS;
            let in_page = (a as usize) & (PAGE_SIZE - 1);
            let n = (PAGE_SIZE - in_page).min(len - off);
            if let Some(p) = pages.get(&page) {
                out[off..off + n].copy_from_slice(&p[in_page..in_page + n]);
            } // untouched pages read as zeros
            off += n;
        }
        Ok(out)
    }

    fn write(&mut self, addr: u64, data: &[u8]) -> Result<()> {
        self.check_range(addr, data.len())?;
        let Some(pages) = &mut self.pages else {
            return Ok(()); // phantom: discard
        };
        let mut off = 0usize;
        while off < data.len() {
            let a = addr + off as u64;
            let page = a >> PAGE_BITS;
            let in_page = (a as usize) & (PAGE_SIZE - 1);
            let n = (PAGE_SIZE - in_page).min(data.len() - off);
            let p = pages
                .entry(page)
                .or_insert_with(|| vec![0u8; PAGE_SIZE].into_boxed_slice());
            p[in_page..in_page + n].copy_from_slice(&data[off..off + n]);
            off += n;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hbm() -> Hbm {
        Hbm::new(HbmConfig::paper_default())
    }

    #[test]
    fn write_read_round_trip() {
        let mut m = hbm();
        let data: Vec<u8> = (0..=255).collect();
        m.write(0x1234, &data).unwrap();
        assert_eq!(m.read(0x1234, 256).unwrap(), data);
    }

    #[test]
    fn cross_page_access() {
        let mut m = hbm();
        let addr = (PAGE_SIZE - 8) as u64; // straddles pages 0 and 1
        let data = vec![0xAB; 16];
        m.write(addr, &data).unwrap();
        assert_eq!(m.read(addr, 16).unwrap(), data);
        // Each side landed on its own page.
        assert_eq!(m.resident_bytes(), 2 * PAGE_SIZE);
    }

    #[test]
    fn untouched_memory_reads_zero() {
        let m = hbm();
        assert_eq!(m.read(0x4000_0000, 16).unwrap(), vec![0; 16]);
    }

    #[test]
    fn out_of_range_rejected() {
        let mut m = hbm();
        let cap = m.capacity();
        assert!(m.read(cap - 4, 8).is_err());
        assert!(m.write(cap, &[1]).is_err());
        assert!(m.read(u64::MAX, 1).is_err()); // overflow guard
    }

    #[test]
    fn phantom_mode_times_but_stores_nothing() {
        let mut m = Hbm::new_phantom(HbmConfig::paper_default());
        m.write(0, &[1, 2, 3]).unwrap();
        assert_eq!(m.read(0, 3).unwrap(), vec![0; 3]);
        assert_eq!(m.resident_bytes(), 0);
        let mut rng = Xoshiro256::seed_from(1);
        assert!(m.access_ns(128, &mut rng) > 0);
    }

    #[test]
    fn access_time_statistics_match_config() {
        let m = hbm();
        let mut rng = Xoshiro256::seed_from(2);
        let mut run = crate::util::stats::Running::new();
        for _ in 0..20_000 {
            run.push(m.access_ns(128, &mut rng) as f64);
        }
        let expected = m.cfg().access_ns as f64
            + (128.0 / m.cfg().bytes_per_ns)
            + m.cfg().refresh_p * m.cfg().refresh_ns as f64;
        assert!(
            (run.mean() - expected).abs() < 5.0,
            "mean {} vs expected {expected}",
            run.mean()
        );
        // Jitter dominated by bank σ plus refresh spikes.
        assert!(run.std_dev() > 25.0 && run.std_dev() < 60.0);
    }

    #[test]
    fn sparse_residency_is_bounded() {
        let mut m = hbm();
        // Touch 1 MB scattered over the 2 GB space.
        for i in 0..16 {
            m.write(i * (128 << 20), &[1u8; 65536]).unwrap();
        }
        assert!(m.resident_bytes() <= 32 * PAGE_SIZE);
    }
}
