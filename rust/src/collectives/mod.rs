//! MPI collectives (paper §3): the NetDAM ring allreduce built from the
//! `ReduceScatter`/`AllGather` instructions, plus the two baselines the
//! evaluation compares against (ring-allreduce over RoCE hosts, and a
//! "native MPI" recursive-doubling allreduce).
//!
//! | impl | where the add runs | transport |
//! |---|---|---|
//! | [`netdam_ring`] | in-memory ALU on each NetDAM device, chained by SROU | NetDAM/UDP, idempotent retransmit |
//! | [`ring_roce`] | host CPU (AVX-512 class) after PCIe DMA | RoCE-like, lossless assumed |
//! | [`mpi_native`] | host CPU, full vector per round | RoCE-like, lossless assumed |

pub mod mpi_native;
pub mod netdam_ring;
pub mod oracle;
pub mod ring_roce;

pub use netdam_ring::{run_ring_allreduce, AllreduceOutcome, RingSpec};
pub use oracle::{oracle_sum, read_vector, seed_gradients};

use crate::sim::SimTime;

/// A completed collective run, as the benches report it.
#[derive(Debug, Clone)]
pub struct CollectiveReport {
    pub algorithm: &'static str,
    pub elements: usize,
    pub elapsed_ns: SimTime,
    pub link_drops: u64,
    pub retransmits: u64,
}

impl CollectiveReport {
    /// Effective allreduce bandwidth: 2·(N−1)/N · V / t, the standard
    /// ring-allreduce "algorithm bandwidth" (bytes/ns = GB/s).
    pub fn algo_bw_gbps(&self, n_ranks: usize) -> f64 {
        let v = self.elements as f64 * 4.0;
        let moved = 2.0 * (n_ranks as f64 - 1.0) / n_ranks as f64 * v;
        moved * 8.0 / self.elapsed_ns as f64
    }
}
