//! MPI collectives (paper §3): software-defined collectives over the
//! NetDAM ISA, plus the host baselines the evaluation compares against.
//!
//! The subsystem is layered: algorithms are *schedule generators*
//! ([`driver::CollectiveAlgorithm`]) and one shared [`driver::Driver`]
//! owns windowing, reliability, completion tracking, and report
//! production — see [`driver`] for the architecture. Schedules lower
//! onto verified packet programs ([`driver::lower_ring_chunk`] /
//! [`driver::lower_store_chain`]) rather than bespoke opcodes; the
//! devices execute them hop-locally (see [`crate::isa::program`]).
//!
//! | algorithm | where the add runs | shape |
//! |---|---|---|
//! | [`netdam_ring::RingAllreduce`] | in-memory ALU, SROU-chained | single-phase ring, fused all-gather |
//! | [`halving_doubling::HalvingDoubling`] | in-memory ALU | 2·log₂N rounds, latency-optimal |
//! | [`hierarchical::HierarchicalAllreduce`] | in-memory ALU | leaf reduce → leader ring → leaf broadcast |
//! | [`switch_reduce::SwitchReduceAllreduce`] | **in the switches** (§2.5) | leaf/spine aggregation tree → binomial down-broadcast |
//! | [`primitives::RingAllGather`] / [`primitives::RingBroadcast`] | — (pure writes) | standalone primitives |
//! | [`tree::TreeBroadcast`] | — (pure writes) | binomial tree, ⌈log₂N⌉ rounds |
//! | [`reduce::RingReduce`] | in-memory ALU | rooted ring reduce: every chain ends at the root |
//! | [`ring_roce::RingRoceAllreduce`] | host CPU after PCIe DMA | Horovod-style baseline |
//! | [`mpi_native::MpiRecursiveDoubling`] | host CPU, full vector/round | native-MPI baseline |

pub mod driver;
pub mod halving_doubling;
pub mod hierarchical;
pub mod mpi_native;
pub mod netdam_ring;
pub mod oracle;
pub mod primitives;
pub mod reduce;
pub mod ring_roce;
pub mod switch_reduce;
pub mod tree;

pub use driver::{
    lower_ring_chunk, lower_store_chain, prog_env, run_collective, AlgoKind, CollectiveAlgorithm,
    CollectiveSpec, Driver, DriverOutcome, Phase, PlanCtx, RunOpts, ScheduledOp, TopoFacts,
};
pub use halving_doubling::HalvingDoubling;
pub use hierarchical::HierarchicalAllreduce;
pub use netdam_ring::{run_ring_allreduce, AllreduceOutcome, RingAllreduce, RingSpec};
pub use oracle::{
    naive_sum, oracle_sum, read_vector, seed_gradients, seed_gradients_exact,
};
pub use primitives::{RingAllGather, RingBroadcast};
pub use reduce::RingReduce;
pub use switch_reduce::SwitchReduceAllreduce;
pub use tree::TreeBroadcast;

use crate::sim::SimTime;

/// A completed collective run, as the benches report it. `Eq` so the
/// sharded-core determinism tests can assert two runs produced the
/// bit-identical report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CollectiveReport {
    pub algorithm: &'static str,
    pub elements: usize,
    pub elapsed_ns: SimTime,
    pub link_drops: u64,
    pub retransmits: u64,
    /// Median per-op completion latency (wire release → completion), ns.
    /// Nearest-rank over whole nanoseconds so the report stays `Eq`.
    pub lat_p50_ns: SimTime,
    /// Tail (p99) per-op completion latency, ns — the incast lens:
    /// pacing that only preserves goodput but queues everything shows up
    /// here, not in `elapsed_ns`.
    pub lat_p99_ns: SimTime,
}

impl CollectiveReport {
    /// Effective allreduce bandwidth: 2·(N−1)/N · V / t, the standard
    /// ring-allreduce "algorithm bandwidth" (Gbit/s). Degenerate inputs
    /// (no elapsed time recorded, or fewer than 2 ranks) report 0 rather
    /// than an infinite/negative bandwidth. For non-allreduce collectives
    /// use [`CollectiveReport::bus_bw_gbps`] with the algorithm's own
    /// data-movement fraction ([`AlgoKind::bw_fraction`]).
    pub fn algo_bw_gbps(&self, n_ranks: usize) -> f64 {
        if n_ranks < 2 {
            return 0.0;
        }
        self.bus_bw_gbps(2.0 * (n_ranks as f64 - 1.0) / n_ranks as f64)
    }

    /// Generic bus bandwidth (Gbit/s): `moved_fraction · V / t`, where
    /// `moved_fraction` is the bytes-moved multiple of the vector size.
    pub fn bus_bw_gbps(&self, moved_fraction: f64) -> f64 {
        if self.elapsed_ns == 0 || moved_fraction <= 0.0 {
            return 0.0;
        }
        let v = self.elements as f64 * 4.0;
        moved_fraction * v * 8.0 / self.elapsed_ns as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bw_guards_degenerate_inputs() {
        let r = CollectiveReport {
            algorithm: "x",
            elements: 1 << 20,
            elapsed_ns: 0,
            link_drops: 0,
            retransmits: 0,
            lat_p50_ns: 0,
            lat_p99_ns: 0,
        };
        assert_eq!(r.algo_bw_gbps(4), 0.0, "zero elapsed must not be inf");
        let r = CollectiveReport {
            elapsed_ns: 1000,
            ..r
        };
        assert_eq!(r.algo_bw_gbps(0), 0.0, "n=0 must not be negative");
        assert_eq!(r.algo_bw_gbps(1), 0.0, "n=1 must not be zero-div");
        assert!(r.algo_bw_gbps(4) > 0.0);
        // Generic bus bandwidth: fraction scales linearly, guards hold.
        assert_eq!(r.bus_bw_gbps(0.0), 0.0);
        let broadcast = r.bus_bw_gbps(AlgoKind::Broadcast.bw_fraction(4));
        let allreduce = r.bus_bw_gbps(AlgoKind::NetdamRing.bw_fraction(4));
        assert!(allreduce > broadcast, "allreduce moves 2(N-1)/N x V > V");
    }
}
