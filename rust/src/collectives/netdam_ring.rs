//! The NetDAM ring allreduce (paper §3.1/§3.2, Figures 6 & 8).
//!
//! Each rank owns chunk `r` of the vector. For every 2048-lane block of
//! its chunk, the rank injects **one** packet carrying a compiled
//! [`Program`](crate::isa::Program) whose SROU stack walks the whole ring
//! twice-minus-one:
//!
//! ```text
//!   r → r+1 → ... → r+N−1 (owner: fused guarded write)
//!       └ store chain: → r → r+1 → ... → r+N−2 → Done → r
//! ```
//!
//! The program is `reduce ×(N−1) → guarded_write → store ×(N−1)`:
//! interim hops fold their local contribution into the packet buffer (no
//! local side effects — idempotent); the owner performs the hash-guarded
//! write (§3.1's block-hash idempotency trick); the store tail carries
//! the finished block back around. Windowing, completion tracking, and
//! reliability live in the shared [`Driver`](super::driver::Driver) —
//! this module only *plans* the ring schedule ([`RingAllreduce`]) and
//! lowers it through
//! [`lower_ring_chunk`](super::driver::lower_ring_chunk).

use anyhow::{ensure, Result};

use crate::isa::SimdOp;
use crate::net::{Cluster, NodeId};
use crate::sim::{Engine, SimTime};
use crate::wire::{DeviceIp, Packet};

use super::driver::{
    guard_hash, lower_ring_chunk, op_flags, prog_env, read_block, CollectiveAlgorithm,
    CollectiveSpec, Driver, PlanCtx, Phase, ScheduledOp,
};

/// Parameters of one allreduce run (back-compat shell over
/// [`CollectiveSpec`] plus the ring's own `fused` knob).
#[derive(Debug, Clone)]
pub struct RingSpec {
    /// Total f32 elements (must divide evenly by the rank count).
    pub elements: usize,
    /// SIMD lanes per packet (the paper's 2048 × f32 blocks).
    pub lanes: usize,
    /// Outstanding blocks per rank (self-clocked window).
    pub window: usize,
    /// Track with timeout-retransmit (for lossy fabrics, E5).
    pub reliable: bool,
    /// Device-local base address of the vector.
    pub base_addr: u64,
    /// `true` = full allreduce (fused all-gather); `false` = reduce-
    /// scatter only (ablation A1).
    pub fused: bool,
}

impl Default for RingSpec {
    fn default() -> Self {
        Self {
            elements: 1 << 16,
            lanes: 2048,
            window: 16,
            reliable: false,
            base_addr: 0,
            fused: true,
        }
    }
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct AllreduceOutcome {
    pub elapsed_ns: SimTime,
    pub blocks: usize,
    pub blocks_done: usize,
    pub retransmits: u64,
    pub hash_guard_drops: u64,
}

/// The ring schedule generator: one compiled program-chain per block,
/// the paper's "whole MPI allreduce chunk in one packet".
pub struct RingAllreduce {
    /// Fused all-gather tail (`false` = reduce-scatter only).
    pub fused: bool,
}

impl CollectiveAlgorithm for RingAllreduce {
    fn name(&self) -> &'static str {
        if self.fused {
            "netdam-ring"
        } else {
            "reduce-scatter"
        }
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        let ops = plan_ring_ops(
            cl,
            ctx.devices,
            ctx.ips,
            ctx.spec,
            self.fused,
            ctx.done_id_base,
        )?;
        Ok(Phase::Ops(ops))
    }
}

/// Build the ring chain schedule over an arbitrary device subset. Ranks
/// in the returned ops index into `devices`; the hierarchical allreduce
/// remaps them onto its global rank space.
pub(crate) fn plan_ring_ops(
    cl: &mut Cluster,
    devices: &[NodeId],
    ips: &[DeviceIp],
    spec: &CollectiveSpec,
    fused: bool,
    id_base: u32,
) -> Result<Vec<ScheduledOp>> {
    let n = devices.len();
    ensure!(n >= 2, "allreduce needs at least 2 ranks");
    ensure!(spec.elements % n == 0, "elements must divide by rank count");
    let hops = if fused { 2 * (n - 1) } else { n - 1 };
    ensure!(
        hops <= crate::wire::srou_hdr::MAX_SEGMENTS,
        "{hops} ring hops exceed the SROU stack"
    );
    let chunk_elems = spec.elements / n;
    let blocks_per_chunk = chunk_elems.div_ceil(spec.lanes);
    let mut ops = Vec::with_capacity(blocks_per_chunk * n);
    for c in 0..n {
        for j in 0..blocks_per_chunk {
            let g = (c * blocks_per_chunk + j) as u32;
            let elem_off = c * chunk_elems + j * spec.lanes;
            let lanes = spec.lanes.min(chunk_elems - j * spec.lanes);
            let len = lanes * 4;
            let addr = spec.base_addr + elem_off as u64 * 4;
            // Payload: the initiator's pristine block. Guard: hash of the
            // owner's pristine block (§3.1 exactly-once write).
            let payload = read_block(cl, devices[c], addr, len)?;
            let owner = (c + n - 1) % n;
            let expect_hash = guard_hash(cl, devices[owner], addr, len)?;
            let srou = crate::srou::ring_chain(ips, c, hops);
            let done_id = id_base + g;
            let env = prog_env(cl, devices[owner], len, hops, spec.reliable);
            let instr = lower_ring_chunk(
                SimdOp::Add,
                addr,
                n,
                fused,
                expect_hash,
                done_id,
                &env,
            )?;
            let pkt = Packet::new(
                ips[c],
                0, // seq assigned by the driver
                srou,
                instr,
            )
            .with_flags(op_flags(spec.reliable))
            .with_payload(payload);
            ops.push(ScheduledOp {
                rank: c,
                done_id,
                pkt,
            });
        }
    }
    Ok(ops)
}

/// Run a ring allreduce over `devices` in `cl` through the shared driver.
/// Blocks until the DES drains; returns timing + integrity counters.
pub fn run_ring_allreduce(
    cl: &mut Cluster,
    eng: &mut Engine<Cluster>,
    devices: &[NodeId],
    spec: &RingSpec,
) -> Result<AllreduceOutcome> {
    let cspec = CollectiveSpec {
        elements: spec.elements,
        lanes: spec.lanes,
        window: spec.window,
        reliable: spec.reliable,
        base_addr: spec.base_addr,
        ..Default::default()
    };
    let mut algo = RingAllreduce { fused: spec.fused };
    let out = Driver::run(cl, eng, devices, &mut algo, &cspec)?;
    Ok(AllreduceOutcome {
        elapsed_ns: out.elapsed_ns,
        blocks: out.ops,
        blocks_done: out.ops_done,
        retransmits: out.retransmits,
        hash_guard_drops: out.hash_guard_drops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle::{oracle_sum, read_vector, seed_gradients};
    use crate::net::{LinkConfig, Topology};

    fn run(elements: usize, spec_mut: impl FnOnce(&mut RingSpec)) -> (f64, AllreduceOutcome) {
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let mut spec = RingSpec {
            elements,
            ..Default::default()
        };
        spec_mut(&mut spec);
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks, "all blocks completed");
        // Verify every device holds the oracle vector.
        let oracle = oracle_sum(&grads);
        let mut max_err = 0.0f64;
        for &d in &devices {
            let got = read_vector(&mut cl, d, 0, elements).unwrap();
            for i in 0..elements {
                let err = (got[i] as f64 - oracle[i] as f64).abs();
                max_err = max_err.max(err);
            }
        }
        (max_err, out)
    }

    #[test]
    fn small_allreduce_is_exact() {
        // One block per chunk: ring-order addition matches the oracle
        // bit-for-bit (same order, same arithmetic).
        let (err, out) = run(4 * 2048, |_| {});
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 4);
        assert!(out.elapsed_ns > 0);
    }

    #[test]
    fn multi_block_allreduce_is_exact() {
        let (err, out) = run(4 * 2048 * 8, |s| s.window = 4);
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 32);
    }

    #[test]
    fn ragged_last_block_supported() {
        // chunk = 2048 + 512 elements → one full + one partial block.
        let (err, out) = run(4 * 2560, |_| {});
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 8);
    }

    #[test]
    fn reduce_scatter_only_mode() {
        let elements = 4 * 2048;
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let spec = RingSpec {
            elements,
            fused: false,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        let oracle = oracle_sum(&grads);
        // Chunk c is reduced only at its owner (c+3)%4; other ranks keep
        // their pristine data for chunks they don't own.
        let chunk = elements / 4;
        for c in 0..4 {
            let owner = (c + 3) % 4;
            let got = read_vector(&mut cl, devices[owner], 0, elements).unwrap();
            for i in c * chunk..(c + 1) * chunk {
                assert_eq!(got[i], oracle[i], "owner has reduced chunk {c}");
            }
        }
    }

    #[test]
    fn allreduce_survives_packet_loss_with_reliability() {
        let elements = 4 * 2048 * 2;
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.loss_p = 0.02;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let spec = RingSpec {
            elements,
            reliable: true,
            window: 2,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks, "loss recovered");
        let oracle = oracle_sum(&grads);
        for &d in &devices {
            let got = read_vector(&mut cl, d, 0, elements).unwrap();
            assert_eq!(got, oracle, "exactly-once semantics under loss");
        }
    }

    #[test]
    fn timing_mode_runs_at_paper_shape() {
        // Phantom devices, 1M elements: elapsed should be within 3× of
        // the line-rate floor 2(N−1)/N·V/rate.
        let t = Topology::star_with(
            1,
            4,
            0,
            LinkConfig::dc_100g(),
            crate::net::DeviceProfile::TimingOnly,
        );
        let mut cl = t.cluster;
        let devices = t.devices;
        let elements = 1 << 20;
        let spec = RingSpec {
            elements,
            window: 32,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        let v = elements as f64 * 4.0;
        let floor_ns = 2.0 * 3.0 / 4.0 * v / 12.5;
        assert!(
            (out.elapsed_ns as f64) < 3.0 * floor_ns,
            "elapsed {} vs floor {floor_ns}",
            out.elapsed_ns
        );
    }
}
