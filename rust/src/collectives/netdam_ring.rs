//! The NetDAM ring allreduce (paper §3.1/§3.2, Figures 6 & 8).
//!
//! Each rank owns chunk `r` of the vector. For every 2048-lane block of
//! its chunk, the rank injects **one** `ReduceScatter` packet whose SROU
//! stack walks the whole ring twice-minus-one:
//!
//! ```text
//!   r → r+1 → ... → r+N−1 (owner: guarded reduced write)
//!       └ fused All-Gather: → r → r+1 → ... → r+N−2 → Done → r
//! ```
//!
//! Interim hops add their local contribution into the packet buffer (no
//! local side effects — idempotent); the owner performs the hash-guarded
//! write (§3.1's block-hash idempotency trick); the fused all-gather
//! carries the finished block back around. A window of outstanding blocks
//! per rank self-clocks against CollectiveDone completions — no barriers,
//! no per-iteration synchronization (the contrast with Figure 7's RoCE
//! flow).

use std::cell::RefCell;
use std::collections::{HashSet, VecDeque};
use std::rc::Rc;

use anyhow::{ensure, Result};

use crate::alu::block_hash;
use crate::isa::registry::MemAccess;
use crate::isa::{Flags, Instruction, SimdOp};
use crate::net::{Cluster, InjectCmd, NodeId};
use crate::sim::{Engine, SimTime};
use crate::transport::ReliabilityTable;
use crate::wire::{DeviceIp, Packet, Payload};

/// Parameters of one allreduce run.
#[derive(Debug, Clone)]
pub struct RingSpec {
    /// Total f32 elements (must divide evenly by the rank count).
    pub elements: usize,
    /// SIMD lanes per packet (the paper's 2048 × f32 blocks).
    pub lanes: usize,
    /// Outstanding blocks per rank (self-clocked window).
    pub window: usize,
    /// Track with timeout-retransmit (for lossy fabrics, E5).
    pub reliable: bool,
    /// Device-local base address of the vector.
    pub base_addr: u64,
    /// `true` = full allreduce (fused all-gather); `false` = reduce-
    /// scatter only (ablation A1).
    pub fused: bool,
}

impl Default for RingSpec {
    fn default() -> Self {
        Self {
            elements: 1 << 16,
            lanes: 2048,
            window: 16,
            reliable: false,
            base_addr: 0,
            fused: true,
        }
    }
}

/// Result of a run.
#[derive(Debug, Clone)]
pub struct AllreduceOutcome {
    pub elapsed_ns: SimTime,
    pub blocks: usize,
    pub blocks_done: usize,
    pub retransmits: u64,
    pub hash_guard_drops: u64,
}

struct BlockPlan {
    initiator_rank: usize,
    pkt: Packet, // seq filled at injection
}

struct Driver {
    pending: Vec<VecDeque<usize>>, // per-rank queue of global block ids
    plans: Vec<Option<BlockPlan>>,
    devices: Vec<NodeId>,
    blocks_per_chunk: usize,
    done: HashSet<u32>,
    last_done: SimTime,
    reliable: bool,
}

impl Driver {
    /// Pop the next pending block for `rank` (sequence numbers were
    /// pre-assigned at plan time).
    fn next_cmd(&mut self, rank: usize) -> Option<InjectCmd> {
        let g = self.pending[rank].pop_front()?;
        let plan = self.plans[g].take().expect("block injected once");
        Some(InjectCmd {
            origin: self.devices[plan.initiator_rank],
            pkt: plan.pkt,
            reliable: self.reliable,
        })
    }
}

/// Run a ring allreduce over `devices` in `cl`. Blocks until the DES
/// drains; returns timing + integrity counters.
pub fn run_ring_allreduce(
    cl: &mut Cluster,
    eng: &mut Engine<Cluster>,
    devices: &[NodeId],
    spec: &RingSpec,
) -> Result<AllreduceOutcome> {
    let n = devices.len();
    ensure!(n >= 2, "allreduce needs at least 2 ranks");
    ensure!(spec.elements % n == 0, "elements must divide by rank count");
    ensure!(2 * (n - 1) <= crate::wire::srou_hdr::MAX_SEGMENTS);
    let chunk_elems = spec.elements / n;
    let blocks_per_chunk = chunk_elems.div_ceil(spec.lanes);
    let total_blocks = blocks_per_chunk * n;
    let ips: Vec<DeviceIp> = devices.iter().map(|&d| cl.device(d).ip()).collect();

    if spec.reliable {
        // Chains take ~10 us idle but queue under load; a generous timeout
        // avoids spurious (harmless but wasteful) duplicate chains.
        cl.xport = ReliabilityTable::new(2_000_000, 12);
    }

    // ---- build one packet plan per block ------------------------------
    let mut plans: Vec<Option<BlockPlan>> = Vec::with_capacity(total_blocks);
    let mut pending: Vec<VecDeque<usize>> = vec![VecDeque::new(); n];
    for c in 0..n {
        for j in 0..blocks_per_chunk {
            let g = c * blocks_per_chunk + j;
            let elem_off = c * chunk_elems + j * spec.lanes;
            let lanes = spec.lanes.min(chunk_elems - j * spec.lanes);
            let len = lanes * 4;
            let addr = spec.base_addr + elem_off as u64 * 4;
            // Payload: the initiator's pristine block.
            let init_dev = cl.device_mut(devices[c]);
            let payload = if init_dev.mem_ref().is_phantom() {
                Payload::phantom(len)
            } else {
                Payload::from_bytes(init_dev.mem().read(addr, len)?)
            };
            // Guard: hash of the owner's pristine block.
            let owner = (c + n - 1) % n;
            let owner_dev = cl.device_mut(devices[owner]);
            let expect_hash = if owner_dev.mem_ref().is_phantom() {
                0
            } else {
                block_hash(&owner_dev.mem().read(addr, len)?)
            };
            // SROU: N−1 reduce hops (+ N−1 gather hops when fused).
            let hops = if spec.fused { 2 * (n - 1) } else { n - 1 };
            let srou = crate::srou::ring_chain(&ips, c, hops);
            let pkt = Packet::new(
                ips[c],
                0, // seq at injection
                srou,
                Instruction::ReduceScatter {
                    op: SimdOp::Add,
                    addr,
                    block: g as u32,
                    rs_left: (n - 1) as u8,
                    expect_hash,
                },
            )
            .with_flags(if spec.reliable {
                Flags(Flags::RELIABLE)
            } else {
                Flags::default()
            })
            .with_payload(payload);
            plans.push(Some(BlockPlan {
                initiator_rank: c,
                pkt,
            }));
            pending[c].push_back(g);
        }
    }

    let driver = Rc::new(RefCell::new(Driver {
        pending,
        plans,
        devices: devices.to_vec(),
        blocks_per_chunk,
        done: HashSet::new(),
        last_done: 0,
        reliable: spec.reliable,
    }));

    // ---- completion hook: windowed self-clocking ----------------------
    // Sequence allocation must go through the cluster, so the hook only
    // *marks* and the actual refill happens via a pre-allocated seq pool:
    // we give every block a unique seq up front instead.
    {
        let mut d = driver.borrow_mut();
        for g in 0..total_blocks {
            let rank = d.plans[g].as_ref().unwrap().initiator_rank;
            let seq = cl.alloc_seq(devices[rank]);
            d.plans[g].as_mut().unwrap().pkt.seq = seq;
        }
    }
    let hook_driver = Rc::clone(&driver);
    cl.on_completion = Some(Box::new(move |rec| {
        let mut d = hook_driver.borrow_mut();
        let Instruction::CollectiveDone { block } = rec.instr else {
            return Vec::new();
        };
        if !d.done.insert(block) {
            return Vec::new(); // duplicate Done (retransmit) — ignore
        }
        d.last_done = rec.time;
        let rank = block as usize / d.blocks_per_chunk;
        match d.next_cmd(rank) {
            Some(cmd) => vec![cmd],
            None => Vec::new(),
        }
    }));

    // ---- kick the initial window --------------------------------------
    let mut kicks = Vec::new();
    {
        let mut d = driver.borrow_mut();
        for rank in 0..n {
            for _ in 0..spec.window.min(blocks_per_chunk) {
                if let Some(cmd) = d.next_cmd(rank) {
                    kicks.push(cmd);
                }
            }
        }
    }
    for cmd in kicks {
        if cmd.reliable {
            cl.inject_reliable(eng, cmd.origin, cmd.pkt);
        } else {
            cl.inject(eng, cmd.origin, cmd.pkt);
        }
    }

    eng.run(cl);
    cl.on_completion = None;

    let d = driver.borrow();
    let guard_drops: u64 = devices
        .iter()
        .map(|&n| cl.device(n).drops_hash_guard)
        .sum();
    Ok(AllreduceOutcome {
        elapsed_ns: d.last_done,
        blocks: total_blocks,
        blocks_done: d.done.len(),
        retransmits: cl.xport.retransmits,
        hash_guard_drops: guard_drops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::oracle::{oracle_sum, read_vector, seed_gradients};
    use crate::net::{LinkConfig, Topology};

    fn run(elements: usize, spec_mut: impl FnOnce(&mut RingSpec)) -> (f64, AllreduceOutcome) {
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let mut spec = RingSpec {
            elements,
            ..Default::default()
        };
        spec_mut(&mut spec);
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks, "all blocks completed");
        // Verify every device holds the oracle vector.
        let oracle = oracle_sum(&grads);
        let mut max_err = 0.0f64;
        for &d in &devices {
            let got = read_vector(&mut cl, d, 0, elements).unwrap();
            for i in 0..elements {
                let err = (got[i] as f64 - oracle[i] as f64).abs();
                max_err = max_err.max(err);
            }
        }
        (max_err, out)
    }

    #[test]
    fn small_allreduce_is_exact() {
        // One block per chunk: ring-order addition matches the oracle
        // bit-for-bit (same order, same arithmetic).
        let (err, out) = run(4 * 2048, |_| {});
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 4);
        assert!(out.elapsed_ns > 0);
    }

    #[test]
    fn multi_block_allreduce_is_exact() {
        let (err, out) = run(4 * 2048 * 8, |s| s.window = 4);
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 32);
    }

    #[test]
    fn ragged_last_block_supported() {
        // chunk = 2048 + 512 elements → one full + one partial block.
        let (err, out) = run(4 * 2560, |_| {});
        assert_eq!(err, 0.0);
        assert_eq!(out.blocks, 8);
    }

    #[test]
    fn reduce_scatter_only_mode() {
        let elements = 4 * 2048;
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let spec = RingSpec {
            elements,
            fused: false,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        let oracle = oracle_sum(&grads);
        // Chunk c is reduced only at its owner (c+3)%4; other ranks keep
        // their pristine data for chunks they don't own.
        let chunk = elements / 4;
        for c in 0..4 {
            let owner = (c + 3) % 4;
            let got = read_vector(&mut cl, devices[owner], 0, elements).unwrap();
            for i in c * chunk..(c + 1) * chunk {
                assert_eq!(got[i], oracle[i], "owner has reduced chunk {c}");
            }
        }
    }

    #[test]
    fn allreduce_survives_packet_loss_with_reliability() {
        let elements = 4 * 2048 * 2;
        let t = Topology::star(42, 4, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.loss_p = 0.02;
        let devices = t.devices;
        let grads = seed_gradients(&mut cl, &devices, elements, 0, 7);
        let spec = RingSpec {
            elements,
            reliable: true,
            window: 2,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks, "loss recovered");
        let oracle = oracle_sum(&grads);
        for &d in &devices {
            let got = read_vector(&mut cl, d, 0, elements).unwrap();
            assert_eq!(got, oracle, "exactly-once semantics under loss");
        }
    }

    #[test]
    fn timing_mode_runs_at_paper_shape() {
        // Phantom devices, 1M elements: elapsed should be within 3× of
        // the line-rate floor 2(N−1)/N·V/rate.
        let t = {
            let mut cl = Cluster::new(1);
            let sw = cl.add_switch(crate::net::Switch::tor(None));
            let mut devices = Vec::new();
            for i in 0..4u8 {
                let d = cl.add_device(
                    crate::device::DeviceConfig::paper_default(DeviceIp::lan(1 + i))
                        .timing_only(),
                );
                cl.connect(sw, d, LinkConfig::dc_100g());
                devices.push(d);
            }
            cl.compute_routes();
            (cl, devices)
        };
        let (mut cl, devices) = t;
        let elements = 1 << 20;
        let spec = RingSpec {
            elements,
            window: 32,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec).unwrap();
        assert_eq!(out.blocks_done, out.blocks);
        let v = elements as f64 * 4.0;
        let floor_ns = 2.0 * 3.0 / 4.0 * v / 12.5;
        assert!(
            (out.elapsed_ns as f64) < 3.0 * floor_ns,
            "elapsed {} vs floor {floor_ns}",
            out.elapsed_ns
        );
    }
}
