//! Recursive halving-doubling allreduce (Rabenseifner-style).
//!
//! Latency-optimal at small message sizes: 2·log₂(N) rounds against the
//! ring's 2·(N−1), at the cost of round synchrony. Every exchange maps
//! onto the NetDAM ISA directly:
//!
//! * **reduce rounds** (vector halving): rank `r` sends the half of its
//!   currently-owned segment that partner `p = r ⊕ d` keeps, as a 1-hop
//!   `reduce → guarded_write` program — a hash-guarded reduced write at
//!   `p` (§3.1's exactly-once trick, so blind retransmission stays safe);
//! * **gather rounds** (vector doubling): `r` streams its whole owned
//!   segment to `p` as idempotent 1-hop store programs.
//!
//! Each round is one driver phase: guards and payloads are captured from
//! live device memory at phase-plan time, which is exactly when the
//! previous round's writes have landed (the driver drains the DES between
//! phases). Within a round every rank has exactly one writer per block,
//! so the per-block guard hashes stay valid for first delivery and reject
//! duplicates.

use anyhow::{ensure, Result};

use crate::isa::SimdOp;
use crate::net::Cluster;
use crate::wire::{Packet, SrouHeader};

use super::driver::{
    guard_hash, lower_ring_chunk, lower_store_chain, op_flags, prog_env, read_block,
    CollectiveAlgorithm, PlanCtx, Phase, ScheduledOp,
};

/// Which instruction a planned exchange uses.
enum ExchangeKind {
    /// Hash-guarded reduced write at the destination (reduce rounds).
    GuardedReduce,
    /// Plain idempotent write at the destination (gather rounds).
    Gather,
}

pub struct HalvingDoubling {
    n: usize,
    log_n: usize,
    /// Per-rank currently-owned segment as `(elem offset, elem len)`.
    owned: Vec<(usize, usize)>,
}

impl HalvingDoubling {
    pub fn new(n_ranks: usize) -> Result<Self> {
        ensure!(
            n_ranks >= 2 && n_ranks.is_power_of_two(),
            "halving-doubling needs a power-of-two rank count, got {n_ranks}"
        );
        Ok(Self {
            n: n_ranks,
            log_n: n_ranks.trailing_zeros() as usize,
            owned: Vec::new(),
        })
    }

    /// Plan one rank's exchange of `[elem_off, elem_off+elem_len)` toward
    /// `to`, blocked into `spec.lanes`-sized packets.
    #[allow(clippy::too_many_arguments)]
    fn push_exchange(
        &self,
        cl: &mut Cluster,
        ctx: &PlanCtx<'_>,
        ops: &mut Vec<ScheduledOp>,
        next_id: &mut u32,
        from: usize,
        to: usize,
        elem_off: usize,
        elem_len: usize,
        kind: &ExchangeKind,
    ) -> Result<()> {
        let lanes = ctx.spec.lanes;
        let mut off = 0;
        while off < elem_len {
            let blk = lanes.min(elem_len - off);
            let addr = ctx.spec.base_addr + (elem_off + off) as u64 * 4;
            let len = blk * 4;
            let payload = read_block(cl, ctx.devices[from], addr, len)?;
            let done_id = *next_id;
            *next_id += 1;
            let instr = match kind {
                ExchangeKind::GuardedReduce => {
                    // A degenerate 2-rank ring chunk: reduce at the
                    // partner, guarded write fused there.
                    let expect_hash = guard_hash(cl, ctx.devices[to], addr, len)?;
                    let env = prog_env(cl, ctx.devices[to], len, 1, ctx.spec.reliable);
                    lower_ring_chunk(SimdOp::Add, addr, 2, false, expect_hash, done_id, &env)?
                }
                ExchangeKind::Gather => {
                    let env = prog_env(cl, ctx.devices[to], len, 1, ctx.spec.reliable);
                    lower_store_chain(addr, 1, done_id, &env)?
                }
            };
            let pkt = Packet::new(ctx.ips[from], 0, SrouHeader::direct(ctx.ips[to]), instr)
                .with_flags(op_flags(ctx.spec.reliable))
                .with_payload(payload);
            ops.push(ScheduledOp {
                rank: from,
                done_id,
                pkt,
            });
            off += blk;
        }
        Ok(())
    }
}

impl CollectiveAlgorithm for HalvingDoubling {
    fn name(&self) -> &'static str {
        "halving-doubling"
    }

    fn phases(&self) -> usize {
        2 * self.log_n
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase> {
        let n = self.n;
        ensure!(ctx.devices.len() == n, "rank count mismatch");
        if phase == 0 {
            ensure!(
                ctx.spec.elements % n == 0,
                "elements must divide by rank count"
            );
            self.owned = vec![(0, ctx.spec.elements); n];
        }
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        if phase < self.log_n {
            // Reduce round: exchange halves at distance d = n / 2^(k+1).
            let d = n >> (phase + 1);
            let mut new_owned = self.owned.clone();
            for r in 0..n {
                let p = r ^ d;
                let (lo, len) = self.owned[r];
                let half = len / 2;
                // The d-bit decides which half a rank keeps: bit clear →
                // lower half, bit set → upper half. `r` sends the other
                // half — exactly the half `p` keeps.
                let (keep, send) = if r & d == 0 {
                    ((lo, half), (lo + half, half))
                } else {
                    ((lo + half, half), (lo, half))
                };
                new_owned[r] = keep;
                self.push_exchange(
                    cl,
                    ctx,
                    &mut ops,
                    &mut next_id,
                    r,
                    p,
                    send.0,
                    send.1,
                    &ExchangeKind::GuardedReduce,
                )?;
            }
            self.owned = new_owned;
        } else {
            // Gather round: same partners in reverse order, d = 2^k.
            let d = 1usize << (phase - self.log_n);
            let mut new_owned = self.owned.clone();
            for r in 0..n {
                let p = r ^ d;
                let (lo, len) = self.owned[r];
                self.push_exchange(
                    cl,
                    ctx,
                    &mut ops,
                    &mut next_id,
                    r,
                    p,
                    lo,
                    len,
                    &ExchangeKind::Gather,
                )?;
                let (plo, plen) = self.owned[p];
                new_owned[r] = (lo.min(plo), len + plen);
            }
            self.owned = new_owned;
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::{naive_sum, read_vector, seed_gradients_exact};
    use crate::net::{Cluster, LinkConfig, Topology};
    use crate::sim::Engine;

    fn run(ranks: usize, elements: usize, window: usize) {
        let t = Topology::star(9, ranks, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x4D);
        let spec = CollectiveSpec {
            elements,
            window,
            ..Default::default()
        };
        let mut algo = HalvingDoubling::new(ranks).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "all exchanges completed");
        assert!(out.elapsed_ns > 0);
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                oracle,
                "ranks={ranks} elements={elements}"
            );
        }
    }

    #[test]
    fn two_ranks_single_block() {
        run(2, 2 * 2048, 4);
    }

    #[test]
    fn four_ranks_multi_block() {
        run(4, 4 * 2048 * 2, 8);
    }

    #[test]
    fn eight_ranks_ragged_blocks() {
        // elements/8 = 1536: sub-lane segments exercise ragged packets.
        run(8, 8 * 1536, 4);
    }

    #[test]
    fn rejects_non_power_of_two() {
        assert!(HalvingDoubling::new(3).is_err());
        assert!(HalvingDoubling::new(6).is_err());
        assert!(HalvingDoubling::new(1).is_err());
    }

    #[test]
    fn survives_loss_with_reliability() {
        let ranks = 4;
        let elements = 4 * 2048;
        let t = Topology::star(11, ranks, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.loss_p = 0.02;
        let devices = t.devices;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x4E);
        let spec = CollectiveSpec {
            elements,
            window: 2,
            reliable: true,
            ..Default::default()
        };
        let mut algo = HalvingDoubling::new(ranks).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "loss recovered");
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(read_vector(&mut cl, d, 0, elements).unwrap(), oracle);
        }
    }
}
