//! Ring allreduce over RoCE hosts — the Horovod-style baseline (§3.3,
//! Figure 7).
//!
//! Each rank is a host app. Per step it streams its chunk to the right
//! neighbour at line rate (MTU-sized WRITEs over the simulated fabric),
//! and when the incoming chunk has fully arrived it charges the host
//! costs NetDAM avoids: PCIe DMA of the chunk + the CPU reduction loop.
//! Steps are self-synchronizing (a rank cannot send step `s+1` before it
//! reduced step `s`) — the implicit barrier the paper points at.

use crate::host::{HostConfig, HostModel};
use crate::isa::Instruction;
use crate::net::{App, AppCtx};
use crate::sim::SimTime;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};
use std::collections::HashMap;

const TOK_SEND: u64 = 1;
const TOK_PROC: u64 = 2;

/// MTU payload per packet (jumbo frame budget, like NetDAM blocks).
pub const MTU_PAYLOAD: usize = 8192;

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    ReduceScatter,
    AllGather,
    Done,
}

pub struct RingRocePeer {
    /// Rank id (diagnostics).
    pub rank: usize,
    n: usize,
    right: DeviceIp,
    chunk_bytes: usize,
    pkts_per_chunk: usize,
    /// Inter-packet pacing at line rate.
    gap_ns: SimTime,
    host: HostModel,
    phase: Phase,
    step: usize,
    sent_pkts: usize,
    send_done: bool,
    recv_processed: bool,
    /// Bytes received per step tag (tolerates one-step-ahead senders).
    rcvd: HashMap<u64, usize>,
    /// Completion metric name.
    metric: &'static str,
}

impl RingRocePeer {
    pub fn new(
        rank: usize,
        n: usize,
        right: DeviceIp,
        elements: usize,
        line_gbps: f64,
        seed: u64,
    ) -> Self {
        assert!(n >= 2 && elements % n == 0);
        let chunk_bytes = elements / n * 4;
        let pkts = chunk_bytes.div_ceil(MTU_PAYLOAD);
        // Wire bytes per MTU packet ≈ payload + ~96B headers.
        let gap = ((MTU_PAYLOAD + 96) as f64 * 8.0 / line_gbps).ceil() as SimTime;
        Self {
            rank,
            n,
            right,
            chunk_bytes,
            pkts_per_chunk: pkts,
            gap_ns: gap,
            host: HostModel::new(HostConfig::paper_default(), seed ^ rank as u64),
            phase: Phase::ReduceScatter,
            step: 0,
            sent_pkts: 0,
            send_done: false,
            recv_processed: false,
            rcvd: HashMap::new(),
            metric: "ring_roce_done_ns",
        }
    }

    fn tag(&self) -> u64 {
        let p = match self.phase {
            Phase::ReduceScatter => 0,
            Phase::AllGather => 1,
            Phase::Done => unreachable!(),
        };
        p * 1000 + self.step as u64
    }

    fn begin_step(&mut self, ctx: &mut AppCtx) {
        self.sent_pkts = 0;
        self.send_done = false;
        self.recv_processed = false;
        // Post-send software overhead, then stream.
        let t = self.host.post_send_ns();
        ctx.timer(t, TOK_SEND);
        // The incoming chunk may already be fully buffered (sender ran
        // one step ahead) — process it immediately.
        self.check_recv(ctx);
    }

    fn send_next(&mut self, ctx: &mut AppCtx) {
        if self.sent_pkts >= self.pkts_per_chunk {
            self.send_done = true;
            self.maybe_advance(ctx);
            return;
        }
        let remaining = self.chunk_bytes - self.sent_pkts * MTU_PAYLOAD;
        let len = remaining.min(MTU_PAYLOAD);
        let seq = ctx.alloc_seq();
        let pkt = Packet::new(
            ctx.self_ip,
            seq,
            SrouHeader::direct(self.right),
            Instruction::Write { addr: self.tag() },
        )
        .with_payload(Payload::phantom(len));
        ctx.send(pkt);
        self.sent_pkts += 1;
        ctx.timer(self.gap_ns, TOK_SEND);
    }

    fn check_recv(&mut self, ctx: &mut AppCtx) {
        if self.recv_processed || self.phase == Phase::Done {
            return;
        }
        let tag = self.tag();
        if self.rcvd.get(&tag).copied().unwrap_or(0) >= self.chunk_bytes {
            // Chunk fully arrived: DMA it down, and in the RS phase run
            // the CPU reduction before the step barrier clears.
            let dma = self.host.nic_write_ns(self.chunk_bytes);
            let t = match self.phase {
                Phase::ReduceScatter => dma + self.host.reduce_ns(self.chunk_bytes),
                _ => dma,
            };
            ctx.timer(t, TOK_PROC);
        }
    }

    fn maybe_advance(&mut self, ctx: &mut AppCtx) {
        if !(self.send_done && self.recv_processed) || self.phase == Phase::Done {
            return;
        }
        self.step += 1;
        if self.step == self.n - 1 {
            match self.phase {
                Phase::ReduceScatter => {
                    self.phase = Phase::AllGather;
                    self.step = 0;
                }
                Phase::AllGather => {
                    self.phase = Phase::Done;
                    ctx.record(self.metric, ctx.now);
                    ctx.count("ring_roce_finished", 1);
                    return;
                }
                Phase::Done => unreachable!(),
            }
        }
        self.begin_step(ctx);
    }
}

impl App for RingRocePeer {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        self.begin_step(ctx);
    }

    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        if let Instruction::Write { addr } = pkt.instr {
            *self.rcvd.entry(addr).or_insert(0) += pkt.payload.len();
            self.check_recv(ctx);
        }
    }

    fn on_timer(&mut self, token: u64, ctx: &mut AppCtx) {
        match token {
            TOK_SEND => self.send_next(ctx),
            TOK_PROC => {
                self.recv_processed = true;
                self.maybe_advance(ctx);
            }
            _ => {}
        }
    }
}

/// Build a star of `n` RoCE hosts, run ring allreduce, return elapsed ns.
pub fn run_ring_roce(seed: u64, n: usize, elements: usize) -> crate::collectives::CollectiveReport {
    use crate::net::{Cluster, LinkConfig, Switch};
    use crate::sim::Engine;

    let mut cl = Cluster::new(seed);
    let sw = cl.add_switch(Switch::tor(None));
    let link = LinkConfig::dc_100g();
    let ips: Vec<DeviceIp> = (0..n).map(|i| DeviceIp::lan(101 + i as u8)).collect();
    for (r, &ip) in ips.iter().enumerate() {
        let app = RingRocePeer::new(r, n, ips[(r + 1) % n], elements, link.rate.0, seed);
        let h = cl.add_host(ip, Some(Box::new(app)));
        cl.connect(sw, h, link.clone());
    }
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    cl.start_apps(&mut eng);
    eng.run(&mut cl);
    let finished = cl.metrics.counter("ring_roce_finished");
    assert_eq!(finished as usize, n, "all ranks completed");
    let elapsed = cl.metrics.hist("ring_roce_done_ns").map(|h| h.max()).unwrap_or(0);
    crate::collectives::CollectiveReport {
        algorithm: "ring-roce",
        elements,
        elapsed_ns: elapsed,
        link_drops: cl.metrics.counter("link_drops"),
        retransmits: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_and_scales_with_volume() {
        let r1 = run_ring_roce(5, 4, 4 * 8192);
        let r2 = run_ring_roce(5, 4, 4 * 8192 * 8);
        assert!(r1.elapsed_ns > 0);
        assert!(
            r2.elapsed_ns > 4 * r1.elapsed_ns,
            "8× volume ⇒ ≥4× time ({} vs {})",
            r2.elapsed_ns,
            r1.elapsed_ns
        );
        assert_eq!(r1.link_drops, 0, "lossless ring");
    }

    #[test]
    fn cpu_reduce_dominates_at_scale() {
        // At 1M elements/rank-chunk the reduce term (1.2 B/ns) must be
        // the bulk of the step time vs the wire (12.5 B/ns).
        let elements = 4 << 20;
        let r = run_ring_roce(6, 4, elements);
        let chunk = (elements / 4 * 4) as f64;
        let wire_floor = 6.0 * chunk * 8.0 / 100.0; // 6 steps serialized
        assert!(
            r.elapsed_ns as f64 > 2.0 * wire_floor,
            "host costs must dominate: {} vs wire {}",
            r.elapsed_ns,
            wire_floor
        );
    }
}
