//! Ring allreduce over RoCE hosts — the Horovod-style baseline (§3.3,
//! Figure 7).
//!
//! Each rank is a host app. Per step it streams its chunk to the right
//! neighbour at line rate (MTU-sized WRITEs over the simulated fabric),
//! and when the incoming chunk has fully arrived it charges the host
//! costs NetDAM avoids: PCIe DMA of the chunk + the CPU reduction loop.
//! Steps are self-synchronizing (a rank cannot send step `s+1` before it
//! reduced step `s`) — the implicit barrier the paper points at.
//!
//! The per-rank state machine lives in [`RingRocePeer`]; cluster
//! construction, app start, drain, and report production go through the
//! shared [`Driver`](super::driver::Driver) via [`RingRoceAllreduce`].

use crate::host::{HostConfig, HostModel};
use crate::isa::Instruction;
use crate::net::{App, AppCtx, Cluster};
use crate::sim::SimTime;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};
use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::driver::{CollectiveAlgorithm, Phase, PlanCtx};

const TOK_SEND: u64 = 1;
const TOK_PROC: u64 = 2;

/// MTU payload per packet (jumbo frame budget, like NetDAM blocks).
pub const MTU_PAYLOAD: usize = 8192;

#[derive(Debug, Clone, Copy, PartialEq)]
enum PhaseSm {
    ReduceScatter,
    AllGather,
    Done,
}

pub struct RingRocePeer {
    /// Rank id (diagnostics).
    pub rank: usize,
    n: usize,
    right: DeviceIp,
    chunk_bytes: usize,
    pkts_per_chunk: usize,
    /// Inter-packet pacing at line rate.
    gap_ns: SimTime,
    host: HostModel,
    phase: PhaseSm,
    step: usize,
    sent_pkts: usize,
    send_done: bool,
    recv_processed: bool,
    /// Bytes received per step tag (tolerates one-step-ahead senders).
    rcvd: HashMap<u64, usize>,
    /// Completion metric name.
    metric: &'static str,
}

impl RingRocePeer {
    pub fn new(
        rank: usize,
        n: usize,
        right: DeviceIp,
        elements: usize,
        line_gbps: f64,
        seed: u64,
    ) -> Self {
        assert!(n >= 2 && elements % n == 0);
        let chunk_bytes = elements / n * 4;
        let pkts = chunk_bytes.div_ceil(MTU_PAYLOAD);
        // Wire bytes per MTU packet ≈ payload + ~96B headers.
        let gap = ((MTU_PAYLOAD + 96) as f64 * 8.0 / line_gbps).ceil() as SimTime;
        Self {
            rank,
            n,
            right,
            chunk_bytes,
            pkts_per_chunk: pkts,
            gap_ns: gap,
            host: HostModel::new(HostConfig::paper_default(), seed ^ rank as u64),
            phase: PhaseSm::ReduceScatter,
            step: 0,
            sent_pkts: 0,
            send_done: false,
            recv_processed: false,
            rcvd: HashMap::new(),
            metric: "ring_roce_done_ns",
        }
    }

    fn tag(&self) -> u64 {
        let p = match self.phase {
            PhaseSm::ReduceScatter => 0,
            PhaseSm::AllGather => 1,
            PhaseSm::Done => unreachable!(),
        };
        p * 1000 + self.step as u64
    }

    fn begin_step(&mut self, ctx: &mut AppCtx) {
        self.sent_pkts = 0;
        self.send_done = false;
        self.recv_processed = false;
        // Post-send software overhead, then stream.
        let t = self.host.post_send_ns();
        ctx.timer(t, TOK_SEND);
        // The incoming chunk may already be fully buffered (sender ran
        // one step ahead) — process it immediately.
        self.check_recv(ctx);
    }

    fn send_next(&mut self, ctx: &mut AppCtx) {
        if self.sent_pkts >= self.pkts_per_chunk {
            self.send_done = true;
            self.maybe_advance(ctx);
            return;
        }
        let remaining = self.chunk_bytes - self.sent_pkts * MTU_PAYLOAD;
        let len = remaining.min(MTU_PAYLOAD);
        let seq = ctx.alloc_seq();
        let pkt = Packet::new(
            ctx.self_ip,
            seq,
            SrouHeader::direct(self.right),
            Instruction::Write { addr: self.tag() },
        )
        .with_payload(Payload::phantom(len));
        ctx.send(pkt);
        self.sent_pkts += 1;
        ctx.timer(self.gap_ns, TOK_SEND);
    }

    fn check_recv(&mut self, ctx: &mut AppCtx) {
        if self.recv_processed || self.phase == PhaseSm::Done {
            return;
        }
        let tag = self.tag();
        if self.rcvd.get(&tag).copied().unwrap_or(0) >= self.chunk_bytes {
            // Chunk fully arrived: DMA it down, and in the RS phase run
            // the CPU reduction before the step barrier clears.
            let dma = self.host.nic_write_ns(self.chunk_bytes);
            let t = match self.phase {
                PhaseSm::ReduceScatter => dma + self.host.reduce_ns(self.chunk_bytes),
                _ => dma,
            };
            ctx.timer(t, TOK_PROC);
        }
    }

    fn maybe_advance(&mut self, ctx: &mut AppCtx) {
        if !(self.send_done && self.recv_processed) || self.phase == PhaseSm::Done {
            return;
        }
        self.step += 1;
        if self.step == self.n - 1 {
            match self.phase {
                PhaseSm::ReduceScatter => {
                    self.phase = PhaseSm::AllGather;
                    self.step = 0;
                }
                PhaseSm::AllGather => {
                    self.phase = PhaseSm::Done;
                    ctx.record(self.metric, ctx.now);
                    ctx.count("ring_roce_finished", 1);
                    return;
                }
                PhaseSm::Done => unreachable!(),
            }
        }
        self.begin_step(ctx);
    }
}

impl App for RingRocePeer {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        self.begin_step(ctx);
    }

    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        if let Instruction::Write { addr } = pkt.instr {
            *self.rcvd.entry(addr).or_insert(0) += pkt.payload.len();
            self.check_recv(ctx);
        }
    }

    fn on_timer(&mut self, token: u64, ctx: &mut AppCtx) {
        match token {
            TOK_SEND => self.send_next(ctx),
            TOK_PROC => {
                self.recv_processed = true;
                self.maybe_advance(ctx);
            }
            _ => {}
        }
    }
}

/// The driver-facing baseline: installs a star of RoCE host peers into an
/// empty cluster; the shared driver starts them and reads the metrics.
pub struct RingRoceAllreduce {
    pub ranks: usize,
    pub elements: usize,
    pub seed: u64,
}

impl CollectiveAlgorithm for RingRoceAllreduce {
    fn name(&self) -> &'static str {
        "ring-roce"
    }

    fn plan_phase(&mut self, cl: &mut Cluster, _ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        use crate::net::{LinkConfig, Switch};
        ensure!(
            cl.nodes.is_empty(),
            "ring-roce builds its own host fabric; pass a fresh cluster"
        );
        let sw = cl.add_switch(Switch::tor(None));
        let link = LinkConfig::dc_100g();
        let ips: Vec<DeviceIp> = (0..self.ranks)
            .map(|i| DeviceIp::lan(101 + i as u8))
            .collect();
        for (r, &ip) in ips.iter().enumerate() {
            let app = RingRocePeer::new(
                r,
                self.ranks,
                ips[(r + 1) % self.ranks],
                self.elements,
                link.rate.0,
                self.seed,
            );
            let h = cl.add_host(ip, Some(Box::new(app)));
            cl.connect(sw, h, link.clone());
        }
        cl.compute_routes();
        Ok(Phase::Apps {
            finished_counter: "ring_roce_finished",
            done_hist: "ring_roce_done_ns",
            expect_finished: self.ranks as u64,
        })
    }
}

/// Build a star of `n` RoCE hosts, run ring allreduce, return the report.
pub fn run_ring_roce(seed: u64, n: usize, elements: usize) -> crate::collectives::CollectiveReport {
    use super::driver::{run_collective, AlgoKind, RunOpts};
    run_collective(
        AlgoKind::RingRoce,
        &RunOpts {
            elements,
            ranks: n,
            seed,
            ..Default::default()
        },
    )
    .expect("ring-roce run")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_and_scales_with_volume() {
        let r1 = run_ring_roce(5, 4, 4 * 8192);
        let r2 = run_ring_roce(5, 4, 4 * 8192 * 8);
        assert!(r1.elapsed_ns > 0);
        assert!(
            r2.elapsed_ns > 4 * r1.elapsed_ns,
            "8× volume ⇒ ≥4× time ({} vs {})",
            r2.elapsed_ns,
            r1.elapsed_ns
        );
        assert_eq!(r1.link_drops, 0, "lossless ring");
    }

    #[test]
    fn cpu_reduce_dominates_at_scale() {
        // At 1M elements/rank-chunk the reduce term (1.2 B/ns) must be
        // the bulk of the step time vs the wire (12.5 B/ns).
        let elements = 4 << 20;
        let r = run_ring_roce(6, 4, elements);
        let chunk = (elements / 4 * 4) as f64;
        let wire_floor = 6.0 * chunk * 8.0 / 100.0; // 6 steps serialized
        assert!(
            r.elapsed_ns as f64 > 2.0 * wire_floor,
            "host costs must dominate: {} vs wire {}",
            r.elapsed_ns,
            wire_floor
        );
    }
}
