//! Test oracles + data seeding for the collectives.

use anyhow::Result;

use crate::isa::registry::MemAccess;
use crate::net::{Cluster, NodeId};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::Xoshiro256;

/// Write per-rank gradient vectors into each device's HBM at `base`.
/// Returns the vectors for oracle computation (empty inner vecs when the
/// devices are phantom/timing-only).
pub fn seed_gradients(
    cl: &mut Cluster,
    devices: &[NodeId],
    elements: usize,
    base: u64,
    seed: u64,
) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(devices.len());
    for (r, &node) in devices.iter().enumerate() {
        let dev = cl.device_mut(node);
        if dev.mem_ref().is_phantom() {
            out.push(Vec::new());
            continue;
        }
        let mut rng = Xoshiro256::seed_from(seed ^ (r as u64 + 1).wrapping_mul(0x9E37));
        // Values in a range where f32 ring-order addition is exact enough
        // to compare bitwise against the oracle's identical order.
        let data = rng.f32_vec(elements, -8.0, 8.0);
        dev.mem().write(base, &f32s_to_bytes(&data)).unwrap();
        out.push(data);
    }
    out
}

/// The expected allreduce(+) result — summed in *ring order* per chunk so
/// the comparison can be exact: chunk c accumulates contributions in the
/// order rank c, c+1, ..., c+N−1 (the order the chain adds them).
pub fn oracle_sum(per_rank: &[Vec<f32>]) -> Vec<f32> {
    let n = per_rank.len();
    assert!(n > 0);
    let elements = per_rank[0].len();
    assert!(per_rank.iter().all(|v| v.len() == elements));
    assert_eq!(elements % n, 0);
    let chunk = elements / n;
    let mut out = vec![0.0f32; elements];
    for c in 0..n {
        let lo = c * chunk;
        for i in lo..lo + chunk {
            let mut acc = per_rank[c][i];
            for k in 1..n {
                acc += per_rank[(c + k) % n][i];
            }
            out[i] = acc;
        }
    }
    out
}

/// Read a f32 vector back from a device's memory.
pub fn read_vector(
    cl: &mut Cluster,
    node: NodeId,
    base: u64,
    elements: usize,
) -> Result<Vec<f32>> {
    let bytes = cl.device_mut(node).mem().read(base, elements * 4)?;
    bytes_to_f32s(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_matches_naive_sum_for_commutative_data() {
        // Integers sum exactly in any order — oracle must equal naive.
        let a: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..8).map(|i| (i * 10) as f32).collect();
        let c: Vec<f32> = (0..8).map(|i| (i * 100) as f32).collect();
        let d: Vec<f32> = (0..8).map(|i| (i * 1000) as f32).collect();
        let oracle = oracle_sum(&[a.clone(), b.clone(), c.clone(), d.clone()]);
        for i in 0..8 {
            assert_eq!(oracle[i], a[i] + b[i] + c[i] + d[i]);
        }
    }

    #[test]
    fn seeding_is_deterministic_and_readable() {
        use crate::device::DeviceConfig;
        use crate::wire::DeviceIp;
        let mut cl = Cluster::new(1);
        let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
        let g1 = seed_gradients(&mut cl, &[d], 64, 0, 99);
        let back = read_vector(&mut cl, d, 0, 64).unwrap();
        assert_eq!(g1[0], back);
    }
}
