//! Test oracles + data seeding for the collectives.

use anyhow::Result;

use crate::isa::registry::MemAccess;
use crate::net::{Cluster, NodeId};
use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};
use crate::util::Xoshiro256;

/// The shared seeding loop: writes `gen(rank)`'s vector into each
/// data-bearing device at `base`; phantom devices contribute an empty vec.
fn seed_with(
    cl: &mut Cluster,
    devices: &[NodeId],
    base: u64,
    mut gen: impl FnMut(usize) -> Vec<f32>,
) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(devices.len());
    for (r, &node) in devices.iter().enumerate() {
        let dev = cl.device_mut(node);
        if dev.mem_ref().is_phantom() {
            out.push(Vec::new());
            continue;
        }
        let data = gen(r);
        dev.mem().write(base, &f32s_to_bytes(&data)).unwrap();
        out.push(data);
    }
    out
}

/// Write per-rank gradient vectors into each device's HBM at `base`.
/// Returns the vectors for oracle computation (empty inner vecs when the
/// devices are phantom/timing-only).
pub fn seed_gradients(
    cl: &mut Cluster,
    devices: &[NodeId],
    elements: usize,
    base: u64,
    seed: u64,
) -> Vec<Vec<f32>> {
    seed_with(cl, devices, base, |r| {
        let mut rng = Xoshiro256::seed_from(seed ^ (r as u64 + 1).wrapping_mul(0x9E37));
        // Values in a range where f32 ring-order addition is exact enough
        // to compare bitwise against the oracle's identical order.
        rng.f32_vec(elements, -8.0, 8.0)
    })
}

/// Like [`seed_gradients`], but with *integer-valued* f32s in [-32, 32].
/// Small-integer sums are exact in f32 under **any** association, so this
/// seeding lets algorithms with different reduction orders (halving-
/// doubling, hierarchical) be verified bit-exactly against [`naive_sum`].
pub fn seed_gradients_exact(
    cl: &mut Cluster,
    devices: &[NodeId],
    elements: usize,
    base: u64,
    seed: u64,
) -> Vec<Vec<f32>> {
    seed_with(cl, devices, base, |r| {
        let mut rng = Xoshiro256::seed_from(seed ^ (r as u64 + 1).wrapping_mul(0x51ED));
        (0..elements)
            .map(|_| rng.range_u64(0, 64) as f32 - 32.0)
            .collect()
    })
}

/// Element-wise sum in rank order. With integer-valued data (see
/// [`seed_gradients_exact`]) this equals the result of *any* reduction
/// order bit-for-bit, making it the oracle for order-shuffling
/// algorithms.
pub fn naive_sum(per_rank: &[Vec<f32>]) -> Vec<f32> {
    let n = per_rank.len();
    assert!(n > 0);
    let elements = per_rank[0].len();
    assert!(per_rank.iter().all(|v| v.len() == elements));
    let mut out = per_rank[0].clone();
    for v in &per_rank[1..] {
        for (o, x) in out.iter_mut().zip(v.iter()) {
            *o += x;
        }
    }
    out
}

/// The expected allreduce(+) result — summed in *ring order* per chunk so
/// the comparison can be exact: chunk c accumulates contributions in the
/// order rank c, c+1, ..., c+N−1 (the order the chain adds them).
pub fn oracle_sum(per_rank: &[Vec<f32>]) -> Vec<f32> {
    let n = per_rank.len();
    assert!(n > 0);
    let elements = per_rank[0].len();
    assert!(per_rank.iter().all(|v| v.len() == elements));
    assert_eq!(elements % n, 0);
    let chunk = elements / n;
    let mut out = vec![0.0f32; elements];
    for c in 0..n {
        let lo = c * chunk;
        for i in lo..lo + chunk {
            let mut acc = per_rank[c][i];
            for k in 1..n {
                acc += per_rank[(c + k) % n][i];
            }
            out[i] = acc;
        }
    }
    out
}

/// Read a f32 vector back from a device's memory.
pub fn read_vector(
    cl: &mut Cluster,
    node: NodeId,
    base: u64,
    elements: usize,
) -> Result<Vec<f32>> {
    let bytes = cl.device_mut(node).mem().read(base, elements * 4)?;
    bytes_to_f32s(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_matches_naive_sum_for_commutative_data() {
        // Integers sum exactly in any order — oracle must equal naive.
        let a: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..8).map(|i| (i * 10) as f32).collect();
        let c: Vec<f32> = (0..8).map(|i| (i * 100) as f32).collect();
        let d: Vec<f32> = (0..8).map(|i| (i * 1000) as f32).collect();
        let oracle = oracle_sum(&[a.clone(), b.clone(), c.clone(), d.clone()]);
        for i in 0..8 {
            assert_eq!(oracle[i], a[i] + b[i] + c[i] + d[i]);
        }
    }

    #[test]
    fn exact_seeding_is_integer_valued_and_order_free() {
        use crate::device::DeviceConfig;
        use crate::wire::DeviceIp;
        let mut cl = Cluster::new(1);
        let d1 = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
        let d2 = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(2)));
        let g = seed_gradients_exact(&mut cl, &[d1, d2], 128, 0, 5);
        for v in &g {
            assert!(v.iter().all(|x| x.fract() == 0.0 && x.abs() <= 32.0));
        }
        // Any association is exact: ring-order oracle == naive sum.
        assert_eq!(oracle_sum(&g), naive_sum(&g));
    }

    #[test]
    fn seeding_is_deterministic_and_readable() {
        use crate::device::DeviceConfig;
        use crate::wire::DeviceIp;
        let mut cl = Cluster::new(1);
        let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
        let g1 = seed_gradients(&mut cl, &[d], 64, 0, 99);
        let back = read_vector(&mut cl, d, 0, 64).unwrap();
        assert_eq!(g1[0], back);
    }
}
