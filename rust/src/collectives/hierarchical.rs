//! Hierarchical two-level allreduce for Clos fabrics.
//!
//! On a `fat_tree` topology every leaf hosts a group of devices whose
//! mutual traffic never crosses a spine. The two-level plan exploits
//! that (the NetReduce / SHArP-style hierarchy, built from NetDAM's ISA):
//!
//! 1. **intra-leaf reduce** — per leaf, one `ReduceScatter` chain per
//!    block walks every member and terminates at the leaf *leader* with
//!    the hash-guarded write: leaf-local traffic only;
//! 2. **inter-leader ring allreduce** — the leaders run the §3 ring
//!    (reduce-scatter + fused all-gather) across the spines, on the full
//!    vector chunked by leader count — the only phase that pays
//!    spine bandwidth;
//! 3. **intra-leaf broadcast** — each leader streams the finished vector
//!    back through its members as an idempotent `AllGather` chain.
//!
//! All three phases are plain schedules over the shared
//! [`Driver`](super::driver::Driver); phase 2 literally reuses the ring
//! planner ([`plan_ring_ops`](super::netdam_ring::plan_ring_ops)) over
//! the leader subset.

use anyhow::{ensure, Result};

use crate::isa::{Instruction, SimdOp};
use crate::net::Cluster;
use crate::wire::{Packet, Segment, SrouHeader};

use super::driver::{
    guard_hash, op_flags, read_block, CollectiveAlgorithm, PlanCtx, Phase, ScheduledOp,
};
use super::netdam_ring::plan_ring_ops;

pub struct HierarchicalAllreduce {
    /// Rank indices per leaf; `groups[g][0]` is leaf `g`'s leader.
    groups: Vec<Vec<usize>>,
}

impl HierarchicalAllreduce {
    pub fn new(groups: Vec<Vec<usize>>) -> Result<Self> {
        ensure!(groups.len() >= 2, "hierarchical allreduce needs >= 2 leaf groups");
        ensure!(
            groups.iter().all(|g| !g.is_empty()),
            "every leaf group needs at least one member"
        );
        Ok(Self { groups })
    }

    fn leaders(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g[0]).collect()
    }
}

impl CollectiveAlgorithm for HierarchicalAllreduce {
    fn name(&self) -> &'static str {
        "hierarchical-2level"
    }

    fn phases(&self) -> usize {
        3
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase> {
        let n_ranks: usize = self.groups.iter().map(|g| g.len()).sum();
        ensure!(
            ctx.devices.len() == n_ranks,
            "rank count {} != grouped members {n_ranks}",
            ctx.devices.len()
        );
        let spec = ctx.spec;
        let blocks = |elements: usize| elements.div_ceil(spec.lanes);
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        match phase {
            // ---- intra-leaf reduce chains into the leader -------------
            0 => {
                for group in &self.groups {
                    let k = group.len();
                    if k == 1 {
                        continue; // the leader alone already holds its sum
                    }
                    ensure!(
                        k - 1 <= crate::wire::srou_hdr::MAX_SEGMENTS,
                        "leaf group of {k} exceeds the SROU stack"
                    );
                    let leader = group[0];
                    let initiator = group[1];
                    // Chain: initiator → interims (members 2..) → leader.
                    let segs: Vec<Segment> = group[2..]
                        .iter()
                        .chain(std::iter::once(&leader))
                        .map(|&m| Segment::to(ctx.ips[m]))
                        .collect();
                    for j in 0..blocks(spec.elements) {
                        let elem_off = j * spec.lanes;
                        let lanes = spec.lanes.min(spec.elements - elem_off);
                        let len = lanes * 4;
                        let addr = spec.base_addr + elem_off as u64 * 4;
                        let payload = read_block(cl, ctx.devices[initiator], addr, len)?;
                        let expect_hash = guard_hash(cl, ctx.devices[leader], addr, len)?;
                        let done_id = next_id;
                        next_id += 1;
                        let pkt = Packet::new(
                            ctx.ips[initiator],
                            0,
                            SrouHeader::through(segs.clone()),
                            Instruction::ReduceScatter {
                                op: SimdOp::Add,
                                addr,
                                block: done_id,
                                rs_left: (k - 1) as u8,
                                expect_hash,
                            },
                        )
                        .with_flags(op_flags(spec.reliable))
                        .with_payload(payload);
                        ops.push(ScheduledOp {
                            rank: initiator,
                            done_id,
                            pkt,
                        });
                    }
                }
            }
            // ---- inter-leader ring allreduce over the spines ----------
            1 => {
                let leaders = self.leaders();
                let sub_devices: Vec<_> = leaders.iter().map(|&r| ctx.devices[r]).collect();
                let sub_ips: Vec<_> = leaders.iter().map(|&r| ctx.ips[r]).collect();
                let mut ring =
                    plan_ring_ops(cl, &sub_devices, &sub_ips, spec, true, ctx.done_id_base)?;
                // Ring ranks are leader-local; remap onto the global space.
                for op in &mut ring {
                    op.rank = leaders[op.rank];
                }
                ops = ring;
            }
            // ---- intra-leaf broadcast from the leader -----------------
            _ => {
                for group in &self.groups {
                    let k = group.len();
                    if k == 1 {
                        continue;
                    }
                    let leader = group[0];
                    let segs: Vec<Segment> =
                        group[1..].iter().map(|&m| Segment::to(ctx.ips[m])).collect();
                    for j in 0..blocks(spec.elements) {
                        let elem_off = j * spec.lanes;
                        let lanes = spec.lanes.min(spec.elements - elem_off);
                        let len = lanes * 4;
                        let addr = spec.base_addr + elem_off as u64 * 4;
                        let payload = read_block(cl, ctx.devices[leader], addr, len)?;
                        let done_id = next_id;
                        next_id += 1;
                        let pkt = Packet::new(
                            ctx.ips[leader],
                            0,
                            SrouHeader::through(segs.clone()),
                            Instruction::AllGather {
                                addr,
                                block: done_id,
                            },
                        )
                        .with_flags(op_flags(spec.reliable))
                        .with_payload(payload);
                        ops.push(ScheduledOp {
                            rank: leader,
                            done_id,
                            pkt,
                        });
                    }
                }
            }
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::{naive_sum, read_vector, seed_gradients_exact};
    use crate::net::{EcmpMode, LinkConfig, Topology};
    use crate::sim::Engine;

    fn run_fat_tree(pods: usize, per_leaf: usize, elements: usize) {
        let t = Topology::fat_tree(7, pods, per_leaf, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let groups = t.leaf_groups.clone();
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x2F);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let mut algo = HierarchicalAllreduce::new(groups).unwrap();
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "all phases completed");
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                oracle,
                "pods={pods} per_leaf={per_leaf}"
            );
        }
    }

    #[test]
    fn two_leaves_of_two() {
        run_fat_tree(2, 2, 2 * 2048);
    }

    #[test]
    fn three_leaves_of_three_multi_block() {
        // 3 leaders: elements must divide by 3 for the ring phase.
        run_fat_tree(3, 3, 3 * 2048 * 2);
    }

    #[test]
    fn rejects_single_group() {
        assert!(HierarchicalAllreduce::new(vec![vec![0, 1]]).is_err());
        assert!(HierarchicalAllreduce::new(vec![vec![0], vec![]]).is_err());
    }
}
