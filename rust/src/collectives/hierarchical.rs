//! Hierarchical two-level allreduce for Clos fabrics, with **rotating
//! leaders**.
//!
//! On a `fat_tree` topology every leaf hosts a group of devices whose
//! mutual traffic never crosses a spine. The two-level plan exploits
//! that (the NetReduce / SHArP-style hierarchy, built from NetDAM's
//! packet programs):
//!
//! 1. **intra-leaf reduce** — per leaf and per block, one
//!    `reduce → guarded_write` program chain walks every member and
//!    terminates at that block's leaf leader: leaf-local traffic only;
//! 2. **inter-leader ring allreduce** — per block, the block's leader
//!    set runs the §3 fused ring (`reduce → guarded_write → store`)
//!    across the spines — the only phase that pays spine bandwidth;
//! 3. **intra-leaf broadcast** — each block's leader streams the
//!    finished block back through its leaf as an idempotent store chain.
//!
//! Leadership is **sharded by block**: block `j`'s leader in leaf `g` is
//! `groups[g][j % |g|]`, and the phase-2 ring initiator/owner rotate
//! with `j` too. A fixed leader (`groups[g][0]`, the previous design)
//! funnels the entire spine phase and the whole leaf broadcast through
//! one device's 100G port; rotation spreads that load across every
//! member, lifting the leader bandwidth bottleneck at scale.
//!
//! All three phases are plain schedules over the shared
//! [`Driver`](super::driver::Driver), lowered through the same
//! [`lower_ring_chunk`](super::driver::lower_ring_chunk) /
//! [`lower_store_chain`](super::driver::lower_store_chain) as the flat
//! ring.

use anyhow::{ensure, Result};

use crate::isa::SimdOp;
use crate::net::Cluster;
use crate::wire::{Packet, Segment, SrouHeader};

use super::driver::{
    guard_hash, lower_ring_chunk, lower_store_chain, op_flags, prog_env, read_block,
    CollectiveAlgorithm, PlanCtx, Phase, ScheduledOp,
};

pub struct HierarchicalAllreduce {
    /// Rank indices per leaf; block `j`'s leader in leaf `g` is
    /// `groups[g][j % groups[g].len()]`.
    groups: Vec<Vec<usize>>,
}

impl HierarchicalAllreduce {
    pub fn new(groups: Vec<Vec<usize>>) -> Result<Self> {
        ensure!(groups.len() >= 2, "hierarchical allreduce needs >= 2 leaf groups");
        ensure!(
            groups.iter().all(|g| !g.is_empty()),
            "every leaf group needs at least one member"
        );
        Ok(Self { groups })
    }

    /// Block `j`'s leader within `group` (chunk-sharded leadership).
    fn leader_of(group: &[usize], block: usize) -> usize {
        group[block % group.len()]
    }
}

impl CollectiveAlgorithm for HierarchicalAllreduce {
    fn name(&self) -> &'static str {
        "hierarchical-2level"
    }

    fn phases(&self) -> usize {
        3
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase> {
        let n_ranks: usize = self.groups.iter().map(|g| g.len()).sum();
        ensure!(
            ctx.devices.len() == n_ranks,
            "rank count {} != grouped members {n_ranks}",
            ctx.devices.len()
        );
        let spec = ctx.spec;
        let n_blocks = spec.elements.div_ceil(spec.lanes);
        // Block geometry shared by every phase.
        let block_geom = |j: usize| {
            let elem_off = j * spec.lanes;
            let lanes = spec.lanes.min(spec.elements - elem_off);
            (spec.base_addr + elem_off as u64 * 4, lanes * 4)
        };
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        match phase {
            // ---- intra-leaf reduce chains into the block's leader ------
            0 => {
                for group in &self.groups {
                    let k = group.len();
                    if k == 1 {
                        continue; // the leader alone already holds its sum
                    }
                    ensure!(
                        k - 1 <= crate::wire::srou_hdr::MAX_SEGMENTS,
                        "leaf group of {k} exceeds the SROU stack"
                    );
                    for j in 0..n_blocks {
                        let rot = j % k;
                        let leader = group[rot];
                        // Members after the leader in rotated order; the
                        // first initiates, the rest are reduce hops.
                        let others: Vec<usize> =
                            (1..k).map(|i| group[(rot + i) % k]).collect();
                        let initiator = others[0];
                        let segs: Vec<Segment> = others[1..]
                            .iter()
                            .chain(std::iter::once(&leader))
                            .map(|&m| Segment::to(ctx.ips[m]))
                            .collect();
                        let (addr, len) = block_geom(j);
                        let payload = read_block(cl, ctx.devices[initiator], addr, len)?;
                        let expect_hash = guard_hash(cl, ctx.devices[leader], addr, len)?;
                        let done_id = next_id;
                        next_id += 1;
                        let env = prog_env(cl, ctx.devices[leader], len, segs.len(), spec.reliable);
                        let instr =
                            lower_ring_chunk(SimdOp::Add, addr, k, false, expect_hash, done_id, &env)?;
                        let pkt = Packet::new(ctx.ips[initiator], 0, SrouHeader::through(segs), instr)
                            .with_flags(op_flags(spec.reliable))
                            .with_payload(payload);
                        ops.push(ScheduledOp {
                            rank: initiator,
                            done_id,
                            pkt,
                        });
                    }
                }
            }
            // ---- per-block ring allreduce over that block's leaders ----
            1 => {
                let g_cnt = self.groups.len();
                ensure!(
                    2 * (g_cnt - 1) <= crate::wire::srou_hdr::MAX_SEGMENTS,
                    "{g_cnt} leaf groups exceed the SROU stack"
                );
                for j in 0..n_blocks {
                    let leaders: Vec<usize> = self
                        .groups
                        .iter()
                        .map(|g| Self::leader_of(g, j))
                        .collect();
                    // Rotate the ring start with the block index so no
                    // single leader set member initiates everything.
                    let g0 = j % g_cnt;
                    let order: Vec<usize> =
                        (0..g_cnt).map(|i| leaders[(g0 + i) % g_cnt]).collect();
                    let initiator = order[0];
                    let owner = order[g_cnt - 1];
                    let hops = 2 * (g_cnt - 1);
                    let segs: Vec<Segment> = order[1..]
                        .iter()
                        .chain(order[..g_cnt - 1].iter())
                        .map(|&m| Segment::to(ctx.ips[m]))
                        .collect();
                    let (addr, len) = block_geom(j);
                    let payload = read_block(cl, ctx.devices[initiator], addr, len)?;
                    let expect_hash = guard_hash(cl, ctx.devices[owner], addr, len)?;
                    let done_id = next_id;
                    next_id += 1;
                    let env = prog_env(cl, ctx.devices[owner], len, hops, spec.reliable);
                    let instr =
                        lower_ring_chunk(SimdOp::Add, addr, g_cnt, true, expect_hash, done_id, &env)?;
                    let pkt = Packet::new(ctx.ips[initiator], 0, SrouHeader::through(segs), instr)
                        .with_flags(op_flags(spec.reliable))
                        .with_payload(payload);
                    ops.push(ScheduledOp {
                        rank: initiator,
                        done_id,
                        pkt,
                    });
                }
            }
            // ---- intra-leaf broadcast from the block's leader ----------
            _ => {
                for group in &self.groups {
                    let k = group.len();
                    if k == 1 {
                        continue;
                    }
                    for j in 0..n_blocks {
                        let rot = j % k;
                        let leader = group[rot];
                        let others: Vec<usize> =
                            (1..k).map(|i| group[(rot + i) % k]).collect();
                        let segs: Vec<Segment> =
                            others.iter().map(|&m| Segment::to(ctx.ips[m])).collect();
                        let (addr, len) = block_geom(j);
                        let payload = read_block(cl, ctx.devices[leader], addr, len)?;
                        let done_id = next_id;
                        next_id += 1;
                        let env = prog_env(cl, ctx.devices[leader], len, k - 1, spec.reliable);
                        let instr = lower_store_chain(addr, k - 1, done_id, &env)?;
                        let pkt = Packet::new(ctx.ips[leader], 0, SrouHeader::through(segs), instr)
                            .with_flags(op_flags(spec.reliable))
                            .with_payload(payload);
                        ops.push(ScheduledOp {
                            rank: leader,
                            done_id,
                            pkt,
                        });
                    }
                }
            }
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::{naive_sum, read_vector, seed_gradients_exact};
    use crate::net::{EcmpMode, LinkConfig, Topology};
    use crate::sim::Engine;

    fn run_fat_tree(pods: usize, per_leaf: usize, elements: usize) {
        let t = Topology::fat_tree(7, pods, per_leaf, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let groups = t.leaf_groups.clone();
        let mut cl = t.cluster;
        let devices = t.devices;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x2F);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let mut algo = HierarchicalAllreduce::new(groups).unwrap();
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "all phases completed");
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                oracle,
                "pods={pods} per_leaf={per_leaf}"
            );
        }
    }

    #[test]
    fn two_leaves_of_two() {
        run_fat_tree(2, 2, 2 * 2048);
    }

    #[test]
    fn three_leaves_of_three_multi_block() {
        run_fat_tree(3, 3, 3 * 2048 * 2);
    }

    #[test]
    fn odd_block_count_no_divisibility_needed() {
        // Per-block leader rings have no elements-divide-by-leaders
        // constraint (the old fixed-leader ring required it).
        run_fat_tree(3, 2, 5 * 2048);
    }

    /// The ROADMAP open item: leadership must shard across members, not
    /// funnel through `groups[g][0]` — and stay bit-exact (checked here
    /// against the oracle through `run_fat_tree`).
    #[test]
    fn leader_rotation_spreads_the_bottleneck() {
        // Correctness under rotation, multi-block so rotation engages.
        run_fat_tree(2, 3, 6 * 2048);
        // And structurally: plan the phases and count distinct initiators.
        let t = Topology::fat_tree(7, 2, 3, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let groups = t.leaf_groups.clone();
        let mut cl = t.cluster;
        let devices = t.devices;
        let elements = 6 * 2048; // 6 blocks over groups of 3
        seed_gradients_exact(&mut cl, &devices, elements, 0, 0x2F);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let mut algo = HierarchicalAllreduce::new(groups.clone()).unwrap();
        let ips: Vec<crate::wire::DeviceIp> =
            devices.iter().map(|&d| cl.device(d).ip()).collect();
        let ctx = PlanCtx {
            devices: &devices,
            ips: &ips,
            spec: &spec,
            done_id_base: 0,
        };
        for phase in [1usize, 2] {
            let Phase::Ops(ops) = algo.plan_phase(&mut cl, &ctx, phase).unwrap() else {
                panic!("hierarchical plans packet ops");
            };
            let mut initiators: Vec<usize> = ops.iter().map(|o| o.rank).collect();
            initiators.sort_unstable();
            initiators.dedup();
            assert!(
                initiators.len() > groups.len().min(2),
                "phase {phase}: load funnels through {} initiators",
                initiators.len()
            );
        }
    }

    #[test]
    fn rejects_single_group() {
        assert!(HierarchicalAllreduce::new(vec![vec![0, 1]]).is_err());
        assert!(HierarchicalAllreduce::new(vec![vec![0], vec![]]).is_err());
    }
}
