//! Rooted ring reduce: the whole vector summed at one root rank.
//!
//! MPI_Reduce on the NetDAM ISA is the §3 reduce-scatter chain with the
//! rotation pinned: for **every** block of the vector, one packet
//! program starts at rank `(root+1) % N`, folds each rank's local block
//! into the packet buffer with an on-device `Simd` add
//! (`reduce ×(N−1)`), and terminates at `root` with the hash-guarded
//! exactly-once write — [`lower_ring_chunk`] without the fused
//! all-gather tail. Non-root ranks keep their pristine data (interim
//! reduce hops have no local side effects).
//!
//! Every chain crosses the root's ingress port, so the natural floor is
//! `V / line_rate` — `bw_fraction == 1.0`, like broadcast in the
//! opposite direction.

use anyhow::{ensure, Result};

use crate::isa::SimdOp;
use crate::net::Cluster;
use crate::wire::Packet;

use super::driver::{
    guard_hash, lower_ring_chunk, op_flags, prog_env, read_block, CollectiveAlgorithm, PlanCtx,
    Phase, ScheduledOp,
};

/// The rooted-reduce schedule generator (`AlgoKind::Reduce`).
pub struct RingReduce {
    pub root: usize,
}

impl CollectiveAlgorithm for RingReduce {
    fn name(&self) -> &'static str {
        "reduce"
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        let n = ctx.devices.len();
        ensure!(n >= 2, "reduce needs at least 2 ranks");
        ensure!(self.root < n, "reduce root {} out of range", self.root);
        let hops = n - 1;
        ensure!(
            hops <= crate::wire::srou_hdr::MAX_SEGMENTS,
            "ring of {n} exceeds the SROU stack"
        );
        let spec = ctx.spec;
        // Chains start one past the root so the ring walk
        // start+1, ..., start+N−1 ends exactly at the root.
        let start = (self.root + 1) % n;
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        let mut off = 0usize;
        while off < spec.elements {
            let lanes = spec.lanes.min(spec.elements - off);
            let len = lanes * 4;
            let addr = spec.base_addr + off as u64 * 4;
            // Payload: the initiator's pristine block. Guard: hash of
            // the root's pristine block (§3.1 exactly-once write).
            let payload = read_block(cl, ctx.devices[start], addr, len)?;
            let expect_hash = guard_hash(cl, ctx.devices[self.root], addr, len)?;
            let done_id = next_id;
            next_id += 1;
            let env = prog_env(cl, ctx.devices[self.root], len, hops, spec.reliable);
            let instr = lower_ring_chunk(
                SimdOp::Add,
                addr,
                n,
                false,
                expect_hash,
                done_id,
                &env,
            )?;
            let pkt = Packet::new(
                ctx.ips[start],
                0, // seq assigned by the driver/fabric
                crate::srou::ring_chain(ctx.ips, start, hops),
                instr,
            )
            .with_flags(op_flags(spec.reliable))
            .with_payload(payload);
            ops.push(ScheduledOp {
                rank: start,
                done_id,
                pkt,
            });
            off += lanes;
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::{naive_sum, read_vector, seed_gradients_exact};
    use crate::net::{LinkConfig, Topology};
    use crate::sim::Engine;

    fn run_reduce(n: usize, elements: usize, root: usize) {
        let t = Topology::star(11, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        // Integer-valued data: any association sums exactly, so the
        // rooted chain order equals naive_sum bit-for-bit.
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x5EED);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let mut algo = RingReduce { root };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "all chains retired");
        let oracle = naive_sum(&grads);
        for (r, &d) in devices.iter().enumerate() {
            let got = read_vector(&mut cl, d, 0, elements).unwrap();
            if r == root {
                assert_eq!(got, oracle, "root holds the full sum");
            } else {
                assert_eq!(got, grads[r], "rank {r} keeps pristine data");
            }
        }
    }

    #[test]
    fn reduce_lands_the_sum_at_root_zero() {
        run_reduce(4, 2 * 2048 + 512, 0);
    }

    #[test]
    fn reduce_supports_any_root() {
        for root in 0..4 {
            run_reduce(4, 2048, root);
        }
    }

    #[test]
    fn reduce_rejects_bad_root() {
        let t = Topology::star(3, 2, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let spec = CollectiveSpec {
            elements: 2048,
            ..Default::default()
        };
        let mut algo = RingReduce { root: 5 };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        assert!(Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).is_err());
    }
}
