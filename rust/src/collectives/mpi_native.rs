//! "Native MPI" allreduce baseline: recursive doubling.
//!
//! The paper's slowest arm (2.8 s at full scale). Classic recursive
//! doubling exchanges the **full vector** with a partner at distance
//! `2^k` each round and reduces the whole vector on the CPU every round —
//! log₂(N) rounds, each costing wire(V) + DMA(V) + reduce(V). The CPU
//! term is paid log₂(N) times on the *full* volume (vs `(N−1)/N·V` once
//! for ring) which is exactly why it loses at scale.
//!
//! The per-rank state machine lives in [`RecursiveDoublingPeer`]; cluster
//! construction and the run loop go through the shared
//! [`Driver`](super::driver::Driver) via [`MpiRecursiveDoubling`].

use crate::host::{HostConfig, HostModel};
use crate::isa::Instruction;
use crate::net::{App, AppCtx, Cluster};
use crate::sim::SimTime;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};
use std::collections::HashMap;

use anyhow::{ensure, Result};

use super::driver::{CollectiveAlgorithm, Phase, PlanCtx};

const TOK_SEND: u64 = 1;
const TOK_PROC: u64 = 2;

use super::ring_roce::MTU_PAYLOAD;

pub struct RecursiveDoublingPeer {
    /// Rank id (diagnostics).
    pub rank: usize,
    rounds: usize,
    peers: Vec<DeviceIp>, // partner ip per round
    vector_bytes: usize,
    pkts_per_round: usize,
    gap_ns: SimTime,
    host: HostModel,
    round: usize,
    sent_pkts: usize,
    send_done: bool,
    recv_processed: bool,
    rcvd: HashMap<u64, usize>,
    done: bool,
}

impl RecursiveDoublingPeer {
    pub fn new(
        rank: usize,
        all_ips: &[DeviceIp],
        elements: usize,
        line_gbps: f64,
        seed: u64,
    ) -> Self {
        let n = all_ips.len();
        assert!(n.is_power_of_two() && n >= 2);
        let rounds = n.trailing_zeros() as usize;
        let peers = (0..rounds).map(|k| all_ips[rank ^ (1 << k)]).collect();
        let vector_bytes = elements * 4;
        Self {
            rank,
            rounds,
            peers,
            vector_bytes,
            pkts_per_round: vector_bytes.div_ceil(MTU_PAYLOAD),
            gap_ns: ((MTU_PAYLOAD + 96) as f64 * 8.0 / line_gbps).ceil() as SimTime,
            host: HostModel::new(HostConfig::paper_default(), seed ^ (rank as u64) << 8),
            round: 0,
            sent_pkts: 0,
            send_done: false,
            recv_processed: false,
            rcvd: HashMap::new(),
            done: false,
        }
    }

    fn begin_round(&mut self, ctx: &mut AppCtx) {
        self.sent_pkts = 0;
        self.send_done = false;
        self.recv_processed = false;
        let t = self.host.post_send_ns();
        ctx.timer(t, TOK_SEND);
        self.check_recv(ctx);
    }

    fn send_next(&mut self, ctx: &mut AppCtx) {
        if self.sent_pkts >= self.pkts_per_round {
            self.send_done = true;
            self.maybe_advance(ctx);
            return;
        }
        let remaining = self.vector_bytes - self.sent_pkts * MTU_PAYLOAD;
        let len = remaining.min(MTU_PAYLOAD);
        let seq = ctx.alloc_seq();
        let pkt = Packet::new(
            ctx.self_ip,
            seq,
            SrouHeader::direct(self.peers[self.round]),
            Instruction::Write {
                addr: self.round as u64,
            },
        )
        .with_payload(Payload::phantom(len));
        ctx.send(pkt);
        self.sent_pkts += 1;
        ctx.timer(self.gap_ns, TOK_SEND);
    }

    fn check_recv(&mut self, ctx: &mut AppCtx) {
        if self.recv_processed || self.done {
            return;
        }
        let tag = self.round as u64;
        if self.rcvd.get(&tag).copied().unwrap_or(0) >= self.vector_bytes {
            // Full vector arrived: DMA + full-vector CPU reduce.
            let t = self.host.nic_write_ns(self.vector_bytes)
                + self.host.reduce_ns(self.vector_bytes);
            ctx.timer(t, TOK_PROC);
        }
    }

    fn maybe_advance(&mut self, ctx: &mut AppCtx) {
        if self.done || !(self.send_done && self.recv_processed) {
            return;
        }
        self.round += 1;
        if self.round == self.rounds {
            self.done = true;
            ctx.record("mpi_native_done_ns", ctx.now);
            ctx.count("mpi_native_finished", 1);
            return;
        }
        self.begin_round(ctx);
    }
}

impl App for RecursiveDoublingPeer {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        self.begin_round(ctx);
    }

    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        if let Instruction::Write { addr } = pkt.instr {
            *self.rcvd.entry(addr).or_insert(0) += pkt.payload.len();
            self.check_recv(ctx);
        }
    }

    fn on_timer(&mut self, token: u64, ctx: &mut AppCtx) {
        match token {
            TOK_SEND => self.send_next(ctx),
            TOK_PROC => {
                self.recv_processed = true;
                self.maybe_advance(ctx);
            }
            _ => {}
        }
    }
}

/// The driver-facing baseline: installs a star of recursive-doubling host
/// peers into an empty cluster.
pub struct MpiRecursiveDoubling {
    pub ranks: usize,
    pub elements: usize,
    pub seed: u64,
}

impl CollectiveAlgorithm for MpiRecursiveDoubling {
    fn name(&self) -> &'static str {
        "mpi-native"
    }

    fn plan_phase(&mut self, cl: &mut Cluster, _ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        use crate::net::{LinkConfig, Switch};
        ensure!(
            cl.nodes.is_empty(),
            "mpi-native builds its own host fabric; pass a fresh cluster"
        );
        let sw = cl.add_switch(Switch::tor(None));
        let link = LinkConfig::dc_100g();
        let ips: Vec<DeviceIp> = (0..self.ranks)
            .map(|i| DeviceIp::lan(151 + i as u8))
            .collect();
        for (r, &ip) in ips.iter().enumerate() {
            let app = RecursiveDoublingPeer::new(r, &ips, self.elements, link.rate.0, self.seed);
            let h = cl.add_host(ip, Some(Box::new(app)));
            cl.connect(sw, h, link.clone());
        }
        cl.compute_routes();
        Ok(Phase::Apps {
            finished_counter: "mpi_native_finished",
            done_hist: "mpi_native_done_ns",
            expect_finished: self.ranks as u64,
        })
    }
}

/// Build a star of `n` hosts and run recursive-doubling allreduce.
pub fn run_mpi_native(seed: u64, n: usize, elements: usize) -> crate::collectives::CollectiveReport {
    use super::driver::{run_collective, AlgoKind, RunOpts};
    run_collective(
        AlgoKind::MpiNative,
        &RunOpts {
            elements,
            ranks: n,
            seed,
            ..Default::default()
        },
    )
    .expect("mpi-native run")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring_roce::run_ring_roce;

    #[test]
    fn completes_on_power_of_two_ranks() {
        let r = run_mpi_native(3, 4, 4 * 8192);
        assert!(r.elapsed_ns > 0);
        assert_eq!(r.link_drops, 0);
    }

    #[test]
    fn native_slower_than_ring_at_scale() {
        // The paper's ordering (2.8 s vs 2.1 s at 2 GiB): recursive
        // doubling reduces the full vector every round.
        let elements = 1 << 20;
        let native = run_mpi_native(7, 4, elements);
        let ring = run_ring_roce(7, 4, elements);
        assert!(
            native.elapsed_ns > ring.elapsed_ns,
            "native {} !> ring {}",
            native.elapsed_ns,
            ring.elapsed_ns
        );
    }
}
