//! In-network allreduce over the switch aggregation engine (§2.5).
//!
//! On a `fat_tree` the reduction tree is the physical tree: every
//! non-root rank sends its block **once**, marked [`crate::isa::Flags::AGG`],
//! along the SROU path `leaf → spine → root`. The leaf switch folds its
//! pod's contributions into one packet (expected fan-in rides the SROU
//! segment's `func` field), the block's spine folds the per-leaf
//! partials, and the root device folds whatever reaches it — one merged
//! packet in the fast path, several partials when a switch slot timed
//! out or overflowed (the straggler fallback; see
//! [`crate::net::aggregate`]). The root then returns the finished block
//! down a binomial tree (the same rounds as
//! [`super::tree::TreeBroadcast`]).
//!
//! **Load spreading.** Roots rotate per block (`root_j = j % N`) and each
//! block pins its spine (`j % S`), so no single port funnels the
//! collective — the same trick the hierarchical planner uses for its
//! leaders.
//!
//! **Correctness without trust in the switch.** Aggregation only changes
//! *where* additions happen, never *whether*: every contribution carries
//! a manifest entry, switches union manifests when they merge, and the
//! root completes each entry individually. An evicted or unaggregated
//! contribution arrives as its own packet and is folded at the endpoint
//! — degraded bandwidth, identical sum. The §2.3 relaxed-ordering rule
//! still gates the plan: a probe program (`reduce` on an unordered
//! path) is verified per run, so a non-commutative op is refused with
//! the same typed error every other planner gets.

use anyhow::{ensure, Result};

use crate::isa::{Instruction, ProgramBuilder, SimdOp};
use crate::net::Cluster;
use crate::wire::{AggEntry, AggMeta, Packet, Segment, SrouHeader};

use super::driver::{
    lower_store_chain, op_flags, prog_env, read_block, CollectiveAlgorithm, PlanCtx, Phase,
    ScheduledOp, TopoFacts,
};
use super::tree::{binomial_pairs, ceil_log2};

pub struct SwitchReduceAllreduce {
    topo: TopoFacts,
    ranks: usize,
}

impl SwitchReduceAllreduce {
    pub fn new(topo: TopoFacts) -> Result<Self> {
        let ranks: usize = topo.leaf_groups.iter().map(|g| g.len()).sum();
        ensure!(ranks >= 2, "switch-reduce needs at least 2 ranks");
        ensure!(
            topo.leaf_groups.len() >= 2,
            "switch-reduce needs >= 2 leaf groups (run on fat_tree)"
        );
        ensure!(
            topo.leaf_ips.len() == topo.leaf_groups.len(),
            "switch-reduce needs addressed leaf switches (run on fat_tree)"
        );
        ensure!(
            !topo.spine_ips.is_empty(),
            "switch-reduce needs addressed spine switches (run on fat_tree)"
        );
        Ok(Self { topo, ranks })
    }
}

impl CollectiveAlgorithm for SwitchReduceAllreduce {
    fn name(&self) -> &'static str {
        "switch-reduce"
    }

    fn phases(&self) -> usize {
        // Phase 0: every rank contributes up the aggregation tree.
        // Phases 1..: binomial down-broadcast of the finished blocks.
        1 + ceil_log2(self.ranks)
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase> {
        let n = ctx.devices.len();
        ensure!(n == self.ranks, "planned for {} ranks, ran with {n}", self.ranks);
        let spec = ctx.spec;
        let n_blocks = spec.elements.div_ceil(spec.lanes);
        let block_geom = |j: usize| {
            let elem_off = j * spec.lanes;
            let lanes = spec.lanes.min(spec.elements - elem_off);
            (spec.base_addr + elem_off as u64 * 4, lanes * 4)
        };
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        if phase == 0 {
            // ---- contributions up the leaf → spine aggregation tree ----
            let op = SimdOp::Add;
            for j in 0..n_blocks {
                let root_j = j % n;
                let (addr, len) = block_geom(j);
                let spine = self.topo.spine_ips[j % self.topo.spine_ips.len()];
                // §2.3 gate: verify a representative reduce chain for this
                // block against the live fabric before injecting raw
                // AGG-marked Simd packets that the switches will fold.
                let env = prog_env(cl, ctx.devices[root_j], len, 1, spec.reliable);
                ProgramBuilder::new().reduce(op, addr, 1).build(&env)?;
                // The group id keys switch slots and the root's replay
                // set; the block's first contribution done-id is unique
                // across phases and (within one fabric) across runs.
                let group = next_id;
                for (g, members) in self.topo.leaf_groups.iter().enumerate() {
                    let expected = members.iter().filter(|&&m| m != root_j).count();
                    if expected == 0 {
                        continue; // this leaf holds only the root
                    }
                    for &m in members {
                        if m == root_j {
                            continue;
                        }
                        let payload = read_block(cl, ctx.devices[m], addr, len)?;
                        let done_id = next_id;
                        next_id += 1;
                        let segs = vec![
                            Segment::call(self.topo.leaf_ips[g], expected as u16),
                            Segment::call(spine, (n - 1) as u16),
                            Segment::to(ctx.ips[root_j]),
                        ];
                        let meta = AggMeta {
                            tenant: spec.tenant,
                            group,
                            op,
                            // seq 0 is a placeholder; `lower_schedule`
                            // patches it once the injection seq exists.
                            entries: vec![AggEntry {
                                src: ctx.ips[m],
                                seq: 0,
                                done_id,
                            }],
                        };
                        let pkt = Packet::new(
                            ctx.ips[m],
                            0,
                            SrouHeader::through(segs),
                            Instruction::Simd { op, addr },
                        )
                        .with_flags(op_flags(spec.reliable))
                        .with_agg(meta)
                        .with_payload(payload);
                        ops.push(ScheduledOp {
                            rank: m,
                            done_id,
                            pkt,
                        });
                    }
                }
            }
        } else {
            // ---- binomial down-broadcast, rooted per block ------------
            let round = phase - 1;
            for j in 0..n_blocks {
                let root_j = j % n;
                let (addr, len) = block_geom(j);
                for (sx, dx) in binomial_pairs(n, round) {
                    let src = (root_j + sx) % n;
                    let dst = (root_j + dx) % n;
                    let payload = read_block(cl, ctx.devices[src], addr, len)?;
                    let done_id = next_id;
                    next_id += 1;
                    let env = prog_env(cl, ctx.devices[dst], len, 1, spec.reliable);
                    let instr = lower_store_chain(addr, 1, done_id, &env)?;
                    let pkt = Packet::new(
                        ctx.ips[src],
                        0,
                        SrouHeader::through(vec![Segment::to(ctx.ips[dst])]),
                        instr,
                    )
                    .with_flags(op_flags(spec.reliable))
                    .with_payload(payload);
                    ops.push(ScheduledOp {
                        rank: src,
                        done_id,
                        pkt,
                    });
                }
            }
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::{naive_sum, read_vector, seed_gradients_exact};
    use crate::net::{EcmpMode, LinkConfig, Topology};
    use crate::pool::IommuDirectory;
    use crate::sim::Engine;

    fn facts(t: &Topology) -> TopoFacts {
        TopoFacts {
            leaf_groups: t.leaf_groups.clone(),
            leaf_ips: t.leaf_ips.clone(),
            spine_ips: t.spine_ips.clone(),
        }
    }

    fn run_fat_tree(pods: usize, per_leaf: usize, elements: usize, loss_p: f64) {
        let t = Topology::fat_tree(7, pods, per_leaf, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let topo = facts(&t);
        let switches = t.switches.clone();
        let mut cl = t.cluster;
        cl.fault.loss_p = loss_p;
        let devices = t.devices;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x2F);
        let spec = CollectiveSpec {
            elements,
            window: if loss_p > 0.0 { 4 } else { 8 },
            reliable: loss_p > 0.0,
            ..Default::default()
        };
        let mut algo = SwitchReduceAllreduce::new(topo).unwrap();
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops, "all phases completed");
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                oracle,
                "pods={pods} per_leaf={per_leaf} loss={loss_p}"
            );
        }
        // The point of the subsystem: switches folded packets in flight.
        let merged: u64 = switches.iter().map(|&s| cl.switch(s).agg.counters.merged).sum();
        assert!(merged > 0, "no in-network merges happened");
    }

    #[test]
    fn two_leaves_of_two() {
        run_fat_tree(2, 2, 2 * 2048, 0.0);
    }

    #[test]
    fn three_leaves_of_three_multi_block() {
        run_fat_tree(3, 3, 3 * 2048 * 2, 0.0);
    }

    #[test]
    fn ragged_blocks_and_rotating_roots() {
        run_fat_tree(3, 2, 5 * 2048 + 100, 0.0);
    }

    #[test]
    fn lossy_reliable_falls_back_not_wrong() {
        // Loss evicts switch slots mid-fill; retransmits bypass closed
        // slots and fold at the root. The sum must stay oracle-exact.
        run_fat_tree(2, 3, 4 * 2048, 0.05);
    }

    #[test]
    fn rejects_topologies_without_addressed_switches() {
        assert!(SwitchReduceAllreduce::new(TopoFacts::default()).is_err());
        let t = Topology::star(3, 4, 0, LinkConfig::dc_100g());
        assert!(SwitchReduceAllreduce::new(facts(&t)).is_err());
    }

    #[test]
    fn acl_admits_bound_tenants_and_drops_foreign_ones() {
        let t = Topology::fat_tree(7, 2, 2, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let topo = facts(&t);
        let switches = t.switches.clone();
        let mut cl = t.cluster;
        let devices = t.devices;
        let ips: Vec<_> = devices.iter().map(|&d| cl.device(d).ip()).collect();
        // One control-plane write programs device IOMMUs and switches.
        for &ip in &ips {
            cl.bind_tenant(ips[0], ip, 7);
        }
        let elements = 2 * 2048;
        let grads = seed_gradients_exact(&mut cl, &devices, elements, 0, 0x2F);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            tenant: 7,
            ..Default::default()
        };
        let mut algo = SwitchReduceAllreduce::new(topo.clone()).unwrap();
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        let oracle = naive_sum(&grads);
        for &d in &devices {
            assert_eq!(read_vector(&mut cl, d, 0, elements).unwrap(), oracle);
        }
        let foreign: u64 = switches.iter().map(|&s| cl.switch(s).acl_drops_foreign).sum();
        assert_eq!(foreign, 0, "bound tenant must pass the ACL");

        // Same fabric, a tenant the switches never heard of: every
        // contribution dies at its leaf with a typed drop count, and the
        // collective cannot complete.
        let spec = CollectiveSpec {
            elements,
            window: 8,
            tenant: 9,
            ..spec
        };
        let mut algo = SwitchReduceAllreduce::new(topo).unwrap();
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert!(out.ops_done < out.ops, "foreign tenant must not complete");
        let foreign: u64 = switches.iter().map(|&s| cl.switch(s).acl_drops_foreign).sum();
        assert!(foreign > 0, "drops must be counted as foreign-tenant");
    }
}
