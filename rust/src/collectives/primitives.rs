//! Standalone collective primitives on the NetDAM ISA.
//!
//! The §3 allreduce is reduce-scatter ∘ all-gather fused into one
//! instruction chain; these planners expose the building blocks as
//! first-class collectives over the shared
//! [`Driver`](super::driver::Driver):
//!
//! * **reduce-scatter** — [`super::netdam_ring::RingAllreduce`] with
//!   `fused: false` (chunk `c` reduced at its ring owner);
//! * **all-gather** ([`RingAllGather`]) — every rank streams its chunk
//!   around the ring as an idempotent store-chain program;
//! * **broadcast** ([`RingBroadcast`]) — the root streams the whole
//!   vector through the ring chain.
//!
//! Both planners lower onto pure store-chain programs: writes derived
//! solely from the packet, so blind retransmission is safe (§3.1) and no
//! guard hash is needed.

use anyhow::{ensure, Result};

use crate::net::Cluster;
use crate::wire::Packet;

use super::driver::{
    lower_store_chain, op_flags, prog_env, read_block, CollectiveAlgorithm, PlanCtx, Phase,
    ScheduledOp,
};

/// Ring all-gather: rank `r` owns chunk `r`; after the run every rank
/// holds every chunk.
pub struct RingAllGather;

impl CollectiveAlgorithm for RingAllGather {
    fn name(&self) -> &'static str {
        "all-gather"
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        let n = ctx.devices.len();
        ensure!(n >= 2, "all-gather needs at least 2 ranks");
        ensure!(
            ctx.spec.elements % n == 0,
            "elements must divide by rank count"
        );
        ensure!(
            n - 1 <= crate::wire::srou_hdr::MAX_SEGMENTS,
            "ring of {n} exceeds the SROU stack"
        );
        let spec = ctx.spec;
        let chunk_elems = spec.elements / n;
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        for r in 0..n {
            let mut off = 0;
            while off < chunk_elems {
                let lanes = spec.lanes.min(chunk_elems - off);
                let len = lanes * 4;
                let addr = spec.base_addr + (r * chunk_elems + off) as u64 * 4;
                let payload = read_block(cl, ctx.devices[r], addr, len)?;
                let done_id = next_id;
                next_id += 1;
                let env = prog_env(cl, ctx.devices[(r + 1) % n], len, n - 1, spec.reliable);
                let instr = lower_store_chain(addr, n - 1, done_id, &env)?;
                let pkt = Packet::new(
                    ctx.ips[r],
                    0,
                    crate::srou::ring_chain(ctx.ips, r, n - 1),
                    instr,
                )
                .with_flags(op_flags(spec.reliable))
                .with_payload(payload);
                ops.push(ScheduledOp {
                    rank: r,
                    done_id,
                    pkt,
                });
                off += lanes;
            }
        }
        Ok(Phase::Ops(ops))
    }
}

/// Ring broadcast of `root`'s whole vector to every other rank.
pub struct RingBroadcast {
    pub root: usize,
}

impl CollectiveAlgorithm for RingBroadcast {
    fn name(&self) -> &'static str {
        "broadcast"
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, _phase: usize) -> Result<Phase> {
        let n = ctx.devices.len();
        ensure!(n >= 2, "broadcast needs at least 2 ranks");
        ensure!(self.root < n, "broadcast root {} out of range", self.root);
        ensure!(
            n - 1 <= crate::wire::srou_hdr::MAX_SEGMENTS,
            "ring of {n} exceeds the SROU stack"
        );
        let spec = ctx.spec;
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        let mut off = 0;
        while off < spec.elements {
            let lanes = spec.lanes.min(spec.elements - off);
            let len = lanes * 4;
            let addr = spec.base_addr + off as u64 * 4;
            let payload = read_block(cl, ctx.devices[self.root], addr, len)?;
            let done_id = next_id;
            next_id += 1;
            let env = prog_env(cl, ctx.devices[(self.root + 1) % n], len, n - 1, spec.reliable);
            let instr = lower_store_chain(addr, n - 1, done_id, &env)?;
            let pkt = Packet::new(
                ctx.ips[self.root],
                0,
                crate::srou::ring_chain(ctx.ips, self.root, n - 1),
                instr,
            )
            .with_flags(op_flags(spec.reliable))
            .with_payload(payload);
            ops.push(ScheduledOp {
                rank: self.root,
                done_id,
                pkt,
            });
            off += lanes;
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::read_vector;
    use crate::isa::registry::MemAccess;
    use crate::net::{LinkConfig, Topology};
    use crate::sim::Engine;
    use crate::util::bytes::f32s_to_bytes;
    use crate::util::Xoshiro256;

    /// Seed each rank with rank-tagged data so misplaced chunks are
    /// detectable.
    fn seed_distinct(
        cl: &mut crate::net::Cluster,
        devices: &[crate::net::NodeId],
        elements: usize,
    ) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        for (r, &d) in devices.iter().enumerate() {
            let mut rng = Xoshiro256::seed_from(0xD1 ^ (r as u64) << 4);
            let data = rng.f32_vec(elements, -4.0, 4.0);
            cl.device_mut(d).mem().write(0, &f32s_to_bytes(&data)).unwrap();
            out.push(data);
        }
        out
    }

    #[test]
    fn all_gather_distributes_every_chunk() {
        let n = 4;
        let elements = n * 2048 + n * 512; // ragged chunks too
        let t = Topology::star(3, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let data = seed_distinct(&mut cl, &devices, elements);
        let spec = CollectiveSpec {
            elements,
            window: 4,
            ..Default::default()
        };
        let mut algo = RingAllGather;
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        // Expected image: chunk r everywhere is rank r's chunk r.
        let chunk = elements / n;
        let mut expect = vec![0f32; elements];
        for r in 0..n {
            expect[r * chunk..(r + 1) * chunk].copy_from_slice(&data[r][r * chunk..(r + 1) * chunk]);
        }
        for &d in &devices {
            assert_eq!(read_vector(&mut cl, d, 0, elements).unwrap(), expect);
        }
    }

    #[test]
    fn broadcast_replicates_root() {
        let n = 5;
        let elements = 3 * 2048 + 100;
        let t = Topology::star(4, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let data = seed_distinct(&mut cl, &devices, elements);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let root = 2;
        let mut algo = RingBroadcast { root };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                data[root],
                "every rank holds the root vector"
            );
        }
    }

    #[test]
    fn broadcast_survives_duplication() {
        // Store-chain writes are idempotent: duplicated packets are harmless.
        let n = 4;
        let elements = 2 * 2048;
        let t = Topology::star(8, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.dup_p = 0.05;
        let devices = t.devices;
        let data = seed_distinct(&mut cl, &devices, elements);
        let spec = CollectiveSpec {
            elements,
            window: 2,
            ..Default::default()
        };
        let mut algo = RingBroadcast { root: 0 };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        for &d in &devices {
            assert_eq!(read_vector(&mut cl, d, 0, elements).unwrap(), data[0]);
        }
    }
}
