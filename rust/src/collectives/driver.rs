//! The shared collective driver (the "software-defined collective" layer).
//!
//! Every collective in this crate — the NetDAM in-memory algorithms and
//! the host baselines alike — is split into two halves:
//!
//! * a [`CollectiveAlgorithm`]: a pure *schedule generator* that decides
//!   which chunk moves where and which instruction runs at each hop
//!   (ring chains, halving-doubling exchanges, hierarchical two-level
//!   plans, ...), expressed as [`ScheduledOp`]s, or — for the host
//!   baselines — as installed [`crate::net::App`]s;
//! * the [`Driver`]: sequence allocation, phase sequencing, reliability
//!   setup, and [`CollectiveReport`] production, with all windowed I/O —
//!   the self-clocked per-rank window, reliable injection, completion
//!   matching and dedupe — delegated to the shared
//!   [`crate::transport::WindowEngine`] (ops keyed by
//!   `CompletionKey::DoneId`; the pooled-memory client drives the same
//!   engine keyed by sequence number).
//!
//! Adding a new collective therefore means writing a planner, not another
//! copy of the windowing/completion state machine — the refactor the
//! paper's §3 "one instruction per chunk" design invites.
//!
//! Planners do not emit bespoke opcodes: schedules lower onto **verified
//! packet programs** ([`lower_ring_chunk`] / [`lower_store_chain`]) built
//! from the ordinary ISA (`Simd`, `WriteIfHash`, `Write`). The verifier
//! environment ([`prog_env`]) is derived from the live fabric, so a
//! planner cannot inject a chain that violates the §2.3 relaxed-ordering
//! rule (commutativity on unordered paths, idempotency on lossy ones).
//!
//! Multi-phase algorithms (halving-doubling, hierarchical) return one
//! schedule per phase; the driver drains the DES between phases. That
//! barrier is honest: those algorithms are *round-synchronous* by
//! construction, unlike the single-phase NetDAM ring whose freedom from
//! barriers is exactly the paper's Figure 7 contrast.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::alu::block_hash;
use crate::isa::registry::MemAccess;
use crate::isa::{
    Flags, Instruction, ProgramBuilder, ProgramError, SimdOp, VerifyEnv,
};
use crate::net::{Cluster, NodeId};
use crate::sim::{Engine, SimTime};
use crate::transport::{CcMode, CompletionKey, ReliabilityTable, WindowEngine, WindowedOp};
use crate::util::stats::percentile_ns;
use crate::wire::{DeviceIp, Packet, Payload};

use super::halving_doubling::HalvingDoubling;
use super::hierarchical::HierarchicalAllreduce;
use super::mpi_native::MpiRecursiveDoubling;
use super::netdam_ring::RingAllreduce;
use super::primitives::{RingAllGather, RingBroadcast};
use super::reduce::RingReduce;
use super::ring_roce::RingRoceAllreduce;
use super::switch_reduce::SwitchReduceAllreduce;
use super::tree::TreeBroadcast;
use super::CollectiveReport;

/// Knobs shared by every driver-run collective.
#[derive(Debug, Clone)]
pub struct CollectiveSpec {
    /// Total f32 elements of the collective's vector.
    pub elements: usize,
    /// SIMD lanes per packet (the paper's 2048 × f32 blocks).
    pub lanes: usize,
    /// Outstanding ops per rank (self-clocked window).
    pub window: usize,
    /// Track with timeout-retransmit (for lossy fabrics, E5).
    pub reliable: bool,
    /// Device-local base address of the vector.
    pub base_addr: u64,
    /// Tenant the collective runs under. Carried in aggregation
    /// metadata so switch ACLs (§2.5) can police contributions.
    pub tenant: u32,
}

impl Default for CollectiveSpec {
    fn default() -> Self {
        Self {
            elements: 1 << 16,
            lanes: 2048,
            window: 16,
            reliable: false,
            base_addr: 0,
            tenant: 0,
        }
    }
}

/// Topology facts a planner may consult: the leaf membership of each
/// rank plus — when the topology addresses its switches — the SROU IPs
/// of the leaf and spine tiers, in tier order. The switch-reduce
/// planner needs the IPs to pin aggregation waypoints; topologies
/// without addressed switches (star) leave them empty and such
/// planners refuse to run there.
#[derive(Debug, Clone, Default)]
pub struct TopoFacts {
    /// Device rank indices grouped by leaf switch (empty off fat-tree).
    pub leaf_groups: Vec<Vec<usize>>,
    /// SROU address of each leaf switch, same order as `leaf_groups`.
    pub leaf_ips: Vec<DeviceIp>,
    /// SROU address of each spine switch.
    pub spine_ips: Vec<DeviceIp>,
}

/// What a planner sees when generating one phase.
pub struct PlanCtx<'a> {
    /// Participating NetDAM devices, rank order (empty for host baselines).
    pub devices: &'a [NodeId],
    /// Their IPs, same order.
    pub ips: &'a [DeviceIp],
    pub spec: &'a CollectiveSpec,
    /// First completion id this phase may use; a phase planning `k` ops
    /// must use exactly the ids `done_id_base .. done_id_base + k`.
    pub done_id_base: u32,
}

/// One planned injection: `rank` injects `pkt`, and the driver expects a
/// `CollectiveDone { block: done_id }` back at that rank's device.
pub struct ScheduledOp {
    pub rank: usize,
    pub done_id: u32,
    pub pkt: Packet,
}

/// A phase's schedule.
pub enum Phase {
    /// Packet ops, window-injected and completion-refilled by the driver.
    Ops(Vec<ScheduledOp>),
    /// Host apps were installed into the cluster; the driver starts them,
    /// drains the DES, and reads completion metrics.
    Apps {
        finished_counter: &'static str,
        done_hist: &'static str,
        expect_finished: u64,
    },
}

/// A collective algorithm = a named, possibly multi-phase schedule
/// generator. Planning happens against live device memory (payloads and
/// idempotency-guard hashes are captured per phase).
pub trait CollectiveAlgorithm {
    fn name(&self) -> &'static str;

    /// Number of sequential phases (the driver drains the DES between
    /// phases). Single-phase algorithms keep the default.
    fn phases(&self) -> usize {
        1
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase>;
}

/// What one driver run produced.
#[derive(Debug, Clone)]
pub struct DriverOutcome {
    pub elapsed_ns: SimTime,
    /// Ops planned (or expected app completions) across all phases.
    pub ops: usize,
    /// Ops actually completed. `< ops` means the run did not converge
    /// (e.g. unrecovered loss on an unreliable fabric).
    pub ops_done: usize,
    pub retransmits: u64,
    pub hash_guard_drops: u64,
    pub link_drops: u64,
    /// Median per-op completion latency (wire release → completion), ns.
    pub lat_p50_ns: SimTime,
    /// Tail (p99) per-op completion latency, ns.
    pub lat_p99_ns: SimTime,
}

impl DriverOutcome {
    /// Shape the outcome into the bench-facing report.
    pub fn report(&self, algorithm: &'static str, elements: usize) -> CollectiveReport {
        CollectiveReport {
            algorithm,
            elements,
            elapsed_ns: self.elapsed_ns,
            link_drops: self.link_drops,
            retransmits: self.retransmits,
            lat_p50_ns: self.lat_p50_ns,
            lat_p99_ns: self.lat_p99_ns,
        }
    }
}

/// The collective front of the shared window engine. See the module docs.
pub struct Driver;

impl Driver {
    /// Run `algo` over `devices` in `cl`. Blocks until the DES drains
    /// (every phase); returns timing + integrity counters. Completion is
    /// *reported*, not asserted — callers decide whether `ops_done <
    /// ops` is an error (it is expected on lossy unreliable fabrics).
    pub fn run(
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        devices: &[NodeId],
        algo: &mut dyn CollectiveAlgorithm,
        spec: &CollectiveSpec,
    ) -> Result<DriverOutcome> {
        let ips: Vec<DeviceIp> = devices.iter().map(|&d| cl.device(d).ip()).collect();
        if spec.reliable {
            // Chains take ~10 us idle but queue under load; a generous
            // timeout avoids spurious (harmless but wasteful) duplicates.
            cl.xport = ReliabilityTable::new(2_000_000, 12);
        }
        let mut ops_total = 0usize;
        let mut ops_done = 0usize;
        let mut elapsed: SimTime = eng.now();
        let mut latencies: Vec<SimTime> = Vec::new();
        let mut done_id_base = 0u32;
        let n_phases = algo.phases();
        for phase in 0..n_phases {
            let plan = {
                let ctx = PlanCtx {
                    devices,
                    ips: &ips,
                    spec,
                    done_id_base,
                };
                algo.plan_phase(cl, &ctx, phase)?
            };
            match plan {
                Phase::Ops(ops) => {
                    if ops.is_empty() {
                        continue;
                    }
                    let n_ops = ops.len();
                    done_id_base = done_id_base
                        .checked_add(n_ops as u32)
                        .expect("completion id space exhausted");
                    let wops = lower_schedule(cl, devices, spec.reliable, false, ops)?;
                    let out = WindowEngine::new(spec.window).run(cl, eng, wops)?;
                    ops_total += n_ops;
                    ops_done += out.done;
                    elapsed = out.last_done;
                    latencies.extend(out.latencies);
                    if out.done < n_ops {
                        break; // later phases would compute on stale data
                    }
                }
                Phase::Apps {
                    finished_counter,
                    done_hist,
                    expect_finished,
                } => {
                    cl.start_apps(eng);
                    eng.run(cl);
                    let fin = cl.metrics.counter(finished_counter);
                    elapsed = cl
                        .metrics
                        .hist(done_hist)
                        .map(|h| h.max())
                        .unwrap_or_else(|| eng.now());
                    ops_total += expect_finished as usize;
                    ops_done += fin.min(expect_finished) as usize;
                    if fin < expect_finished {
                        break;
                    }
                }
            }
        }
        let hash_guard_drops: u64 = devices
            .iter()
            .map(|&d| cl.device(d).drops_hash_guard)
            .sum();
        Ok(DriverOutcome {
            elapsed_ns: elapsed,
            ops: ops_total,
            ops_done,
            retransmits: cl.xport.retransmits,
            hash_guard_drops,
            link_drops: cl.metrics.counter("link_drops"),
            lat_p50_ns: percentile_ns(&latencies, 50.0),
            lat_p99_ns: percentile_ns(&latencies, 99.0),
        })
    }
}

/// Lower a planned schedule onto engine ops — one slot per rank,
/// completions keyed by done-id (the engine rejects duplicate ids),
/// seqs allocated up front from each rank's device. Shared by the
/// driver's blocking loop and the session fabric (`crate::comm`).
pub(crate) fn lower_schedule(
    cl: &mut Cluster,
    devices: &[NodeId],
    reliable: bool,
    paced: bool,
    ops: Vec<ScheduledOp>,
) -> Result<Vec<WindowedOp>> {
    let n_ranks = devices.len();
    let mut wops = Vec::with_capacity(ops.len());
    for mut op in ops {
        ensure!(op.rank < n_ranks, "op rank {} out of range", op.rank);
        op.pkt.seq = cl.alloc_seq(devices[op.rank]);
        // Aggregation manifests carry the contributor's (src, seq) so the
        // root collector can ack each origin; planners cannot know the seq
        // at plan time, so they leave a 0 placeholder we patch here.
        if let Some(agg) = op.pkt.agg.as_mut() {
            // Copy-on-write: the manifest is Arc-shared once in flight,
            // but at patch time this op holds the only reference.
            let agg = Arc::make_mut(agg);
            for e in agg.entries.iter_mut().filter(|e| e.seq == 0) {
                e.seq = op.pkt.seq;
            }
        }
        // Self-clocked collectives skip the per-op header encode a
        // wire_bytes() charge costs; under closed-loop congestion
        // control the pacer needs real sizes, so charge them then.
        let pace_bytes = if paced { op.pkt.wire_bytes() } else { 0 };
        wops.push(WindowedOp {
            slot: op.rank,
            origin: devices[op.rank],
            key: CompletionKey::DoneId(op.done_id),
            tag: op.done_id as u64,
            reliable,
            pace_bytes,
            pkt: op.pkt,
        });
    }
    Ok(wops)
}

// ------------------------------------------------- schedule → Program

/// Build the verification environment for a program injected into `cl`
/// whose writes land on device `target`. The §2.3 relaxed-ordering rule
/// becomes a machine-checked property here: collective packets ride an
/// unordered path, and the path is lossless only when no fault injection
/// or timeout-retransmit can replay a chain.
pub fn prog_env<'a>(
    cl: &'a Cluster,
    target: NodeId,
    payload_len: usize,
    srou_hops: usize,
    reliable: bool,
) -> VerifyEnv<'a> {
    VerifyEnv {
        capacity: cl.device(target).mem_ref().capacity(),
        payload_len,
        ordered: false,
        lossless: cl.fault.loss_p == 0.0 && cl.fault.dup_p == 0.0 && !reliable,
        srou_hops,
        registry: Some(cl.registry.as_ref()),
    }
}

/// Lower one §3 ring-allreduce chunk onto a verified packet program:
///
/// ```text
/// reduce(op, addr) ×(N−1)  →  guarded_write(addr, hash)  [→ store(addr) ×(N−1)]
/// ```
///
/// Interim hops fold their local block into the packet buffer, the chain
/// owner performs the hash-guarded exactly-once write, and (when `fused`)
/// the finished block is stored at every remaining ring hop — the whole
/// MPI allreduce chunk in one self-routing packet. This is the lowering
/// every planner shares; it fails with a typed [`ProgramError`] instead
/// of injecting an unsafe chain.
pub fn lower_ring_chunk(
    op: SimdOp,
    addr: u64,
    ranks: usize,
    fused: bool,
    expect_hash: u64,
    done_id: u32,
    env: &VerifyEnv<'_>,
) -> Result<Instruction, ProgramError> {
    let mut b = ProgramBuilder::new()
        .reduce(op, addr, (ranks - 1) as u8)
        .guarded_write(addr, expect_hash);
    if fused {
        b = b.store(addr, (ranks - 1) as u8);
    }
    Ok(Instruction::Program(Arc::new(
        b.on_retire(done_id).build(env)?,
    )))
}

/// Lower an idempotent store chain (the all-gather / broadcast shape):
/// the payload is written at each of the next `hops` SROU hops.
pub fn lower_store_chain(
    addr: u64,
    hops: usize,
    done_id: u32,
    env: &VerifyEnv<'_>,
) -> Result<Instruction, ProgramError> {
    Ok(Instruction::Program(Arc::new(
        ProgramBuilder::new()
            .store(addr, hops as u8)
            .on_retire(done_id)
            .build(env)?,
    )))
}

// ---------------------------------------------------------------- helpers

/// Wire flags for driver-scheduled ops.
pub(crate) fn op_flags(reliable: bool) -> Flags {
    if reliable {
        Flags(Flags::RELIABLE)
    } else {
        Flags::default()
    }
}

/// Read a payload block from device memory (phantom-aware).
pub(crate) fn read_block(cl: &mut Cluster, node: NodeId, addr: u64, len: usize) -> Result<Payload> {
    let dev = cl.device_mut(node);
    if dev.mem_ref().is_phantom() {
        Ok(Payload::phantom(len))
    } else {
        Ok(Payload::from_bytes(dev.mem().read(addr, len)?))
    }
}

/// Hash of a device's pristine block — the §3.1 idempotency guard.
/// Phantom (timing-only) devices return 0; their guard always passes.
pub(crate) fn guard_hash(cl: &mut Cluster, node: NodeId, addr: u64, len: usize) -> Result<u64> {
    let dev = cl.device_mut(node);
    if dev.mem_ref().is_phantom() {
        Ok(0)
    } else {
        Ok(block_hash(&dev.mem().read(addr, len)?))
    }
}

// ------------------------------------------------------- the algorithm menu

/// The collectives the driver can run off the shelf.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoKind {
    /// The paper's §3 in-memory ring allreduce (fused all-gather).
    NetdamRing,
    /// Latency-optimal recursive halving/doubling allreduce (2^k ranks).
    HalvingDoubling,
    /// Two-level allreduce: reduce within each leaf, ring across leaves,
    /// broadcast back — for the `fat_tree` topology.
    Hierarchical,
    /// Ring reduce-scatter only (each chunk reduced at its owner).
    ReduceScatter,
    /// Ring all-gather of per-rank chunks.
    AllGather,
    /// Ring broadcast of rank 0's vector.
    Broadcast,
    /// Rooted reduce: the whole vector summed at the root rank.
    Reduce,
    /// In-network allreduce: leaf/spine switches fold marked
    /// contributions in flight (§2.5), the root broadcasts back down a
    /// binomial tree — for the `fat_tree` topology.
    SwitchReduce,
    /// Binomial-tree broadcast of the root rank's vector.
    TreeBcast,
    /// Host baseline: Horovod-style ring allreduce over RoCE hosts.
    RingRoce,
    /// Host baseline: native-MPI recursive doubling.
    MpiNative,
}

impl AlgoKind {
    pub const ALL: [AlgoKind; 11] = [
        AlgoKind::NetdamRing,
        AlgoKind::HalvingDoubling,
        AlgoKind::Hierarchical,
        AlgoKind::SwitchReduce,
        AlgoKind::ReduceScatter,
        AlgoKind::AllGather,
        AlgoKind::Broadcast,
        AlgoKind::TreeBcast,
        AlgoKind::Reduce,
        AlgoKind::RingRoce,
        AlgoKind::MpiNative,
    ];

    pub fn name(self) -> &'static str {
        match self {
            AlgoKind::NetdamRing => "netdam-ring",
            AlgoKind::HalvingDoubling => "halving-doubling",
            AlgoKind::Hierarchical => "hierarchical-2level",
            AlgoKind::SwitchReduce => "switch-reduce",
            AlgoKind::TreeBcast => "tree-bcast",
            AlgoKind::ReduceScatter => "reduce-scatter",
            AlgoKind::AllGather => "all-gather",
            AlgoKind::Broadcast => "broadcast",
            AlgoKind::Reduce => "reduce",
            AlgoKind::RingRoce => "ring-roce",
            AlgoKind::MpiNative => "mpi-native",
        }
    }

    /// Parse a CLI name (accepts a few aliases).
    pub fn parse(s: &str) -> Result<AlgoKind> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "netdam-ring" | "ring" | "netdam" => AlgoKind::NetdamRing,
            "halving-doubling" | "hd" => AlgoKind::HalvingDoubling,
            "hierarchical-2level" | "hierarchical" | "2level" => AlgoKind::Hierarchical,
            "switch-reduce" | "sr" | "innet" => AlgoKind::SwitchReduce,
            "tree-bcast" | "tbcast" | "binomial-bcast" => AlgoKind::TreeBcast,
            "reduce-scatter" | "rs" => AlgoKind::ReduceScatter,
            "all-gather" | "ag" | "allgather" => AlgoKind::AllGather,
            "broadcast" | "bcast" => AlgoKind::Broadcast,
            "reduce" | "rooted-reduce" => AlgoKind::Reduce,
            "ring-roce" | "roce" => AlgoKind::RingRoce,
            "mpi-native" | "native" => AlgoKind::MpiNative,
            other => anyhow::bail!(
                "unknown algorithm {other:?} (menu: {})",
                AlgoKind::ALL
                    .iter()
                    .map(|a| a.name())
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        })
    }

    /// Host-CPU baselines build their own host fabric instead of running
    /// on NetDAM devices.
    pub fn is_host_baseline(self) -> bool {
        matches!(self, AlgoKind::RingRoce | AlgoKind::MpiNative)
    }

    /// Bytes moved per rank as a fraction of the vector size V — the
    /// nccl-tests "bus bandwidth" convention. Allreduces move
    /// 2·(N−1)/N·V, reduce-scatter/all-gather (N−1)/N·V,
    /// broadcast/reduce V (the root port is the bottleneck).
    pub fn bw_fraction(self, n_ranks: usize) -> f64 {
        let n = n_ranks as f64;
        match self {
            AlgoKind::NetdamRing
            | AlgoKind::HalvingDoubling
            | AlgoKind::Hierarchical
            | AlgoKind::SwitchReduce
            | AlgoKind::RingRoce
            | AlgoKind::MpiNative => 2.0 * (n - 1.0) / n,
            AlgoKind::ReduceScatter | AlgoKind::AllGather => (n - 1.0) / n,
            AlgoKind::Broadcast | AlgoKind::TreeBcast | AlgoKind::Reduce => 1.0,
        }
    }

    /// Construct the schedule generator for a device-run collective.
    /// `topo` feeds the topology-aware planners (hierarchical,
    /// switch-reduce); `root` the rooted collectives (broadcast,
    /// reduce). Host baselines have no device planner and error here.
    pub fn planner(
        self,
        ranks: usize,
        topo: &TopoFacts,
        root: usize,
    ) -> Result<Box<dyn CollectiveAlgorithm>> {
        let algo: Box<dyn CollectiveAlgorithm> = match self {
            AlgoKind::NetdamRing => Box::new(RingAllreduce { fused: true }),
            AlgoKind::ReduceScatter => Box::new(RingAllreduce { fused: false }),
            AlgoKind::HalvingDoubling => Box::new(HalvingDoubling::new(ranks)?),
            AlgoKind::Hierarchical => {
                Box::new(HierarchicalAllreduce::new(topo.leaf_groups.to_vec())?)
            }
            AlgoKind::SwitchReduce => Box::new(SwitchReduceAllreduce::new(topo.clone())?),
            AlgoKind::AllGather => Box::new(RingAllGather),
            AlgoKind::Broadcast => Box::new(RingBroadcast { root }),
            AlgoKind::TreeBcast => Box::new(TreeBroadcast { root, ranks }),
            AlgoKind::Reduce => Box::new(RingReduce { root }),
            AlgoKind::RingRoce | AlgoKind::MpiNative => anyhow::bail!(
                "{} is a host baseline (no device planner)",
                self.name()
            ),
        };
        Ok(algo)
    }
}

/// Options for [`run_collective`] — the one-call bench/CLI front door.
#[derive(Debug, Clone)]
pub struct RunOpts {
    pub elements: usize,
    pub ranks: usize,
    pub seed: u64,
    pub window: usize,
    /// Phantom payloads (timing-only devices) for paper-scale vectors.
    pub timing_only: bool,
    pub reliable: bool,
    /// Per-wire loss probability (fault injection).
    pub loss_p: f64,
    /// Congestion control for device-run collectives: static budgets
    /// (the default, self-clocked window only) or closed-loop DCQCN.
    /// Host baselines ignore it (they model their own DCQCN-lite).
    pub cc: CcMode,
}

impl Default for RunOpts {
    fn default() -> Self {
        Self {
            elements: 1 << 20,
            ranks: 4,
            seed: 0xC011,
            window: 16,
            timing_only: false,
            reliable: false,
            loss_p: 0.0,
            cc: CcMode::Static,
        }
    }
}

/// One-call compatibility shim over the session API: build a
/// **single-use** [`crate::comm::Fabric`], derive one communicator, run
/// `kind` to completion, and return the report. Long-lived applications
/// (and anything wanting concurrency, bucketing, or nonblocking ops)
/// should hold a `Fabric` and call the communicator API directly — this
/// entry keeps the CLI (`--algo`), bench grid, and E2 coordinator
/// working unchanged.
pub fn run_collective(kind: AlgoKind, opts: &RunOpts) -> Result<CollectiveReport> {
    if kind.is_host_baseline() {
        // The host baselines model a PFC-lossless RoCE fabric and have no
        // retransmit machinery; reject fault injection instead of
        // silently dropping the knob.
        ensure!(
            opts.loss_p == 0.0,
            "{} assumes a lossless fabric (loss_p must be 0)",
            kind.name()
        );
        let spec = CollectiveSpec {
            elements: opts.elements,
            window: opts.window,
            reliable: opts.reliable,
            ..Default::default()
        };
        let mut eng: Engine<Cluster> = Engine::new();
        let mut cl = Cluster::new(opts.seed);
        let out = match kind {
            AlgoKind::RingRoce => {
                let mut algo = RingRoceAllreduce {
                    ranks: opts.ranks,
                    elements: opts.elements,
                    seed: opts.seed,
                };
                Driver::run(&mut cl, &mut eng, &[], &mut algo, &spec)?
            }
            _ => {
                let mut algo = MpiRecursiveDoubling {
                    ranks: opts.ranks,
                    elements: opts.elements,
                    seed: opts.seed,
                };
                Driver::run(&mut cl, &mut eng, &[], &mut algo, &spec)?
            }
        };
        ensure!(
            out.ops_done == out.ops,
            "{} incomplete: {}/{} ranks finished",
            kind.name(),
            out.ops_done,
            out.ops
        );
        return Ok(out.report(kind.name(), opts.elements));
    }

    let mut fabric = crate::comm::Fabric::builder()
        .seed(opts.seed)
        .window(opts.window)
        .reliable(opts.reliable)
        .loss(opts.loss_p)
        .timing_only(opts.timing_only)
        .with_congestion_control(opts.cc.clone())
        .for_algo(kind, opts.ranks)?
        .build()?;
    let comm = fabric.communicator(opts.elements as u64 * 4)?;
    if !opts.timing_only {
        comm.seed_gradients(&mut fabric, opts.elements, opts.seed);
    }
    let h = comm.icollective(&mut fabric, kind, opts.elements, 0)?;
    let out = fabric.wait(h)?;
    if opts.loss_p == 0.0 || opts.reliable {
        ensure!(
            out.complete(),
            "{} incomplete: {}/{} ops done",
            kind.name(),
            out.ops_done,
            out.ops
        );
    }
    Ok(fabric.report(&out))
}
