//! Binomial-tree broadcast, plus the tree-shape helpers shared with the
//! switch-reduce planner.
//!
//! A binomial broadcast doubles the set of informed ranks every round:
//! after round `k`, the `2^(k+1)` ranks closest to the root (in relabeled
//! order) hold the vector, so `⌈log₂N⌉` rounds finish the job — against
//! the ring broadcast's `N−1` serial hops. Each round is one driver
//! phase; every send is a 1-hop idempotent store chain, so the planner
//! needs no guard hashes and survives duplication like the ring version.
//!
//! The round structure ([`binomial_pairs`]) and depth ([`ceil_log2`]) are
//! also what the switch-reduce allreduce uses for its root-to-leaves
//! down-broadcast — one tree shape, two planners.

use anyhow::{ensure, Result};

use crate::net::Cluster;
use crate::wire::{Packet, Segment, SrouHeader};

use super::driver::{
    lower_store_chain, op_flags, prog_env, read_block, CollectiveAlgorithm, PlanCtx, Phase,
    ScheduledOp,
};

/// `⌈log₂ n⌉` for `n ≥ 1` — the binomial tree's round count.
pub(crate) fn ceil_log2(n: usize) -> usize {
    let mut rounds = 0;
    let mut span = 1usize;
    while span < n {
        span <<= 1;
        rounds += 1;
    }
    rounds
}

/// The (sender, receiver) pairs of binomial round `round`, in
/// *relabeled* rank space where the root is 0: every rank `x < 2^round`
/// already holds the data and sends to `x + 2^round` (when that rank
/// exists). Callers rotate by their actual root: `actual = (root + x) % n`.
pub(crate) fn binomial_pairs(n: usize, round: usize) -> Vec<(usize, usize)> {
    let span = 1usize << round;
    (0..span.min(n))
        .filter_map(|x| {
            let dst = x + span;
            (dst < n).then_some((x, dst))
        })
        .collect()
}

/// Binomial-tree broadcast of `root`'s whole vector to every other rank.
pub struct TreeBroadcast {
    pub root: usize,
    /// Rank count, fixed at planning-time (`phases()` needs it before
    /// the first [`PlanCtx`] exists).
    pub ranks: usize,
}

impl CollectiveAlgorithm for TreeBroadcast {
    fn name(&self) -> &'static str {
        "tree-bcast"
    }

    fn phases(&self) -> usize {
        // One driver phase per binomial round: a round's sends re-plan
        // only after the previous round's stores landed — the tree's
        // data dependency made explicit.
        ceil_log2(self.ranks).max(1)
    }

    fn plan_phase(&mut self, cl: &mut Cluster, ctx: &PlanCtx<'_>, phase: usize) -> Result<Phase> {
        let n = ctx.devices.len();
        ensure!(n >= 2, "broadcast needs at least 2 ranks");
        ensure!(n == self.ranks, "planned for {} ranks, ran with {n}", self.ranks);
        ensure!(self.root < n, "broadcast root {} out of range", self.root);
        let spec = ctx.spec;
        let mut ops = Vec::new();
        let mut next_id = ctx.done_id_base;
        for (sx, dx) in binomial_pairs(n, phase) {
            let src = (self.root + sx) % n;
            let dst = (self.root + dx) % n;
            let mut off = 0;
            while off < spec.elements {
                let lanes = spec.lanes.min(spec.elements - off);
                let len = lanes * 4;
                let addr = spec.base_addr + off as u64 * 4;
                let payload = read_block(cl, ctx.devices[src], addr, len)?;
                let done_id = next_id;
                next_id += 1;
                let env = prog_env(cl, ctx.devices[dst], len, 1, spec.reliable);
                let instr = lower_store_chain(addr, 1, done_id, &env)?;
                let pkt = Packet::new(
                    ctx.ips[src],
                    0,
                    SrouHeader::through(vec![Segment::to(ctx.ips[dst])]),
                    instr,
                )
                .with_flags(op_flags(spec.reliable))
                .with_payload(payload);
                ops.push(ScheduledOp {
                    rank: src,
                    done_id,
                    pkt,
                });
                off += lanes;
            }
        }
        Ok(Phase::Ops(ops))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::driver::{CollectiveSpec, Driver};
    use crate::collectives::oracle::read_vector;
    use crate::isa::registry::MemAccess;
    use crate::net::{LinkConfig, Topology};
    use crate::sim::Engine;
    use crate::util::bytes::f32s_to_bytes;
    use crate::util::Xoshiro256;

    #[test]
    fn tree_shape() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(binomial_pairs(6, 0), vec![(0, 1)]);
        assert_eq!(binomial_pairs(6, 1), vec![(0, 2), (1, 3)]);
        assert_eq!(binomial_pairs(6, 2), vec![(0, 4), (1, 5)]);
        // Every non-root rank receives exactly once across all rounds.
        for n in 2..=17 {
            let mut recv = vec![0usize; n];
            for k in 0..ceil_log2(n) {
                for (s, d) in binomial_pairs(n, k) {
                    assert!(s < d && d < n);
                    recv[d] += 1;
                }
            }
            assert!(recv[1..].iter().all(|&c| c == 1), "n={n}: {recv:?}");
        }
    }

    fn seed_distinct(
        cl: &mut crate::net::Cluster,
        devices: &[crate::net::NodeId],
        elements: usize,
    ) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        for (r, &d) in devices.iter().enumerate() {
            let mut rng = Xoshiro256::seed_from(0xB0 ^ (r as u64) << 4);
            let data = rng.f32_vec(elements, -4.0, 4.0);
            cl.device_mut(d).mem().write(0, &f32s_to_bytes(&data)).unwrap();
            out.push(data);
        }
        out
    }

    #[test]
    fn tree_broadcast_replicates_root() {
        let n = 6; // non-power-of-two exercises the ragged last round
        let elements = 2 * 2048 + 100;
        let t = Topology::star(5, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let devices = t.devices;
        let data = seed_distinct(&mut cl, &devices, elements);
        let spec = CollectiveSpec {
            elements,
            window: 8,
            ..Default::default()
        };
        let root = 3;
        let mut algo = TreeBroadcast { root, ranks: n };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        for &d in &devices {
            assert_eq!(
                read_vector(&mut cl, d, 0, elements).unwrap(),
                data[root],
                "every rank holds the root vector"
            );
        }
    }

    #[test]
    fn tree_broadcast_survives_duplication() {
        // 1-hop store chains are idempotent; duplicated frames are noise.
        let n = 5;
        let elements = 2048;
        let t = Topology::star(9, n, 0, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        cl.fault.dup_p = 0.05;
        let devices = t.devices;
        let data = seed_distinct(&mut cl, &devices, elements);
        let spec = CollectiveSpec {
            elements,
            window: 4,
            ..Default::default()
        };
        let mut algo = TreeBroadcast { root: 0, ranks: n };
        let mut eng: Engine<crate::net::Cluster> = Engine::new();
        let out = Driver::run(&mut cl, &mut eng, &devices, &mut algo, &spec).unwrap();
        assert_eq!(out.ops_done, out.ops);
        for &d in &devices {
            assert_eq!(read_vector(&mut cl, d, 0, elements).unwrap(), data[0]);
        }
    }
}
