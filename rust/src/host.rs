//! The conventional-host cost model (paper §1.1, §3.1, and reference [10],
//! "Understanding PCIe Performance for End Host Networking").
//!
//! The RoCE baseline's latency and throughput are dominated by exactly the
//! costs NetDAM bypasses: PCIe doorbells and DMA, host DRAM contention,
//! interrupt/scheduling jitter, and CPU-side reduction at AVX-512 width.
//! This module provides those constants + samplers; [`crate::roce`] and
//! the baseline collectives consume them.

use crate::sim::SimTime;
use crate::util::Xoshiro256;

/// Calibrated host parameters (2× Xeon Gold 6230R, CX516A, PCIe3 x16).
#[derive(Debug, Clone)]
pub struct HostConfig {
    /// Doorbell + DMA descriptor fetch + completion round trip.
    pub pcie_rtt_ns: SimTime,
    /// DMA streaming bandwidth (bytes/ns). PCIe3 x16 ≈ 12–13 GB/s
    /// effective with descriptor overhead.
    pub pcie_bytes_per_ns: f64,
    /// Host DRAM streaming bandwidth available to the NIC path.
    pub dram_bytes_per_ns: f64,
    /// Effective CPU reduction throughput (bytes of *output* per ns) for
    /// the MPI sum loop: load a + load b + store, cache misses, MPI
    /// progress engine. Measured Horovod-class efficiency ≈ 1.2 B/ns.
    pub reduce_bytes_per_ns: f64,
    /// NIC pipeline latency each way.
    pub nic_ns: SimTime,
    /// Probability a request eats an interrupt/scheduler stall...
    pub stall_p: f64,
    /// ...mean of the (exponential) stall when it happens.
    pub stall_mean_ns: f64,
    /// Gaussian σ on the PCIe/DRAM service path.
    pub jitter_ns: f64,
    /// Per-message software overhead (verbs post + completion handling).
    pub sw_overhead_ns: SimTime,
}

impl HostConfig {
    pub fn paper_default() -> Self {
        Self {
            pcie_rtt_ns: 900,
            pcie_bytes_per_ns: 12.0,
            dram_bytes_per_ns: 40.0,
            reduce_bytes_per_ns: 1.2,
            nic_ns: 250,
            stall_p: 0.03,
            stall_mean_ns: 2500.0,
            jitter_ns: 150.0,
            sw_overhead_ns: 350,
        }
    }
}

/// Samples service times for one host.
#[derive(Debug, Clone)]
pub struct HostModel {
    pub cfg: HostConfig,
    rng: Xoshiro256,
}

impl HostModel {
    pub fn new(cfg: HostConfig, seed: u64) -> Self {
        Self {
            cfg,
            rng: Xoshiro256::seed_from(seed ^ 0x57_05_7E_11),
        }
    }

    fn jitter(&mut self) -> f64 {
        let g = self.rng.next_gaussian() * self.cfg.jitter_ns;
        let stall = if self.rng.chance(self.cfg.stall_p) {
            // Exponential tail: -mean · ln(U)
            -self.cfg.stall_mean_ns * (1.0 - self.rng.next_f64()).ln()
        } else {
            0.0
        };
        g.max(-3.0 * self.cfg.jitter_ns) + stall
    }

    /// Time for the NIC to satisfy a remote READ of `len` bytes:
    /// NIC rx → PCIe DMA from host DRAM → NIC tx. (RDMA READ is
    /// NIC-terminated; no CPU, but the PCIe+DRAM path jitters.)
    pub fn nic_read_ns(&mut self, len: usize) -> SimTime {
        let stream = len as f64 / self.cfg.pcie_bytes_per_ns
            + len as f64 / self.cfg.dram_bytes_per_ns;
        let t = self.cfg.nic_ns as f64 * 2.0
            + self.cfg.pcie_rtt_ns as f64
            + stream
            + self.jitter();
        t.max(100.0) as SimTime
    }

    /// Same for a remote WRITE landing in host memory.
    pub fn nic_write_ns(&mut self, len: usize) -> SimTime {
        let stream = len as f64 / self.cfg.pcie_bytes_per_ns;
        let t = self.cfg.nic_ns as f64 * 2.0 + self.cfg.pcie_rtt_ns as f64 * 0.5
            + stream
            + self.jitter();
        t.max(100.0) as SimTime
    }

    /// CPU-side lane-wise reduction of `bytes` of f32 (the per-iteration
    /// sum the paper's Figure 7 shows needing explicit load/store).
    pub fn reduce_ns(&mut self, bytes: usize) -> SimTime {
        let t = self.cfg.sw_overhead_ns as f64
            + bytes as f64 / self.cfg.reduce_bytes_per_ns
            + self.jitter().max(0.0);
        t as SimTime
    }

    /// Post-send overhead for one verbs message.
    pub fn post_send_ns(&mut self) -> SimTime {
        (self.cfg.sw_overhead_ns as f64 + self.cfg.pcie_rtt_ns as f64 * 0.5 + self.jitter().max(0.0))
            as SimTime
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roce_read_is_slower_and_jitterier_than_netdam() {
        // E1's qualitative claim: the host path is several × slower with a
        // heavy tail. NetDAM mean is 618 ns; host READ should be ≥ 2×.
        let mut h = HostModel::new(HostConfig::paper_default(), 42);
        let mut run = crate::util::stats::Running::new();
        for _ in 0..20_000 {
            run.push(h.nic_read_ns(128) as f64);
        }
        assert!(run.mean() > 1400.0, "mean {}", run.mean());
        assert!(run.mean() < 5000.0, "mean {}", run.mean());
        // Jitter: must dwarf NetDAM's 39 ns.
        assert!(run.std_dev() > 200.0, "std {}", run.std_dev());
        // Tail: max should blow past 2× mean (interrupt stalls).
        assert!(run.max() > 2.0 * run.mean());
    }

    #[test]
    fn reduce_throughput_matches_config() {
        let mut h = HostModel::new(HostConfig::paper_default(), 1);
        let bytes = 64 << 20; // 64 MB fusion buffer
        let t = h.reduce_ns(bytes);
        let eff = bytes as f64 / t as f64;
        assert!((eff - 1.2).abs() < 0.1, "effective {eff} B/ns");
    }

    #[test]
    fn costs_scale_with_length() {
        let mut h = HostModel::new(HostConfig::paper_default(), 2);
        let small: f64 = (0..200).map(|_| h.nic_read_ns(128) as f64).sum();
        let big: f64 = (0..200).map(|_| h.nic_read_ns(65536) as f64).sum();
        assert!(big > small * 1.8, "streaming term must matter");
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = HostModel::new(HostConfig::paper_default(), 9);
        let mut b = HostModel::new(HostConfig::paper_default(), 9);
        for _ in 0..100 {
            assert_eq!(a.nic_read_ns(4096), b.nic_read_ns(4096));
        }
    }
}
