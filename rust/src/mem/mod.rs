//! The pooled-memory data plane: GVA-addressed scatter-gather I/O.
//!
//! [`MemClient`] is the host-side half of the paper's §2.5/§2.6 memory
//! pool. A client holds a tenant identity and the pool's
//! [`InterleaveMap`]; reads, writes and CAS are issued against **global
//! virtual addresses** and compiled into scatter-gather packet plans over
//! the per-device extents. All plans are driven by the shared
//! [`crate::transport::WindowEngine`] — one self-clocked in-flight
//! window per device (slot), reliable timeout-retransmit injection,
//! completions matched by sequence number, read data reassembled in GVA
//! order, and NAKs surfaced as typed [`MemError::Nak`] (a NAK cancels
//! the rest of the plan: in-flight ops drain, queued ops are dropped,
//! and no reliability timers or completion hooks are left behind).
//!
//! Three client-library layers sit on the engine:
//!
//! * **Single ops** — [`MemClient::read`] / [`write`](MemClient::write) /
//!   [`cas`](MemClient::cas) / [`gather_sum`](MemClient::gather_sum),
//!   each a one-entry batch.
//! * **Pipelined batches** — [`MemClient::batch`] returns a [`MemBatch`]
//!   accumulator: submit any mix of reads/writes/CAS/gathers (each
//!   returns an [`OpHandle`]), then [`MemBatch::run`] drives *all* of
//!   them through one windowed run — many logical ops in flight per
//!   device at once — and [`BatchResult`] redeems the handles. Ops
//!   within a batch are unordered and concurrent: do not batch an op
//!   with another op that depends on its effect.
//! * **Paced mode** — [`MemClient::with_pace`] routes every injection
//!   through a token bucket in the engine's refill decision (the §2.5
//!   "sequencing and rate-limited READ" incast cure; reads charge the
//!   bucket for their *response* bytes). E3's pull-back arm runs on
//!   exactly this.
//!
//! Access control is *not* checked here: the plan is sent as-is and the
//! device IOMMUs — programmed by the SDN controller
//! ([`crate::pool::SdnController::malloc_mapped`]) — enforce the lease.
//!
//! CAS is **replay-safe**: devices keep a response-dedupe cache keyed on
//! `(src, seq)`, so a lost response plus a reliable retransmit replays
//! the original `CasResp` instead of re-executing the swap — a winner
//! can no longer be told `swapped=false` by its own retransmit.
//!
//! [`MemClient::gather_sum`] is the TensorDIMM-style near-memory gather:
//! a sparse set of GVA rows is folded with on-device `Simd` adds by one
//! self-routing packet [`crate::isa::Program`], and only the pooled
//! result row crosses the host link. Batched bags pipeline through
//! [`MemBatch::gather_sum`].

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::iommu::NakReason;
use crate::isa::registry::MemAccess;
use crate::isa::{Flags, Instruction, ProgramBuilder, SimdOp, VerifyEnv, MAX_PROGRAM_STEPS};
use crate::net::{Cluster, NodeId};
use crate::pool::{InterleaveMap, TenantId};
use crate::sim::Engine;
use crate::transport::{CompletionKey, NakRecord, Retired, TokenBucket, WindowEngine, WindowedOp};
use crate::wire::packet::MAX_PAYLOAD;
use crate::wire::{DeviceIp, Packet, Payload, Segment, SrouHeader};

/// Typed failure of a pooled-memory operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    /// A device IOMMU rejected the access and NAK'd it on the wire.
    Nak {
        device: DeviceIp,
        gva: u64,
        reason: NakReason,
    },
    /// Not every op completed (loss beyond the retransmit budget).
    Incomplete { done: usize, total: usize },
    /// The plan could not be compiled (bad shape, verifier rejection).
    Plan(String),
    /// A response arrived without the expected content.
    BadResponse { gva: u64 },
}

impl fmt::Display for MemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemError::Nak {
                device,
                gva,
                reason,
            } => write!(
                f,
                "device {device} NAK'd access at gva {gva:#x}: {reason}"
            ),
            MemError::Incomplete { done, total } => {
                write!(f, "pooled op incomplete: {done}/{total} completions")
            }
            MemError::Plan(msg) => write!(f, "plan rejected: {msg}"),
            MemError::BadResponse { gva } => {
                write!(f, "malformed response for gva {gva:#x}")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// One planned packet of a scatter-gather operation.
struct PlanOp {
    device: DeviceIp,
    gva: u64,
    /// Index of the logical batch entry this packet belongs to.
    entry: usize,
    /// For reads: destination offset in the entry's reassembly buffer.
    read_off: Option<usize>,
    len: usize,
    pkt: Packet,
    reliable: bool,
}

/// What one logical batch entry is (drives result redemption).
enum EntryKind {
    Read { len: usize },
    Write,
    Cas { seq: u64 },
    Gather,
}

/// Pacing configuration (token-bucket READ/WRITE release).
#[derive(Debug, Clone, Copy)]
struct PaceConf {
    gbps: f64,
    burst: usize,
}

/// A tenant's handle onto the pooled-memory data plane.
pub struct MemClient {
    /// Host node injecting the plans (its mailbox collects responses).
    host: NodeId,
    host_ip: DeviceIp,
    /// The tenant this client acts for (device-side enforcement keys on
    /// the *source IP* binding the controller installed, not this field —
    /// it documents intent and labels errors).
    pub tenant: TenantId,
    map: InterleaveMap,
    /// In-flight window per device.
    window: usize,
    /// Token-bucket pacing applied to every plan (fresh bucket per run).
    pace: Option<PaceConf>,
}

impl MemClient {
    pub fn new(host: NodeId, host_ip: DeviceIp, tenant: TenantId, map: InterleaveMap) -> Self {
        Self {
            host,
            host_ip,
            tenant,
            map,
            window: 4,
            pace: None,
        }
    }

    /// Override the per-device in-flight window (default 4).
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    /// Pace every plan with a `gbps` token bucket of `burst` bytes depth
    /// — the paper's rate-limited READ pull (§2.5). The bucket starts
    /// full on each run; reads charge it for their response payload.
    /// A non-positive rate is a configuration error (it would defer
    /// releases to the end of simulated time), so it panics here rather
    /// than producing absurd timings.
    pub fn with_pace(mut self, gbps: f64, burst: usize) -> Self {
        assert!(
            gbps > 0.0,
            "with_pace requires a positive rate (got {gbps} Gbit/s)"
        );
        self.pace = Some(PaceConf { gbps, burst });
        self
    }

    pub fn map(&self) -> &InterleaveMap {
        &self.map
    }

    /// A paced twin of this client (same tenant/host/map) — the §2.5
    /// rate-limited READ pull without re-deriving the tenant.
    pub fn clone_with_pace(&self, gbps: f64, burst: usize) -> MemClient {
        MemClient::new(self.host, self.host_ip, self.tenant, self.map.clone())
            .with_window(self.window)
            .with_pace(gbps, burst)
    }

    // ------------------------------------------------------- public ops

    /// Start an empty pipelined batch. Submit ops, then [`MemBatch::run`].
    pub fn batch(&self) -> MemBatch<'_> {
        MemBatch {
            client: self,
            plan: Vec::new(),
            entries: Vec::new(),
        }
    }

    /// Read `len` bytes at `gva`, scatter-gathered across the pool and
    /// reassembled in GVA order.
    pub fn read(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        len: usize,
    ) -> Result<Vec<u8>, MemError> {
        let mut b = self.batch();
        let h = b.read(cl, gva, len);
        let mut out = b.run(cl, eng)?;
        out.take_read(h).ok_or(MemError::BadResponse { gva })
    }

    /// Write `data` at `gva`, sprayed over the interleaved extents with
    /// one reliable in-flight window per device.
    pub fn write(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        data: &[u8],
    ) -> Result<(), MemError> {
        let mut b = self.batch();
        b.write(cl, gva, data);
        b.run(cl, eng)?;
        Ok(())
    }

    /// Compare-and-swap the u64 at `gva` (must not straddle an interleave
    /// block). Returns `(old_value, swapped)`.
    ///
    /// Replay-safe on lossy fabrics: the op is sent reliably, and the
    /// device's `(src, seq)` response-dedupe cache guarantees a
    /// retransmit after a lost response returns the *original* outcome
    /// instead of re-executing the swap.
    pub fn cas(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        expected: u64,
        new: u64,
    ) -> Result<(u64, bool), MemError> {
        let mut b = self.batch();
        let h = b.cas(cl, gva, expected, new)?;
        let out = b.run(cl, eng)?;
        out.cas_outcome(h).ok_or(MemError::BadResponse { gva })
    }

    /// TensorDIMM-style near-memory gather: fold the `rows` (each
    /// `row_bytes` long, fully inside one interleave block) into a zero
    /// accumulator with on-device `Simd` adds — one self-routing packet
    /// program visiting each row's device — and write the pooled sum at
    /// `dst_gva`. Only the result row ever crosses the host link. For
    /// many bags per call, pipeline them through [`MemBatch::gather_sum`].
    pub fn gather_sum(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        rows: &[u64],
        row_bytes: usize,
        dst_gva: u64,
    ) -> Result<(), MemError> {
        let mut b = self.batch();
        b.gather_sum(cl, rows, row_bytes, dst_gva)?;
        b.run(cl, eng)?;
        Ok(())
    }

    // ----------------------------------------------------- plan builders

    /// Split `[gva, gva+len)` along interleave blocks and the payload MTU
    /// into `(piece_gva, range_off, piece_len)` triples, in GVA order.
    fn pieces(&self, gva: u64, len: usize) -> Vec<(u64, usize, usize)> {
        let mut out = Vec::new();
        for e in self.map.scatter(gva, len as u64) {
            let mut off = 0u64;
            while off < e.len {
                let piece = (e.len - off).min(MAX_PAYLOAD as u64) as usize;
                out.push((
                    gva + e.range_off + off,
                    (e.range_off + off) as usize,
                    piece,
                ));
                off += piece as u64;
            }
        }
        out
    }

    /// Compile one gather bag into its packet-program plan op.
    fn plan_gather(
        &self,
        cl: &mut Cluster,
        rows: &[u64],
        row_bytes: usize,
        dst_gva: u64,
        entry: usize,
    ) -> Result<PlanOp, MemError> {
        if rows.is_empty() || rows.len() + 1 > MAX_PROGRAM_STEPS {
            return Err(MemError::Plan(format!(
                "gather of {} rows outside 1..={} (program step budget)",
                rows.len(),
                MAX_PROGRAM_STEPS - 1
            )));
        }
        let block = self.map.block_bytes();
        let mut b = ProgramBuilder::new();
        let mut segs = Vec::with_capacity(rows.len() + 1);
        for &row in rows.iter().chain(std::iter::once(&dst_gva)) {
            if row % block + row_bytes as u64 > block {
                return Err(MemError::Plan(format!(
                    "row at gva {row:#x} straddles an interleave block"
                )));
            }
        }
        for &row in rows {
            let (device, local) = self.map.translate(row);
            b = b.hop(Instruction::Simd {
                op: SimdOp::Add,
                addr: local,
            });
            segs.push(Segment::to(device));
        }
        let (dst_dev, dst_local) = self.map.translate(dst_gva);
        b = b.hop(Instruction::Write { addr: dst_local });
        segs.push(Segment::to(dst_dev));
        let capacity = cl
            .node_by_ip(dst_dev)
            .map(|n| cl.device(n).mem_ref().capacity())
            .unwrap_or(u64::MAX);
        let env = VerifyEnv {
            capacity,
            payload_len: row_bytes,
            ordered: false,
            lossless: false, // conservative: require idempotent steps
            srou_hops: segs.len(),
            registry: Some(cl.registry.as_ref()),
        };
        let prog = b.build(&env).map_err(|e| MemError::Plan(e.to_string()))?;
        let seq = cl.alloc_seq(self.host);
        let pkt = Packet::new(
            self.host_ip,
            seq,
            SrouHeader::through(segs),
            Instruction::Program(std::sync::Arc::new(prog)),
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_bytes(vec![0u8; row_bytes]));
        Ok(PlanOp {
            device: dst_dev,
            gva: dst_gva,
            entry,
            read_off: None,
            len: row_bytes,
            pkt,
            reliable: true,
        })
    }

}

/// A compiled, engine-ready memory plan: the windowed ops plus the
/// redemption bookkeeping. Produced by [`MemBatch::prepare`]. The
/// standalone [`MemBatch::run`] drives it through a private
/// [`WindowEngine`]; [`crate::comm::Fabric::submit_mem`] submits the
/// same ops onto the fabric's **shared** session instead, so pooled
/// I/O flies concurrently with in-flight collectives.
pub struct PreparedMemPlan {
    host: NodeId,
    total: usize,
    /// The client's per-device in-flight window.
    window: usize,
    /// The owning client's token-bucket pacing, if configured.
    pace: Option<PaceConf>,
    entries: Vec<EntryKind>,
    wops: Vec<WindowedOp>,
    /// Read placement per sequence: `(entry, buffer_off, len)`.
    read_of_seq: HashMap<u64, (usize, usize, usize)>,
    cas_of_seq: HashMap<u64, usize>,
    plan_seqs: HashSet<u64>,
}

impl PreparedMemPlan {
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// The per-device in-flight window the owning client configured.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Whether the owning client configured token-bucket pacing.
    pub fn is_paced(&self) -> bool {
        self.pace.is_some()
    }

    /// The pacing `(gbps, burst_bytes)` the owning client configured, if
    /// any — whoever runs the plan builds the fresh-per-run bucket.
    pub fn pace(&self) -> Option<(f64, usize)> {
        self.pace.map(|p| (p.gbps, p.burst))
    }

    /// Whether the engine must record responses (CAS outcomes need them).
    pub fn wants_responses(&self) -> bool {
        !self.cas_of_seq.is_empty()
    }

    /// Take the engine ops (once). Slots are per-device indices local to
    /// this plan; whoever runs them windows per device.
    pub fn take_ops(&mut self) -> Vec<WindowedOp> {
        std::mem::take(&mut self.wops)
    }

    /// Redeem the plan against its engine outcome: surface the NAK as a
    /// typed error, check completeness, collect CAS outcomes from the
    /// recorded responses, drain *this plan's* packets from the host
    /// mailbox (other traffic on the host survives), and reassemble
    /// read data in GVA order.
    pub fn redeem(
        self,
        cl: &mut Cluster,
        done: usize,
        nak: Option<&NakRecord>,
        responses: &[Retired],
    ) -> Result<BatchResult, MemError> {
        let mut reads: Vec<Option<Vec<u8>>> = self
            .entries
            .iter()
            .map(|e| match e {
                EntryKind::Read { len } => Some(vec![0u8; *len]),
                _ => None,
            })
            .collect();
        if self.total == 0 {
            return Ok(BatchResult {
                reads,
                cas: HashMap::new(),
            });
        }
        // Drain before any early error return so a failed plan leaves no
        // stale responses behind.
        let mailbox = std::mem::take(&mut cl.host_mut(self.host).mailbox);
        let (ours, theirs): (Vec<_>, Vec<_>) = mailbox
            .into_iter()
            .partition(|(_, pkt)| self.plan_seqs.contains(&pkt.seq));
        cl.host_mut(self.host).mailbox = theirs;
        if let Some(nak) = nak {
            return Err(MemError::Nak {
                device: nak.from,
                gva: nak.tag,
                reason: NakReason::from_u8(nak.reason),
            });
        }
        if done < self.total {
            return Err(MemError::Incomplete {
                done,
                total: self.total,
            });
        }
        // CAS outcomes from the recorded completions.
        let mut cas = HashMap::new();
        for r in responses {
            if let Instruction::CasResp { old, swapped, .. } = r.instr {
                if let CompletionKey::Seq(s) = r.key {
                    if let Some(&e) = self.cas_of_seq.get(&s) {
                        cas.insert(e, (old, swapped));
                    }
                }
            }
        }
        // Reassemble read data in GVA order, per entry.
        for (_, pkt) in ours {
            if !matches!(pkt.instr, Instruction::ReadResp { .. }) {
                continue;
            }
            let Some(&(entry, off, len)) = self.read_of_seq.get(&pkt.seq) else {
                continue;
            };
            let Some(buf) = reads[entry].as_mut() else {
                continue;
            };
            if let Some(bytes) = pkt.payload.bytes() {
                let n = bytes.len().min(len).min(buf.len().saturating_sub(off));
                buf[off..off + n].copy_from_slice(&bytes[..n]);
            }
            // Phantom payloads (timing-only devices) leave zeros.
        }
        Ok(BatchResult { reads, cas })
    }
}

// -------------------------------------------------------- batched API

/// Handle to one logical op submitted into a [`MemBatch`]; redeem it
/// against the [`BatchResult`] the batch run returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpHandle(usize);

/// A pipelined multi-op submission: accumulate reads/writes/CAS/gathers,
/// then [`run`](Self::run) them through one windowed engine pass — every
/// op in flight concurrently under the per-device windows (and the
/// client's pacer, if configured). Ops in a batch are unordered; do not
/// batch dependent ops together.
pub struct MemBatch<'a> {
    client: &'a MemClient,
    plan: Vec<PlanOp>,
    entries: Vec<EntryKind>,
}

impl MemBatch<'_> {
    /// Queue a scatter-gather read of `len` bytes at `gva`.
    pub fn read(&mut self, cl: &mut Cluster, gva: u64, len: usize) -> OpHandle {
        let entry = self.entries.len();
        for (piece_gva, off, piece_len) in self.client.pieces(gva, len) {
            let (device, local) = self.client.map.translate(piece_gva);
            let seq = cl.alloc_seq(self.client.host);
            let pkt = Packet::new(
                self.client.host_ip,
                seq,
                SrouHeader::direct(device),
                Instruction::Read {
                    addr: local,
                    len: piece_len as u32,
                },
            );
            self.plan.push(PlanOp {
                device,
                gva: piece_gva,
                entry,
                read_off: Some(off),
                len: piece_len,
                pkt,
                reliable: true,
            });
        }
        self.entries.push(EntryKind::Read { len });
        OpHandle(entry)
    }

    /// Queue a scatter write of `data` at `gva`.
    pub fn write(&mut self, cl: &mut Cluster, gva: u64, data: &[u8]) -> OpHandle {
        let entry = self.entries.len();
        for (piece_gva, off, piece_len) in self.client.pieces(gva, data.len()) {
            let (device, local) = self.client.map.translate(piece_gva);
            let seq = cl.alloc_seq(self.client.host);
            let pkt = Packet::new(
                self.client.host_ip,
                seq,
                SrouHeader::direct(device),
                Instruction::Write { addr: local },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_bytes(data[off..off + piece_len].to_vec()));
            self.plan.push(PlanOp {
                device,
                gva: piece_gva,
                entry,
                read_off: None,
                len: piece_len,
                pkt,
                reliable: true,
            });
        }
        self.entries.push(EntryKind::Write);
        OpHandle(entry)
    }

    /// Queue a compare-and-swap of the u64 at `gva`.
    pub fn cas(
        &mut self,
        cl: &mut Cluster,
        gva: u64,
        expected: u64,
        new: u64,
    ) -> Result<OpHandle, MemError> {
        let block = self.client.map.block_bytes();
        if gva % block + 8 > block {
            return Err(MemError::Plan(format!(
                "cas at gva {gva:#x} straddles an interleave block"
            )));
        }
        let (device, local) = self.client.map.translate(gva);
        let seq = cl.alloc_seq(self.client.host);
        let pkt = Packet::new(
            self.client.host_ip,
            seq,
            SrouHeader::direct(device),
            Instruction::Cas {
                addr: local,
                expected,
                new,
            },
        )
        .with_flags(Flags(Flags::RELIABLE));
        let entry = self.entries.len();
        self.plan.push(PlanOp {
            device,
            gva,
            entry,
            read_off: None,
            len: 8,
            pkt,
            reliable: true,
        });
        self.entries.push(EntryKind::Cas { seq });
        Ok(OpHandle(entry))
    }

    /// Queue one near-memory gather bag (see [`MemClient::gather_sum`]).
    /// Multiple bags in one batch pipeline across the pool — each bag is
    /// one self-routing program, windowed on its result device.
    pub fn gather_sum(
        &mut self,
        cl: &mut Cluster,
        rows: &[u64],
        row_bytes: usize,
        dst_gva: u64,
    ) -> Result<OpHandle, MemError> {
        let entry = self.entries.len();
        let op = self.client.plan_gather(cl, rows, row_bytes, dst_gva, entry)?;
        self.plan.push(op);
        self.entries.push(EntryKind::Gather);
        Ok(OpHandle(entry))
    }

    /// Packets queued so far (diagnostics).
    pub fn len(&self) -> usize {
        self.plan.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plan.is_empty()
    }

    /// Compile the queued ops into an engine-ready plan: per-device
    /// window slots, pace charges, and the redemption bookkeeping. The
    /// plan is self-contained — submit it standalone ([`Self::run`] does)
    /// or onto a fabric's shared session
    /// ([`crate::comm::Fabric::submit_mem`]).
    pub fn prepare(self) -> PreparedMemPlan {
        let client = self.client;
        let total = self.plan.len();
        let mut cas_of_seq: HashMap<u64, usize> = HashMap::new();
        for (i, e) in self.entries.iter().enumerate() {
            if let EntryKind::Cas { seq } = e {
                cas_of_seq.insert(*seq, i);
            }
        }
        // Per-device window slots; remember read placement per sequence.
        let mut slots: Vec<DeviceIp> = Vec::new();
        let mut read_of_seq: HashMap<u64, (usize, usize, usize)> = HashMap::new();
        let mut plan_seqs: HashSet<u64> = HashSet::with_capacity(total);
        let mut wops = Vec::with_capacity(total);
        for op in self.plan {
            let slot = match slots.iter().position(|&d| d == op.device) {
                Some(i) => i,
                None => {
                    slots.push(op.device);
                    slots.len() - 1
                }
            };
            if let Some(off) = op.read_off {
                read_of_seq.insert(op.pkt.seq, (op.entry, off, op.len));
            }
            plan_seqs.insert(op.pkt.seq);
            // Pace on the bytes the op moves: a READ's request is tiny
            // but its response carries `len` — that is what the §2.5
            // pull-back rate limit must meter. Unpaced plans skip the
            // per-op header encode wire_bytes() costs.
            let pace_bytes = if client.pace.is_some() {
                op.len.max(op.pkt.wire_bytes())
            } else {
                0
            };
            wops.push(WindowedOp {
                slot,
                origin: client.host,
                key: CompletionKey::Seq(op.pkt.seq),
                tag: op.gva,
                reliable: op.reliable,
                pace_bytes,
                pkt: op.pkt,
            });
        }
        PreparedMemPlan {
            host: client.host,
            total,
            window: client.window,
            pace: client.pace,
            entries: self.entries,
            wops,
            read_of_seq,
            cas_of_seq,
            plan_seqs,
        }
    }

    /// Drive every queued op to completion through the window engine.
    pub fn run(
        self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
    ) -> Result<BatchResult, MemError> {
        let window = self.client.window;
        let pace = self.client.pace;
        let mut prepared = self.prepare();
        if prepared.is_empty() {
            return prepared.redeem(cl, 0, None, &[]);
        }
        // Record completions only when something consumes them (CAS
        // outcomes); read data arrives via the mailbox packets instead.
        let mut engine =
            WindowEngine::new(window).record_responses(prepared.wants_responses());
        if let Some(p) = pace {
            engine = engine.paced(TokenBucket::new(p.gbps, p.burst));
        }
        let ops = prepared.take_ops();
        let out = engine
            .run(cl, eng, ops)
            .map_err(|e| MemError::Plan(e.to_string()))?;
        prepared.redeem(cl, out.done, out.nak.as_ref(), &out.responses)
    }
}

/// Results of a [`MemBatch`] run, redeemed by [`OpHandle`]. `Eq` so the
/// sharded-core determinism tests can compare whole batch outcomes.
#[derive(Debug, PartialEq, Eq)]
pub struct BatchResult {
    reads: Vec<Option<Vec<u8>>>,
    cas: HashMap<usize, (u64, bool)>,
}

impl BatchResult {
    /// Take a read's reassembled bytes (once). `None` for non-read
    /// handles or a second take.
    pub fn take_read(&mut self, h: OpHandle) -> Option<Vec<u8>> {
        self.reads.get_mut(h.0)?.take()
    }

    /// A CAS op's `(old_value, swapped)` outcome.
    pub fn cas_outcome(&self, h: OpHandle) -> Option<(u64, bool)> {
        self.cas.get(&h.0).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{LinkConfig, Topology};
    use crate::pool::SdnController;
    use crate::transport::ReliabilityTable;
    use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};

    /// 4 pool devices + 1 client host, controller programming the fabric.
    fn world() -> (Cluster, MemClient, SdnController, Vec<crate::net::NodeId>) {
        let t = Topology::star(0x3E3, 4, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map.clone(), 1 << 20);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let client = MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, map);
        (cl, client, ctl, t.devices)
    }

    #[test]
    fn pooled_write_read_round_trip() {
        let (mut cl, client, mut ctl, devices) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 64 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let data: Vec<u8> = (0..64 << 10).map(|i| (i * 31 % 251) as u8).collect();
        client.write(&mut cl, &mut eng, a.gva, &data).unwrap();
        let back = client.read(&mut cl, &mut eng, a.gva, data.len()).unwrap();
        assert_eq!(back, data, "reassembled in GVA order");
        // The plan genuinely scattered: every device holds some of it and
        // runs a programmed (non-identity) IOMMU.
        for &d in &devices {
            assert!(cl.device(d).pkts_in > 0);
            assert_eq!(cl.device(d).iommu_naks, 0);
        }
        // Offsets into the middle work too.
        let mid = client.read(&mut cl, &mut eng, a.gva + 12_000, 20_000).unwrap();
        assert_eq!(mid[..], data[12_000..32_000]);
    }

    #[test]
    fn out_of_lease_read_naks() {
        let (mut cl, client, mut ctl, devices) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 16 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        // Far past the lease: unmapped on the device.
        let err = client
            .read(&mut cl, &mut eng, a.gva + (1 << 19), 64)
            .unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::Unmapped, .. }),
            "{err:?}"
        );
        let naks: u64 = devices.iter().map(|&d| cl.device(d).iommu_naks).sum();
        assert!(naks >= 1, "the denial happened on a device, on the wire");
    }

    #[test]
    fn readonly_lease_rejects_writes_at_the_device() {
        let (mut cl, client, mut ctl, devices) = world();
        let ro = ctl.malloc_mapped(&mut cl, 1, 8192, false).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let err = client
            .write(&mut cl, &mut eng, ro.gva, &[7u8; 64])
            .unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::WriteDenied, .. }),
            "{err:?}"
        );
        // Reads still fine, and memory was never dirtied.
        let back = client.read(&mut cl, &mut eng, ro.gva, 64).unwrap();
        assert_eq!(back, vec![0u8; 64]);
        let naks: u64 = devices.iter().map(|&d| cl.device(d).iommu_naks).sum();
        assert!(naks >= 1);
    }

    #[test]
    fn cas_through_the_pool() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 8192, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let (old, swapped) = client.cas(&mut cl, &mut eng, a.gva, 0, 42).unwrap();
        assert_eq!((old, swapped), (0, true));
        let (old, swapped) = client.cas(&mut cl, &mut eng, a.gva, 0, 43).unwrap();
        assert_eq!((old, swapped), (42, false), "second CAS sees the swap");
    }

    /// The ROADMAP replay-safety regression, end to end on a lossy
    /// fabric: even when the CAS *response* is dropped and the reliable
    /// layer retransmits the request, the winner must still see its
    /// original `swapped=true` — served from the device's (src, seq)
    /// response-dedupe cache, never re-executed.
    #[test]
    fn cas_is_replay_safe_on_a_lossy_fabric() {
        let mut cache_hits = 0u64;
        let mut retransmits = 0u64;
        for seed in 0..24u64 {
            let t = Topology::star(
                0xCA5 ^ seed.wrapping_mul(0x9E37_79B9),
                4,
                1,
                LinkConfig::dc_100g(),
            );
            let mut cl = t.cluster;
            let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
            let mut ctl = SdnController::new(map.clone(), 1 << 20);
            ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
            let a = ctl.malloc_mapped(&mut cl, 1, 8192, true).unwrap();
            let client = MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, map);
            cl.fault.loss_p = 0.25;
            cl.xport = ReliabilityTable::new(20_000, 64);
            let mut eng: Engine<Cluster> = Engine::new();
            let (old, swapped) = client.cas(&mut cl, &mut eng, a.gva, 0, 42).unwrap();
            assert_eq!(
                (old, swapped),
                (0, true),
                "seed {seed}: the CAS winner saw a lie after a retransmit"
            );
            retransmits += cl.xport.retransmits;
            let (dev_ip, _) = client.map().translate(a.gva);
            let node = cl.node_by_ip(dev_ip).unwrap();
            cache_hits += cl.device(node).resp_cache_hits;
        }
        assert!(retransmits > 0, "the sweep never exercised a retransmit");
        assert!(
            cache_hits > 0,
            "the sweep never exercised the response-loss replay path"
        );
    }

    #[test]
    fn gather_sum_reduces_rows_on_device() {
        let (mut cl, client, mut ctl, _) = world();
        // 64 rows of 64 f32 each (two interleave blocks → two devices),
        // plus a result row that lands on a third device.
        let rows = 64usize;
        let row_bytes = 64 * 4;
        let table = ctl
            .malloc_mapped(&mut cl, 1, (rows * row_bytes) as u64, true)
            .unwrap();
        let out = ctl.malloc_mapped(&mut cl, 1, row_bytes as u64, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let mut bytes = Vec::new();
        for r in 0..rows {
            bytes.extend_from_slice(&f32s_to_bytes(&[r as f32; 64]));
        }
        client.write(&mut cl, &mut eng, table.gva, &bytes).unwrap();
        // Rows 3 and 40 live on different devices; the program visits
        // both and writes the sum on a third.
        let picks = [3u64, 40, 62];
        let gvas: Vec<u64> = picks
            .iter()
            .map(|&r| table.gva + r * row_bytes as u64)
            .collect();
        let (d_a, _) = client.map().translate(gvas[0]);
        let (d_b, _) = client.map().translate(gvas[1]);
        let (d_out, _) = client.map().translate(out.gva);
        assert!(d_a != d_b && d_out != d_a && d_out != d_b, "cross-device gather");
        client
            .gather_sum(&mut cl, &mut eng, &gvas, row_bytes, out.gva)
            .unwrap();
        let got = client.read(&mut cl, &mut eng, out.gva, row_bytes).unwrap();
        let lanes = bytes_to_f32s(&got).unwrap();
        assert_eq!(lanes, vec![105.0f32; 64], "3 + 40 + 62 summed near memory");
    }

    #[test]
    fn gather_rejects_overlong_bags() {
        let (mut cl, client, _ctl, _) = world();
        let mut eng: Engine<Cluster> = Engine::new();
        let too_many: Vec<u64> = (0..MAX_PROGRAM_STEPS as u64).map(|i| i * 1024).collect();
        let err = client
            .gather_sum(&mut cl, &mut eng, &too_many, 1024, 0)
            .unwrap_err();
        assert!(matches!(err, MemError::Plan(_)), "{err:?}");
    }

    #[test]
    fn freed_lease_faults_unmapped() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 16 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        client.write(&mut cl, &mut eng, a.gva, &[1u8; 128]).unwrap();
        ctl.free_mapped(&mut cl, 1, a.gva).unwrap();
        let err = client.read(&mut cl, &mut eng, a.gva, 128).unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::Unmapped, .. }),
            "{err:?}"
        );
    }

    #[test]
    fn batch_pipelines_reads_writes_and_cas() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 64 << 10, true).unwrap();
        let b = ctl.malloc_mapped(&mut cl, 1, 8192, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let data: Vec<u8> = (0..64 << 10).map(|i| (i * 13 % 251) as u8).collect();
        client.write(&mut cl, &mut eng, a.gva, &data).unwrap();
        // One windowed run carrying two reads, a CAS and a write — all
        // in flight together under the per-device windows.
        let mut batch = client.batch();
        let r1 = batch.read(&mut cl, a.gva, 16 << 10);
        let r2 = batch.read(&mut cl, a.gva + (32 << 10), 16 << 10);
        let c1 = batch.cas(&mut cl, b.gva, 0, 99).unwrap();
        let w1 = batch.write(&mut cl, b.gva + 1024, &[5u8; 64]);
        assert!(!batch.is_empty());
        let mut res = batch.run(&mut cl, &mut eng).unwrap();
        assert_eq!(res.take_read(r1).unwrap(), data[..16 << 10]);
        assert_eq!(res.take_read(r2).unwrap(), data[32 << 10..48 << 10]);
        assert_eq!(res.take_read(r1), None, "reads redeem once");
        assert_eq!(res.cas_outcome(c1), Some((0, true)));
        assert_eq!(res.cas_outcome(w1), None, "writes have no CAS outcome");
        // The batched write landed.
        assert_eq!(
            client.read(&mut cl, &mut eng, b.gva + 1024, 64).unwrap(),
            vec![5u8; 64]
        );
    }

    #[test]
    fn batched_multi_bag_gather_pipelines() {
        let (mut cl, client, mut ctl, _) = world();
        let rows = 32usize;
        let row_bytes = 1024usize;
        let table = ctl
            .malloc_mapped(&mut cl, 1, (rows * row_bytes) as u64, true)
            .unwrap();
        let out = ctl
            .malloc_mapped(&mut cl, 1, (4 * row_bytes) as u64, true)
            .unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let mut bytes = Vec::new();
        for r in 0..rows {
            bytes.extend_from_slice(&f32s_to_bytes(&[r as f32; 256]));
        }
        client.write(&mut cl, &mut eng, table.gva, &bytes).unwrap();
        // Four bags in one batch — the old API ran one program per call.
        let bags: [[u64; 2]; 4] = [[1, 2], [3, 8], [9, 21], [5, 30]];
        let mut batch = client.batch();
        for (b, bag) in bags.iter().enumerate() {
            let gvas: Vec<u64> = bag
                .iter()
                .map(|&r| table.gva + r * row_bytes as u64)
                .collect();
            batch
                .gather_sum(&mut cl, &gvas, row_bytes, out.gva + (b * row_bytes) as u64)
                .unwrap();
        }
        assert_eq!(batch.len(), 4, "one program packet per bag");
        batch.run(&mut cl, &mut eng).unwrap();
        let got = client
            .read(&mut cl, &mut eng, out.gva, 4 * row_bytes)
            .unwrap();
        for (b, bag) in bags.iter().enumerate() {
            let want = (bag[0] + bag[1]) as f32;
            let lanes = bytes_to_f32s(&got[b * row_bytes..(b + 1) * row_bytes]).unwrap();
            assert_eq!(lanes, vec![want; 256], "bag {b}");
        }
    }

    #[test]
    fn paced_reads_throttle_to_the_token_rate() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 64 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let data = vec![0xA5u8; 64 << 10];
        client.write(&mut cl, &mut eng, a.gva, &data).unwrap();
        let t0 = eng.now();
        assert_eq!(client.read(&mut cl, &mut eng, a.gva, data.len()).unwrap(), data);
        let unpaced_ns = eng.now() - t0;
        // 8 Gbps = 1 B/ns with an 8 KiB burst: 64 KiB must take at least
        // (64 - 8) KiB worth of refill time.
        let paced = MemClient::new(client.host, DeviceIp::lan(101), 1, client.map().clone())
            .with_pace(8.0, 8 << 10);
        let t0 = eng.now();
        assert_eq!(paced.read(&mut cl, &mut eng, a.gva, data.len()).unwrap(), data);
        let paced_ns = eng.now() - t0;
        assert!(
            paced_ns >= (56 << 10) as u64,
            "paced read finished in {paced_ns} ns — faster than the bucket allows"
        );
        assert!(paced_ns > unpaced_ns, "pacing must actually throttle");
    }
}
