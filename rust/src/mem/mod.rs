//! The pooled-memory data plane: GVA-addressed scatter-gather I/O.
//!
//! [`MemClient`] is the host-side half of the paper's §2.5/§2.6 memory
//! pool. A client holds a tenant identity and the pool's
//! [`InterleaveMap`]; reads, writes and CAS are issued against **global
//! virtual addresses** and compiled into scatter-gather packet plans over
//! the per-device extents — one self-clocked in-flight window per device
//! (reusing the transport's timeout-retransmit reliability), completions
//! matched by sequence number and read data reassembled in GVA order.
//!
//! Access control is *not* checked here: the plan is sent as-is and the
//! device IOMMUs — programmed by the SDN controller
//! ([`crate::pool::SdnController::malloc_mapped`]) — enforce the lease.
//! A denied translation comes back as a wire-level `Nack` whose reason
//! byte surfaces as a typed [`MemError::Nak`].
//!
//! [`MemClient::gather_sum`] is the TensorDIMM-style near-memory gather:
//! a sparse set of GVA rows is folded with on-device `Simd` adds by one
//! self-routing packet [`crate::isa::Program`], and only the pooled
//! result row crosses the host link.

use std::cell::RefCell;
use std::collections::{HashMap, HashSet, VecDeque};
use std::fmt;
use std::rc::Rc;

use crate::iommu::NakReason;
use crate::isa::registry::MemAccess;
use crate::isa::{Flags, Instruction, ProgramBuilder, SimdOp, VerifyEnv, MAX_PROGRAM_STEPS};
use crate::net::{Cluster, InjectCmd, NodeId};
use crate::pool::{InterleaveMap, TenantId};
use crate::sim::Engine;
use crate::wire::packet::MAX_PAYLOAD;
use crate::wire::{DeviceIp, Packet, Payload, Segment, SrouHeader};

/// Typed failure of a pooled-memory operation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    /// A device IOMMU rejected the access and NAK'd it on the wire.
    Nak {
        device: DeviceIp,
        gva: u64,
        reason: NakReason,
    },
    /// Not every op completed (loss beyond the retransmit budget).
    Incomplete { done: usize, total: usize },
    /// The plan could not be compiled (bad shape, verifier rejection).
    Plan(String),
    /// A response arrived without the expected content.
    BadResponse { gva: u64 },
}

impl fmt::Display for MemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemError::Nak {
                device,
                gva,
                reason,
            } => write!(
                f,
                "device {device} NAK'd access at gva {gva:#x}: {reason}"
            ),
            MemError::Incomplete { done, total } => {
                write!(f, "pooled op incomplete: {done}/{total} completions")
            }
            MemError::Plan(msg) => write!(f, "plan rejected: {msg}"),
            MemError::BadResponse { gva } => {
                write!(f, "malformed response for gva {gva:#x}")
            }
        }
    }
}

impl std::error::Error for MemError {}

/// One planned packet of a scatter-gather operation.
struct PlanOp {
    device: DeviceIp,
    gva: u64,
    /// For reads: destination offset in the reassembly buffer.
    read_off: Option<usize>,
    len: usize,
    pkt: Packet,
    reliable: bool,
}

/// Per-device pending queue entry.
struct Pending {
    seq: u64,
    gva: u64,
    pkt: Packet,
    reliable: bool,
}

/// Windowing state shared with the completion hook.
struct Shared {
    queues: Vec<VecDeque<Pending>>,
    /// seq → (device slot, gva) of the in-flight op.
    inflight: HashMap<u64, (usize, u64)>,
    done: usize,
    cas: Option<(u64, bool)>,
    nak: Option<(DeviceIp, u64, u8)>,
}

#[derive(Default)]
struct RunOut {
    data: Vec<u8>,
    cas: Option<(u64, bool)>,
}

/// A tenant's handle onto the pooled-memory data plane.
pub struct MemClient {
    /// Host node injecting the plans (its mailbox collects responses).
    host: NodeId,
    host_ip: DeviceIp,
    /// The tenant this client acts for (device-side enforcement keys on
    /// the *source IP* binding the controller installed, not this field —
    /// it documents intent and labels errors).
    pub tenant: TenantId,
    map: InterleaveMap,
    /// In-flight window per device.
    window: usize,
}

impl MemClient {
    pub fn new(host: NodeId, host_ip: DeviceIp, tenant: TenantId, map: InterleaveMap) -> Self {
        Self {
            host,
            host_ip,
            tenant,
            map,
            window: 4,
        }
    }

    /// Override the per-device in-flight window (default 4).
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    pub fn map(&self) -> &InterleaveMap {
        &self.map
    }

    // ------------------------------------------------------- public ops

    /// Read `len` bytes at `gva`, scatter-gathered across the pool and
    /// reassembled in GVA order.
    pub fn read(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        len: usize,
    ) -> Result<Vec<u8>, MemError> {
        let mut ops = Vec::new();
        for (piece_gva, off, piece_len) in self.pieces(gva, len) {
            let (device, local) = self.map.translate(piece_gva);
            let seq = cl.alloc_seq(self.host);
            let pkt = Packet::new(
                self.host_ip,
                seq,
                SrouHeader::direct(device),
                Instruction::Read {
                    addr: local,
                    len: piece_len as u32,
                },
            );
            ops.push(PlanOp {
                device,
                gva: piece_gva,
                read_off: Some(off),
                len: piece_len,
                pkt,
                reliable: true,
            });
        }
        let out = self.run_plan(cl, eng, ops, len)?;
        Ok(out.data)
    }

    /// Write `data` at `gva`, sprayed over the interleaved extents with
    /// one reliable in-flight window per device.
    pub fn write(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        data: &[u8],
    ) -> Result<(), MemError> {
        let mut ops = Vec::new();
        for (piece_gva, off, piece_len) in self.pieces(gva, data.len()) {
            let (device, local) = self.map.translate(piece_gva);
            let seq = cl.alloc_seq(self.host);
            let pkt = Packet::new(
                self.host_ip,
                seq,
                SrouHeader::direct(device),
                Instruction::Write { addr: local },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_bytes(data[off..off + piece_len].to_vec()));
            ops.push(PlanOp {
                device,
                gva: piece_gva,
                read_off: None,
                len: piece_len,
                pkt,
                reliable: true,
            });
        }
        self.run_plan(cl, eng, ops, 0)?;
        Ok(())
    }

    /// Compare-and-swap the u64 at `gva` (must not straddle an interleave
    /// block). Returns `(old_value, swapped)`.
    ///
    /// Caveat (lossy fabrics): if the *response* is lost, the reliable
    /// retransmit re-executes the CAS on the device; a caller whose first
    /// attempt actually won then sees `(new, false)` and believes it lost.
    /// The pool paths in this crate run lossless; a replay-safe CAS needs
    /// a device-side dedupe keyed on sequence number (ROADMAP).
    pub fn cas(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        gva: u64,
        expected: u64,
        new: u64,
    ) -> Result<(u64, bool), MemError> {
        let block = self.map.block_bytes();
        if gva % block + 8 > block {
            return Err(MemError::Plan(format!(
                "cas at gva {gva:#x} straddles an interleave block"
            )));
        }
        let (device, local) = self.map.translate(gva);
        let seq = cl.alloc_seq(self.host);
        let pkt = Packet::new(
            self.host_ip,
            seq,
            SrouHeader::direct(device),
            Instruction::Cas {
                addr: local,
                expected,
                new,
            },
        );
        // CAS with expected == new is not idempotent (§3.1): send it
        // unreliably rather than risk a duplicated swap.
        let reliable = expected != new;
        let ops = vec![PlanOp {
            device,
            gva,
            read_off: None,
            len: 8,
            pkt,
            reliable,
        }];
        let out = self.run_plan(cl, eng, ops, 0)?;
        out.cas.ok_or(MemError::BadResponse { gva })
    }

    /// TensorDIMM-style near-memory gather: fold the `rows` (each
    /// `row_bytes` long, fully inside one interleave block) into a zero
    /// accumulator with on-device `Simd` adds — one self-routing packet
    /// program visiting each row's device — and write the pooled sum at
    /// `dst_gva`. Only the result row ever crosses the host link.
    pub fn gather_sum(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        rows: &[u64],
        row_bytes: usize,
        dst_gva: u64,
    ) -> Result<(), MemError> {
        if rows.is_empty() || rows.len() + 1 > MAX_PROGRAM_STEPS {
            return Err(MemError::Plan(format!(
                "gather of {} rows outside 1..={} (program step budget)",
                rows.len(),
                MAX_PROGRAM_STEPS - 1
            )));
        }
        let block = self.map.block_bytes();
        let mut b = ProgramBuilder::new();
        let mut segs = Vec::with_capacity(rows.len() + 1);
        for &row in rows.iter().chain(std::iter::once(&dst_gva)) {
            if row % block + row_bytes as u64 > block {
                return Err(MemError::Plan(format!(
                    "row at gva {row:#x} straddles an interleave block"
                )));
            }
        }
        for &row in rows {
            let (device, local) = self.map.translate(row);
            b = b.hop(Instruction::Simd {
                op: SimdOp::Add,
                addr: local,
            });
            segs.push(Segment::to(device));
        }
        let (dst_dev, dst_local) = self.map.translate(dst_gva);
        b = b.hop(Instruction::Write { addr: dst_local });
        segs.push(Segment::to(dst_dev));
        let capacity = cl
            .node_by_ip(dst_dev)
            .map(|n| cl.device(n).mem_ref().capacity())
            .unwrap_or(u64::MAX);
        let env = VerifyEnv {
            capacity,
            payload_len: row_bytes,
            ordered: false,
            lossless: false, // conservative: require idempotent steps
            srou_hops: segs.len(),
            registry: Some(cl.registry.as_ref()),
        };
        let prog = b.build(&env).map_err(|e| MemError::Plan(e.to_string()))?;
        let seq = cl.alloc_seq(self.host);
        let pkt = Packet::new(
            self.host_ip,
            seq,
            SrouHeader::through(segs),
            Instruction::Program(Box::new(prog)),
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_bytes(vec![0u8; row_bytes]));
        let ops = vec![PlanOp {
            device: dst_dev,
            gva: dst_gva,
            read_off: None,
            len: row_bytes,
            pkt,
            reliable: true,
        }];
        self.run_plan(cl, eng, ops, 0)?;
        Ok(())
    }

    // --------------------------------------------------- plan execution

    /// Split `[gva, gva+len)` along interleave blocks and the payload MTU
    /// into `(piece_gva, range_off, piece_len)` triples, in GVA order.
    fn pieces(&self, gva: u64, len: usize) -> Vec<(u64, usize, usize)> {
        let mut out = Vec::new();
        for e in self.map.scatter(gva, len as u64) {
            let mut off = 0u64;
            while off < e.len {
                let piece = (e.len - off).min(MAX_PAYLOAD as u64) as usize;
                out.push((
                    gva + e.range_off + off,
                    (e.range_off + off) as usize,
                    piece,
                ));
                off += piece as u64;
            }
        }
        out
    }

    /// Drive a compiled plan to completion: per-device windows, reliable
    /// injection, completion-hook refill, NAK detection, and (for reads)
    /// GVA-order reassembly of `read_len` bytes.
    fn run_plan(
        &self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        ops: Vec<PlanOp>,
        read_len: usize,
    ) -> Result<RunOut, MemError> {
        let total = ops.len();
        if total == 0 {
            return Ok(RunOut::default());
        }
        // Group ops into per-device slots and remember read placement.
        let mut slots: Vec<DeviceIp> = Vec::new();
        let mut queues: Vec<VecDeque<Pending>> = Vec::new();
        let mut read_of_seq: HashMap<u64, (usize, usize)> = HashMap::new();
        let mut plan_seqs: HashSet<u64> = HashSet::with_capacity(total);
        for op in ops {
            let slot = match slots.iter().position(|&d| d == op.device) {
                Some(i) => i,
                None => {
                    slots.push(op.device);
                    queues.push(VecDeque::new());
                    slots.len() - 1
                }
            };
            if let Some(off) = op.read_off {
                read_of_seq.insert(op.pkt.seq, (off, op.len));
            }
            plan_seqs.insert(op.pkt.seq);
            queues[slot].push_back(Pending {
                seq: op.pkt.seq,
                gva: op.gva,
                pkt: op.pkt,
                reliable: op.reliable,
            });
        }
        let shared = Rc::new(RefCell::new(Shared {
            queues,
            inflight: HashMap::with_capacity(total),
            done: 0,
            cas: None,
            nak: None,
        }));
        // Completion hook: one refill per retired op, per-device window.
        let hook_state = Rc::clone(&shared);
        let host = self.host;
        cl.on_completion = Some(Box::new(move |rec| {
            if rec.node != host {
                return Vec::new();
            }
            let mut s = hook_state.borrow_mut();
            let Some((slot, gva)) = s.inflight.remove(&rec.seq) else {
                return Vec::new(); // foreign or duplicate completion
            };
            match &rec.instr {
                Instruction::Nack { reason, .. } => {
                    if s.nak.is_none() {
                        s.nak = Some((rec.from, gva, *reason));
                    }
                }
                Instruction::CasResp { old, swapped, .. } => {
                    s.cas = Some((*old, *swapped));
                }
                _ => {}
            }
            s.done += 1;
            if let Some(p) = s.queues[slot].pop_front() {
                s.inflight.insert(p.seq, (slot, p.gva));
                return vec![InjectCmd {
                    origin: host,
                    pkt: p.pkt,
                    reliable: p.reliable,
                }];
            }
            Vec::new()
        }));
        // Kick the initial per-device windows.
        let mut kicks = Vec::new();
        {
            let mut s = shared.borrow_mut();
            for slot in 0..s.queues.len() {
                for _ in 0..self.window {
                    match s.queues[slot].pop_front() {
                        Some(p) => {
                            s.inflight.insert(p.seq, (slot, p.gva));
                            kicks.push(InjectCmd {
                                origin: host,
                                pkt: p.pkt,
                                reliable: p.reliable,
                            });
                        }
                        None => break,
                    }
                }
            }
        }
        for cmd in kicks {
            cl.inject_cmd(eng, cmd);
        }
        eng.run(cl);
        cl.on_completion = None;
        let s = Rc::try_unwrap(shared)
            .ok()
            .expect("completion hook released")
            .into_inner();
        // Drain only *this plan's* responses from the host mailbox —
        // other traffic the app may be exchanging on the same host node
        // survives — before any early error return.
        let mailbox = std::mem::take(&mut cl.host_mut(self.host).mailbox);
        let (ours, theirs): (Vec<_>, Vec<_>) = mailbox
            .into_iter()
            .partition(|(_, pkt)| plan_seqs.contains(&pkt.seq));
        cl.host_mut(self.host).mailbox = theirs;
        if let Some((device, gva, reason)) = s.nak {
            return Err(MemError::Nak {
                device,
                gva,
                reason: NakReason::from_u8(reason),
            });
        }
        if s.done < total {
            return Err(MemError::Incomplete {
                done: s.done,
                total,
            });
        }
        // Reassemble read data in GVA order.
        let mut data = vec![0u8; read_len];
        for (_, pkt) in ours {
            if !matches!(pkt.instr, Instruction::ReadResp { .. }) {
                continue;
            }
            let Some(&(off, len)) = read_of_seq.get(&pkt.seq) else {
                continue;
            };
            if let Some(bytes) = pkt.payload.bytes() {
                let n = bytes.len().min(len).min(data.len().saturating_sub(off));
                data[off..off + n].copy_from_slice(&bytes[..n]);
            }
            // Phantom payloads (timing-only devices) leave zeros.
        }
        Ok(RunOut { data, cas: s.cas })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{LinkConfig, Topology};
    use crate::pool::SdnController;
    use crate::util::bytes::{bytes_to_f32s, f32s_to_bytes};

    /// 4 pool devices + 1 client host, controller programming the fabric.
    fn world() -> (Cluster, MemClient, SdnController, Vec<crate::net::NodeId>) {
        let t = Topology::star(0x3E3, 4, 1, LinkConfig::dc_100g());
        let mut cl = t.cluster;
        let map = InterleaveMap::paper_default((1..=4).map(DeviceIp::lan).collect());
        let mut ctl = SdnController::new(map.clone(), 1 << 20);
        ctl.grant_host(&mut cl, 1, DeviceIp::lan(101));
        let client = MemClient::new(t.hosts[0], DeviceIp::lan(101), 1, map);
        (cl, client, ctl, t.devices)
    }

    #[test]
    fn pooled_write_read_round_trip() {
        let (mut cl, client, mut ctl, devices) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 64 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let data: Vec<u8> = (0..64 << 10).map(|i| (i * 31 % 251) as u8).collect();
        client.write(&mut cl, &mut eng, a.gva, &data).unwrap();
        let back = client.read(&mut cl, &mut eng, a.gva, data.len()).unwrap();
        assert_eq!(back, data, "reassembled in GVA order");
        // The plan genuinely scattered: every device holds some of it and
        // runs a programmed (non-identity) IOMMU.
        for &d in &devices {
            assert!(cl.device(d).pkts_in > 0);
            assert_eq!(cl.device(d).iommu_naks, 0);
        }
        // Offsets into the middle work too.
        let mid = client.read(&mut cl, &mut eng, a.gva + 12_000, 20_000).unwrap();
        assert_eq!(mid[..], data[12_000..32_000]);
    }

    #[test]
    fn out_of_lease_read_naks() {
        let (mut cl, client, mut ctl, devices) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 16 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        // Far past the lease: unmapped on the device.
        let err = client
            .read(&mut cl, &mut eng, a.gva + (1 << 19), 64)
            .unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::Unmapped, .. }),
            "{err:?}"
        );
        let naks: u64 = devices.iter().map(|&d| cl.device(d).iommu_naks).sum();
        assert!(naks >= 1, "the denial happened on a device, on the wire");
    }

    #[test]
    fn readonly_lease_rejects_writes_at_the_device() {
        let (mut cl, client, mut ctl, devices) = world();
        let ro = ctl.malloc_mapped(&mut cl, 1, 8192, false).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let err = client
            .write(&mut cl, &mut eng, ro.gva, &[7u8; 64])
            .unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::WriteDenied, .. }),
            "{err:?}"
        );
        // Reads still fine, and memory was never dirtied.
        let back = client.read(&mut cl, &mut eng, ro.gva, 64).unwrap();
        assert_eq!(back, vec![0u8; 64]);
        let naks: u64 = devices.iter().map(|&d| cl.device(d).iommu_naks).sum();
        assert!(naks >= 1);
    }

    #[test]
    fn cas_through_the_pool() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 8192, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let (old, swapped) = client.cas(&mut cl, &mut eng, a.gva, 0, 42).unwrap();
        assert_eq!((old, swapped), (0, true));
        let (old, swapped) = client.cas(&mut cl, &mut eng, a.gva, 0, 43).unwrap();
        assert_eq!((old, swapped), (42, false), "second CAS sees the swap");
    }

    #[test]
    fn gather_sum_reduces_rows_on_device() {
        let (mut cl, client, mut ctl, _) = world();
        // 64 rows of 64 f32 each (two interleave blocks → two devices),
        // plus a result row that lands on a third device.
        let rows = 64usize;
        let row_bytes = 64 * 4;
        let table = ctl
            .malloc_mapped(&mut cl, 1, (rows * row_bytes) as u64, true)
            .unwrap();
        let out = ctl.malloc_mapped(&mut cl, 1, row_bytes as u64, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        let mut bytes = Vec::new();
        for r in 0..rows {
            bytes.extend_from_slice(&f32s_to_bytes(&[r as f32; 64]));
        }
        client.write(&mut cl, &mut eng, table.gva, &bytes).unwrap();
        // Rows 3 and 40 live on different devices; the program visits
        // both and writes the sum on a third.
        let picks = [3u64, 40, 62];
        let gvas: Vec<u64> = picks
            .iter()
            .map(|&r| table.gva + r * row_bytes as u64)
            .collect();
        let (d_a, _) = client.map().translate(gvas[0]);
        let (d_b, _) = client.map().translate(gvas[1]);
        let (d_out, _) = client.map().translate(out.gva);
        assert!(d_a != d_b && d_out != d_a && d_out != d_b, "cross-device gather");
        client
            .gather_sum(&mut cl, &mut eng, &gvas, row_bytes, out.gva)
            .unwrap();
        let got = client.read(&mut cl, &mut eng, out.gva, row_bytes).unwrap();
        let lanes = bytes_to_f32s(&got).unwrap();
        assert_eq!(lanes, vec![105.0f32; 64], "3 + 40 + 62 summed near memory");
    }

    #[test]
    fn gather_rejects_overlong_bags() {
        let (mut cl, client, _ctl, _) = world();
        let mut eng: Engine<Cluster> = Engine::new();
        let too_many: Vec<u64> = (0..MAX_PROGRAM_STEPS as u64).map(|i| i * 1024).collect();
        let err = client
            .gather_sum(&mut cl, &mut eng, &too_many, 1024, 0)
            .unwrap_err();
        assert!(matches!(err, MemError::Plan(_)), "{err:?}");
    }

    #[test]
    fn freed_lease_faults_unmapped() {
        let (mut cl, client, mut ctl, _) = world();
        let a = ctl.malloc_mapped(&mut cl, 1, 16 << 10, true).unwrap();
        let mut eng: Engine<Cluster> = Engine::new();
        client.write(&mut cl, &mut eng, a.gva, &[1u8; 128]).unwrap();
        ctl.free_mapped(&mut cl, 1, a.gva).unwrap();
        let err = client.read(&mut cl, &mut eng, a.gva, 128).unwrap_err();
        assert!(
            matches!(err, MemError::Nak { reason: NakReason::Unmapped, .. }),
            "{err:?}"
        );
    }
}
