//! Experiment configuration: a small TOML-subset parser plus typed config
//! structs. (`serde`/`toml` are unavailable in this offline build; the
//! subset — `[section]`, `key = value` with string/int/float/bool values
//! and `#` comments — covers every config the launcher needs.)

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
        }
    }
}

/// `section.key -> value` map with typed accessors.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse the TOML subset from a string.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            if key.is_empty() || key.ends_with('.') {
                bail!("line {}: empty key", lineno + 1);
            }
            let val = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value {v:?}", lineno + 1))?;
            if values.insert(key.clone(), val).is_some() {
                bail!("line {}: duplicate key {key}", lineno + 1);
            }
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Overlay `key=value` overrides (CLI `--set section.key=value`).
    pub fn set(&mut self, key: &str, raw: &str) -> Result<()> {
        self.values.insert(key.to_string(), parse_value(raw)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        match self.values.get(key) {
            Some(Value::Str(s)) => s.clone(),
            Some(v) => v.to_string(),
            None => default.to_string(),
        }
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        match self.values.get(key) {
            Some(Value::Int(i)) if *i >= 0 => *i as u64,
            _ => default,
        }
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.u64(key, default as u64) as usize
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        match self.values.get(key) {
            Some(Value::Float(x)) => *x,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.values.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.is_empty() {
        bail!("empty value");
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let cleaned = s.replace('_', "");
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = cleaned.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    // Bare word → string (friendlier for enum-ish settings).
    if s.chars().all(|c| c.is_alphanumeric() || "-_./:".contains(c)) {
        return Ok(Value::Str(s.to_string()));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
[cluster]
devices = 4
link_gbps = 100.0
topology = "star"
timing_only = false
[workload]
elements = 536_870_912   # paper scale
name = ring-allreduce
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.u64("seed", 0), 42);
        assert_eq!(c.usize("cluster.devices", 0), 4);
        assert_eq!(c.f64("cluster.link_gbps", 0.0), 100.0);
        assert_eq!(c.str("cluster.topology", ""), "star");
        assert!(!c.bool("cluster.timing_only", true));
        assert_eq!(c.u64("workload.elements", 0), 536_870_912);
        assert_eq!(c.str("workload.name", ""), "ring-allreduce");
    }

    #[test]
    fn defaults_apply_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.u64("nope", 7), 7);
        assert_eq!(c.str("nope", "x"), "x");
    }

    #[test]
    fn cli_override_wins() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set("cluster.devices", "8").unwrap();
        assert_eq!(c.usize("cluster.devices", 0), 8);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = Config::parse("k = \"a#b\"").unwrap();
        assert_eq!(c.str("k", ""), "a#b");
    }

    #[test]
    fn errors_are_informative() {
        assert!(Config::parse("[unterminated").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("k = 1\nk = 2").is_err());
        assert!(Config::parse("k = \"open").is_err());
    }
}
