//! A hashed hierarchical timer wheel with generation-stamped slots.
//!
//! Retransmit timers dominate the DES's schedule/cancel churn: almost
//! every reliable packet arms a timeout that is cancelled moments later
//! when the completion arrives. A binary heap makes that pattern O(log n)
//! to arm and — worse — forces cancellation to be *lazy* (tombstone sets
//! that grow with traffic). The wheel makes both O(1):
//!
//! * **Arm** picks a level by distance (64 slots per level, 6 bits each,
//!   [`TICK_SHIFT`]-ns base ticks) and pushes the timer onto an intrusive
//!   doubly-linked bucket list inside a slab — no allocation once the
//!   slab has warmed up (freed slots are recycled through a freelist).
//! * **Cancel** is an exact unlink by [`TimerId`]: the slab slot's
//!   generation counter is bumped on every free, so a stale id (the
//!   timer already fired, or was cancelled before) simply misses. No
//!   tombstones, no drift between heap size and live-event count.
//! * **No cascading.** Classic wheels migrate entries downward as the
//!   clock turns. Here the owning [`super::Engine`] never advances time
//!   *past* a live timer (it always executes the globally earliest
//!   event), so an entry's distance to `cur_tick` only shrinks and its
//!   original (level, slot) placement stays valid for its whole life.
//!   `peek` exploits the same invariant: at each level the earliest
//!   occupied slot in rotation order from the current cursor holds that
//!   level's minimum, found with one `rotate_right` + `trailing_zeros`
//!   on the occupancy bitmap.
//!
//! Determinism: the wheel stores the caller-provided `(time, seq)` key
//! and `peek`/`pop_min` select the exact minimum of that pair, so merged
//! heap-vs-wheel event ordering is identical to a single heap ordered by
//! `(time, seq)`.

use super::time::SimTime;

/// log2 of the base tick in nanoseconds (1024 ns). Retransmit timeouts
/// are tens of microseconds to milliseconds, which lands them on levels
/// 0–2; level 3 covers ~17 s and a spillover list handles the rest.
pub const TICK_SHIFT: u32 = 10;

const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS;
const LEVELS: usize = 4;
const NIL: u32 = u32::MAX;
/// Bucket code for the overflow list (anything ≥ 64^4 ticks out).
const OVERFLOW: u16 = (LEVELS * SLOTS) as u16;

/// Handle to an armed timer. Cancellation by a stale id (already fired
/// or already cancelled) is a detectable no-op thanks to the generation
/// stamp.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TimerId {
    idx: u32,
    gen: u32,
}

/// One slab slot: an intrusive doubly-linked list node. Freed slots are
/// chained through `next` into a freelist and their `gen` is bumped.
struct Slot<T> {
    gen: u32,
    prev: u32,
    next: u32,
    /// `level * 64 + slot`, or [`OVERFLOW`].
    bucket: u16,
    time: SimTime,
    seq: u64,
    ev: Option<T>,
}

/// The wheel itself. Generic over the event payload so the engine can
/// store typed world events directly.
pub struct TimerWheel<T> {
    slab: Vec<Slot<T>>,
    /// Freelist head (chained through `Slot::next`).
    free: u32,
    heads: [[u32; SLOTS]; LEVELS],
    /// Per-level occupancy bitmaps (bit = slot has entries).
    occ: [u64; LEVELS],
    overflow_head: u32,
    cur_tick: u64,
    /// Memoized minimum `(time, seq, slab idx)`; invalidated when that
    /// entry is removed.
    cached_min: Option<(SimTime, u64, u32)>,
    len: usize,
}

impl<T> Default for TimerWheel<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> TimerWheel<T> {
    pub fn new() -> Self {
        Self {
            slab: Vec::new(),
            free: NIL,
            heads: [[NIL; SLOTS]; LEVELS],
            occ: [0; LEVELS],
            overflow_head: NIL,
            cur_tick: 0,
            cached_min: None,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Advance the wheel clock. The caller guarantees `t` is not past
    /// any live timer's `time` (the engine pops in global key order).
    pub fn advance_to(&mut self, t: SimTime) {
        let tick = t >> TICK_SHIFT;
        if tick > self.cur_tick {
            self.cur_tick = tick;
        }
    }

    /// Arm a timer at `(time, seq)`. O(1): level by distance, intrusive
    /// push onto the bucket. Times in the past fire immediately (tick
    /// clamps to the current cursor), mirroring `schedule_at`'s clamp.
    pub fn arm(&mut self, time: SimTime, seq: u64, ev: T) -> TimerId {
        let tick = (time >> TICK_SHIFT).max(self.cur_tick);
        // Smallest level whose super-tick distance fits in one turn.
        let mut bucket = OVERFLOW;
        for l in 0..LEVELS {
            let shift = SLOT_BITS * l as u32;
            if (tick >> shift) - (self.cur_tick >> shift) < SLOTS as u64 {
                let slot = ((tick >> shift) & (SLOTS as u64 - 1)) as usize;
                bucket = (l * SLOTS + slot) as u16;
                break;
            }
        }
        let idx = self.alloc(time, seq, ev, bucket);
        if bucket == OVERFLOW {
            self.link(idx, NIL, true);
        } else {
            let (l, s) = (bucket as usize / SLOTS, bucket as usize % SLOTS);
            self.link(idx, (l * SLOTS + s) as u32, false);
            self.occ[l] |= 1u64 << s;
        }
        self.len += 1;
        if let Some((bt, bs, _)) = self.cached_min {
            if (time, seq) < (bt, bs) {
                self.cached_min = Some((time, seq, idx));
            }
        } else if self.len == 1 {
            self.cached_min = Some((time, seq, idx));
        }
        TimerId {
            idx,
            gen: self.slab[idx as usize].gen,
        }
    }

    /// Exact O(1) cancel. Returns false for a stale id (already fired or
    /// already cancelled) — nothing is left behind either way.
    pub fn cancel(&mut self, id: TimerId) -> bool {
        match self.slab.get(id.idx as usize) {
            Some(s) if s.gen == id.gen && s.ev.is_some() => {
                self.remove(id.idx);
                true
            }
            _ => false,
        }
    }

    /// Key of the earliest live timer.
    pub fn peek(&mut self) -> Option<(SimTime, u64)> {
        if self.len == 0 {
            return None;
        }
        if let Some((t, s, _)) = self.cached_min {
            return Some((t, s));
        }
        let mut best: Option<(SimTime, u64, u32)> = None;
        for l in 0..LEVELS {
            if self.occ[l] == 0 {
                continue;
            }
            let cursor = ((self.cur_tick >> (SLOT_BITS * l as u32)) & (SLOTS as u64 - 1)) as u32;
            // Earliest occupied slot in rotation order from the cursor;
            // it holds this level's minimum (see module docs).
            let dist = self.occ[l].rotate_right(cursor).trailing_zeros();
            let slot = ((cursor + dist) as usize) % SLOTS;
            self.scan_bucket(self.heads[l][slot], &mut best);
        }
        self.scan_bucket(self.overflow_head, &mut best);
        self.cached_min = best;
        best.map(|(t, s, _)| (t, s))
    }

    /// Pop the earliest live timer.
    pub fn pop_min(&mut self) -> Option<(SimTime, u64, T)> {
        self.peek()?;
        let (time, seq, idx) = self.cached_min.expect("peek filled the cache");
        let ev = self.remove(idx);
        Some((time, seq, ev))
    }

    fn scan_bucket(&self, mut cur: u32, best: &mut Option<(SimTime, u64, u32)>) {
        while cur != NIL {
            let s = &self.slab[cur as usize];
            let better = match *best {
                None => true,
                Some((bt, bs, _)) => (s.time, s.seq) < (bt, bs),
            };
            if better {
                *best = Some((s.time, s.seq, cur));
            }
            cur = s.next;
        }
    }

    fn alloc(&mut self, time: SimTime, seq: u64, ev: T, bucket: u16) -> u32 {
        if self.free != NIL {
            let idx = self.free;
            let s = &mut self.slab[idx as usize];
            self.free = s.next;
            s.prev = NIL;
            s.next = NIL;
            s.bucket = bucket;
            s.time = time;
            s.seq = seq;
            s.ev = Some(ev);
            idx
        } else {
            let idx = self.slab.len() as u32;
            self.slab.push(Slot {
                gen: 0,
                prev: NIL,
                next: NIL,
                bucket,
                time,
                seq,
                ev: Some(ev),
            });
            idx
        }
    }

    /// Link `idx` at the head of its bucket list.
    fn link(&mut self, idx: u32, bucket_code: u32, overflow: bool) {
        let head = if overflow {
            self.overflow_head
        } else {
            let (l, s) = (bucket_code as usize / SLOTS, bucket_code as usize % SLOTS);
            self.heads[l][s]
        };
        self.slab[idx as usize].next = head;
        if head != NIL {
            self.slab[head as usize].prev = idx;
        }
        if overflow {
            self.overflow_head = idx;
        } else {
            let (l, s) = (bucket_code as usize / SLOTS, bucket_code as usize % SLOTS);
            self.heads[l][s] = idx;
        }
    }

    /// Unlink a live entry, bump its generation, recycle the slot.
    fn remove(&mut self, idx: u32) -> T {
        let (prev, next, bucket) = {
            let s = &self.slab[idx as usize];
            (s.prev, s.next, s.bucket)
        };
        if prev != NIL {
            self.slab[prev as usize].next = next;
        }
        if next != NIL {
            self.slab[next as usize].prev = prev;
        }
        if bucket == OVERFLOW {
            if self.overflow_head == idx {
                self.overflow_head = next;
            }
        } else {
            let (l, s) = (bucket as usize / SLOTS, bucket as usize % SLOTS);
            if self.heads[l][s] == idx {
                self.heads[l][s] = next;
            }
            if self.heads[l][s] == NIL {
                self.occ[l] &= !(1u64 << s);
            }
        }
        let s = &mut self.slab[idx as usize];
        s.gen = s.gen.wrapping_add(1);
        s.prev = NIL;
        s.next = self.free;
        self.free = idx;
        let ev = s.ev.take().expect("removing a live timer");
        self.len -= 1;
        if let Some((_, _, i)) = self.cached_min {
            if i == idx {
                self.cached_min = None;
            }
        }
        ev
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny deterministic LCG so the property tests need no RNG dep.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0 >> 11
        }
        fn below(&mut self, n: u64) -> u64 {
            self.next() % n
        }
    }

    #[test]
    fn fires_in_key_order_across_levels() {
        let mut w: TimerWheel<u32> = TimerWheel::new();
        // Deliberately spans level 0 (near), level 1–2 (mid), overflow (far).
        let times: Vec<SimTime> = vec![
            50,
            1_000,
            70_000,
            2_000_000,
            400_000_000,
            30_000_000_000,
            u64::MAX / 2,
        ];
        for (i, &t) in times.iter().enumerate() {
            w.arm(t, i as u64, i as u32);
        }
        let mut fired = Vec::new();
        while let Some((t, _seq, v)) = w.pop_min() {
            w.advance_to(t);
            fired.push((t, v));
        }
        let expect: Vec<(SimTime, u32)> =
            times.iter().enumerate().map(|(i, &t)| (t, i as u32)).collect();
        assert_eq!(fired, expect);
        assert!(w.is_empty());
    }

    #[test]
    fn same_time_breaks_ties_by_seq() {
        let mut w: TimerWheel<u32> = TimerWheel::new();
        w.arm(5_000, 9, 9);
        w.arm(5_000, 3, 3);
        w.arm(5_000, 7, 7);
        assert_eq!(w.peek(), Some((5_000, 3)));
        assert_eq!(w.pop_min().unwrap().2, 3);
        assert_eq!(w.pop_min().unwrap().2, 7);
        assert_eq!(w.pop_min().unwrap().2, 9);
    }

    #[test]
    fn cancel_is_exact_and_stale_ids_miss() {
        let mut w: TimerWheel<u32> = TimerWheel::new();
        let a = w.arm(10_000, 0, 0);
        let b = w.arm(20_000, 1, 1);
        assert!(w.cancel(a));
        assert!(!w.cancel(a), "double cancel is a detectable no-op");
        assert_eq!(w.len(), 1);
        let (_, _, v) = w.pop_min().unwrap();
        assert_eq!(v, 1);
        assert!(!w.cancel(b), "cancel after fire misses");
        // The freed slot is recycled with a fresh generation: the old id
        // must not cancel the new occupant.
        let c = w.arm(30_000, 2, 2);
        assert!(!w.cancel(a));
        assert!(!w.cancel(b));
        assert!(w.cancel(c));
        assert!(w.is_empty());
    }

    #[test]
    fn level_boundary_distances_never_fire_early() {
        // Distances that straddle level boundaries (the classic wheel
        // wraparound bug): each must fire at its own time, never before
        // a nearer timer.
        let mut w: TimerWheel<usize> = TimerWheel::new();
        let base: SimTime = 123_456_789;
        w.advance_to(base);
        let tick = 1u64 << TICK_SHIFT;
        let dists = [
            1,
            63,
            64,
            65,
            4_095,
            4_096,
            4_097,
            262_143,
            262_144,
            262_145,
            16_777_215,
            16_777_216,
            16_777_217,
        ];
        let mut expect: Vec<(SimTime, usize)> = Vec::new();
        for (i, d) in dists.iter().enumerate() {
            let t = base + d * tick;
            w.arm(t, i as u64, i);
            expect.push((t, i));
        }
        expect.sort();
        let mut fired = Vec::new();
        let mut last = 0;
        while let Some((t, _s, v)) = w.pop_min() {
            assert!(t >= last, "fired early: {t} after {last}");
            last = t;
            w.advance_to(t);
            fired.push((t, v));
        }
        assert_eq!(fired, expect);
    }

    #[test]
    fn randomized_arm_cancel_fire_matches_reference_model() {
        // Property: against a naive sorted-vec reference, the wheel
        // never fires early, never loses a timer, and cancel removes
        // exactly the requested entry. Clock advances monotonically
        // through fires (the engine's usage pattern).
        let mut rng = Lcg(0x9E3779B97F4A7C15);
        for round in 0..20u64 {
            let mut w: TimerWheel<u64> = TimerWheel::new();
            let mut model: Vec<(SimTime, u64)> = Vec::new(); // (time, seq)
            let mut ids: Vec<(TimerId, SimTime, u64)> = Vec::new();
            let mut now: SimTime = round * 977;
            w.advance_to(now);
            let mut seq = 0u64;
            for _ in 0..400 {
                match rng.below(10) {
                    // 60%: arm a timer at a random future distance.
                    0..=5 => {
                        let dist = match rng.below(4) {
                            0 => rng.below(1 << 12),
                            1 => rng.below(1 << 20),
                            2 => rng.below(1 << 28),
                            _ => rng.below(1 << 40),
                        };
                        let t = now + dist;
                        let id = w.arm(t, seq, seq);
                        model.push((t, seq));
                        ids.push((id, t, seq));
                        seq += 1;
                    }
                    // 20%: cancel a random live timer.
                    6..=7 => {
                        if !ids.is_empty() {
                            let k = rng.below(ids.len() as u64) as usize;
                            let (id, t, s) = ids.swap_remove(k);
                            assert!(w.cancel(id), "live timer must cancel");
                            let pos = model
                                .iter()
                                .position(|&e| e == (t, s))
                                .expect("model has it");
                            model.swap_remove(pos);
                        }
                    }
                    // 20%: fire the earliest timer.
                    _ => {
                        model.sort();
                        match (w.pop_min(), model.first().copied()) {
                            (None, None) => {}
                            (Some((t, s, v)), Some(m)) => {
                                assert_eq!((t, s), m, "wheel min != model min");
                                assert_eq!(v, s);
                                assert!(t >= now, "fired early");
                                now = t;
                                w.advance_to(now);
                                model.remove(0);
                                let pos =
                                    ids.iter().position(|&(_, mt, ms)| (mt, ms) == (t, s));
                                ids.swap_remove(pos.expect("fired timer was live"));
                            }
                            (a, b) => panic!("wheel/model diverged: {a:?} vs {b:?}"),
                        }
                    }
                }
                assert_eq!(w.len(), model.len(), "live counts diverged");
            }
            // Drain: every remaining timer fires exactly once, in order.
            model.sort();
            for &m in &model {
                let (t, s, _) = w.pop_min().expect("timer lost");
                assert_eq!((t, s), m);
                assert!(t >= now);
                now = t;
                w.advance_to(now);
            }
            assert!(w.pop_min().is_none());
            assert!(w.is_empty());
        }
    }

    #[test]
    fn slab_recycles_without_growth() {
        let mut w: TimerWheel<u8> = TimerWheel::new();
        for i in 0..1_000u64 {
            let id = w.arm(i * 2_048, i, 0);
            if i % 2 == 0 {
                assert!(w.cancel(id));
            } else {
                let (t, s, _) = w.pop_min().unwrap();
                assert_eq!((t, s), (i * 2_048, i));
                w.advance_to(t);
            }
        }
        assert!(w.is_empty());
        assert!(
            w.slab.len() <= 2,
            "freelist must recycle slots, slab grew to {}",
            w.slab.len()
        );
    }
}
