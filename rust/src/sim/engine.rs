//! The event engine.
//!
//! `Engine<W>` is generic over a *world* type `W` (the component graph:
//! devices, switches, hosts). Events are **typed**: the world declares an
//! event representation via the [`World`] trait ([`World::Event`]) and a
//! `fire` dispatcher, so the steady-state packet path pays a `match`
//! instead of a heap-allocated boxed closure per event. Boxed
//! `FnOnce(&mut W, &mut Engine<W>)` closures remain available as the
//! escape hatch for one-off coordinator/app logic: `schedule_at` lifts
//! them into the world's event type via [`World::lift`] (the network
//! world wraps them in its `Hook` variant).
//!
//! Ordering: a min-heap on `(time, seq)` where `seq` is a monotone
//! insertion counter — simultaneous events run in the order they were
//! scheduled, which makes runs bit-reproducible regardless of heap
//! internals. Timers ([`Engine::schedule_timer_in`]) live on a
//! [`TimerWheel`] instead of the heap — O(1) to arm and *exactly* O(1)
//! to cancel by [`TimerId`] (generation-stamped slots, no tombstone
//! sets) — and draw `seq` from the same counter, so the merged
//! heap/wheel order is identical to a single `(time, seq)` heap.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;
use super::wheel::{TimerId, TimerWheel};

/// Error returned by [`Engine::schedule_at_strict`] when the requested
/// absolute time is already in the past.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulePastError {
    /// The time the caller asked for.
    pub requested: SimTime,
    /// The engine clock at the time of the call.
    pub now: SimTime,
}

impl std::fmt::Display for SchedulePastError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "schedule into the past: requested t={} but now={}",
            self.requested, self.now
        )
    }
}

impl std::error::Error for SchedulePastError {}

/// The boxed event handler type (the escape-hatch representation).
pub type EventFn<W> = Box<dyn FnOnce(&mut W, &mut Engine<W>)>;

/// A world that runs on the engine: a typed event representation plus
/// the dispatcher that executes one event.
///
/// Packet-path events should be plain enum variants (no allocation to
/// schedule, `match` to dispatch); `lift` adapts the boxed-closure API
/// onto the same representation for the rare control-plane event.
pub trait World: Sized {
    /// The typed event representation.
    type Event;
    /// Wrap a boxed closure as an event (the escape hatch).
    fn lift(f: EventFn<Self>) -> Self::Event;
    /// Execute one event.
    fn fire(ev: Self::Event, world: &mut Self, eng: &mut Engine<Self>);
}

struct Entry<E> {
    time: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event engine.
pub struct Engine<W: World> {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Entry<W::Event>>,
    /// Cancellable timers (retransmit timeouts) live here, off the heap.
    wheel: TimerWheel<W::Event>,
    processed: u64,
    peak_live: usize,
    stopped: bool,
}

impl<W: World> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Engine<W> {
    pub fn new() -> Self {
        Self {
            now: 0,
            seq: 0,
            heap: BinaryHeap::new(),
            wheel: TimerWheel::new(),
            processed: 0,
            peak_live: 0,
            stopped: false,
        }
    }

    /// Current simulation time (ns).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (perf counter for § Perf).
    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    /// Events still pending (heap + timer wheel).
    pub fn pending(&self) -> usize {
        self.heap.len() + self.wheel.len()
    }

    /// High-water mark of simultaneously live events (bench metadata).
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    #[inline]
    fn note_live(&mut self) {
        let live = self.heap.len() + self.wheel.len();
        if live > self.peak_live {
            self.peak_live = live;
        }
    }

    /// Schedule a typed event at absolute time `t`.
    ///
    /// A `t` in the past saturates to `now` — the event runs at the
    /// current time, never travels backwards, identically in debug and
    /// release builds.
    #[inline]
    pub fn schedule_event_at(&mut self, t: SimTime, ev: W::Event) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            time: t.max(self.now),
            seq,
            ev,
        });
        self.note_live();
    }

    /// Schedule a typed event after a relative delay `dt`.
    #[inline]
    pub fn schedule_event_in(&mut self, dt: SimTime, ev: W::Event) {
        self.schedule_event_at(self.now.saturating_add(dt), ev);
    }

    /// Schedule a boxed-closure event at absolute time `t` (past times
    /// clamp to `now`, as in [`Engine::schedule_event_at`]). Callers that
    /// consider a past `t` a logic error should use
    /// [`Engine::schedule_at_strict`].
    pub fn schedule_at<F>(&mut self, t: SimTime, f: F)
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        self.schedule_event_at(t, W::lift(Box::new(f)));
    }

    /// Schedule `f` at absolute time `t`, rejecting past times with a typed
    /// error instead of clamping.
    pub fn schedule_at_strict<F>(&mut self, t: SimTime, f: F) -> Result<(), SchedulePastError>
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        if t < self.now {
            return Err(SchedulePastError {
                requested: t,
                now: self.now,
            });
        }
        self.schedule_at(t, f);
        Ok(())
    }

    /// Schedule `f` after a relative delay `dt`.
    pub fn schedule_in<F>(&mut self, dt: SimTime, f: F)
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        self.schedule_event_in(dt, W::lift(Box::new(f)));
    }

    /// Arm a cancellable timer firing `ev` at absolute time `t` (clamped
    /// to `now`). O(1); the returned [`TimerId`] cancels in O(1).
    pub fn schedule_timer_at(&mut self, t: SimTime, ev: W::Event) -> TimerId {
        let seq = self.seq;
        self.seq += 1;
        let id = self.wheel.arm(t.max(self.now), seq, ev);
        self.note_live();
        id
    }

    /// Arm a cancellable timer firing `ev` after `dt`.
    pub fn schedule_timer_in(&mut self, dt: SimTime, ev: W::Event) -> TimerId {
        self.schedule_timer_at(self.now.saturating_add(dt), ev)
    }

    /// Exact-cancel a timer. A stale id (the timer already fired or was
    /// already cancelled) returns `false` and leaves nothing behind.
    pub fn cancel_timer(&mut self, id: TimerId) -> bool {
        self.wheel.cancel(id)
    }

    /// Advance the clock to `t` without running anything (no-op if `t` is
    /// in the past). The sharded runtime uses this to re-sync an engine
    /// whose world just ran on a different clock.
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
            self.wheel.advance_to(t);
        }
    }

    /// Ask the engine to stop after the current event returns.
    pub fn stop(&mut self) {
        self.stopped = true;
    }

    /// Key of the globally next event (heap and wheel merged).
    fn next_key(&mut self) -> Option<(SimTime, u64)> {
        let hk = self.heap.peek().map(|e| (e.time, e.seq));
        let wk = self.wheel.peek();
        match (hk, wk) {
            (Some(h), Some(w)) => Some(h.min(w)),
            (h, w) => h.or(w),
        }
    }

    /// Pop the globally next event. `seq` is unique across heap and
    /// wheel (one shared counter), so the merge order is total.
    fn pop_next(&mut self) -> Option<(SimTime, W::Event)> {
        let hk = self.heap.peek().map(|e| (e.time, e.seq));
        let wk = self.wheel.peek();
        let from_heap = match (hk, wk) {
            (None, None) => return None,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (Some(h), Some(w)) => h < w,
        };
        if from_heap {
            let e = self.heap.pop().expect("peeked");
            Some((e.time, e.ev))
        } else {
            let (t, _seq, ev) = self.wheel.pop_min().expect("peeked");
            Some((t, ev))
        }
    }

    /// Run until the queue is empty or `stop()` was called.
    /// Returns the final simulation time.
    pub fn run(&mut self, world: &mut W) -> SimTime {
        while !self.stopped {
            let Some((t, ev)) = self.pop_next() else { break };
            self.now = t;
            self.wheel.advance_to(t);
            self.processed += 1;
            W::fire(ev, world, self);
        }
        self.stopped = false;
        self.now
    }

    /// Run until simulation time would exceed `deadline` (events at exactly
    /// `deadline` still run). Pending later events remain queued.
    pub fn run_until(&mut self, world: &mut W, deadline: SimTime) -> SimTime {
        while !self.stopped {
            match self.next_key() {
                Some((t, _)) if t <= deadline => {}
                _ => break,
            }
            let (t, ev) = self.pop_next().expect("peeked a key");
            self.now = t;
            self.wheel.advance_to(t);
            self.processed += 1;
            W::fire(ev, world, self);
        }
        self.stopped = false;
        // Clock advances to the deadline even if the queue drained earlier,
        // so callers can schedule relative to it.
        if deadline > self.now {
            self.now = deadline;
            self.wheel.advance_to(deadline);
        }
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct TestWorld {
        log: Vec<(SimTime, u32)>,
    }

    /// Closure-only world: events *are* boxed handlers (the escape hatch
    /// is the whole event model here).
    impl World for TestWorld {
        type Event = EventFn<TestWorld>;
        fn lift(f: EventFn<TestWorld>) -> Self::Event {
            f
        }
        fn fire(ev: Self::Event, world: &mut Self, eng: &mut Engine<Self>) {
            ev(world, eng);
        }
    }

    #[test]
    fn events_run_in_time_order() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(30, |w, e| w.log.push((e.now(), 3)));
        eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.schedule_at(20, |w, e| w.log.push((e.now(), 2)));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        for i in 0..10 {
            eng.schedule_at(5, move |w, e| w.log.push((e.now(), i)));
        }
        eng.run(&mut w);
        let order: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_follow_ups() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(1, |_, e| {
            e.schedule_in(4, |w: &mut TestWorld, e: &mut Engine<TestWorld>| {
                w.log.push((e.now(), 99))
            });
        });
        let end = eng.run(&mut w);
        assert_eq!(w.log, vec![(5, 99)]);
        assert_eq!(end, 5);
        assert_eq!(eng.events_processed(), 2);
    }

    #[test]
    fn timers_interleave_with_heap_events_in_key_order() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 2)));
        eng.schedule_timer_at(20, boxed);
        eng.schedule_at(30, |w, e| w.log.push((e.now(), 3)));
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 4)));
        eng.schedule_timer_at(40_000, boxed);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3), (40_000, 4)]);
    }

    #[test]
    fn same_time_timer_and_event_order_by_schedule_sequence() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 1)));
        eng.schedule_timer_at(50, boxed); // seq 0
        eng.schedule_at(50, |w, e| w.log.push((e.now(), 2))); // seq 1
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 3)));
        eng.schedule_timer_at(50, boxed); // seq 2
        eng.run(&mut w);
        assert_eq!(w.log, vec![(50, 1), (50, 2), (50, 3)]);
    }

    #[test]
    fn cancelled_timer_never_fires_and_stale_cancel_is_noop() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 1)));
        let id = eng.schedule_timer_at(10, boxed);
        eng.schedule_at(20, |w, e| w.log.push((e.now(), 2)));
        assert!(eng.cancel_timer(id));
        assert_eq!(eng.pending(), 1, "exact cancel removes the entry");
        eng.run(&mut w);
        assert_eq!(w.log, vec![(20, 2)]);
        assert!(!eng.cancel_timer(id), "stale id is a detectable no-op");
    }

    #[test]
    fn timer_fired_then_cancelled_is_noop() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 1)));
        let id = eng.schedule_timer_at(5, boxed);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(5, 1)]);
        assert!(!eng.cancel_timer(id), "fired timers leave no residue");
        assert_eq!(eng.pending(), 0);
    }

    #[test]
    fn run_until_leaves_later_events() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.schedule_at(100, |w, e| w.log.push((e.now(), 2)));
        eng.run_until(&mut w, 50);
        assert_eq!(w.log, vec![(10, 1)]);
        assert_eq!(eng.pending(), 1);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (100, 2)]);
    }

    #[test]
    fn run_until_leaves_later_timers() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 1)));
        eng.schedule_timer_at(10, boxed);
        let boxed: EventFn<TestWorld> = Box::new(|w, e| w.log.push((e.now(), 2)));
        let late = eng.schedule_timer_at(100_000, boxed);
        eng.run_until(&mut w, 50);
        assert_eq!(w.log, vec![(10, 1)]);
        assert_eq!(eng.pending(), 1);
        assert!(eng.cancel_timer(late), "still cancellable after the window");
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1)]);
    }

    #[test]
    fn past_time_schedule_clamps_to_now_in_all_builds() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(50, |w, e| {
            w.log.push((e.now(), 1));
            // From inside an event at t=50, ask for t=10: runs at 50.
            e.schedule_at(10, |w: &mut TestWorld, e: &mut Engine<TestWorld>| {
                w.log.push((e.now(), 2));
            });
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(50, 1), (50, 2)], "past schedule saturates to now");
    }

    #[test]
    fn strict_schedule_rejects_past_times() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(50, |_, e| {
            let err = e
                .schedule_at_strict(10, |_: &mut TestWorld, _: &mut Engine<TestWorld>| {})
                .unwrap_err();
            assert_eq!(err, SchedulePastError { requested: 10, now: 50 });
            // Present/future times are fine.
            assert!(e
                .schedule_at_strict(50, |w: &mut TestWorld, e: &mut Engine<TestWorld>| {
                    w.log.push((e.now(), 7));
                })
                .is_ok());
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(50, 7)]);
    }

    #[test]
    fn advance_to_moves_clock_forward_only() {
        let mut eng: Engine<TestWorld> = Engine::new();
        eng.advance_to(100);
        assert_eq!(eng.now(), 100);
        eng.advance_to(40);
        assert_eq!(eng.now(), 100, "advance_to never rewinds");
    }

    #[test]
    fn stop_halts_mid_run() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        eng.schedule_at(1, |w, e| {
            w.log.push((e.now(), 1));
            e.stop();
        });
        eng.schedule_at(2, |w, e| w.log.push((e.now(), 2)));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(1, 1)]);
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn peak_live_tracks_high_water_mark() {
        let mut eng: Engine<TestWorld> = Engine::new();
        let mut w = TestWorld::default();
        for t in 1..=5 {
            eng.schedule_at(t, |_, _| {});
        }
        let boxed: EventFn<TestWorld> = Box::new(|_, _| {});
        eng.schedule_timer_at(6, boxed);
        assert_eq!(eng.peak_live(), 6);
        eng.run(&mut w);
        assert_eq!(eng.peak_live(), 6, "peak survives the drain");
        assert_eq!(eng.pending(), 0);
    }
}
