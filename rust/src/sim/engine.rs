//! The event engine.
//!
//! `Engine<W>` is generic over a *world* type `W` (the component graph:
//! devices, switches, hosts). Events are boxed `FnOnce(&mut W, &mut
//! Engine<W>)` closures: a handler mutates the world and schedules follow-up
//! events. The engine never borrows the world except while running one
//! event, so handlers can freely schedule.
//!
//! Ordering: min-heap on `(time, seq)` where `seq` is a monotone insertion
//! counter — simultaneous events run in the order they were scheduled,
//! which makes runs bit-reproducible regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::time::SimTime;

/// Identifier returned by `schedule_*`; usable for cancellation.
pub type EventId = u64;

/// Error returned by [`Engine::schedule_at_strict`] when the requested
/// absolute time is already in the past.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SchedulePastError {
    /// The time the caller asked for.
    pub requested: SimTime,
    /// The engine clock at the time of the call.
    pub now: SimTime,
}

impl std::fmt::Display for SchedulePastError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "schedule into the past: requested t={} but now={}",
            self.requested, self.now
        )
    }
}

impl std::error::Error for SchedulePastError {}

/// The boxed event handler type.
pub type EventFn<W> = Box<dyn FnOnce(&mut W, &mut Engine<W>)>;

struct Entry<W> {
    time: SimTime,
    seq: u64,
    f: Option<EventFn<W>>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert to get earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic discrete-event engine.
pub struct Engine<W> {
    now: SimTime,
    seq: u64,
    heap: BinaryHeap<Entry<W>>,
    /// Ids of events still sitting in the heap. Guards `cancel` against
    /// ids that already executed: without the check, every such id would
    /// sit in `cancelled` forever (unbounded growth on long runs).
    pending_ids: std::collections::HashSet<EventId>,
    /// Pending ids whose events were cancelled (lazily skipped on pop).
    cancelled: std::collections::HashSet<EventId>,
    processed: u64,
    stopped: bool,
}

impl<W> Default for Engine<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Engine<W> {
    pub fn new() -> Self {
        Self {
            now: 0,
            seq: 0,
            heap: BinaryHeap::new(),
            pending_ids: std::collections::HashSet::new(),
            cancelled: std::collections::HashSet::new(),
            processed: 0,
            stopped: false,
        }
    }

    /// Current simulation time (ns).
    #[inline]
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far (perf counter for § Perf).
    pub fn events_processed(&self) -> u64 {
        self.processed
    }

    /// Events still pending.
    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `f` at absolute time `t`.
    ///
    /// A `t` in the past saturates to `now` — the event runs at the current
    /// time, never travels backwards. This clamping is identical in debug
    /// and release builds (it used to be a `debug_assert!` followed by a
    /// silent clamp, so debug and release disagreed on past-time inputs).
    /// Callers that consider a past `t` a logic error should use
    /// [`Engine::schedule_at_strict`].
    pub fn schedule_at<F>(&mut self, t: SimTime, f: F) -> EventId
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        let seq = self.seq;
        self.seq += 1;
        self.pending_ids.insert(seq);
        self.heap.push(Entry {
            time: t.max(self.now),
            seq,
            f: Some(Box::new(f)),
        });
        seq
    }

    /// Schedule `f` at absolute time `t`, rejecting past times with a typed
    /// error instead of clamping.
    pub fn schedule_at_strict<F>(&mut self, t: SimTime, f: F) -> Result<EventId, SchedulePastError>
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        if t < self.now {
            return Err(SchedulePastError {
                requested: t,
                now: self.now,
            });
        }
        Ok(self.schedule_at(t, f))
    }

    /// Advance the clock to `t` without running anything (no-op if `t` is
    /// in the past). The sharded runtime uses this to re-sync an engine
    /// whose world just ran on a different clock.
    pub fn advance_to(&mut self, t: SimTime) {
        self.now = self.now.max(t);
    }

    /// Schedule `f` after a relative delay `dt`.
    pub fn schedule_in<F>(&mut self, dt: SimTime, f: F) -> EventId
    where
        F: FnOnce(&mut W, &mut Engine<W>) + 'static,
    {
        let t = self.now.saturating_add(dt);
        self.schedule_at(t, f)
    }

    /// Cancel a pending event (e.g. a retransmit timer whose ACK arrived).
    /// Lazy cancellation: the entry stays in the heap and is skipped on
    /// pop. Cancelling an id that already executed (or was never issued)
    /// is a no-op — stale ids are not retained.
    pub fn cancel(&mut self, id: EventId) {
        if self.pending_ids.contains(&id) {
            self.cancelled.insert(id);
        }
    }

    /// Cancelled-but-not-yet-popped entries (diagnostic; bounded by
    /// `pending()`).
    pub fn cancelled_backlog(&self) -> usize {
        self.cancelled.len()
    }

    /// Ask the engine to stop after the current event returns.
    pub fn stop(&mut self) {
        self.stopped = true;
    }

    fn pop_live(&mut self) -> Option<Entry<W>> {
        while let Some(e) = self.heap.pop() {
            self.pending_ids.remove(&e.seq);
            if self.cancelled.remove(&e.seq) {
                continue;
            }
            return Some(e);
        }
        None
    }

    /// Run until the queue is empty or `stop()` was called.
    /// Returns the final simulation time.
    pub fn run(&mut self, world: &mut W) -> SimTime {
        while !self.stopped {
            let Some(mut e) = self.pop_live() else { break };
            self.now = e.time;
            self.processed += 1;
            let f = e.f.take().expect("event fn present");
            f(world, self);
        }
        self.stopped = false;
        self.now
    }

    /// Run until simulation time would exceed `deadline` (events at exactly
    /// `deadline` still run). Pending later events remain queued.
    pub fn run_until(&mut self, world: &mut W, deadline: SimTime) -> SimTime {
        while !self.stopped {
            match self.heap.peek() {
                Some(e) if e.time <= deadline => {}
                _ => break,
            }
            let Some(mut e) = self.pop_live() else { break };
            if e.time > deadline {
                // pop_live may skip past the peeked entry; re-queue.
                self.pending_ids.insert(e.seq);
                self.heap.push(e);
                break;
            }
            self.now = e.time;
            self.processed += 1;
            let f = e.f.take().expect("event fn present");
            f(world, self);
        }
        self.stopped = false;
        // Clock advances to the deadline even if the queue drained earlier,
        // so callers can schedule relative to it.
        self.now = self.now.max(deadline);
        self.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        log: Vec<(SimTime, u32)>,
    }

    #[test]
    fn events_run_in_time_order() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(30, |w, e| w.log.push((e.now(), 3)));
        eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.schedule_at(20, |w, e| w.log.push((e.now(), 2)));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        for i in 0..10 {
            eng.schedule_at(5, move |w, e| w.log.push((e.now(), i)));
        }
        eng.run(&mut w);
        let order: Vec<u32> = w.log.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_follow_ups() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(1, |_, e| {
            e.schedule_in(4, |w: &mut World, e: &mut Engine<World>| {
                w.log.push((e.now(), 99))
            });
        });
        let end = eng.run(&mut w);
        assert_eq!(w.log, vec![(5, 99)]);
        assert_eq!(end, 5);
        assert_eq!(eng.events_processed(), 2);
    }

    #[test]
    fn cancel_skips_event() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        let id = eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.schedule_at(20, |w, e| w.log.push((e.now(), 2)));
        eng.cancel(id);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(20, 2)]);
    }

    #[test]
    fn cancel_after_execution_does_not_accumulate() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        let ids: Vec<EventId> = (0..100)
            .map(|i| eng.schedule_at(i, |_, _| {}))
            .collect();
        eng.run(&mut w);
        // All ids are stale now; cancelling them must not grow the set.
        for id in ids {
            eng.cancel(id);
        }
        assert_eq!(eng.cancelled_backlog(), 0, "stale ids must not be kept");
    }

    #[test]
    fn cancelled_pending_event_is_purged_on_pop() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        let id = eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.cancel(id);
        assert_eq!(eng.cancelled_backlog(), 1);
        eng.run(&mut w);
        assert!(w.log.is_empty());
        assert_eq!(eng.cancelled_backlog(), 0, "set drains as entries pop");
    }

    #[test]
    fn run_until_requeue_keeps_event_cancellable() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        // A cancelled early event forces pop_live to skip past the peeked
        // entry inside run_until, exercising the re-queue path.
        let early = eng.schedule_at(40, |w, e| w.log.push((e.now(), 1)));
        let late = eng.schedule_at(60, |w, e| w.log.push((e.now(), 2)));
        eng.cancel(early);
        eng.run_until(&mut w, 50);
        assert!(w.log.is_empty());
        assert_eq!(eng.pending(), 1);
        // The re-queued event must still be cancellable.
        eng.cancel(late);
        eng.run(&mut w);
        assert!(w.log.is_empty());
        assert_eq!(eng.cancelled_backlog(), 0);
    }

    #[test]
    fn run_until_leaves_later_events() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(10, |w, e| w.log.push((e.now(), 1)));
        eng.schedule_at(100, |w, e| w.log.push((e.now(), 2)));
        eng.run_until(&mut w, 50);
        assert_eq!(w.log, vec![(10, 1)]);
        assert_eq!(eng.pending(), 1);
        eng.run(&mut w);
        assert_eq!(w.log, vec![(10, 1), (100, 2)]);
    }

    #[test]
    fn past_time_schedule_clamps_to_now_in_all_builds() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(50, |w, e| {
            w.log.push((e.now(), 1));
            // From inside an event at t=50, ask for t=10: runs at 50.
            e.schedule_at(10, |w: &mut World, e: &mut Engine<World>| {
                w.log.push((e.now(), 2));
            });
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(50, 1), (50, 2)], "past schedule saturates to now");
    }

    #[test]
    fn strict_schedule_rejects_past_times() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(50, |_, e| {
            let err = e
                .schedule_at_strict(10, |_: &mut World, _: &mut Engine<World>| {})
                .unwrap_err();
            assert_eq!(err, SchedulePastError { requested: 10, now: 50 });
            // Present/future times are fine.
            assert!(e
                .schedule_at_strict(50, |w: &mut World, e: &mut Engine<World>| {
                    w.log.push((e.now(), 7));
                })
                .is_ok());
        });
        eng.run(&mut w);
        assert_eq!(w.log, vec![(50, 7)]);
    }

    #[test]
    fn advance_to_moves_clock_forward_only() {
        let mut eng: Engine<World> = Engine::new();
        eng.advance_to(100);
        assert_eq!(eng.now(), 100);
        eng.advance_to(40);
        assert_eq!(eng.now(), 100, "advance_to never rewinds");
    }

    #[test]
    fn stop_halts_mid_run() {
        let mut eng: Engine<World> = Engine::new();
        let mut w = World::default();
        eng.schedule_at(1, |w, e| {
            w.log.push((e.now(), 1));
            e.stop();
        });
        eng.schedule_at(2, |w, e| w.log.push((e.now(), 2)));
        eng.run(&mut w);
        assert_eq!(w.log, vec![(1, 1)]);
        assert_eq!(eng.pending(), 1);
    }
}
