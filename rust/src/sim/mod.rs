//! Deterministic discrete-event simulation (DES) core.
//!
//! Every hardware component the paper's testbed provides — FPGA pipeline,
//! HBM, 100G links, the Nexus switch, host NICs/PCIe — is modeled as event
//! handlers scheduled on this engine. Time is `u64` nanoseconds (the paper
//! reports latencies in ns; 1 ns resolution also cleanly expresses 100G
//! serialization: 1 byte = 0.08 ns, so we track *picosecond* residue in the
//! link models and round there, keeping the global clock integral).
//!
//! Determinism contract: given the same seed and the same sequence of
//! `schedule` calls, a run is bit-reproducible. Ties in time break by
//! insertion order (a monotone sequence number), never by heap internals.

//! ## Scaling: the sharded core
//!
//! `Engine` remains the default single-threaded path (and the degenerate
//! single-shard case). For 1024-rank-scale worlds, [`sharded`] partitions
//! the world into independently-clocked shards joined by latency-carrying
//! channels: each shard owns an event heap ordered by a shard-invariant
//! [`EventKey`], windows advance under conservative lookahead (the
//! minimum cross-shard latency), and shards only exchange events at
//! window barriers. Same seed ⇒ bit-identical results at any shard or
//! thread count; see `net::shard` for the network-world instantiation.

mod engine;
pub mod sharded;
mod time;
mod wheel;

pub use engine::{Engine, EventFn, SchedulePastError, World};
pub use sharded::{EventKey, ShardRunStats, ShardWorld, ShardedEngine, COORDINATOR_SRC};
pub use time::{fmt_ns, SimTime, GBPS, MICROS, MILLIS, SECS};
pub use wheel::{TimerId, TimerWheel};
