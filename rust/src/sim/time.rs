//! Simulation time: u64 nanoseconds with helpers for rates and units.

/// Nanoseconds since simulation start.
pub type SimTime = u64;

/// One microsecond in [`SimTime`] units.
pub const MICROS: SimTime = 1_000;
/// One millisecond.
pub const MILLIS: SimTime = 1_000_000;
/// One second.
pub const SECS: SimTime = 1_000_000_000;

/// Serialization helpers for a line rate expressed in Gbit/s.
///
/// 100G Ethernet moves 12.5 bytes/ns; a 64 B frame takes 5.12 ns. We keep
/// sub-ns residue by computing in picoseconds and letting the caller
/// accumulate (see `net::Link`), so back-to-back frames don't drift.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GBPS(pub f64);

impl GBPS {
    /// Picoseconds to serialize `bytes` at this rate (exact to 1 ps).
    #[inline]
    pub fn ser_ps(&self, bytes: usize) -> u64 {
        // bits * 1000 / gbps = ps
        ((bytes as u64 * 8) as f64 * 1000.0 / self.0).round() as u64
    }

    /// Nanoseconds (rounded) to serialize `bytes` — convenience for tests.
    #[inline]
    pub fn ser_ns(&self, bytes: usize) -> SimTime {
        (self.ser_ps(bytes) + 500) / 1000
    }

    /// Bytes per nanosecond.
    #[inline]
    pub fn bytes_per_ns(&self) -> f64 {
        self.0 / 8.0
    }
}

/// Render a [`SimTime`] human-readably (used by the table printers).
pub fn fmt_ns(t: SimTime) -> String {
    if t >= SECS {
        format!("{:.3} s", t as f64 / SECS as f64)
    } else if t >= MILLIS {
        format!("{:.3} ms", t as f64 / MILLIS as f64)
    } else if t >= MICROS {
        format!("{:.3} us", t as f64 / MICROS as f64)
    } else {
        format!("{t} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serialization_100g() {
        let r = GBPS(100.0);
        // 64B @ 100G = 5.12 ns = 5120 ps
        assert_eq!(r.ser_ps(64), 5120);
        assert_eq!(r.ser_ns(64), 5);
        // 9000B jumbo = 720 ns
        assert_eq!(r.ser_ns(9000), 720);
    }

    #[test]
    fn serialization_is_linear() {
        let r = GBPS(25.0);
        assert_eq!(r.ser_ps(2000), 2 * r.ser_ps(1000));
    }

    #[test]
    fn fmt_spans_units() {
        assert_eq!(fmt_ns(618), "618 ns");
        assert_eq!(fmt_ns(2 * MICROS + 500), "2.500 us");
        assert_eq!(fmt_ns(400 * MILLIS), "400.000 ms");
        assert_eq!(fmt_ns(2 * SECS + 100 * MILLIS), "2.100 s");
    }
}
