//! Conservative-lookahead sharded DES runtime.
//!
//! The classic [`Engine`](super::Engine) runs one event heap on one
//! thread. This module adds the parallel alternative: the world is
//! partitioned into *shards*, each advancing its own event heap on its own
//! local clock, synchronized only at window barriers (a conservative
//! "null-message-free" PDES in the Chandy–Misra–Bryant family, same shape
//! as DAM-style independently-clocked contexts joined by latency-carrying
//! channels).
//!
//! The contract that makes it correct *and* deterministic:
//!
//! * **Lookahead.** Every cross-shard interaction carries at least
//!   `lookahead` ns of model latency (for the network world: the minimum
//!   link propagation delay, capped by the host-injection latency). Each
//!   epoch computes `end = min over shards of next-event-time + lookahead`
//!   and lets every shard run all events with `time < end` without
//!   communicating: any event such a window *sends* to another shard
//!   lands at `time >= end`, i.e. strictly in a later window.
//! * **Canonical keys.** Events are ordered by [`EventKey`] — `(time,
//!   scheduling node, per-node counter)` — which never mentions shards or
//!   threads. Two events that can touch shared state always live on the
//!   same shard at every shard count, and their relative order is a pure
//!   function of their keys, so a run is bit-identical at any shard count
//!   and any thread count.
//! * **Barrier coordination.** Between epochs the caller-provided
//!   `between` hook runs on the coordinating thread with all shards
//!   quiescent — that is where the network world sorts completion records
//!   into global key order and applies reactive injections.
//!
//! Worker threads are plain `std::thread::scope` spawns per epoch (no
//! dependencies, no persistent pool): a few microseconds of setup per
//! epoch against windows that typically execute thousands of events.

use std::thread;

use super::time::SimTime;

/// Total order on sharded events, invariant across shard/thread counts:
/// `(time, scheduling entity, per-entity monotone counter)`.
///
/// `src` is the id of the node whose event *scheduled* this one (the
/// coordinator uses [`COORDINATOR_SRC`]); `seq` is that node's own
/// scheduling counter. Because every node is owned by exactly one shard,
/// keys are globally unique and their assignment never depends on the
/// partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EventKey {
    pub time: SimTime,
    pub src: usize,
    pub seq: u64,
}

/// `EventKey::src` for events injected by the inter-epoch coordinator
/// (completion-hook reactions, initial kick-offs). Sorts after every real
/// node at equal times, and coordinator injections are themselves applied
/// in a deterministic order, so this preserves the global total order.
pub const COORDINATOR_SRC: usize = usize::MAX;

/// One shard of a partitioned world.
///
/// `Send` (not `Sync`): a shard is owned by exactly one worker per epoch;
/// shards only move between threads at barriers.
pub trait ShardWorld: Send {
    /// A cross-shard event in flight. Carries its own [`EventKey`]-style
    /// ordering information; the lookahead contract guarantees its time
    /// is at or after the window edge it was emitted from.
    type Msg: Send;

    /// Time of this shard's earliest pending event, if any.
    fn next_time(&self) -> Option<SimTime>;

    /// Run every pending event with `time < end` (in key order), returning
    /// the cross-shard messages born in this window as `(destination
    /// shard, message)` pairs, in emission order.
    fn run_window(&mut self, end: SimTime) -> Vec<(usize, Self::Msg)>;

    /// Enqueue a message emitted by another shard's window.
    fn accept(&mut self, msg: Self::Msg);

    /// Cumulative events executed by this shard.
    fn events_processed(&self) -> u64;

    /// Time of the last event this shard executed (0 if none yet).
    fn last_event_time(&self) -> SimTime;
}

/// What a sharded run did — the sim-speed bench's raw material.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardRunStats {
    /// Events executed across all shards during this run.
    pub events: u64,
    /// Window barriers crossed.
    pub epochs: u64,
    /// Maximum executed event time — the value the caller should advance
    /// its wall clock to (matches the classic engine's `now` after `run`,
    /// which is the last *event* time, not the last window edge).
    pub end_time: SimTime,
}

/// Epoch-barrier executor over a set of [`ShardWorld`]s.
pub struct ShardedEngine<S: ShardWorld> {
    shards: Vec<S>,
    lookahead: SimTime,
    threads: usize,
}

impl<S: ShardWorld> ShardedEngine<S> {
    /// `lookahead` is clamped to ≥ 1 ns so every window makes progress.
    /// Thread count defaults to `available_parallelism` capped at the
    /// shard count; override with [`ShardedEngine::with_threads`].
    pub fn new(shards: Vec<S>, lookahead: SimTime) -> Self {
        let hw = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let threads = hw.min(shards.len().max(1));
        Self {
            shards,
            lookahead: lookahead.max(1),
            threads,
        }
    }

    /// Use exactly `n` worker threads (1 = run windows inline).
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn shards(&self) -> &[S] {
        &self.shards
    }

    pub fn shards_mut(&mut self) -> &mut [S] {
        &mut self.shards
    }

    pub fn into_shards(self) -> Vec<S> {
        self.shards
    }

    /// Run the sharded world to quiescence.
    ///
    /// `between(shards, window_end)` runs at every barrier after the
    /// window's cross-shard messages have been exchanged; it may inject
    /// new events (at times `>= window_end`) into any shard. The run ends
    /// when no shard has pending events and `between` injects nothing.
    pub fn run<F>(&mut self, mut between: F) -> ShardRunStats
    where
        F: FnMut(&mut [S], SimTime),
    {
        let base: u64 = self.shards.iter().map(|s| s.events_processed()).sum();
        let mut stats = ShardRunStats::default();
        loop {
            let tmin = self.shards.iter().filter_map(|s| s.next_time()).min();
            let Some(tmin) = tmin else { break };
            let end = tmin.saturating_add(self.lookahead);
            let outboxes = self.run_windows(end);
            // Exchange in (source shard, emission) order — deterministic,
            // and receivers re-order by key anyway.
            for msgs in outboxes {
                for (dst, m) in msgs {
                    self.shards[dst].accept(m);
                }
            }
            stats.epochs += 1;
            between(&mut self.shards, end);
        }
        stats.events = self
            .shards
            .iter()
            .map(|s| s.events_processed())
            .sum::<u64>()
            - base;
        stats.end_time = self
            .shards
            .iter()
            .map(|s| s.last_event_time())
            .max()
            .unwrap_or(0);
        stats
    }

    /// One epoch: every shard runs `[.., end)`, in parallel when
    /// configured. Output order is shard order regardless of thread
    /// scheduling, so parallelism never leaks into results.
    fn run_windows(&mut self, end: SimTime) -> Vec<Vec<(usize, S::Msg)>> {
        if self.threads <= 1 || self.shards.len() <= 1 {
            return self.shards.iter_mut().map(|s| s.run_window(end)).collect();
        }
        let per = self.shards.len().div_ceil(self.threads);
        let chunks: Vec<&mut [S]> = self.shards.chunks_mut(per).collect();
        let joined: Vec<Vec<Vec<(usize, S::Msg)>>> = thread::scope(|scope| {
            let handles: Vec<_> = chunks
                .into_iter()
                .map(|chunk| {
                    scope.spawn(move || {
                        chunk
                            .iter_mut()
                            .map(|s| s.run_window(end))
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        joined.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Toy shard: forwards a token to `peer` after `latency` ns until
    /// `limit`, logging every execution.
    struct Pinger {
        peer: usize,
        latency: SimTime,
        limit: SimTime,
        heap: BinaryHeap<Reverse<(SimTime, u64)>>,
        seq: u64,
        processed: u64,
        last: SimTime,
        log: Vec<SimTime>,
    }

    impl Pinger {
        fn new(peer: usize, latency: SimTime, limit: SimTime) -> Self {
            Self {
                peer,
                latency,
                limit,
                heap: BinaryHeap::new(),
                seq: 0,
                processed: 0,
                last: 0,
                log: Vec::new(),
            }
        }

        fn seed(&mut self, t: SimTime) {
            self.accept(t);
        }
    }

    impl ShardWorld for Pinger {
        type Msg = SimTime;

        fn next_time(&self) -> Option<SimTime> {
            self.heap.peek().map(|Reverse((t, _))| *t)
        }

        fn run_window(&mut self, end: SimTime) -> Vec<(usize, SimTime)> {
            let mut out = Vec::new();
            while let Some(Reverse((t, _))) = self.heap.peek().copied() {
                if t >= end {
                    break;
                }
                self.heap.pop();
                self.processed += 1;
                self.last = t;
                self.log.push(t);
                let next = t + self.latency;
                if next <= self.limit {
                    out.push((self.peer, next));
                }
            }
            out
        }

        fn accept(&mut self, msg: SimTime) {
            self.seq += 1;
            self.heap.push(Reverse((msg, self.seq)));
        }

        fn events_processed(&self) -> u64 {
            self.processed
        }

        fn last_event_time(&self) -> SimTime {
            self.last
        }
    }

    #[test]
    fn two_shard_ping_pong_crosses_windows() {
        let mut a = Pinger::new(1, 10, 100);
        let b = Pinger::new(0, 10, 100);
        a.seed(0);
        let mut eng = ShardedEngine::new(vec![a, b], 10).with_threads(1);
        let stats = eng.run(|_, _| {});
        // Token bounces 0,10,...,100 → 11 events, alternating shards.
        assert_eq!(stats.events, 11);
        assert_eq!(stats.end_time, 100);
        let shards = eng.shards();
        assert_eq!(shards[0].log, vec![0, 20, 40, 60, 80, 100]);
        assert_eq!(shards[1].log, vec![10, 30, 50, 70, 90]);
    }

    #[test]
    fn threaded_run_matches_serial() {
        let build = || {
            let mut shards: Vec<Pinger> = (0..4).map(|i| Pinger::new((i + 1) % 4, 7, 300)).collect();
            shards[0].seed(0);
            shards[2].seed(3);
            shards
        };
        let mut serial = ShardedEngine::new(build(), 7).with_threads(1);
        let s1 = serial.run(|_, _| {});
        let mut threaded = ShardedEngine::new(build(), 7).with_threads(3);
        let s2 = threaded.run(|_, _| {});
        assert_eq!(s1, s2);
        for (a, b) in serial.shards().iter().zip(threaded.shards()) {
            assert_eq!(a.log, b.log, "thread count must not change results");
        }
    }

    #[test]
    fn between_hook_can_inject_more_work() {
        let mut a = Pinger::new(0, 5, 20);
        a.seed(0);
        let mut eng = ShardedEngine::new(vec![a], 5).with_threads(1);
        let mut extra = false;
        let stats = eng.run(|shards, end| {
            if !extra && shards[0].next_time().is_none() {
                extra = true;
                // Coordinator injections must land at or after the edge.
                shards[0].accept(end + 100);
            }
        });
        assert!(extra, "hook observed quiescence");
        // 0,5,10,15,20 then the injected one (which itself ping-pongs to
        // its limit... limit=20 so it terminates immediately).
        assert_eq!(stats.events, 6);
    }
}
