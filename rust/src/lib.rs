//! NetDAM: Network Direct Attached Memory with a programmable in-memory
//! computing ISA — a full-system reproduction of Fang & Peng (2021).
//!
//! The original system is an FPGA (Xilinx Alveo U55N) prototype: HBM memory
//! attached directly to a 100G Ethernet MAC with a fixed packet-processing
//! pipeline and a programmable instruction set executed near memory. This
//! crate reproduces the *system* in software as a deterministic,
//! cycle-approximate discrete-event simulation plus a real compute plane:
//! the SIMD/in-memory ALU operations are authored as JAX/Pallas kernels,
//! AOT-lowered to HLO, and executed from rust through the PJRT C API
//! (see [`runtime`]), so the actual arithmetic of every collective runs
//! through the same compiled artifacts a hardware ALU array would model.
//!
//! # Layers
//! * **L3 (this crate)** — the coordinator and every substrate the paper
//!   depends on: the DES engine ([`sim`]), packet format ([`wire`]),
//!   programmable ISA ([`isa`]), device pipeline model ([`device`]),
//!   Ethernet fabric ([`net`]), segment routing ([`srou`]), transport
//!   ([`transport`]), IOMMU ([`iommu`]), global memory pool ([`pool`]),
//!   host/PCIe/RoCE baselines ([`host`], [`roce`]), the unified
//!   collective engine ([`collectives`] — a shared
//!   [`collectives::driver`] running a menu of schedule-generating
//!   algorithms: NetDAM ring, halving-doubling, hierarchical two-level,
//!   reduce-scatter/all-gather/broadcast primitives, and the host
//!   baselines), the session API ([`comm`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2 (python/compile/model.py)** — JAX compute graphs (SIMD block ops,
//!   reduce step, block hash, MLP train step) lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels implementing the
//!   paper's 2048-lane SIMD ALU semantics, verified against a pure-jnp
//!   oracle. The [`runtime`] module executes their ABI; in this offline
//!   build it computes through the bit-identical native ALU (PJRT stub).
//!
//! # The program layer (builder → verifier → executor)
//!
//! The ISA's fused behaviours are **packet programs**
//! ([`isa::Program`]): a packet carries a bounded step sequence that the
//! devices on its SROU path execute hop-locally, each step consuming the
//! previous step's result payload. Programs are assembled with
//! [`isa::ProgramBuilder`], statically checked by [`isa::Program::verify`]
//! (bounded length, memory ranges, SROU hop budget, and the paper's §2.3
//! relaxed-ordering rule — non-commutative reduces on unordered paths and
//! non-idempotent steps on lossy paths are rejected with a typed
//! [`isa::ProgramError`]), and executed by the micro-executor loop in
//! [`device`] with per-step cost accounting. Collective planners lower
//! their schedules onto programs via
//! [`collectives::driver::lower_ring_chunk`] /
//! [`collectives::driver::lower_store_chain`]: the §3 fused allreduce
//! chunk is `reduce ×(N−1) → guarded_write → store ×(N−1)` in one
//! self-routing packet, and DPU offloads chain the same way
//! (`crypto_write → crc32` — see `netdam prog`).
//!
//! # The transport engine (one window under collectives and memory)
//!
//! All host-side windowed I/O runs on **one** reliable-injection /
//! completion-refill state machine: [`transport::WindowEngine`].
//! The collective [`collectives::driver::Driver`] lowers its schedules
//! onto engine ops keyed by completion id (`CompletionKey::DoneId` — a
//! chain retires wherever its program's last hop runs), and the pooled
//! [`mem::MemClient`] keys by sequence number (`CompletionKey::Seq` —
//! RDMA-PSN-style request/response correlation); neither module owns a
//! windowing loop of its own. The engine provides per-slot self-clocked
//! windows, exactly-once retirement (retransmit echoes are deduped),
//! NAK surfacing with plan cancellation (queued ops are dropped,
//! in-flight ops drain, no timers or hooks leak), and a **paced mode**
//! that wires [`transport::TokenBucket`] into the refill decision — the
//! §2.5 "sequencing and rate-limited READ" incast cure as an engine
//! property rather than an app-level loop (E3's pull-back arm is a
//! `MemClient` paced read).
//!
//! # The memory plane (controller → lease → IOMMU → MemClient)
//!
//! The §2.5/§2.6 memory pool is a first-class data plane. The SDN
//! controller ([`pool::SdnController`]) owns the block-interleaved GVA
//! space; `malloc_mapped` turns each lease into per-device [`iommu`]
//! programs (map + R/W perms + tenant fence) and `grant_host` installs
//! the requester→tenant ACL on every device, so access control is
//! enforced **on the device**: a denied translation surfaces as a typed
//! wire-level `Nack` (see [`iommu::NakReason`]), not an in-process
//! error. Hosts drive the pool through [`mem::MemClient`]: reads/writes/
//! CAS against global virtual addresses compile into scatter-gather
//! packet plans over the interleave extents, driven by the shared
//! window engine (one reliable in-flight window per device, read data
//! reassembled in GVA order). [`mem::MemBatch`] pipelines many logical
//! ops — reads, writes, CAS, multi-bag gathers — through one windowed
//! run, `MemClient::with_pace` token-bucket-paces a client's plans, and
//! CAS is **replay-safe**: devices answer retransmits from a `(src,
//! seq)` response-dedupe cache instead of re-executing the swap.
//! `gather_sum` lowers a TensorDIMM-style sparse gather onto an
//! on-device `Simd`-reduce packet program. E3 (incast) and the kvstore/
//! mempool/embedding examples all run on this path — no raw physical
//! addresses on the host side.
//!
//! # The session API (one fabric, many tenants)
//!
//! The application surface is [`comm`]: a [`comm::Fabric`] is built
//! **once** ([`comm::FabricBuilder`]: topology + registry + DES engine
//! + optional pool controller) and tenants derive
//! [`comm::Communicator`]s from it. Communicator ops are
//! **nonblocking** — `iallreduce` / `ireduce_scatter` / `iallgather` /
//! `ibcast` / the rooted `ireduce` return redeemable handles, and
//! [`comm::Fabric::wait`] drives the shared DES — so concurrent
//! collectives from multiple communicators and pooled-memory batches
//! ([`comm::Fabric::submit_mem`]) multiplex onto **one**
//! [`transport::EngineSession`] with per-plan windows, per-plan NAK
//! cancellation (one tenant's bad lease never cancels a neighbor), and
//! optionally per-slot token buckets (per-destination pacing). The
//! gradient-bucketing fusion layer ([`comm::plan_buckets`]) packs
//! streams of small tensors into interleave-block-sized buckets before
//! lowering onto the planners — the NetReduce/Horovod fusion-buffer
//! trick. `collectives::run_collective` remains as a compatibility shim
//! over a single-use fabric; `netdam comm` demos two overlapping jobs.
//!
//! # The sharded DES core (scaling to 1024+ ranks)
//!
//! The simulator itself parallelizes: [`comm::FabricBuilder::with_shards`]
//! partitions the world onto `n` event shards — each with its own heap
//! and local clock ([`sim::ShardWorld`]) — advanced in bounded windows
//! under conservative lookahead (the minimum cross-shard link latency)
//! by [`sim::ShardedEngine`], with boundary-crossing events exchanged at
//! window edges over scoped threads. [`net::ShardedRuntime`] binds the
//! NetDAM cluster onto that machinery and replays session-layer
//! injections deterministically, so everything above — [`comm`],
//! [`collectives`], [`mem`] — runs unmodified on either core; the
//! classic single-heap [`sim::Engine`] remains the `shards = 0` default.
//! Determinism is the contract, not an aspiration: RNG streams are
//! partitioned per link and per host, so the same seed yields
//! **bit-identical** reports at any shard count, thread count, or rerun,
//! including under packet loss (`rust/tests/sharded_determinism.rs`).
//! `cargo bench --bench sim` measures events/sec across the shard grid
//! and writes `BENCH_sim.json`; `netdam comm --shards N` demos the path.
//!
//! # In-network aggregation (switches that compute, §2.5)
//!
//! The switches are a compute point, not just a forwarding fabric. A
//! bounded aggregation engine ([`net::AggEngine`]) lives in every
//! addressed switch: reduce contributions flagged
//! [`isa::Flags::AGG`] carry an aggregation manifest
//! ([`wire::AggMeta`] — tenant, group, op, and per-source entries) and
//! are folded **in the switch** through the same commutative-only SIMD
//! rules the program verifier enforces, with expected-fanin counting,
//! slot caps, and timeout eviction. An evicted or overflowed slot
//! degrades to plain forwarding — stragglers reduce at the endpoint,
//! never a wrong answer, and the engine's counters
//! ([`net::AggCounters`]) make the fast/slow split observable. The
//! [`collectives::AlgoKind::SwitchReduce`] planner lowers allreduce
//! onto the fat-tree's physical hierarchy (device → leaf → spine →
//! rotating per-block root, then a binomial down-broadcast shared with
//! [`collectives::TreeBroadcast`]), and the switch mirrors the memory
//! plane's §2.5 ACL: [`pool::IommuDirectory::bind_tenant`] programs
//! requester→tenant checks on the switches too, so a foreign tenant's
//! contributions are dropped (and counted) at the first hop.
//! Topology-aware shard placement ([`net::ShardPartition::Pods`]) keeps
//! each pod's devices and leaf on one DES shard; results stay
//! bit-identical to the default striping.
//!
//! # Closed-loop congestion control (DCQCN in the transport engine)
//!
//! Static token-bucket budgets (the §2.5 "rate-limited READ") need the
//! operator to know the fan-in; [`comm::FabricBuilder::with_congestion_control`]
//! with [`transport::CcMode::Dcqcn`] closes the loop instead. Switch
//! egress links RED-mark frames past a deterministic credit-based ramp
//! ([`net::LinkConfig::with_ecn`]), devices echo the CE bit onto every
//! emit of a marked request so it returns on the (uncongested)
//! completion path, and the session treats each CE-marked completion as
//! a CNP to the owning slot's [`roce::RateController`] — DCQCN's
//! α-tracked multiplicative cut, then timed fast-recovery and additive
//! probing ([`roce::DcqcnConfig`]). The controller's output drives the
//! slot's [`transport::TokenBucket`] via `set_rate`, whose release
//! envelope stays `burst + ∫rate(t)dt` across retargets, so adaptive
//! pacing inherits every paced-mode property. CE marking, echo, and CNP
//! absorption run identically on the classic and sharded DES cores
//! (CNPs fire from barrier-replayed completion records in global key
//! order), keeping rate *trajectories* bit-identical at any shard
//! count. `cargo bench --bench incast` runs the A/B: unpaced vs best
//! static budget vs DCQCN under fan-in {8, 32, 128} incast, reporting
//! goodput, p50/p99 completion latency, and Jain fairness
//! (`BENCH_incast.json`); `--cc dcqcn` turns it on from the CLI.
//!
//! # The serving tier (multi-tenant KV/embedding over the pool)
//!
//! [`serve`] drives the pooled fabric like a production inference
//! tier: a fleet of tenants, each with a private seeded request stream
//! ([`serve::TenantWorkload`]) of Zipf-skewed GET/PUT/CAS plus
//! TensorDIMM-style embedding bags (`gather_sum` packet programs),
//! runs open-loop on ONE [`comm::Fabric`] — every tenant's wave plan
//! submitted before any is redeemed — while scratch leases churn
//! (free + malloc reprogramming the device IOMMUs under live neighbor
//! traffic). The subsystem owns its reporting
//! ([`serve::ServeReport`]): per-tenant p50/p99/p99.9 tails
//! ([`util::stats::TailNs`] — all-integer, bit-comparable across DES
//! shard counts), goodput, NAK/cancellation counts, and fabric-wide
//! retransmit/CNP/churn counters. [`serve::isolation_check`] is the
//! tail-at-scale verdict: the same fleet replays with a deliberately
//! misbehaving tenant (a NAK storm compiled against a revoked lease —
//! killed by per-plan cancellation — plus an incast burst that DCQCN
//! rate-controls), and every well-behaved tenant's p99 must stay
//! within a configured bound of its aggressor-free baseline
//! (`rust/tests/serving_isolation.rs` pins 2x, bit-identical across
//! shard counts {1, 2, 4}). Surfaces: `netdam serve`,
//! `coordinator::run_e5`, and `cargo bench --bench serving`
//! (`BENCH_serving.json`: tenant-count x skew x cc-mode grid).
//!
//! # The allocation-free event model (typed events, shared bodies, wheel)
//!
//! Steady-state packet flow performs **no per-event heap allocation**.
//! Three mechanisms compose:
//!
//! * **Typed events.** The classic engine is generic over a
//!   [`sim::World`] whose associated `Event` type it stores *by value*
//!   and dispatches by `match` — the cluster's event vocabulary is
//!   [`net::NetEvent`] (send, arrive, deliver, retransmit, app tick),
//!   with a boxed-closure `Hook` variant kept only for setup/test code
//!   via `World::lift`. The sharded core has used typed per-shard events
//!   since PR 5; PR 9 brings the single-heap engine to parity.
//! * **Shared packet bodies.** [`wire::Payload`] stores ≤ 8-byte scalars
//!   inline and refcounts larger bodies (`Arc<Vec<u8>>`); SROU segment
//!   lists are a fixed inline array ([`wire::SegVec`]); aggregation
//!   manifests and packet programs ride behind `Arc` with copy-on-write
//!   (`Arc::make_mut`) at the single hop that mutates them. A `Packet`
//!   clone — into the retransmit buffer, a fan-out copy, a duplicate
//!   fault — is a few refcount bumps and a header memcpy.
//! * **The timer wheel.** Retransmit timers live on a hashed
//!   hierarchical [`sim::TimerWheel`] (4 levels × 64 slots,
//!   generation-stamped slab slots): O(1) arm, O(1) *exact* cancel when
//!   the ack lands — no tombstones accumulating behind a heap. The
//!   engine merges wheel and heap by `(time, seq)` with one shared
//!   sequence counter, so event order is bit-identical to a single heap
//!   and every `sharded_determinism.rs` guarantee survives.
//!
//! `rust/tests/alloc_free_hot_path.rs` enforces the contract with a
//! counting global allocator (zero allocations across warmed
//! Write→WriteAck round trips); `cargo bench --bench sim` reports
//! whole-run allocations-per-event alongside events/sec.

pub mod alu;
pub mod cli;
pub mod collectives;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod examples_support;
pub mod host;
pub mod iommu;
pub mod isa;
pub mod mem;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod roce;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod srou;
pub mod transport;
pub mod util;
pub mod wire;
