//! The network world on the sharded DES core (`sim::sharded`).
//!
//! [`ShardedRuntime`] partitions a built [`Cluster`] — devices, switches,
//! hosts, links — into `n` shards (node id modulo `n`; a link lives with
//! its transmitting node), runs them under conservative lookahead, and
//! reassembles the cluster afterwards so everything that pokes at nodes
//! between runs (gradient seeding, mailbox redemption, phase planning)
//! keeps working unchanged.
//!
//! [`ClusterShard`] deliberately mirrors `cluster.rs`'s forwarding and
//! delivery logic (`send_from` → `transmit_on` → `deliver` →
//! `exec_on_device` / app callbacks / completion notes) — keep the two in
//! sync when touching either. The differences are exactly the ones that
//! make parallel determinism possible:
//!
//! * **Events are plain data** ([`NetEvent`]), not boxed closures, so they
//!   can cross threads, and every event carries a canonical
//!   [`EventKey`] `(time, scheduling node, per-node counter)` — shards pop
//!   in key order, so execution order is a pure function of keys and
//!   never of the partition.
//! * **Randomness is partitioned**: loss/duplication draws come from a
//!   per-*link* stream and app randomness from a per-*host* stream (both
//!   seeded from `(seed, index)`), instead of the classic single
//!   `Cluster::rng`. Same seed ⇒ identical draws at any shard count.
//! * **Reliability and reordering are partitioned** by origin node and
//!   destination node respectively; counters merge back after the run.
//! * **Completion hooks run at window barriers**: shards log
//!   `(EventKey, CompletionRecord)`; between epochs the coordinator sorts
//!   the union by key, runs `Cluster::on_completion` in that global
//!   order, and applies the returned [`InjectCmd`]s with
//!   coordinator-stamped keys. Injection times are computed from the
//!   *record's* time (exactly like the classic inline hook), so the
//!   deferred dispatch is timing-transparent.
//!
//! Lookahead is `min(INJECT_NS, min link propagation delay)`: every
//! cross-shard event (a link delivery, or a coordinator injection) lands
//! at least that far past the window's base, i.e. always in a future
//! window.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::isa::Flags;
use crate::metrics::Metrics;
use crate::sim::{
    Engine, EventKey, ShardRunStats, ShardWorld, ShardedEngine, SimTime, COORDINATOR_SRC,
};
use crate::transport::{ReliabilityTable, ReorderBuffer, RetryVerdict};
use crate::util::Xoshiro256;
use crate::wire::{DeviceIp, Packet};

use super::cluster::{
    ecmp_hash, is_completion, Action, AppCtx, Cluster, CompletionRecord, InjectCmd, Node, NodeId,
    INJECT_NS, LOOPBACK_NS,
};
use super::link::{Link, LinkId, TxResult};

/// A network event as plain (thread-mobile) data. Every variant executes
/// on exactly one node, and same-time follow-ups are always scheduled by
/// the node that executes them — the two facts the determinism argument
/// leans on.
///
/// Retransmit timers stay on the shard heap as epoch-guarded [`Retry`]
/// events rather than on a cancellable timer wheel (the classic engine's
/// approach): a completion may land on a different shard than the shard
/// holding the timer, so cancellation would require cross-shard
/// communication mid-window. Stale timers instead no-op through the
/// epoch check — a bounded, deterministic cost.
///
/// [`Retry`]: NetEvent::Retry
#[derive(Debug)]
pub(crate) enum NetEvent {
    /// Emit `pkt` from `node` toward its current SROU segment.
    SendFrom { node: NodeId, pkt: Packet },
    /// `pkt` arrives at `node` (the only event kind born cross-shard).
    Deliver { node: NodeId, pkt: Packet },
    /// Retransmit timer for `(origin, seq)` at `epoch`.
    Retry { origin: NodeId, seq: u64, epoch: u32 },
    /// Host app `on_start`.
    AppStart { node: NodeId },
    /// Host app `on_timer(token)`.
    AppTimer { node: NodeId, token: u64 },
}

impl NetEvent {
    /// The node that executes this event (decides shard ownership).
    fn node(&self) -> NodeId {
        match self {
            NetEvent::SendFrom { node, .. }
            | NetEvent::Deliver { node, .. }
            | NetEvent::AppStart { node }
            | NetEvent::AppTimer { node, .. } => *node,
            NetEvent::Retry { origin, .. } => *origin,
        }
    }
}

/// Heap entry; ordering by key only (min-heap via inverted cmp).
pub(crate) struct ShardEntry {
    key: EventKey,
    ev: NetEvent,
}

impl PartialEq for ShardEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl Eq for ShardEntry {}
impl PartialOrd for ShardEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ShardEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other.key.cmp(&self.key) // earliest-first
    }
}

/// How nodes are mapped onto shards.
///
/// `Modulo` is the historical default (`node % nshards`). `Pods` keeps a
/// fat-tree pod — its devices and its leaf switch — on one shard, so the
/// dense leaf-local traffic (including in-network aggregation at the
/// leaf) never crosses a shard boundary; only spine hops do. The actual
/// pod→shard table is computed where the topology is known
/// ([`crate::comm::FabricBuilder`]) and installed with
/// [`ShardedRuntime::with_assignment`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardPartition {
    /// `node % nshards` — the historical mapping.
    #[default]
    Modulo,
    /// Fat-tree pods map whole onto shards; falls back to `Modulo` on
    /// topologies without pods.
    Pods,
}

/// Immutable routing facts shared by all shards (the topology is fixed
/// once a cluster is built).
struct Routes {
    fib: Vec<std::collections::HashMap<DeviceIp, Vec<LinkId>>>,
    node_ip: Vec<Option<DeviceIp>>,
    link_owner: Vec<NodeId>,
    /// Node → shard table (resolved [`ShardPartition`]).
    assign: Vec<usize>,
}

/// One shard: the nodes/links it owns (full-length `Option` vectors so
/// global ids index directly), its event heap, and its partitioned slices
/// of the cluster's mutable state.
pub(crate) struct ClusterShard {
    index: usize,
    routes: Arc<Routes>,
    nodes: Vec<Option<Node>>,
    links: Vec<Option<Link>>,
    link_rng: Vec<Option<Xoshiro256>>,
    host_rng: Vec<Option<Xoshiro256>>,
    reorder: Vec<Option<ReorderBuffer>>,
    xport: ReliabilityTable,
    fault: super::cluster::FaultModel,
    metrics: Metrics,
    trace_device_service: bool,
    heap: BinaryHeap<ShardEntry>,
    /// Per-node scheduling counters (only owned indices are used).
    sched_seq: Vec<u64>,
    /// Cross-shard events born this window: `(destination shard, entry)`.
    outbox: Vec<(usize, ShardEntry)>,
    /// `(key of the executing event, record)` — drained by the
    /// coordinator at each barrier and replayed in global key order.
    completion_log: Vec<(EventKey, CompletionRecord)>,
    now: SimTime,
    current_key: EventKey,
    processed: u64,
    last_event: SimTime,
    /// High-water mark of this shard's heap (live scheduled events) —
    /// the sharded analogue of the classic engine's `peak_live`.
    peak_live: usize,
    /// Reused buffer for device emissions (allocation-free hot path).
    emit_scratch: Vec<crate::device::Emit>,
}

impl ClusterShard {
    fn owns(&self, node: NodeId) -> bool {
        self.routes.assign[node] == self.index
    }

    /// Push an event created outside the shard's own execution (a
    /// coordinator injection or an initial kick).
    pub(crate) fn push_external(&mut self, key: EventKey, ev: NetEvent) {
        debug_assert!(self.owns(ev.node()), "event routed to wrong shard");
        self.heap.push(ShardEntry { key, ev });
        self.peak_live = self.peak_live.max(self.heap.len());
    }

    pub(crate) fn take_completions(&mut self) -> Vec<(EventKey, CompletionRecord)> {
        std::mem::take(&mut self.completion_log)
    }

    /// Schedule a follow-up created by node `by`'s execution. Routed to
    /// the owner shard (heap if local, outbox if not).
    fn sched(&mut self, time: SimTime, by: NodeId, ev: NetEvent) {
        let seq = self.sched_seq[by];
        self.sched_seq[by] += 1;
        let key = EventKey { time, src: by, seq };
        let dst_shard = self.routes.assign[ev.node()];
        if dst_shard == self.index {
            self.heap.push(ShardEntry { key, ev });
            self.peak_live = self.peak_live.max(self.heap.len());
        } else {
            self.outbox.push((dst_shard, ShardEntry { key, ev }));
        }
    }

    fn exec(&mut self, ev: NetEvent) {
        match ev {
            NetEvent::SendFrom { node, pkt } => self.send_from(node, pkt),
            NetEvent::Deliver { node, pkt } => self.deliver(node, pkt),
            NetEvent::Retry { origin, seq, epoch } => {
                match self.xport.on_timeout(origin, seq, epoch) {
                    RetryVerdict::Done | RetryVerdict::Failed => {}
                    RetryVerdict::Resend(pkt) => {
                        self.metrics.inc("retransmits");
                        let next_epoch =
                            self.xport.epoch(origin, seq).expect("pending after resend");
                        self.arm_retry(origin, seq, next_epoch);
                        self.send_from(origin, pkt);
                    }
                }
            }
            NetEvent::AppStart { node } => self.with_app(node, |app, ctx| app.on_start(ctx)),
            NetEvent::AppTimer { node, token } => {
                self.with_app(node, |app, ctx| app.on_timer(token, ctx))
            }
        }
    }

    fn arm_retry(&mut self, origin: NodeId, seq: u64, epoch: u32) {
        let timeout = self.xport.timeout_ns;
        self.sched(
            self.now + timeout,
            origin,
            NetEvent::Retry { origin, seq, epoch },
        );
    }

    fn inject(&mut self, origin: NodeId, pkt: Packet) {
        self.sched(
            self.now + INJECT_NS,
            origin,
            NetEvent::SendFrom { node: origin, pkt },
        );
    }

    fn inject_reliable(&mut self, origin: NodeId, pkt: Packet) {
        debug_assert!(
            pkt.instr.replay_safe(pkt.flags),
            "reliable injection of non-replay-safe {:?}",
            pkt.instr
        );
        let seq = pkt.seq;
        let epoch = self.xport.track(origin, pkt.clone());
        self.arm_retry(origin, seq, epoch);
        self.inject(origin, pkt);
    }

    // Mirrors `Cluster::send_from`.
    fn send_from(&mut self, node: NodeId, pkt: Packet) {
        let Some(dst) = pkt.dst() else {
            self.metrics.inc("drop_no_segment");
            return;
        };
        if self.routes.node_ip[node] == Some(dst) {
            self.sched(
                self.now + LOOPBACK_NS,
                node,
                NetEvent::Deliver { node, pkt },
            );
            return;
        }
        let Some(cands) = self.routes.fib[node].get(&dst) else {
            self.metrics.inc("drop_no_route");
            return;
        };
        debug_assert!(!cands.is_empty());
        let lid = if cands.len() == 1 {
            cands[0]
        } else {
            let pick = match self.nodes[node].as_mut().expect("own node") {
                Node::Switch(sw) => sw.pick(&pkt, dst, cands.len()),
                _ => ecmp_hash(pkt.src, dst, cands.len()),
            };
            cands[pick]
        };
        self.transmit_on(lid, pkt);
    }

    // Mirrors `Cluster::transmit_on`, with the loss/dup draws moved to the
    // link's own RNG stream (same draw order: loss, dup, then jitter).
    fn transmit_on(&mut self, lid: LinkId, mut pkt: Packet) {
        let bytes = pkt.wire_bytes();
        let now = self.now;
        let from = self.routes.link_owner[lid];
        let link = self.links[lid].as_mut().expect("link owned by shard");
        let to = link.to;
        let tx = link.transmit(now, bytes);
        match tx {
            TxResult::Dropped => {
                self.metrics.inc("link_drops");
            }
            TxResult::Sent {
                arrival,
                departure: _,
                ecn,
            } => {
                if ecn {
                    pkt.flags = pkt.flags.with(Flags::ECN);
                }
                let (lost, dup_jitter) = {
                    let rng = self.link_rng[lid].as_mut().expect("link rng");
                    let lost = self.fault.loss_p > 0.0 && rng.chance(self.fault.loss_p);
                    let dup = self.fault.dup_p > 0.0 && rng.chance(self.fault.dup_p);
                    let jitter = if dup {
                        Some(200 + rng.next_below(800))
                    } else {
                        None
                    };
                    (lost, jitter)
                };
                let mut pkt = Some(pkt);
                if lost {
                    self.metrics.inc("fault_lost");
                } else {
                    // Clone only if the duplicate also needs the packet
                    // (shallow: Arc bumps + header memcpy).
                    let p = if dup_jitter.is_some() {
                        pkt.clone().expect("packet present")
                    } else {
                        pkt.take().expect("packet present")
                    };
                    self.sched(arrival, from, NetEvent::Deliver { node: to, pkt: p });
                }
                if let Some(jitter) = dup_jitter {
                    self.metrics.inc("fault_duplicated");
                    let p = pkt.take().expect("packet present");
                    self.sched(
                        arrival + jitter,
                        from,
                        NetEvent::Deliver { node: to, pkt: p },
                    );
                }
            }
        }
    }

    // Mirrors `Cluster::deliver`, with per-destination reorder buffers.
    fn deliver(&mut self, node: NodeId, mut pkt: Packet) {
        // Keep in sync with `Cluster::deliver`: aggregation-marked
        // packets reaching a switch take the ACL + slot-table path.
        if pkt.flags.agg() && matches!(self.nodes[node], Some(Node::Switch(_))) {
            return self.deliver_agg(node, pkt);
        }
        enum Kind {
            Switch { latency: SimTime },
            Device,
            Host { has_app: bool },
        }
        let kind = match self.nodes[node].as_mut().expect("own node") {
            Node::Switch(sw) => {
                if let (Some(ip), Some(cur)) = (sw.ip, pkt.srou.current()) {
                    if cur.node == ip {
                        pkt.srou.advance();
                    }
                }
                if pkt.dst().is_none() {
                    sw.no_route_drops += 1;
                    self.metrics.inc("drop_no_segment");
                    return;
                }
                sw.forwarded += 1;
                Kind::Switch {
                    latency: sw.latency_ns,
                }
            }
            Node::Device(dev) => {
                if pkt.dst() != Some(dev.ip()) {
                    self.metrics.inc("drop_misrouted");
                    return;
                }
                Kind::Device
            }
            Node::Host(h) => {
                if pkt.dst() != Some(h.ip) {
                    self.metrics.inc("drop_misrouted");
                    return;
                }
                Kind::Host {
                    has_app: h.app.is_some(),
                }
            }
        };
        if !matches!(kind, Kind::Switch { .. }) && pkt.flags.ecn() {
            self.metrics.inc("ecn_ce_received");
        }
        match kind {
            Kind::Switch { latency } => {
                self.sched(self.now + latency, node, NetEvent::SendFrom { node, pkt });
            }
            Kind::Device => {
                if is_completion(&pkt.instr) {
                    self.note_completion(node, &pkt);
                }
                if pkt.flags.ordered() {
                    let src = pkt.src;
                    let release = self.reorder[node]
                        .as_mut()
                        .expect("reorder buf")
                        .offer(src, pkt);
                    for p in release {
                        self.exec_on_device(node, p);
                    }
                } else {
                    self.exec_on_device(node, pkt);
                }
            }
            Kind::Host { has_app } => {
                if is_completion(&pkt.instr) {
                    self.note_completion(node, &pkt);
                }
                if has_app {
                    self.with_app(node, |app, ctx| app.on_packet(pkt, ctx));
                } else {
                    let now = self.now;
                    match self.nodes[node].as_mut().expect("own node") {
                        Node::Host(h) => h.mailbox.push((now, pkt)),
                        _ => unreachable!(),
                    }
                }
            }
        }
    }

    // Mirrors the aggregation branch of `Cluster::deliver`: the switch's
    // ACL + bounded slot table decide what (if anything) to forward.
    fn deliver_agg(&mut self, node: NodeId, mut pkt: Packet) {
        let now = self.now;
        let verdict = match self.nodes[node].as_mut().expect("own node") {
            Node::Switch(sw) => {
                let mut was_waypoint = false;
                let mut fanin = 0u16;
                if let (Some(ip), Some(cur)) = (sw.ip, pkt.srou.current()) {
                    if cur.node == ip {
                        was_waypoint = true;
                        fanin = cur.func;
                        pkt.srou.advance();
                    }
                }
                if pkt.dst().is_none() {
                    sw.no_route_drops += 1;
                    self.metrics.inc("drop_no_segment");
                    None
                } else {
                    let outs = sw.offer_agg(now, was_waypoint, fanin, pkt);
                    sw.forwarded += outs.len() as u64;
                    self.metrics
                        .add("switch_agg_absorbed", outs.is_empty() as u64);
                    Some((outs, sw.latency_ns))
                }
            }
            _ => unreachable!("deliver_agg only runs on switches"),
        };
        if let Some((outs, latency)) = verdict {
            for p in outs {
                self.sched(now + latency, node, NetEvent::SendFrom { node, pkt: p });
            }
        }
    }

    // Mirrors `Cluster::exec_on_device`.
    fn exec_on_device(&mut self, node: NodeId, pkt: Packet) {
        let now = self.now;
        let mut emits = std::mem::take(&mut self.emit_scratch);
        emits.clear();
        match self.nodes[node].as_mut().expect("own node") {
            Node::Device(d) => d.handle_packet_into(now, pkt, &mut emits),
            _ => unreachable!(),
        }
        for e in emits.drain(..) {
            if self.trace_device_service {
                self.metrics.record("device_service_ns", e.delay);
            }
            self.sched(
                now + e.delay,
                node,
                NetEvent::SendFrom { node, pkt: e.pkt },
            );
        }
        self.emit_scratch = emits;
    }

    // Mirrors `Cluster::note_completion`, except the hook dispatch is
    // deferred to the barrier coordinator (which replays records in
    // global key order).
    fn note_completion(&mut self, node: NodeId, pkt: &Packet) {
        // No wheel timer to cancel here: sharded retries are epoch-guarded
        // heap events, so the returned TimerId is always None.
        let _ = self.xport.complete(node, pkt.seq);
        let rec = CompletionRecord {
            time: self.now,
            node,
            from: pkt.src,
            seq: pkt.seq,
            instr: pkt.instr.clone(),
            ecn: pkt.flags.ecn(),
        };
        self.completion_log.push((self.current_key, rec));
    }

    // Mirrors `Cluster::with_app`, drawing from the host's own RNG stream.
    fn with_app<F>(&mut self, node: NodeId, f: F)
    where
        F: FnOnce(&mut dyn super::cluster::App, &mut AppCtx),
    {
        let (ip, mut app, mut next_seq) = match self.nodes[node].as_mut().expect("own node") {
            Node::Host(h) => (h.ip, h.app.take().expect("app present"), h.next_seq),
            _ => panic!("with_app on non-host"),
        };
        let actions = {
            let rng = self.host_rng[node].as_mut().expect("host rng");
            let mut ctx = AppCtx {
                now: self.now,
                self_ip: ip,
                rng,
                next_seq: &mut next_seq,
                actions: Vec::new(),
            };
            f(app.as_mut(), &mut ctx);
            std::mem::take(&mut ctx.actions)
        };
        if let Some(Node::Host(h)) = self.nodes[node].as_mut() {
            h.app = Some(app);
            h.next_seq = next_seq;
        }
        for a in actions {
            match a {
                Action::Send(pkt) => self.inject(node, pkt),
                Action::SendReliable(pkt) => self.inject_reliable(node, pkt),
                Action::Timer(delay, token) => {
                    self.sched(self.now + delay, node, NetEvent::AppTimer { node, token });
                }
                Action::Record(name, v) => self.metrics.record(&name, v),
                Action::Count(name, v) => self.metrics.add(&name, v),
            }
        }
    }
}

impl ShardWorld for ClusterShard {
    type Msg = ShardEntry;

    fn next_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.key.time)
    }

    fn run_window(&mut self, end: SimTime) -> Vec<(usize, ShardEntry)> {
        while let Some(e) = self.heap.peek() {
            if e.key.time >= end {
                break;
            }
            let e = self.heap.pop().expect("peeked");
            self.now = e.key.time;
            self.current_key = e.key;
            self.processed += 1;
            self.last_event = e.key.time;
            self.exec(e.ev);
        }
        std::mem::take(&mut self.outbox)
    }

    fn accept(&mut self, msg: ShardEntry) {
        debug_assert!(self.owns(msg.ev.node()), "message routed to wrong shard");
        self.heap.push(msg);
        self.peak_live = self.peak_live.max(self.heap.len());
    }

    fn events_processed(&self) -> u64 {
        self.processed
    }

    fn last_event_time(&self) -> SimTime {
        self.last_event
    }
}

/// Persistent sharded-execution state for one cluster: the shared route
/// snapshot, the per-link / per-host RNG streams and per-node reorder
/// buffers (all of which must survive across successive `drive` rounds,
/// exactly like `Cluster::rng`/`Cluster::reorder` survive across
/// `Engine::run` calls), and cumulative run statistics.
pub struct ShardedRuntime {
    nshards: usize,
    threads: usize,
    lookahead: SimTime,
    routes: Arc<Routes>,
    link_rng: Vec<Xoshiro256>,
    host_rng: Vec<Xoshiro256>,
    reorder: Vec<ReorderBuffer>,
    coord_seq: u64,
    /// Cumulative events executed across all `drive` rounds.
    pub events: u64,
    /// Cumulative window barriers crossed.
    pub epochs: u64,
    /// High-water mark of live scheduled events, summed across shards
    /// within a round and maxed across rounds — the sharded counterpart
    /// of the classic engine's `peak_live`. Per-shard peaks need not be
    /// simultaneous, so this is a (tight in practice) upper bound on the
    /// instantaneous global live-event count.
    pub peak_live: u64,
}

fn stream_seed(seed: u64, tag: u64, index: usize) -> u64 {
    seed ^ (tag << 56) ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

impl ShardedRuntime {
    /// Build for a fully-constructed cluster (topology must be final:
    /// routes are snapshotted here). `threads = 0` means "pick from
    /// available parallelism".
    pub fn new(cl: &Cluster, seed: u64, nshards: usize, threads: usize) -> Self {
        let nshards = nshards.max(1);
        let n = cl.nodes.len();
        let min_prop = cl.links.iter().map(|l| l.cfg.prop_ns).min().unwrap_or(INJECT_NS);
        if nshards > 1 {
            assert!(
                min_prop >= 1,
                "sharded execution needs >= 1 ns of link propagation for lookahead"
            );
        }
        let routes = Arc::new(Routes {
            fib: cl.fib.clone(),
            node_ip: (0..n).map(|i| cl.node_ip(i)).collect(),
            link_owner: cl.links.iter().map(|l| l.from).collect(),
            assign: (0..n).map(|i| i % nshards).collect(),
        });
        Self {
            nshards,
            threads,
            lookahead: INJECT_NS.min(min_prop).max(1),
            routes,
            link_rng: (0..cl.links.len())
                .map(|i| Xoshiro256::seed_from(stream_seed(seed, 0x51, i)))
                .collect(),
            host_rng: (0..n)
                .map(|i| Xoshiro256::seed_from(stream_seed(seed, 0x52, i)))
                .collect(),
            reorder: (0..n).map(|_| ReorderBuffer::new()).collect(),
            coord_seq: 0,
            events: 0,
            epochs: 0,
            peak_live: 0,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.nshards
    }

    /// Install a custom node→shard table (a resolved [`ShardPartition`]
    /// mapping, e.g. fat-tree pods→shards). Must be called before the
    /// first `drive`; determinism is unaffected — event order is a pure
    /// function of [`EventKey`]s, never of the partition.
    pub fn with_assignment(mut self, assign: Vec<usize>) -> Self {
        let routes = Arc::get_mut(&mut self.routes)
            .expect("set the shard assignment before the first drive");
        assert_eq!(
            assign.len(),
            routes.node_ip.len(),
            "assignment must cover every node"
        );
        assert!(
            assign.iter().all(|&s| s < self.nshards),
            "assignment names a shard >= {}",
            self.nshards
        );
        routes.assign = assign;
        self
    }

    /// Partition the cluster's mutable state into shards.
    fn decompose(&mut self, cl: &mut Cluster) -> Vec<ClusterShard> {
        let n = cl.nodes.len();
        let nlinks = cl.links.len();
        let mut shards: Vec<ClusterShard> = (0..self.nshards)
            .map(|index| ClusterShard {
                index,
                routes: Arc::clone(&self.routes),
                nodes: (0..n).map(|_| None).collect(),
                links: (0..nlinks).map(|_| None).collect(),
                link_rng: (0..nlinks).map(|_| None).collect(),
                host_rng: (0..n).map(|_| None).collect(),
                reorder: (0..n).map(|_| None).collect(),
                xport: ReliabilityTable::new(cl.xport.timeout_ns, cl.xport.max_retries),
                fault: cl.fault.clone(),
                metrics: Metrics::new(),
                trace_device_service: cl.trace_device_service,
                heap: BinaryHeap::new(),
                sched_seq: vec![0; n],
                outbox: Vec::new(),
                completion_log: Vec::new(),
                now: 0,
                current_key: EventKey {
                    time: 0,
                    src: COORDINATOR_SRC,
                    seq: 0,
                },
                processed: 0,
                last_event: 0,
                peak_live: 0,
                emit_scratch: Vec::new(),
            })
            .collect();
        for (i, node) in std::mem::take(&mut cl.nodes).into_iter().enumerate() {
            shards[self.routes.assign[i]].nodes[i] = Some(node);
        }
        for (lid, link) in std::mem::take(&mut cl.links).into_iter().enumerate() {
            let owner = self.routes.assign[link.from];
            shards[owner].links[lid] = Some(link);
        }
        for (lid, rng) in std::mem::take(&mut self.link_rng).into_iter().enumerate() {
            let owner = self.routes.assign[self.routes.link_owner[lid]];
            shards[owner].link_rng[lid] = Some(rng);
        }
        for (i, rng) in std::mem::take(&mut self.host_rng).into_iter().enumerate() {
            shards[self.routes.assign[i]].host_rng[i] = Some(rng);
        }
        for (i, buf) in std::mem::take(&mut self.reorder).into_iter().enumerate() {
            shards[self.routes.assign[i]].reorder[i] = Some(buf);
        }
        shards
    }

    /// Put everything back and fold partitioned state into the cluster.
    fn reassemble(&mut self, cl: &mut Cluster, shards: Vec<ClusterShard>) {
        let n = self.routes.node_ip.len();
        let nlinks = self.routes.link_owner.len();
        let mut nodes: Vec<Option<Node>> = (0..n).map(|_| None).collect();
        let mut links: Vec<Option<Link>> = (0..nlinks).map(|_| None).collect();
        let mut link_rng: Vec<Option<Xoshiro256>> = (0..nlinks).map(|_| None).collect();
        let mut host_rng: Vec<Option<Xoshiro256>> = (0..n).map(|_| None).collect();
        let mut reorder: Vec<Option<ReorderBuffer>> = (0..n).map(|_| None).collect();
        let mut round_peak = 0u64;
        for shard in shards {
            round_peak += shard.peak_live as u64;
            debug_assert_eq!(shard.xport.outstanding(), 0, "run ended with pending retries");
            cl.xport.retransmits += shard.xport.retransmits;
            cl.xport.failures += shard.xport.failures;
            cl.xport.completed += shard.xport.completed;
            cl.metrics.merge(&shard.metrics);
            for (i, slot) in shard.nodes.into_iter().enumerate() {
                if let Some(node) = slot {
                    nodes[i] = Some(node);
                }
            }
            for (i, slot) in shard.links.into_iter().enumerate() {
                if let Some(link) = slot {
                    links[i] = Some(link);
                }
            }
            for (i, slot) in shard.link_rng.into_iter().enumerate() {
                if let Some(rng) = slot {
                    link_rng[i] = Some(rng);
                }
            }
            for (i, slot) in shard.host_rng.into_iter().enumerate() {
                if let Some(rng) = slot {
                    host_rng[i] = Some(rng);
                }
            }
            for (i, slot) in shard.reorder.into_iter().enumerate() {
                if let Some(buf) = slot {
                    reorder[i] = Some(buf);
                }
            }
        }
        cl.nodes = nodes.into_iter().map(|s| s.expect("node returned")).collect();
        cl.links = links.into_iter().map(|s| s.expect("link returned")).collect();
        self.link_rng = link_rng
            .into_iter()
            .map(|s| s.expect("link rng returned"))
            .collect();
        self.host_rng = host_rng
            .into_iter()
            .map(|s| s.expect("host rng returned"))
            .collect();
        self.reorder = reorder
            .into_iter()
            .map(|s| s.expect("reorder returned"))
            .collect();
        self.peak_live = self.peak_live.max(round_peak);
    }

    /// Run the cluster to quiescence on the sharded core.
    ///
    /// `injected` is the drained capture buffer: `(capture time, cmd)`
    /// pairs recorded by [`Cluster::inject_cmd`] while in capture mode.
    /// Completion hooks fire at window barriers in global key order; the
    /// engine's clock is advanced to the last executed event time so
    /// subsequent submissions stamp the same times the classic path
    /// would.
    pub fn drive(
        &mut self,
        cl: &mut Cluster,
        eng: &mut Engine<Cluster>,
        injected: Vec<(SimTime, InjectCmd)>,
    ) -> ShardRunStats {
        let mut shards = self.decompose(cl);
        let routes = Arc::clone(&self.routes);
        let mut coord_seq = self.coord_seq;
        for (base, cmd) in injected {
            apply_cmd(&mut shards, &routes.assign, cmd, base, &mut coord_seq);
        }
        let mut engine = ShardedEngine::new(shards, self.lookahead);
        if self.threads > 0 {
            engine = engine.with_threads(self.threads);
        }
        let stats = engine.run(|shards, _end| {
            let mut recs: Vec<(EventKey, CompletionRecord)> = Vec::new();
            for s in shards.iter_mut() {
                recs.append(&mut s.take_completions());
            }
            recs.sort_by(|a, b| a.0.cmp(&b.0));
            for (_, rec) in recs {
                if let Some(mut hook) = cl.on_completion.take() {
                    let cmds = hook(&rec);
                    cl.on_completion.replace(hook);
                    for c in cmds {
                        apply_cmd(shards, &routes.assign, c, rec.time, &mut coord_seq);
                    }
                }
                cl.completions.push(rec);
            }
        });
        self.coord_seq = coord_seq;
        let shards = engine.into_shards();
        self.reassemble(cl, shards);
        self.events += stats.events;
        self.epochs += stats.epochs;
        eng.advance_to(stats.end_time);
        stats
    }
}

/// Apply an [`InjectCmd`] as a coordinator injection: reliability
/// tracking on the origin's shard plus a `SendFrom` after the classic
/// request-queue latency, both stamped with coordinator keys. Mirrors
/// `Cluster::inject_cmd` / `inject_reliable` timing exactly
/// (`base + delay` is when the classic deferred closure would run).
fn apply_cmd(
    shards: &mut [ClusterShard],
    assign: &[usize],
    cmd: InjectCmd,
    base: SimTime,
    coord_seq: &mut u64,
) {
    let InjectCmd {
        origin,
        pkt,
        reliable,
        delay,
    } = cmd;
    let t0 = base + delay;
    let shard = &mut shards[assign[origin]];
    if reliable {
        debug_assert!(
            pkt.instr.replay_safe(pkt.flags),
            "reliable injection of non-replay-safe {:?}",
            pkt.instr
        );
        let seq = pkt.seq;
        let epoch = shard.xport.track(origin, pkt.clone());
        let timeout = shard.xport.timeout_ns;
        *coord_seq += 1;
        shard.push_external(
            EventKey {
                time: t0 + timeout,
                src: COORDINATOR_SRC,
                seq: *coord_seq,
            },
            NetEvent::Retry { origin, seq, epoch },
        );
    }
    *coord_seq += 1;
    shard.push_external(
        EventKey {
            time: t0 + INJECT_NS,
            src: COORDINATOR_SRC,
            seq: *coord_seq,
        },
        NetEvent::SendFrom { node: origin, pkt },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceConfig;
    use crate::isa::Instruction;
    use crate::net::switch::Switch;
    use crate::net::LinkConfig;
    use crate::wire::{Payload, SrouHeader};

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    fn star(seed: u64) -> (Cluster, NodeId) {
        let mut cl = Cluster::new(seed);
        let sw = cl.add_switch(Switch::tor(None));
        let h = cl.add_host(ip(100), None);
        let d1 = cl.add_device(DeviceConfig::paper_default(ip(1)));
        let d2 = cl.add_device(DeviceConfig::paper_default(ip(2)));
        for n in [h, d1, d2] {
            cl.connect(sw, n, LinkConfig::dc_100g());
        }
        cl.compute_routes();
        (cl, h)
    }

    fn write_then_read(nshards: usize) -> (SimTime, Vec<f32>) {
        let (mut cl, h) = star(7);
        let mut eng: Engine<Cluster> = Engine::new();
        let mut rt = ShardedRuntime::new(&cl, 7, nshards, 1);
        let seq = cl.alloc_seq(h);
        let w = Packet::new(
            ip(100),
            seq,
            SrouHeader::direct(ip(1)),
            Instruction::Write { addr: 0x40 },
        )
        .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        let seq2 = cl.alloc_seq(h);
        let r = Packet::new(
            ip(100),
            seq2,
            SrouHeader::direct(ip(1)),
            Instruction::Read { addr: 0x40, len: 8 },
        );
        // Write at t=0, read well after it settles.
        let injected = vec![
            (
                0,
                InjectCmd {
                    origin: h,
                    pkt: w,
                    reliable: false,
                    delay: 0,
                },
            ),
            (
                0,
                InjectCmd {
                    origin: h,
                    pkt: r,
                    reliable: false,
                    delay: 100_000,
                },
            ),
        ];
        let stats = rt.drive(&mut cl, &mut eng, injected);
        assert!(stats.events > 0);
        assert_eq!(eng.now(), stats.end_time);
        let mailbox = &cl.host_mut(h).mailbox;
        assert_eq!(mailbox.len(), 1);
        let (t, resp) = &mailbox[0];
        assert!(matches!(resp.instr, Instruction::ReadResp { addr: 0x40 }));
        (*t, resp.payload.f32s().unwrap().unwrap())
    }

    #[test]
    fn sharded_round_trip_matches_across_shard_counts() {
        let (t1, d1) = write_then_read(1);
        let (t2, d2) = write_then_read(2);
        let (t3, d3) = write_then_read(3);
        assert_eq!((t1, &d1), (t2, &d2));
        assert_eq!((t1, &d1), (t3, &d3));
        assert_eq!(d1, vec![1.0, 2.0], "read returns the written payload");
        assert!(t1 > 100_000);
    }

    #[test]
    fn peak_live_is_recorded_and_deterministic() {
        let run = |nshards| {
            let (mut cl, h) = star(7);
            let mut eng: Engine<Cluster> = Engine::new();
            let mut rt = ShardedRuntime::new(&cl, 7, nshards, 1);
            let seq = cl.alloc_seq(h);
            let w = Packet::new(
                ip(100),
                seq,
                SrouHeader::direct(ip(1)),
                Instruction::Write { addr: 0x40 },
            )
            .with_payload(Payload::from_f32s(&[1.0]));
            rt.drive(
                &mut cl,
                &mut eng,
                vec![(
                    0,
                    InjectCmd {
                        origin: h,
                        pkt: w,
                        reliable: false,
                        delay: 0,
                    },
                )],
            );
            rt.peak_live
        };
        // Any run schedules at least one event, so the high-water mark is
        // nonzero, and on one shard it is exact (single heap).
        let single = run(1);
        assert!(single > 0, "peak_live never recorded");
        assert_eq!(single, run(1), "peak_live not deterministic");
        // More shards split the heap; each shard's peak is bounded by the
        // single-heap peak, so the summed bound is at most nshards times it.
        let split = run(2);
        assert!(split > 0 && split <= single * 2, "split peak {split} vs {single}");
    }

    #[test]
    fn reliable_injection_retransmits_through_loss_sharded() {
        for nshards in [1usize, 2, 4] {
            let (mut cl, h) = star(9);
            cl.fault.loss_p = 0.2;
            cl.xport = ReliabilityTable::new(20_000, 30);
            let mut eng: Engine<Cluster> = Engine::new();
            let mut rt = ShardedRuntime::new(&cl, 9, nshards, 1);
            let seq = cl.alloc_seq(h);
            let w = Packet::new(
                ip(100),
                seq,
                SrouHeader::direct(ip(1)),
                Instruction::Write { addr: 0 },
            )
            .with_flags(Flags(Flags::RELIABLE))
            .with_payload(Payload::from_f32s(&[42.0]));
            rt.drive(
                &mut cl,
                &mut eng,
                vec![(
                    0,
                    InjectCmd {
                        origin: h,
                        pkt: w,
                        reliable: true,
                        delay: 0,
                    },
                )],
            );
            assert_eq!(cl.xport.outstanding(), 0);
            assert_eq!(cl.xport.failures, 0, "loss but generous retries");
            let d1 = cl.node_by_ip(ip(1)).unwrap();
            let v = cl.device_mut(d1).mem().read(0, 4).unwrap();
            assert_eq!(v, 42.0f32.to_le_bytes());
        }
    }
}
