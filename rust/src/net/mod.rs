//! The Ethernet fabric: links, switches, topologies, and the [`Cluster`]
//! world that ties devices, switches and hosts onto the DES engine.
//!
//! This is the substrate the paper's testbed provides physically (100G
//! ports + a Cisco Nexus 93180FX): store-and-forward switching with finite
//! egress buffers (tail-drop + ECN), picosecond-accurate serialization,
//! ECMP (flow-hash or per-packet spray), and SROU waypoint routing so a
//! source can pin a packet's path through a named spine (§2.3 multipath).

pub mod aggregate;
mod cluster;
mod link;
pub(crate) mod shard;
pub mod switch;
mod topology;

pub use aggregate::{AggConfig, AggCounters, AggEngine};
pub use cluster::{
    App, AppCtx, Cluster, CompletionHook, CompletionRecord, FaultModel, Host, InjectCmd, NetEvent,
    Node, NodeId,
};
pub use link::{Link, LinkConfig, LinkId, TxResult};
pub use shard::{ShardPartition, ShardedRuntime};
pub use switch::{flow_hash, EcmpMode, Switch};
pub use topology::{DeviceProfile, Topology};
