//! [`Cluster`] — the simulation world: devices + switches + hosts wired by
//! links, with SROU routing, optional reliability, ordering and fault
//! injection. All experiments (E1–E5, the examples, the benches) build a
//! `Cluster`, inject NetDAM packets, and run the DES engine over it.

use std::collections::HashMap;
use std::sync::Arc;

use crate::device::{DeviceConfig, Emit, NetDamDevice};
use crate::isa::registry::InstructionRegistry;
use crate::isa::{Flags, Instruction};
use crate::metrics::Metrics;
use crate::sim::{Engine, EventFn, SimTime, World};
use crate::transport::{ReliabilityTable, ReorderBuffer, RetryVerdict};
use crate::util::Xoshiro256;
use crate::wire::{DeviceIp, Packet};

use super::link::{Link, LinkConfig, LinkId, TxResult};
use super::switch::Switch;

pub type NodeId = usize;

/// Typed DES events for the cluster world.
///
/// Steady-state packet flow uses only these variants: scheduling one moves
/// a [`Packet`] (whose heavy parts — payload, program, agg metadata — are
/// `Arc`-shared) straight into the event heap, so a hop costs zero heap
/// allocations. [`NetEvent::Hook`] is the boxed-closure escape hatch for
/// one-off setup code and tests; it never appears on the packet hot path.
pub enum NetEvent {
    /// Host app `on_start` callback.
    AppStart { node: NodeId },
    /// Host app `on_timer(token)` callback.
    AppTick { node: NodeId, token: u64 },
    /// A pace-delayed injection being released.
    Inject {
        origin: NodeId,
        pkt: Packet,
        reliable: bool,
    },
    /// Emit a packet from `node` toward its current SROU segment.
    SendFrom { node: NodeId, pkt: Packet },
    /// Wire arrival at the far end of a link.
    LinkArrive { node: NodeId, pkt: Packet },
    /// Local delivery (loopback, switch forward hand-off).
    Deliver { node: NodeId, pkt: Packet },
    /// Retransmit timer for a reliability-tracked op. Lives on the engine's
    /// timer wheel, so a completion cancels it in O(1); the epoch guard is
    /// kept as defense in depth (and for parity with the sharded core,
    /// where timers are uncancellable heap events).
    RetxTimer { origin: NodeId, seq: u64, epoch: u32 },
    /// Boxed-closure escape hatch (setup code, tests).
    Hook(EventFn<Cluster>),
}

impl World for Cluster {
    type Event = NetEvent;

    fn lift(f: EventFn<Cluster>) -> NetEvent {
        NetEvent::Hook(f)
    }

    fn fire(ev: NetEvent, cl: &mut Cluster, eng: &mut Engine<Cluster>) {
        match ev {
            NetEvent::AppStart { node } => cl.with_app(node, eng, |app, ctx| app.on_start(ctx)),
            NetEvent::AppTick { node, token } => cl.app_timer(eng, node, token),
            NetEvent::Inject {
                origin,
                pkt,
                reliable,
            } => cl.inject_cmd(
                eng,
                InjectCmd {
                    origin,
                    pkt,
                    reliable,
                    delay: 0,
                },
            ),
            NetEvent::SendFrom { node, pkt } => cl.send_from(eng, node, pkt),
            NetEvent::LinkArrive { node, pkt } | NetEvent::Deliver { node, pkt } => {
                cl.deliver(eng, node, pkt)
            }
            NetEvent::RetxTimer { origin, seq, epoch } => cl.retx_fire(eng, origin, seq, epoch),
            NetEvent::Hook(f) => f(cl, eng),
        }
    }
}

/// Time to move a packet from the host request queue (memif) into the
/// device TX path — the "software writes the NetDAM packet to Request
/// Queue memory address" step of §2.4.
pub(crate) const INJECT_NS: SimTime = 150;
/// Local loopback delivery (device to its own completion queue).
pub(crate) const LOOPBACK_NS: SimTime = 100;

/// An application driving a [`Host`] node (latency clients, RoCE engines,
/// incast senders...). Implementations are event-driven and interact with
/// the world only through [`AppCtx`]. `Send` because the sharded runtime
/// (`net::shard`) moves host nodes across worker threads at window
/// barriers; apps are plain state machines, so this costs nothing.
pub trait App: Send {
    fn on_start(&mut self, _ctx: &mut AppCtx) {}
    fn on_packet(&mut self, _pkt: Packet, _ctx: &mut AppCtx) {}
    fn on_timer(&mut self, _token: u64, _ctx: &mut AppCtx) {}
}

/// Deferred actions an [`App`] can take during a callback.
pub(crate) enum Action {
    Send(Packet),
    SendReliable(Packet),
    Timer(SimTime, u64),
    Record(String, u64),
    Count(String, u64),
}

/// The view an [`App`] gets of the world.
pub struct AppCtx<'a> {
    pub now: SimTime,
    pub self_ip: DeviceIp,
    pub rng: &'a mut Xoshiro256,
    pub(crate) next_seq: &'a mut u64,
    pub(crate) actions: Vec<Action>,
}

impl AppCtx<'_> {
    /// Allocate the next sequence number for this host.
    pub fn alloc_seq(&mut self) -> u64 {
        let s = *self.next_seq;
        *self.next_seq += 1;
        s
    }

    /// Send a packet into the fabric (request-queue latency applies).
    pub fn send(&mut self, pkt: Packet) {
        self.actions.push(Action::Send(pkt));
    }

    /// Send with timeout-retransmit tracking.
    pub fn send_reliable(&mut self, pkt: Packet) {
        self.actions.push(Action::SendReliable(pkt));
    }

    /// Arm `on_timer(token)` after `delay` ns.
    pub fn timer(&mut self, delay: SimTime, token: u64) {
        self.actions.push(Action::Timer(delay, token));
    }

    /// Record a histogram sample into the cluster metrics.
    pub fn record(&mut self, name: &str, v: u64) {
        self.actions.push(Action::Record(name.to_string(), v));
    }

    /// Bump a counter in the cluster metrics.
    pub fn count(&mut self, name: &str, v: u64) {
        self.actions.push(Action::Count(name.to_string(), v));
    }
}

/// A host endpoint: an IP + optional app + a completion mailbox.
pub struct Host {
    pub ip: DeviceIp,
    pub app: Option<Box<dyn App>>,
    pub mailbox: Vec<(SimTime, Packet)>,
    pub(crate) next_seq: u64,
}

pub enum Node {
    Device(NetDamDevice),
    Switch(Switch),
    Host(Host),
}

/// Per-link loss/duplication fault injection (experiment E5).
#[derive(Debug, Clone, Default)]
pub struct FaultModel {
    pub loss_p: f64,
    pub dup_p: f64,
}

/// A deferred injection a completion hook asks for.
pub struct InjectCmd {
    pub origin: NodeId,
    pub pkt: Packet,
    pub reliable: bool,
    /// Defer the injection by this many ns (0 = immediate). The window
    /// engine's paced mode releases ops on the token bucket's schedule;
    /// reliability tracking is armed at release time, not decision time.
    pub delay: SimTime,
}

/// Callback invoked for every completion record; returns follow-up
/// injections (e.g. the allreduce driver's windowing logic).
pub type CompletionHook = Box<dyn FnMut(&CompletionRecord) -> Vec<InjectCmd>>;

/// A completion (response packet) that reached its origin.
#[derive(Debug, Clone)]
pub struct CompletionRecord {
    pub time: SimTime,
    pub node: NodeId,
    pub from: DeviceIp,
    pub seq: u64,
    pub instr: Instruction,
    /// The response carried a congestion-experienced mark (set by a switch
    /// queue en route, or echoed by the device from the request). The
    /// window engine treats this as a CNP for the owning slot's DCQCN
    /// controller.
    pub ecn: bool,
}

pub struct Cluster {
    pub nodes: Vec<Node>,
    pub links: Vec<Link>,
    /// Outgoing link ids per node.
    pub(crate) adj: Vec<Vec<LinkId>>,
    /// Per-node FIB: destination ip → equal-cost outgoing links.
    pub(crate) fib: Vec<HashMap<DeviceIp, Vec<LinkId>>>,
    pub(crate) ip_to_node: HashMap<DeviceIp, NodeId>,
    pub registry: Arc<InstructionRegistry>,
    pub metrics: Metrics,
    pub rng: Xoshiro256,
    pub fault: FaultModel,
    pub xport: ReliabilityTable,
    reorder: ReorderBuffer,
    pub completions: Vec<CompletionRecord>,
    /// Reactive driver hook — see [`CompletionHook`].
    pub on_completion: Option<CompletionHook>,
    /// Record device service time per response into metrics
    /// (`device_service_ns`) — experiment E1's measurement point.
    pub trace_device_service: bool,
    /// When `Some`, [`Cluster::inject_cmd`] records `(now, cmd)` here
    /// instead of scheduling — the sharded runtime (`net::shard`) drains
    /// the buffer and replays the commands as coordinator injections, so
    /// session kick-off code works unmodified at any shard count. `None`
    /// (the default) leaves the classic single-engine path untouched.
    pub(crate) capture: Option<Vec<(SimTime, InjectCmd)>>,
    /// Reused buffer for device emissions (allocation-free hot path).
    emit_scratch: Vec<Emit>,
    /// Reused buffer for app actions (allocation-free hot path).
    action_scratch: Vec<Action>,
}

impl Cluster {
    pub fn new(seed: u64) -> Self {
        Self::with_registry(seed, Arc::new(InstructionRegistry::new()))
    }

    pub fn with_registry(seed: u64, registry: Arc<InstructionRegistry>) -> Self {
        Self {
            nodes: Vec::new(),
            links: Vec::new(),
            adj: Vec::new(),
            fib: Vec::new(),
            ip_to_node: HashMap::new(),
            registry,
            metrics: Metrics::new(),
            rng: Xoshiro256::seed_from(seed ^ 0xC1_05_7E_12),
            fault: FaultModel::default(),
            xport: ReliabilityTable::new(50_000, 8), // 50 us timeout
            reorder: ReorderBuffer::new(),
            completions: Vec::new(),
            on_completion: None,
            trace_device_service: false,
            capture: None,
            emit_scratch: Vec::new(),
            action_scratch: Vec::new(),
        }
    }

    // ------------------------------------------------------ construction

    fn push_node(&mut self, node: Node, ip: Option<DeviceIp>) -> NodeId {
        let id = self.nodes.len();
        if let Some(ip) = ip {
            let prev = self.ip_to_node.insert(ip, id);
            assert!(prev.is_none(), "duplicate node ip {ip}");
        }
        self.nodes.push(node);
        self.adj.push(Vec::new());
        self.fib.push(HashMap::new());
        id
    }

    pub fn add_device(&mut self, cfg: DeviceConfig) -> NodeId {
        let ip = cfg.ip;
        let dev = NetDamDevice::new(cfg, Arc::clone(&self.registry));
        self.push_node(Node::Device(dev), Some(ip))
    }

    pub fn add_switch(&mut self, sw: Switch) -> NodeId {
        let ip = sw.ip;
        self.push_node(Node::Switch(sw), ip)
    }

    pub fn add_host(&mut self, ip: DeviceIp, app: Option<Box<dyn App>>) -> NodeId {
        self.push_node(
            Node::Host(Host {
                ip,
                app,
                mailbox: Vec::new(),
                next_seq: 1,
            }),
            Some(ip),
        )
    }

    /// Connect `a ↔ b` with symmetric links.
    pub fn connect(&mut self, a: NodeId, b: NodeId, cfg: LinkConfig) {
        let l1 = self.links.len();
        self.links.push(Link::new(a, b, cfg.clone()));
        self.adj[a].push(l1);
        let l2 = self.links.len();
        self.links.push(Link::new(b, a, cfg));
        self.adj[b].push(l2);
    }

    /// Compute shortest-path FIBs (all equal-cost next hops) for every
    /// addressed node. Must be called after topology construction.
    pub fn compute_routes(&mut self) {
        let n = self.nodes.len();
        // incoming links per node, for reverse BFS
        let mut rev: Vec<Vec<LinkId>> = vec![Vec::new(); n];
        for (lid, l) in self.links.iter().enumerate() {
            rev[l.to].push(lid);
        }
        let dests: Vec<(DeviceIp, NodeId)> =
            self.ip_to_node.iter().map(|(&ip, &id)| (ip, id)).collect();
        for (ip, dst) in dests {
            let mut dist = vec![usize::MAX; n];
            dist[dst] = 0;
            let mut q = std::collections::VecDeque::from([dst]);
            while let Some(v) = q.pop_front() {
                for &lid in &rev[v] {
                    let u = self.links[lid].from;
                    if dist[u] == usize::MAX {
                        dist[u] = dist[v] + 1;
                        q.push_back(u);
                    }
                }
            }
            for u in 0..n {
                if u == dst || dist[u] == usize::MAX {
                    continue;
                }
                let hops: Vec<LinkId> = self.adj[u]
                    .iter()
                    .copied()
                    .filter(|&lid| {
                        let v = self.links[lid].to;
                        dist[v] + 1 == dist[u]
                    })
                    .collect();
                if !hops.is_empty() {
                    self.fib[u].insert(ip, hops);
                }
            }
        }
    }

    pub fn node_by_ip(&self, ip: DeviceIp) -> Option<NodeId> {
        self.ip_to_node.get(&ip).copied()
    }

    /// The FIB of `node` (read-only; for tests and diagnostics).
    pub fn fib_of(&self, node: NodeId) -> &HashMap<DeviceIp, Vec<LinkId>> {
        &self.fib[node]
    }

    pub fn device(&self, node: NodeId) -> &NetDamDevice {
        match &self.nodes[node] {
            Node::Device(d) => d,
            _ => panic!("node {node} is not a device"),
        }
    }

    pub fn device_mut(&mut self, node: NodeId) -> &mut NetDamDevice {
        match &mut self.nodes[node] {
            Node::Device(d) => d,
            _ => panic!("node {node} is not a device"),
        }
    }

    pub fn host_mut(&mut self, node: NodeId) -> &mut Host {
        match &mut self.nodes[node] {
            Node::Host(h) => h,
            _ => panic!("node {node} is not a host"),
        }
    }

    pub fn switch(&self, node: NodeId) -> &Switch {
        match &self.nodes[node] {
            Node::Switch(s) => s,
            _ => panic!("node {node} is not a switch"),
        }
    }

    pub fn switch_mut(&mut self, node: NodeId) -> &mut Switch {
        match &mut self.nodes[node] {
            Node::Switch(s) => s,
            _ => panic!("node {node} is not a switch"),
        }
    }

    pub(crate) fn node_ip(&self, node: NodeId) -> Option<DeviceIp> {
        match &self.nodes[node] {
            Node::Device(d) => Some(d.ip()),
            Node::Switch(s) => s.ip,
            Node::Host(h) => Some(h.ip),
        }
    }

    /// Allocate a sequence number for packets originated at `node`.
    pub fn alloc_seq(&mut self, node: NodeId) -> u64 {
        match &mut self.nodes[node] {
            Node::Device(d) => d.next_seq(),
            Node::Host(h) => {
                let s = h.next_seq;
                h.next_seq += 1;
                s
            }
            Node::Switch(_) => panic!("switches don't originate packets"),
        }
    }

    // -------------------------------------------------------- injection

    /// Start all host apps (schedules their `on_start` at t=0).
    pub fn start_apps(&mut self, eng: &mut Engine<Cluster>) {
        for node in 0..self.nodes.len() {
            if matches!(&self.nodes[node], Node::Host(h) if h.app.is_some()) {
                eng.schedule_event_at(0, NetEvent::AppStart { node });
            }
        }
    }

    /// Host software writes a packet into the request queue; the device
    /// (or host NIC) sends it after the memif hop.
    pub fn inject(&mut self, eng: &mut Engine<Cluster>, origin: NodeId, pkt: Packet) {
        eng.schedule_event_in(INJECT_NS, NetEvent::SendFrom { node: origin, pkt });
    }

    /// Inject a deferred command (the window engine's currency): one
    /// entry point for plain, reliability-tracked, and pace-delayed
    /// injection, usable both from completion hooks and from engine
    /// kick-off code.
    pub fn inject_cmd(&mut self, eng: &mut Engine<Cluster>, cmd: InjectCmd) {
        if let Some(buf) = self.capture.as_mut() {
            buf.push((eng.now(), cmd));
            return;
        }
        if cmd.delay > 0 {
            let InjectCmd {
                origin,
                pkt,
                reliable,
                delay,
            } = cmd;
            eng.schedule_event_in(
                delay,
                NetEvent::Inject {
                    origin,
                    pkt,
                    reliable,
                },
            );
            return;
        }
        if cmd.reliable {
            self.inject_reliable(eng, cmd.origin, cmd.pkt);
        } else {
            self.inject(eng, cmd.origin, cmd.pkt);
        }
    }

    /// Inject with timeout-retransmit tracking. The instruction should be
    /// replay-safe (debug-asserted): idempotent, or CAS, whose
    /// retransmits the device answers from its response-dedupe cache —
    /// that is NetDAM's reliability model.
    pub fn inject_reliable(&mut self, eng: &mut Engine<Cluster>, origin: NodeId, pkt: Packet) {
        debug_assert!(
            pkt.instr.replay_safe(pkt.flags),
            "reliable injection of non-replay-safe {:?}",
            pkt.instr
        );
        let seq = pkt.seq;
        let epoch = self.xport.track(origin, pkt.clone());
        self.arm_retry(eng, origin, seq, epoch);
        self.inject(eng, origin, pkt);
    }

    /// Arm the retransmit timer on the engine's timer wheel and register
    /// its id with the reliability table so an ack cancels it in O(1).
    fn arm_retry(&mut self, eng: &mut Engine<Cluster>, origin: NodeId, seq: u64, epoch: u32) {
        let timeout = self.xport.timeout_ns;
        let id = eng.schedule_timer_in(timeout, NetEvent::RetxTimer { origin, seq, epoch });
        self.xport.set_timer(origin, seq, id);
    }

    /// A retransmit timer fired (reached here only if never cancelled).
    fn retx_fire(&mut self, eng: &mut Engine<Cluster>, origin: NodeId, seq: u64, epoch: u32) {
        match self.xport.on_timeout(origin, seq, epoch) {
            RetryVerdict::Done | RetryVerdict::Failed => {}
            RetryVerdict::Resend(pkt) => {
                self.metrics.inc("retransmits");
                let next_epoch = self.xport.epoch(origin, seq).expect("pending after resend");
                self.arm_retry(eng, origin, seq, next_epoch);
                self.send_from(eng, origin, pkt);
            }
        }
    }

    // ------------------------------------------------------- forwarding

    /// Emit a packet from `node` toward its current SROU segment.
    pub fn send_from(&mut self, eng: &mut Engine<Cluster>, node: NodeId, pkt: Packet) {
        let Some(dst) = pkt.dst() else {
            self.metrics.inc("drop_no_segment");
            return;
        };
        if self.node_ip(node) == Some(dst) {
            // Loopback (e.g. a reduce chunk terminating at its origin).
            eng.schedule_event_in(LOOPBACK_NS, NetEvent::Deliver { node, pkt });
            return;
        }
        let Some(cands) = self.fib[node].get(&dst) else {
            self.metrics.inc("drop_no_route");
            return;
        };
        debug_assert!(!cands.is_empty());
        let lid = if cands.len() == 1 {
            cands[0]
        } else {
            // Source/switch ECMP among equal-cost links.
            let pick = match &mut self.nodes[node] {
                Node::Switch(sw) => sw.pick(&pkt, dst, cands.len()),
                _ => ecmp_hash(pkt.src, dst, cands.len()),
            };
            cands[pick]
        };
        self.transmit_on(eng, lid, pkt);
    }

    fn transmit_on(&mut self, eng: &mut Engine<Cluster>, lid: LinkId, mut pkt: Packet) {
        let bytes = pkt.wire_bytes();
        let now = eng.now();
        let to = self.links[lid].to;
        match self.links[lid].transmit(now, bytes) {
            TxResult::Dropped => {
                self.metrics.inc("link_drops");
            }
            TxResult::Sent {
                arrival,
                departure: _,
                ecn,
            } => {
                if ecn {
                    pkt.flags = pkt.flags.with(Flags::ECN);
                }
                // Buffer release is lazy inside the Link (no event).
                // Fault injection (loss/duplication) on the wire. Draw
                // order (lost, dup, jitter-if-dup) and event schedule
                // order (arrival before dup) are part of the determinism
                // contract — do not reorder.
                let lost = self.fault.loss_p > 0.0 && self.rng.chance(self.fault.loss_p);
                let dup = self.fault.dup_p > 0.0 && self.rng.chance(self.fault.dup_p);
                let jitter = if dup {
                    200 + self.rng.next_below(800)
                } else {
                    0
                };
                let mut pkt = Some(pkt);
                if lost {
                    self.metrics.inc("fault_lost");
                } else {
                    // Clone only when the duplicate also needs the packet;
                    // the clone is shallow (Arc bumps + header memcpy).
                    let p = if dup {
                        pkt.clone().expect("packet present")
                    } else {
                        pkt.take().expect("packet present")
                    };
                    eng.schedule_event_at(arrival, NetEvent::LinkArrive { node: to, pkt: p });
                }
                if dup {
                    self.metrics.inc("fault_duplicated");
                    let p = pkt.take().expect("packet present");
                    eng.schedule_event_at(
                        arrival + jitter,
                        NetEvent::LinkArrive { node: to, pkt: p },
                    );
                }
            }
        }
    }

    /// A packet arrives at `node`.
    pub fn deliver(&mut self, eng: &mut Engine<Cluster>, node: NodeId, mut pkt: Packet) {
        // Pull the per-kind facts out first to keep borrows short.
        enum Kind {
            Switch { latency: SimTime },
            Device,
            Host { has_app: bool },
        }
        let kind = match &mut self.nodes[node] {
            Node::Switch(sw) => {
                // SROU waypoint: this switch is the current segment. An
                // aggregation-marked packet whose segment names us also
                // carries the expected fan-in in the segment's `func`
                // argument — that is the in-network reduce entry point.
                let mut was_waypoint = false;
                let mut fanin = 0u16;
                if let (Some(ip), Some(cur)) = (sw.ip, pkt.srou.current()) {
                    if cur.node == ip {
                        was_waypoint = true;
                        fanin = cur.func;
                        pkt.srou.advance();
                    }
                }
                if pkt.dst().is_none() {
                    sw.no_route_drops += 1;
                    self.metrics.inc("drop_no_segment");
                    return;
                }
                if pkt.flags.agg() {
                    let outs = sw.offer_agg(eng.now(), was_waypoint, fanin, pkt);
                    sw.forwarded += outs.len() as u64;
                    let latency = sw.latency_ns;
                    self.metrics
                        .add("switch_agg_absorbed", outs.is_empty() as u64);
                    for p in outs {
                        eng.schedule_event_in(latency, NetEvent::SendFrom { node, pkt: p });
                    }
                    return;
                }
                sw.forwarded += 1;
                Kind::Switch {
                    latency: sw.latency_ns,
                }
            }
            Node::Device(dev) => {
                if pkt.dst() != Some(dev.ip()) {
                    self.metrics.inc("drop_misrouted");
                    return;
                }
                Kind::Device
            }
            Node::Host(h) => {
                if pkt.dst() != Some(h.ip) {
                    self.metrics.inc("drop_misrouted");
                    return;
                }
                Kind::Host {
                    has_app: h.app.is_some(),
                }
            }
        };
        // Count CE marks where they terminate: the endpoint is what a
        // DCQCN-style rate controller would hang its CNP echo off.
        if !matches!(kind, Kind::Switch { .. }) && pkt.flags.ecn() {
            self.metrics.inc("ecn_ce_received");
        }
        match kind {
            Kind::Switch { latency } => {
                eng.schedule_event_in(latency, NetEvent::SendFrom { node, pkt });
            }
            Kind::Device => {
                if is_completion(&pkt.instr) {
                    self.note_completion(eng, node, &pkt);
                }
                if pkt.flags.ordered() {
                    let src = pkt.src;
                    let release = self.reorder.offer(src, pkt);
                    for p in release {
                        self.exec_on_device(eng, node, p);
                    }
                } else {
                    self.exec_on_device(eng, node, pkt);
                }
            }
            Kind::Host { has_app } => {
                if is_completion(&pkt.instr) {
                    self.note_completion(eng, node, &pkt);
                }
                if has_app {
                    self.with_app(node, eng, |app, ctx| app.on_packet(pkt, ctx));
                } else {
                    let now = eng.now();
                    self.host_mut(node).mailbox.push((now, pkt));
                }
            }
        }
    }

    fn exec_on_device(&mut self, eng: &mut Engine<Cluster>, node: NodeId, pkt: Packet) {
        let now = eng.now();
        let mut emits = std::mem::take(&mut self.emit_scratch);
        emits.clear();
        match &mut self.nodes[node] {
            Node::Device(d) => d.handle_packet_into(now, pkt, &mut emits),
            _ => unreachable!(),
        }
        for e in emits.drain(..) {
            if self.trace_device_service {
                self.metrics.record("device_service_ns", e.delay);
            }
            eng.schedule_event_in(e.delay, NetEvent::SendFrom { node, pkt: e.pkt });
        }
        self.emit_scratch = emits;
    }

    fn note_completion(&mut self, eng: &mut Engine<Cluster>, node: NodeId, pkt: &Packet) {
        if let Some(tid) = self.xport.complete(node, pkt.seq) {
            eng.cancel_timer(tid);
        }
        let rec = CompletionRecord {
            time: eng.now(),
            node,
            from: pkt.src,
            seq: pkt.seq,
            instr: pkt.instr.clone(),
            ecn: pkt.flags.ecn(),
        };
        if let Some(mut hook) = self.on_completion.take() {
            let cmds = hook(&rec);
            // Put the engine's hook back (take/call/put-back avoids
            // aliasing &mut self into the callback). Only the transport
            // window engine installs hooks; this is dispatch, not a
            // windowing loop.
            self.on_completion.replace(hook);
            for c in cmds {
                self.inject_cmd(eng, c);
            }
        }
        self.completions.push(rec);
    }

    /// Concrete trampoline for timer events (keeps the generic
    /// `with_app` out of the event-closure type and so avoids an
    /// infinitely-recursive monomorphization).
    fn app_timer(&mut self, eng: &mut Engine<Cluster>, node: NodeId, token: u64) {
        self.with_app(node, eng, |app, ctx| app.on_timer(token, ctx));
    }

    /// Run an app callback with the usual take-the-app-out dance.
    fn with_app<F>(&mut self, node: NodeId, eng: &mut Engine<Cluster>, f: F)
    where
        F: FnOnce(&mut dyn App, &mut AppCtx),
    {
        let (ip, mut app, mut next_seq) = match &mut self.nodes[node] {
            Node::Host(h) => (
                h.ip,
                h.app.take().expect("app present"),
                h.next_seq,
            ),
            _ => panic!("with_app on non-host"),
        };
        let mut ctx = AppCtx {
            now: eng.now(),
            self_ip: ip,
            rng: &mut self.rng,
            next_seq: &mut next_seq,
            actions: std::mem::take(&mut self.action_scratch),
        };
        f(app.as_mut(), &mut ctx);
        let mut actions = std::mem::take(&mut ctx.actions);
        // Put the app back before processing actions (they may re-enter).
        if let Node::Host(h) = &mut self.nodes[node] {
            h.app = Some(app);
            h.next_seq = next_seq;
        }
        for a in actions.drain(..) {
            match a {
                Action::Send(pkt) => self.inject(eng, node, pkt),
                Action::SendReliable(pkt) => self.inject_reliable(eng, node, pkt),
                Action::Timer(delay, token) => {
                    eng.schedule_event_in(delay, NetEvent::AppTick { node, token });
                }
                Action::Record(name, v) => self.metrics.record(&name, v),
                Action::Count(name, v) => self.metrics.add(&name, v),
            }
        }
        self.action_scratch = actions;
    }

    /// Total link drops + fault losses (for assertions in tests).
    pub fn total_drops(&self) -> u64 {
        self.metrics.counter("link_drops")
            + self.metrics.counter("fault_lost")
            + self.metrics.counter("drop_no_route")
    }
}

/// The SDN controller's window onto the fabric (paper §2.6): the pool
/// controller programs device IOMMUs and requester ACLs through this —
/// the control plane "applying the ACL to each NetDAM".
impl crate::pool::IommuDirectory for Cluster {
    fn device_iommu(&mut self, dev: DeviceIp) -> Option<&mut crate::iommu::Iommu> {
        let id = self.node_by_ip(dev)?;
        match &mut self.nodes[id] {
            Node::Device(d) => Some(d.iommu_mut()),
            _ => None,
        }
    }

    fn bind_tenant(&mut self, dev: DeviceIp, host: DeviceIp, tenant: crate::iommu::TenantId) {
        let Some(id) = self.node_by_ip(dev) else {
            return;
        };
        if let Node::Device(d) = &mut self.nodes[id] {
            d.bind_tenant(host, tenant);
        }
        // §2.5: the same control-plane write programs the switch ACL
        // tables, so in-network aggregation polices the identical
        // requester → tenant map the device IOMMUs enforce.
        for n in &mut self.nodes {
            if let Node::Switch(s) = n {
                s.bind_tenant(host, tenant);
            }
        }
    }
}

/// Deterministic source-side ECMP hash.
pub(crate) fn ecmp_hash(src: DeviceIp, dst: DeviceIp, n: usize) -> usize {
    let mut h = src.0 as u64 ^ ((dst.0 as u64) << 32) ^ 0x5bd1_e995;
    h ^= h >> 29;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 32;
    (h % n as u64) as usize
}

/// Is this instruction a response/completion (terminates at the origin)?
pub fn is_completion(i: &Instruction) -> bool {
    matches!(
        i,
        Instruction::ReadResp { .. }
            | Instruction::WriteAck { .. }
            | Instruction::CasResp { .. }
            | Instruction::SimdResp { .. }
            | Instruction::BlockHashResp { .. }
            | Instruction::CollectiveDone { .. }
            | Instruction::Ack { .. }
            | Instruction::Nack { .. }
            | Instruction::MallocResp { .. }
            | Instruction::FreeResp { .. }
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::registry::MemAccess;
    use crate::sim::Engine;
    use crate::wire::{Payload, SrouHeader};

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    /// 1 host + 2 devices on one ToR switch.
    fn star() -> (Cluster, NodeId, NodeId, NodeId) {
        let mut cl = Cluster::new(7);
        let sw = cl.add_switch(Switch::tor(None));
        let h = cl.add_host(ip(100), None);
        let d1 = cl.add_device(DeviceConfig::paper_default(ip(1)));
        let d2 = cl.add_device(DeviceConfig::paper_default(ip(2)));
        for n in [h, d1, d2] {
            cl.connect(sw, n, LinkConfig::dc_100g());
        }
        cl.compute_routes();
        (cl, h, d1, d2)
    }

    #[test]
    fn routes_computed_through_switch() {
        let (cl, h, ..) = star();
        assert!(cl.fib[h].contains_key(&ip(1)));
        assert!(cl.fib[h].contains_key(&ip(2)));
        assert_eq!(cl.fib[h][&ip(1)].len(), 1);
    }

    #[test]
    fn write_then_read_round_trip_through_fabric() {
        let (mut cl, h, _d1, _d2) = star();
        let mut eng: Engine<Cluster> = Engine::new();
        let seq = cl.alloc_seq(h);
        let w = Packet::new(ip(100), seq, SrouHeader::direct(ip(1)), Instruction::Write {
            addr: 0x40,
        })
        .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        cl.inject(&mut eng, h, w);
        let seq2 = cl.alloc_seq(h);
        // Read back after the write settles (sequenced by time here).
        eng.schedule_at(100_000, move |cl: &mut Cluster, eng| {
            let r = Packet::new(ip(100), seq2, SrouHeader::direct(ip(1)), Instruction::Read {
                addr: 0x40,
                len: 8,
            });
            cl.send_from(eng, 1, r); // h == node 1
        });
        eng.run(&mut cl);
        let mailbox = &cl.host_mut(h).mailbox;
        assert_eq!(mailbox.len(), 1);
        let (t, resp) = &mailbox[0];
        assert!(matches!(resp.instr, Instruction::ReadResp { addr: 0x40 }));
        assert_eq!(resp.payload.f32s().unwrap().unwrap(), vec![1.0, 2.0]);
        assert!(*t > 100_000);
        assert_eq!(cl.total_drops(), 0);
    }

    #[test]
    fn e2e_latency_is_physical() {
        // Request path: host→switch→device (~600ns switch + 2×500ns prop)
        // + device service (~620ns) + response path. Must be > 2.5us and
        // well under 10us on an idle fabric.
        let (mut cl, h, ..) = star();
        let mut eng: Engine<Cluster> = Engine::new();
        let seq = cl.alloc_seq(h);
        let r = Packet::new(ip(100), seq, SrouHeader::direct(ip(1)), Instruction::Read {
            addr: 0,
            len: 128,
        });
        cl.inject(&mut eng, h, r);
        eng.run(&mut cl);
        let (t, _) = cl.host_mut(h).mailbox[0];
        assert!(t > 2500 && t < 10_000, "rtt {t} ns");
    }

    #[test]
    fn reliable_injection_retransmits_through_loss() {
        let (mut cl, h, ..) = star();
        // 20% loss *per link* (4 link crossings per attempt ⇒ ~41%
        // end-to-end success); 30 retries make failure vanishingly rare.
        cl.fault.loss_p = 0.2;
        cl.xport = ReliabilityTable::new(20_000, 30);
        let mut eng: Engine<Cluster> = Engine::new();
        let seq = cl.alloc_seq(h);
        let w = Packet::new(ip(100), seq, SrouHeader::direct(ip(1)), Instruction::Write {
            addr: 0,
        })
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::from_f32s(&[42.0]));
        cl.inject_reliable(&mut eng, h, w);
        eng.run(&mut cl);
        // Either the original or a retransmit must have landed.
        assert_eq!(cl.xport.outstanding(), 0);
        assert_eq!(cl.xport.failures, 0, "20% loss but 30 retries");
        let d1 = cl.node_by_ip(ip(1)).unwrap();
        let v = cl.device_mut(d1).mem().read(0, 4).unwrap();
        assert_eq!(v, 42.0f32.to_le_bytes());
    }

    #[test]
    fn srou_waypoint_pins_path() {
        // Two parallel switches; SROU names one of them explicitly.
        let mut cl = Cluster::new(3);
        let s1 = cl.add_switch(Switch::tor(Some(ip(201))));
        let s2 = cl.add_switch(Switch::tor(Some(ip(202))));
        let h = cl.add_host(ip(100), None);
        let d = cl.add_device(DeviceConfig::paper_default(ip(1)));
        cl.connect(h, s1, LinkConfig::dc_100g());
        cl.connect(h, s2, LinkConfig::dc_100g());
        cl.connect(s1, d, LinkConfig::dc_100g());
        cl.connect(s2, d, LinkConfig::dc_100g());
        cl.compute_routes();
        let mut eng: Engine<Cluster> = Engine::new();
        // Pin via s2.
        use crate::wire::Segment;
        let srou = SrouHeader::through(vec![Segment::to(ip(202)), Segment::to(ip(1))]);
        let seq = cl.alloc_seq(h);
        let r = Packet::new(ip(100), seq, srou, Instruction::Read { addr: 0, len: 64 });
        cl.inject(&mut eng, h, r);
        eng.run(&mut cl);
        assert_eq!(cl.host_mut(h).mailbox.len(), 1);
        // The *request* must leave the host on the s2 uplink only (the
        // response path back is free to take either spine).
        let tx = |from: NodeId, to: NodeId| {
            cl.links
                .iter()
                .find(|l| l.from == from && l.to == to)
                .unwrap()
                .tx_pkts
        };
        assert_eq!(tx(h, s1), 0, "request must not use spine 1");
        assert_eq!(tx(h, s2), 1);
        match &cl.nodes[s2] {
            Node::Switch(b) => assert!(b.forwarded >= 1),
            _ => unreachable!(),
        }
    }

    #[test]
    fn apps_drive_traffic() {
        struct Pinger {
            target: DeviceIp,
            got: u64,
        }
        impl App for Pinger {
            fn on_start(&mut self, ctx: &mut AppCtx) {
                let seq = ctx.alloc_seq();
                ctx.send(Packet::new(
                    ctx.self_ip,
                    seq,
                    SrouHeader::direct(self.target),
                    Instruction::Read { addr: 0, len: 32 },
                ));
            }
            fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
                assert!(matches!(pkt.instr, Instruction::ReadResp { .. }));
                self.got += 1;
                ctx.record("rtt_done", ctx.now);
                if self.got < 3 {
                    let seq = ctx.alloc_seq();
                    ctx.send(Packet::new(
                        ctx.self_ip,
                        seq,
                        SrouHeader::direct(self.target),
                        Instruction::Read { addr: 0, len: 32 },
                    ));
                }
            }
        }
        let mut cl = Cluster::new(9);
        let sw = cl.add_switch(Switch::tor(None));
        let h = cl.add_host(
            ip(100),
            Some(Box::new(Pinger {
                target: ip(1),
                got: 0,
            })),
        );
        let d = cl.add_device(DeviceConfig::paper_default(ip(1)));
        cl.connect(sw, h, LinkConfig::dc_100g());
        cl.connect(sw, d, LinkConfig::dc_100g());
        cl.compute_routes();
        let mut eng: Engine<Cluster> = Engine::new();
        cl.start_apps(&mut eng);
        eng.run(&mut cl);
        assert_eq!(cl.metrics.hist("rtt_done").unwrap().count(), 3);
    }

    #[test]
    fn congestion_marks_are_counted_at_the_receiver() {
        // Blast enough back-to-back writes through one uplink to push its
        // queue past the ECN threshold; the marks must survive to the
        // receiving device and be counted there.
        let (mut cl, h, d1, _d2) = star();
        let mut eng: Engine<Cluster> = Engine::new();
        let _ = d1;
        for i in 0..40u64 {
            let seq = cl.alloc_seq(h);
            let w = Packet::new(
                ip(100),
                seq,
                SrouHeader::direct(ip(1)),
                Instruction::Write { addr: i * 8192 },
            )
            .with_payload(Payload::from_bytes(vec![0u8; 8192]));
            cl.inject(&mut eng, h, w);
        }
        eng.run(&mut cl);
        // 40 × ~8.3 KB queued at once ≈ 330 KB ≫ the 100 KB threshold.
        assert!(
            cl.metrics.counter("ecn_ce_received") > 0,
            "CE marks must be carried to and counted at the endpoint"
        );
        assert_eq!(cl.total_drops(), 0, "marking, not dropping");
    }

    #[test]
    fn completion_log_records_collective_done() {
        let (mut cl, _h, d1, _d2) = star();
        let mut eng: Engine<Cluster> = Engine::new();
        // d1 sends a guarded-reduce *program* directly to d2 (single hop);
        // retiring it emits the CollectiveDone completion.
        let seq = cl.alloc_seq(d1);
        use crate::isa::{ProgramBuilder, SimdOp};
        let prog = ProgramBuilder::new()
            .reduce(SimdOp::Add, 0, 1)
            .guarded_write(0, crate::alu::block_hash(&[0u8; 8]))
            .on_retire(3)
            .build_unchecked();
        let pkt = Packet::new(
            ip(1),
            seq,
            SrouHeader::direct(ip(2)),
            Instruction::Program(Arc::new(prog)),
        )
        .with_payload(Payload::from_f32s(&[1.0, 2.0]));
        cl.inject(&mut eng, d1, pkt);
        eng.run(&mut cl);
        assert_eq!(cl.completions.len(), 1);
        let c = &cl.completions[0];
        assert!(matches!(c.instr, Instruction::CollectiveDone { block: 3 }));
        assert_eq!(c.from, ip(2));
    }
}
