//! The switch aggregation engine (paper §2.5's "or in datacenter
//! switch" compute point, NetReduce-style).
//!
//! A [`crate::net::Switch`] owns one [`AggEngine`]: a **bounded** table
//! of aggregation slots keyed `(tenant, group)`. Aggregation-marked
//! packets ([`crate::isa::Flags::AGG`] + [`AggMeta`]) whose current SROU
//! segment names this switch are *offered* to the engine instead of
//! being forwarded. The engine buffers the original packets; when the
//! buffered manifests reach the expected fan-in (the SROU segment's
//! `func` argument — counted in manifest *entries*, not packets, so an
//! upstream eviction that forwarded singles still completes the slot),
//! it folds the payloads with the slot's commutative [`SimdOp`] and
//! emits **one** reduced packet carrying the union manifest, inheriting
//! the first contribution's `(src, seq)` transport identity and its
//! (already advanced) SROU path.
//!
//! The INSIGHT survey's reliability taxonomy shapes the failure paths —
//! every one degrades to plain forwarding, never to a wrong answer:
//!
//! * **timeout** — a slot past its deadline is evicted and its buffered
//!   originals forwarded individually (straggler fallback: the root
//!   collector reduces them endpoint-side);
//! * **overflow** — a full table refuses new slots and forwards;
//! * **late stragglers** — contributions for a recently evicted slot
//!   pass straight through instead of re-opening a doomed slot;
//! * **duplicates** — a retransmit whose manifest intersects a buffered
//!   slot is dropped (the buffered original already carries it);
//! * **non-commutative ops** — refused (forwarded), mirroring the
//!   program verifier's §2.3 relaxed-ordering rule: only reduces that
//!   are legal on unordered paths are legal in a switch.
//!
//! Determinism: slots live in a `BTreeMap` and eviction scans it in key
//! order, so the engine's behaviour is a pure function of the arrival
//! sequence — which the sharded DES core already makes shard-count
//! invariant.

use std::collections::{BTreeMap, HashSet, VecDeque};
use std::sync::Arc;

use crate::alu::{AluBackend, NativeAlu};
use crate::isa::SimdOp;
use crate::sim::SimTime;
use crate::wire::{Packet, Payload};

/// Remembered evicted/merged slot keys (bounded FIFO): late stragglers
/// for these pass through instead of opening a slot that can never fill.
const RECENT_KEYS_CAP: usize = 4096;

/// Aggregation-table knobs.
#[derive(Debug, Clone)]
pub struct AggConfig {
    /// Concurrent aggregation slots per switch (the bounded SRAM table).
    pub max_slots: usize,
    /// Slot lifetime: older slots are evicted (straggler fallback).
    /// Kept below the transport's 2 ms retransmit timeout so a
    /// retransmit arriving at the switch always finds the slot expired
    /// rather than half-filled.
    pub timeout_ns: SimTime,
}

impl Default for AggConfig {
    fn default() -> Self {
        Self {
            max_slots: 256,
            timeout_ns: 1_000_000,
        }
    }
}

/// Observability counters (all monotonic).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AggCounters {
    /// Slots that reached fan-in and emitted one reduced packet.
    pub merged: u64,
    /// Contribution packets absorbed into a slot buffer.
    pub absorbed: u64,
    /// Slots evicted on timeout.
    pub evicted_slots: u64,
    /// Buffered packets forwarded by those evictions.
    pub evicted_pkts: u64,
    /// New slots refused because the table was full.
    pub overflow: u64,
    /// Post-eviction stragglers passed through unaggregated.
    pub late: u64,
    /// Duplicate contributions dropped (manifest already buffered).
    pub dup_drops: u64,
    /// Non-commutative reduce ops refused (forwarded unaggregated).
    pub refused: u64,
}

#[derive(Debug)]
struct Slot {
    op: SimdOp,
    /// Expected descendant contribution *entries* (SROU segment `func`).
    fanin: usize,
    deadline: SimTime,
    /// Buffered originals, arrival order (the fold order).
    pkts: Vec<Packet>,
    /// Total manifest entries across `pkts`.
    entries: usize,
    /// Contribution identities buffered so far (duplicate filter).
    seen: HashSet<(u32, u64)>,
}

/// The per-switch bounded aggregation table. See the module docs.
#[derive(Debug)]
pub struct AggEngine {
    cfg: AggConfig,
    slots: BTreeMap<(u32, u32), Slot>,
    recent: VecDeque<(u32, u32)>,
    recent_set: HashSet<(u32, u32)>,
    alu: NativeAlu,
    pub counters: AggCounters,
}

impl Default for AggEngine {
    fn default() -> Self {
        Self::new(AggConfig::default())
    }
}

impl AggEngine {
    pub fn new(cfg: AggConfig) -> Self {
        Self {
            cfg,
            slots: BTreeMap::new(),
            recent: VecDeque::new(),
            recent_set: HashSet::new(),
            alu: NativeAlu::new(),
            counters: AggCounters::default(),
        }
    }

    /// Slots currently buffering.
    pub fn live_slots(&self) -> usize {
        self.slots.len()
    }

    /// Offer `pkt` to the table. Returns the packets the switch must
    /// forward *now* (possibly none if the packet was absorbed, possibly
    /// several if slots expired): evicted originals first (slot-key
    /// order), then the verdict on `pkt` itself — passed through, or the
    /// merged emission if it completed a slot. Every contribution entry
    /// ever offered leaves the switch exactly once (inside a merged
    /// manifest or as its original packet), except duplicates, which are
    /// dropped.
    ///
    /// `was_waypoint` says the packet's pre-advance SROU segment named
    /// this switch; `fanin` is that segment's `func` argument.
    pub fn offer(
        &mut self,
        now: SimTime,
        was_waypoint: bool,
        fanin: u16,
        pkt: Packet,
    ) -> Vec<Packet> {
        let mut out = self.expire(now);
        // Not aggregation traffic for this hop: plain forwarding.
        let eligible = was_waypoint && fanin > 0 && pkt.flags.agg() && pkt.agg.is_some();
        if !eligible {
            out.push(pkt);
            return out;
        }
        let meta = pkt.agg.as_ref().expect("eligible implies metadata");
        if !meta.op.commutative() {
            // The verifier's rule, enforced in the data plane too: a
            // switch reduces in arrival order, so only commutative ops.
            self.counters.refused += 1;
            out.push(pkt);
            return out;
        }
        let key = (meta.tenant, meta.group);
        if !self.slots.contains_key(&key) {
            if self.recent_set.contains(&key) {
                // The slot already merged or evicted; a late straggler
                // can never complete it — send it on to the root.
                self.counters.late += 1;
                out.push(pkt);
                return out;
            }
            if self.slots.len() >= self.cfg.max_slots {
                self.counters.overflow += 1;
                out.push(pkt);
                return out;
            }
            self.slots.insert(
                key,
                Slot {
                    op: meta.op,
                    fanin: fanin as usize,
                    deadline: now + self.cfg.timeout_ns,
                    pkts: Vec::new(),
                    entries: 0,
                    seen: HashSet::new(),
                },
            );
        }
        let slot = self.slots.get_mut(&key).expect("just ensured");
        if slot.op != meta.op {
            // A group must agree on its reduce op; don't corrupt the slot.
            self.counters.refused += 1;
            out.push(pkt);
            return out;
        }
        if meta
            .entries
            .iter()
            .any(|e| slot.seen.contains(&(e.src.0, e.seq)))
        {
            // Retransmit echo of a buffered contribution: the original
            // is already in the slot, so this copy is redundant.
            self.counters.dup_drops += 1;
            return out;
        }
        for e in &meta.entries {
            slot.seen.insert((e.src.0, e.seq));
        }
        slot.entries += meta.entries.len();
        slot.pkts.push(pkt);
        self.counters.absorbed += 1;
        if slot.entries >= slot.fanin {
            let slot = self.slots.remove(&key).expect("complete slot");
            self.remember(key);
            match self.merge(slot) {
                Ok(merged) => {
                    self.counters.merged += 1;
                    out.push(merged);
                }
                Err(pkts) => {
                    // Defensive: un-mergeable payloads fall back to
                    // forwarding the originals (endpoint reduction).
                    self.counters.evicted_pkts += pkts.len() as u64;
                    out.extend(pkts);
                }
            }
        }
        out
    }

    /// Evict every slot past its deadline; returns their buffered
    /// originals (slot-key order, then arrival order within a slot).
    pub fn expire(&mut self, now: SimTime) -> Vec<Packet> {
        let expired: Vec<(u32, u32)> = self
            .slots
            .iter()
            .filter(|(_, s)| s.deadline <= now)
            .map(|(&k, _)| k)
            .collect();
        let mut out = Vec::new();
        for key in expired {
            let slot = self.slots.remove(&key).expect("listed as expired");
            self.remember(key);
            self.counters.evicted_slots += 1;
            self.counters.evicted_pkts += slot.pkts.len() as u64;
            out.extend(slot.pkts);
        }
        out
    }

    fn remember(&mut self, key: (u32, u32)) {
        if self.recent_set.insert(key) {
            self.recent.push_back(key);
            if self.recent.len() > RECENT_KEYS_CAP {
                if let Some(old) = self.recent.pop_front() {
                    self.recent_set.remove(&old);
                }
            }
        }
    }

    /// Fold a complete slot into one packet. On un-mergeable contents
    /// (length mismatch, undecodable lanes) the originals come back as
    /// the error value and are forwarded instead.
    fn merge(&mut self, slot: Slot) -> Result<Packet, Vec<Packet>> {
        let mut it = slot.pkts.iter();
        let first = it.next().expect("a complete slot is non-empty");
        let len = first.payload.len();
        if slot.pkts.iter().any(|p| p.payload.len() != len) {
            return Err(slot.pkts);
        }
        let payload = if slot.pkts.iter().any(|p| p.payload.is_phantom()) {
            Payload::phantom(len)
        } else {
            let Some(Ok(mut acc)) = first.payload.f32s() else {
                return Err(slot.pkts);
            };
            for p in it {
                let Some(Ok(lanes)) = p.payload.f32s() else {
                    return Err(slot.pkts);
                };
                self.alu.apply(slot.op, &mut acc, &lanes);
            }
            Payload::from_f32s(&acc)
        };
        let mut merged = first.clone().with_payload(payload);
        // COW fold: the merged packet's manifest is cloned out of the
        // shared Arc exactly once, then extended in place.
        let meta = Arc::make_mut(merged.agg.as_mut().expect("buffered packets carry AGG"));
        for p in &slot.pkts[1..] {
            meta.entries
                .extend(p.agg.as_ref().expect("buffered AGG").entries.iter().copied());
        }
        Ok(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Flags, Instruction};
    use crate::wire::{AggEntry, AggMeta, DeviceIp, Segment, SrouHeader};

    fn ip(x: u8) -> DeviceIp {
        DeviceIp::lan(x)
    }

    /// A contribution packet as it looks *after* the leaf advanced its
    /// SROU (current segment = spine), carrying `vals` and one entry.
    fn contrib(src: u8, seq: u64, group: u32, vals: &[f32]) -> Packet {
        contrib_op(src, seq, group, vals, SimdOp::Add)
    }

    fn contrib_op(src: u8, seq: u64, group: u32, vals: &[f32], op: SimdOp) -> Packet {
        let mut srou = SrouHeader::through(vec![
            Segment::call(ip(150), 2),
            Segment::call(ip(200), 3),
            Segment::to(ip(1)),
        ]);
        srou.advance(); // the leaf hop already happened
        Packet::new(ip(src), seq, srou, Instruction::Simd { op, addr: 0 })
            .with_flags(Flags(Flags::RELIABLE))
            .with_agg(AggMeta {
                tenant: 1,
                group,
                op,
                entries: vec![AggEntry {
                    src: ip(src),
                    seq,
                    done_id: group + src as u32,
                }],
            })
            .with_payload(Payload::from_f32s(vals))
    }

    #[test]
    fn fanin_met_emits_one_reduced_packet() {
        let mut eng = AggEngine::default();
        assert!(eng.offer(0, true, 3, contrib(2, 10, 7, &[1.0, 2.0])).is_empty());
        assert!(eng.offer(5, true, 3, contrib(3, 11, 7, &[10.0, 20.0])).is_empty());
        let out = eng.offer(9, true, 3, contrib(4, 12, 7, &[100.0, 200.0]));
        assert_eq!(out.len(), 1);
        let m = &out[0];
        assert_eq!(m.src, ip(2), "inherits the first contribution's identity");
        assert_eq!(m.seq, 10);
        assert_eq!(m.payload.f32s().unwrap().unwrap(), vec![111.0, 222.0]);
        let meta = m.agg.as_ref().unwrap();
        assert_eq!(meta.entries.len(), 3, "manifest is the union");
        assert_eq!(eng.counters.merged, 1);
        assert_eq!(eng.counters.absorbed, 3);
        assert_eq!(eng.live_slots(), 0);
    }

    #[test]
    fn entry_counted_fanin_tolerates_upstream_eviction() {
        // A two-entry merged packet plus a single completes fanin 3.
        let mut eng = AggEngine::default();
        let mut pre = contrib(2, 10, 7, &[1.0]);
        Arc::make_mut(pre.agg.as_mut().unwrap()).entries.push(AggEntry {
            src: ip(3),
            seq: 11,
            done_id: 99,
        });
        assert!(eng.offer(0, true, 3, pre).is_empty());
        let out = eng.offer(1, true, 3, contrib(4, 12, 7, &[5.0]));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].agg.as_ref().unwrap().entries.len(), 3);
        assert_eq!(out[0].payload.f32s().unwrap().unwrap(), vec![6.0]);
    }

    #[test]
    fn timeout_evicts_originals_and_late_stragglers_pass_through() {
        let mut eng = AggEngine::new(AggConfig {
            max_slots: 8,
            timeout_ns: 100,
        });
        let a = contrib(2, 10, 7, &[1.0]);
        let b = contrib(3, 11, 7, &[2.0]);
        assert!(eng.offer(0, true, 3, a.clone()).is_empty());
        assert!(eng.offer(50, true, 3, b.clone()).is_empty());
        // A packet for another group arrives after the deadline: the
        // expired slot's originals ride out ahead of it, untouched.
        let other = contrib(5, 20, 8, &[9.0]);
        let out = eng.offer(200, true, 3, other.clone());
        assert_eq!(out, vec![a, b]);
        assert_eq!(eng.counters.evicted_slots, 1);
        assert_eq!(eng.counters.evicted_pkts, 2);
        // The evicted group's third contribution arrives late: pass-through.
        let c = contrib(4, 12, 7, &[3.0]);
        let out = eng.offer(210, true, 3, c.clone());
        assert_eq!(out, vec![c]);
        assert_eq!(eng.counters.late, 1);
    }

    #[test]
    fn table_overflow_degrades_to_forwarding() {
        let mut eng = AggEngine::new(AggConfig {
            max_slots: 2,
            timeout_ns: 1_000_000,
        });
        assert!(eng.offer(0, true, 2, contrib(2, 1, 1, &[1.0])).is_empty());
        assert!(eng.offer(0, true, 2, contrib(3, 2, 2, &[1.0])).is_empty());
        let c = contrib(4, 3, 3, &[1.0]);
        let out = eng.offer(0, true, 2, c.clone());
        assert_eq!(out, vec![c], "third group bounces off the full table");
        assert_eq!(eng.counters.overflow, 1);
        assert_eq!(eng.live_slots(), 2);
    }

    #[test]
    fn duplicate_contribution_is_dropped_while_buffered() {
        let mut eng = AggEngine::default();
        let a = contrib(2, 10, 7, &[1.0]);
        assert!(eng.offer(0, true, 2, a.clone()).is_empty());
        assert!(eng.offer(1, true, 2, a).is_empty(), "retransmit echo absorbed");
        assert_eq!(eng.counters.dup_drops, 1);
        // The real second contribution still completes the slot.
        let out = eng.offer(2, true, 2, contrib(3, 11, 7, &[2.0]));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].payload.f32s().unwrap().unwrap(), vec![3.0]);
    }

    #[test]
    fn non_commutative_and_non_waypoint_traffic_forwarded() {
        let mut eng = AggEngine::default();
        let sub = contrib_op(2, 10, 7, &[1.0], SimdOp::Sub);
        let out = eng.offer(0, true, 2, sub.clone());
        assert_eq!(out, vec![sub], "Sub is not switch-eligible");
        assert_eq!(eng.counters.refused, 1);
        let thru = contrib(3, 11, 8, &[1.0]);
        let out = eng.offer(0, false, 2, thru.clone());
        assert_eq!(out, vec![thru], "transit traffic never aggregates");
        assert_eq!(eng.live_slots(), 0);
    }

    /// The exactly-once invariant under a randomized arrival schedule:
    /// every distinct contribution entry leaves the switch exactly once
    /// (merged or forwarded), duplicates never do.
    #[test]
    fn property_every_entry_leaves_exactly_once() {
        let mut rng = crate::util::Xoshiro256::seed_from(0xA66);
        for round in 0..50u64 {
            let mut eng = AggEngine::new(AggConfig {
                max_slots: 3,
                timeout_ns: 64,
            });
            let groups = 1 + (round % 5) as u32;
            let fanin = 2 + (round % 3) as u16;
            let mut offered: Vec<(u32, u64)> = Vec::new();
            let mut escaped: Vec<(u32, u64)> = Vec::new();
            let mut now = 0;
            for i in 0..40u64 {
                now += rng.next_below(40);
                let g = rng.next_below(groups as u64) as u32;
                let src = 2 + rng.next_below(6) as u8;
                let dup = !offered.is_empty() && rng.next_below(4) == 0;
                let (src, seq) = if dup {
                    let (s, q) = offered[rng.next_below(offered.len() as u64) as usize];
                    (s as u8, q)
                } else {
                    (src, 1000 * round + i)
                };
                let pkt = contrib(src, seq, g, &[1.0]);
                if !dup {
                    offered.push((src as u32, seq));
                }
                for out in eng.offer(now, true, fanin, pkt) {
                    for e in &out.agg.as_ref().unwrap().entries {
                        escaped.push((e.src.0 & 0xFF, e.seq));
                    }
                }
            }
            // Flush everything still buffered.
            for out in eng.expire(u64::MAX) {
                for e in &out.agg.as_ref().unwrap().entries {
                    escaped.push((e.src.0 & 0xFF, e.seq));
                }
            }
            let mut want: Vec<(u32, u64)> = offered.clone();
            want.sort_unstable();
            escaped.sort_unstable();
            assert_eq!(
                escaped, want,
                "round {round}: each unique entry must escape exactly once"
            );
        }
    }
}
