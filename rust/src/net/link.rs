//! Point-to-point link model.
//!
//! A link is unidirectional (topology builders create pairs) with a line
//! rate, propagation delay, and a finite egress buffer. Serialization is
//! tracked in **picoseconds** so back-to-back 64 B frames at 100G (5.12 ns
//! each) don't accumulate rounding drift over millions of packets.

use std::collections::VecDeque;

use crate::sim::{SimTime, GBPS};

use super::cluster::NodeId;

pub type LinkId = usize;

#[derive(Debug, Clone)]
pub struct LinkConfig {
    pub rate: GBPS,
    /// Propagation + PHY delay one way.
    pub prop_ns: SimTime,
    /// Egress buffer (bytes) shared by everything queued on this link.
    pub buffer_bytes: usize,
    /// RED min threshold (bytes queued): below it no frame is marked.
    /// `usize::MAX` disables marking.
    pub ecn_threshold: usize,
    /// RED max threshold: at or above it every frame is marked. Between
    /// min and max the marking probability ramps linearly — realized
    /// *deterministically* via a credit accumulator so the sharded core
    /// stays bit-identical across shard counts (no RNG draw per frame).
    pub ecn_max: usize,
}

impl LinkConfig {
    /// 100G datacenter port: ~500 KB egress buffer per port (shallow
    /// Nexus-class shared buffer share), RED ramp over 20%–60% occupancy.
    pub fn dc_100g() -> Self {
        Self {
            rate: GBPS(100.0),
            prop_ns: 500, // ~100 m fiber equivalent incl. PHY
            buffer_bytes: 500_000,
            ecn_threshold: 100_000,
            ecn_max: 300_000,
        }
    }

    pub fn with_rate(mut self, gbps: f64) -> Self {
        self.rate = GBPS(gbps);
        self
    }

    pub fn with_buffer(mut self, bytes: usize) -> Self {
        self.buffer_bytes = bytes;
        self
    }

    /// Set the RED marking ramp: no marks below `min` bytes queued, every
    /// frame marked at `max` and above, linear in between.
    pub fn with_ecn(mut self, min: usize, max: usize) -> Self {
        self.ecn_threshold = min;
        self.ecn_max = max.max(min);
        self
    }
}

#[derive(Debug)]
pub struct Link {
    pub from: NodeId,
    pub to: NodeId,
    pub cfg: LinkConfig,
    /// Picosecond time until which the transmitter is busy.
    busy_until_ps: u64,
    /// Bytes currently queued (including the frame in flight).
    queued_bytes: usize,
    /// Frames awaiting their departure instant `(departure_ps, bytes)`.
    /// Drained lazily on the next `transmit`/`backlog` call — this keeps
    /// buffer accounting exact *without a DES event per frame* (§ Perf:
    /// removed one third of all events).
    in_flight: VecDeque<(u64, usize)>,
    /// RED marking credit: each frame in the [min, max) ramp deposits its
    /// marking fraction; a mark fires (and spends 1.0) when the balance
    /// reaches 1. Deterministic stand-in for RED's random draw.
    ecn_credit: f64,
    // --- counters ---
    pub tx_pkts: u64,
    pub tx_bytes: u64,
    pub drops: u64,
    pub ecn_marks: u64,
}

/// Result of attempting to enqueue a frame.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TxResult {
    /// Frame accepted; arrives at `.0` ns at the far end; `.1` is the
    /// departure (end of serialization) used to release buffer space.
    Sent { arrival: SimTime, departure: SimTime, ecn: bool },
    /// Buffer full — tail drop.
    Dropped,
}

impl Link {
    pub fn new(from: NodeId, to: NodeId, cfg: LinkConfig) -> Self {
        Self {
            from,
            to,
            cfg,
            busy_until_ps: 0,
            queued_bytes: 0,
            in_flight: VecDeque::new(),
            ecn_credit: 0.0,
            tx_pkts: 0,
            tx_bytes: 0,
            drops: 0,
            ecn_marks: 0,
        }
    }

    /// Release every frame whose serialization finished by `now_ps`.
    #[inline]
    fn drain(&mut self, now_ps: u64) {
        while let Some(&(dep, b)) = self.in_flight.front() {
            if dep > now_ps {
                break;
            }
            self.in_flight.pop_front();
            debug_assert!(self.queued_bytes >= b);
            self.queued_bytes -= b;
        }
    }

    /// Attempt to transmit `bytes` at time `now`.
    pub fn transmit(&mut self, now: SimTime, bytes: usize) -> TxResult {
        self.drain(now * 1000);
        if self.queued_bytes + bytes > self.cfg.buffer_bytes {
            self.drops += 1;
            return TxResult::Dropped;
        }
        let ecn = self.red_mark(self.queued_bytes);
        if ecn {
            self.ecn_marks += 1;
        }
        let now_ps = now * 1000;
        let start = self.busy_until_ps.max(now_ps);
        let end = start + self.cfg.rate.ser_ps(bytes);
        self.busy_until_ps = end;
        self.queued_bytes += bytes;
        self.in_flight.push_back((end, bytes));
        self.tx_pkts += 1;
        self.tx_bytes += bytes as u64;
        let departure = end.div_ceil(1000);
        TxResult::Sent {
            arrival: departure + self.cfg.prop_ns,
            departure,
            ecn,
        }
    }

    /// RED marking decision for a frame seeing `queued` bytes ahead of it.
    /// Below min: no mark, credit resets (the queue drained). At/above
    /// max: always mark. In between: deposit the linear fraction and mark
    /// when the accumulated credit crosses 1 — same average mark rate as
    /// probabilistic RED, but a pure function of the arrival sequence, so
    /// identical across shard counts.
    fn red_mark(&mut self, queued: usize) -> bool {
        let min = self.cfg.ecn_threshold;
        let max = self.cfg.ecn_max.max(min);
        if queued < min {
            self.ecn_credit = 0.0;
            false
        } else if queued >= max || min == max {
            true
        } else {
            self.ecn_credit += (queued - min) as f64 / (max - min) as f64;
            if self.ecn_credit >= 1.0 {
                self.ecn_credit -= 1.0;
                true
            } else {
                false
            }
        }
    }

    /// Current backlog in bytes at time `now`.
    pub fn backlog_at(&mut self, now: SimTime) -> usize {
        self.drain(now * 1000);
        self.queued_bytes
    }

    /// Backlog without draining (tests/diagnostics).
    pub fn backlog(&self) -> usize {
        self.queued_bytes
    }

    /// Queueing delay a new frame would see right now (ns).
    pub fn queue_delay_ns(&self, now: SimTime) -> SimTime {
        (self.busy_until_ps / 1000).saturating_sub(now)
    }

    /// Utilization over an interval, given bytes sent in it.
    pub fn utilization(&self, interval_ns: SimTime) -> f64 {
        if interval_ns == 0 {
            return 0.0;
        }
        (self.tx_bytes as f64 * 8.0) / (self.cfg.rate.0 * interval_ns as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link::new(0, 1, LinkConfig::dc_100g())
    }

    #[test]
    fn first_frame_arrival_time() {
        let mut l = link();
        // 9000B at 100G = 720ns serialization + 500ns prop.
        match l.transmit(0, 9000) {
            TxResult::Sent { arrival, departure, ecn } => {
                assert_eq!(departure, 720);
                assert_eq!(arrival, 1220);
                assert!(!ecn);
            }
            _ => panic!("dropped"),
        }
    }

    #[test]
    fn back_to_back_frames_queue() {
        let mut l = link();
        let TxResult::Sent { departure: d1, .. } = l.transmit(0, 9000) else {
            panic!()
        };
        let TxResult::Sent { departure: d2, .. } = l.transmit(0, 9000) else {
            panic!()
        };
        assert_eq!(d2, d1 + 720, "second frame serializes after the first");
        assert_eq!(l.backlog(), 18000);
        // Lazy release: once the first frame's departure time passes,
        // the next backlog query reclaims its bytes.
        assert_eq!(l.backlog_at(d1), 9000);
        assert_eq!(l.backlog_at(d2), 0);
    }

    #[test]
    fn no_rounding_drift_at_64b() {
        let mut l = link();
        // 1000 × 64B = 64000B = 5.12us exactly at 100G.
        let mut last = 0;
        for _ in 0..1000 {
            if let TxResult::Sent { departure, .. } = l.transmit(0, 64) {
                last = departure;
            }
        }
        assert_eq!(last, 5120);
    }

    #[test]
    fn buffer_overflow_drops() {
        let mut l = Link::new(0, 1, LinkConfig::dc_100g().with_buffer(20_000));
        assert!(matches!(l.transmit(0, 9000), TxResult::Sent { .. }));
        assert!(matches!(l.transmit(0, 9000), TxResult::Sent { .. }));
        assert_eq!(l.transmit(0, 9000), TxResult::Dropped);
        assert_eq!(l.drops, 1);
    }

    fn sent_ecn(l: &mut Link, now: SimTime, bytes: usize) -> bool {
        match l.transmit(now, bytes) {
            TxResult::Sent { ecn, .. } => ecn,
            TxResult::Dropped => panic!("dropped"),
        }
    }

    #[test]
    fn red_ramp_marks_by_accumulated_credit() {
        // Ramp [5000, 21000): fractions accumulate until a mark fires.
        let mut l = Link::new(0, 1, LinkConfig::dc_100g().with_ecn(5_000, 21_000));
        assert!(!sent_ecn(&mut l, 0, 9000), "queue 0 < min");
        assert!(!sent_ecn(&mut l, 0, 9000), "credit 0.25 (4000/16000)");
        assert!(
            sent_ecn(&mut l, 0, 9000),
            "credit 0.25 + 0.8125 crosses 1.0"
        );
        assert!(sent_ecn(&mut l, 0, 9000), "queue 27000 >= max always marks");
        assert_eq!(l.ecn_marks, 2);
    }

    #[test]
    fn red_credit_resets_when_queue_drains() {
        let mut l = Link::new(0, 1, LinkConfig::dc_100g().with_ecn(5_000, 21_000));
        l.transmit(0, 9000);
        assert!(!sent_ecn(&mut l, 0, 9000), "banks 0.25 credit");
        // Much later the queue has drained below min: the banked credit
        // must not leak into the next congestion epoch.
        assert!(!sent_ecn(&mut l, 1_000_000, 9000), "queue 0 resets credit");
        assert!(!sent_ecn(&mut l, 1_000_000, 9000), "0.25 again, no carryover");
    }

    #[test]
    fn degenerate_ramp_marks_like_a_step() {
        // min == max: classic step-threshold behavior at 10 KB.
        let mut l = Link::new(0, 1, LinkConfig::dc_100g().with_ecn(10_000, 10_000));
        assert!(!sent_ecn(&mut l, 0, 9000), "queue 0");
        assert!(!sent_ecn(&mut l, 0, 9000), "queue 9000 < 10000");
        assert!(sent_ecn(&mut l, 0, 9000), "queue 18000 >= threshold");
        assert_eq!(l.ecn_marks, 1);
    }

    #[test]
    fn idle_link_resets_to_now() {
        let mut l = link();
        l.transmit(0, 9000);
        // Much later, a new frame starts fresh from `now` (and the lazy
        // drain reclaims the first frame's buffer).
        if let TxResult::Sent { departure, .. } = l.transmit(1_000_000, 64) {
            assert_eq!(departure, 1_000_006); // 5.12ns → ceil 6
        } else {
            panic!()
        }
    }
}
