//! Topology builders for the experiments.
//!
//! * [`Topology::star`] — the paper's testbed: N NetDAM devices (+ hosts)
//!   on one ToR switch (Nexus 93180FX).
//! * [`Topology::dual_spine`] — two parallel spines between leaves: the
//!   multipath scenario of §2.3 (experiment E4).
//! * [`Topology::fat_tree`] — a k-ary 2-level Clos for pool-scale runs.
//!
//! Every builder has a `*_with` variant taking a [`DeviceProfile`]
//! (data-bearing vs timing-only phantom HBM) and records the leaf
//! membership of each device in [`Topology::leaf_groups`] — the grouping
//! the hierarchical collectives consume.

use crate::device::DeviceConfig;
use crate::wire::DeviceIp;

use super::cluster::{Cluster, NodeId};
use super::link::LinkConfig;
use super::switch::{EcmpMode, Switch};

/// How the builders configure each NetDAM device.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum DeviceProfile {
    /// Real HBM contents (verifiable collectives).
    #[default]
    Data,
    /// Phantom payload accounting only — paper-scale vectors at simulation
    /// speed (2^29 floats without 2 GiB per device).
    TimingOnly,
}

impl DeviceProfile {
    fn config(self, ip: DeviceIp) -> DeviceConfig {
        let cfg = DeviceConfig::paper_default(ip);
        match self {
            DeviceProfile::Data => cfg,
            DeviceProfile::TimingOnly => cfg.timing_only(),
        }
    }
}

/// Handles to the nodes a builder created.
pub struct Topology {
    pub cluster: Cluster,
    pub devices: Vec<NodeId>,
    pub hosts: Vec<NodeId>,
    pub switches: Vec<NodeId>,
    /// Indices into `devices`, grouped by the leaf switch they hang off
    /// (one group for the star). Group order follows device order.
    pub leaf_groups: Vec<Vec<usize>>,
    /// SROU-addressable leaf-switch ips, one per `leaf_groups` entry
    /// (empty when the topology's leaves are unaddressed, e.g. star).
    pub leaf_ips: Vec<DeviceIp>,
    /// SROU-addressable spine ips (empty when there is no spine tier).
    pub spine_ips: Vec<DeviceIp>,
}

impl Topology {
    /// N devices and H plain hosts on one switch. Device ips are
    /// 10.0.0.1.., host ips 10.0.0.101.., switch unaddressed.
    pub fn star(seed: u64, n_devices: usize, n_hosts: usize, link: LinkConfig) -> Topology {
        Self::star_with(seed, n_devices, n_hosts, link, DeviceProfile::Data)
    }

    /// [`Topology::star`] with an explicit device profile.
    pub fn star_with(
        seed: u64,
        n_devices: usize,
        n_hosts: usize,
        link: LinkConfig,
        profile: DeviceProfile,
    ) -> Topology {
        let mut cl = Cluster::new(seed);
        let sw = cl.add_switch(Switch::tor(None));
        let mut devices = Vec::new();
        let mut hosts = Vec::new();
        for i in 0..n_devices {
            let d = cl.add_device(profile.config(DeviceIp::lan(1 + i as u8)));
            cl.connect(sw, d, link.clone());
            devices.push(d);
        }
        for i in 0..n_hosts {
            let h = cl.add_host(DeviceIp::lan(101 + i as u8), None);
            cl.connect(sw, h, link.clone());
            hosts.push(h);
        }
        cl.compute_routes();
        Topology {
            cluster: cl,
            leaf_groups: vec![(0..devices.len()).collect()],
            devices,
            hosts,
            switches: vec![sw],
            leaf_ips: vec![],
            spine_ips: vec![],
        }
    }

    /// The paper's 4-device testbed (2× U55N, 2 devices each) + 1 driver
    /// host, 100G everywhere.
    pub fn paper_testbed(seed: u64) -> Topology {
        Self::star(seed, 4, 1, LinkConfig::dc_100g())
    }

    /// Two leaves, two spines, everything dual-homed: equal-cost pair of
    /// paths between any cross-leaf pair. Spines are SROU-addressable
    /// (ips 10.0.0.201/202) so sources can pin paths.
    pub fn dual_spine(
        seed: u64,
        devs_per_leaf: usize,
        link: LinkConfig,
        ecmp: EcmpMode,
    ) -> Topology {
        let mut cl = Cluster::new(seed);
        let leaf1 = cl.add_switch(Switch::new(None, 600, ecmp));
        let leaf2 = cl.add_switch(Switch::new(None, 600, ecmp));
        let spine1 = cl.add_switch(Switch::new(Some(DeviceIp::lan(201)), 600, ecmp));
        let spine2 = cl.add_switch(Switch::new(Some(DeviceIp::lan(202)), 600, ecmp));
        for leaf in [leaf1, leaf2] {
            cl.connect(leaf, spine1, link.clone());
            cl.connect(leaf, spine2, link.clone());
        }
        let mut devices = Vec::new();
        for i in 0..devs_per_leaf * 2 {
            let leaf = if i < devs_per_leaf { leaf1 } else { leaf2 };
            let d = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1 + i as u8)));
            cl.connect(leaf, d, link.clone());
            devices.push(d);
        }
        cl.compute_routes();
        Topology {
            cluster: cl,
            leaf_groups: vec![
                (0..devs_per_leaf).collect(),
                (devs_per_leaf..devs_per_leaf * 2).collect(),
            ],
            devices,
            hosts: vec![],
            switches: vec![leaf1, leaf2, spine1, spine2],
            leaf_ips: vec![],
            spine_ips: vec![DeviceIp::lan(201), DeviceIp::lan(202)],
        }
    }

    /// Two-level Clos: `pods` leaf switches × `devs_per_leaf` devices,
    /// `spines` spine switches, every leaf connected to every spine.
    pub fn fat_tree(
        seed: u64,
        pods: usize,
        devs_per_leaf: usize,
        spines: usize,
        link: LinkConfig,
        ecmp: EcmpMode,
    ) -> Topology {
        Self::fat_tree_with(
            seed,
            pods,
            devs_per_leaf,
            spines,
            link,
            ecmp,
            DeviceProfile::Data,
        )
    }

    /// Device ip of the `idx`-th fat-tree device. Up to 96 devices keep
    /// the historic `10.0.0.(1+idx)` addresses (tests and docs rely on
    /// them); beyond that, devices spill into `10.1.x.y` — disjoint from
    /// both the small-LAN range and the spine range (`10.0.0.200+`), so
    /// 1024-rank grids address cleanly.
    fn fat_tree_device_ip(idx: usize) -> DeviceIp {
        if idx < 96 {
            DeviceIp::lan(1 + idx as u8)
        } else {
            let wide = idx - 96;
            assert!(wide < 65_536, "fat-tree device index out of ip space");
            DeviceIp(0x0A01_0000 | wide as u32)
        }
    }

    /// [`Topology::fat_tree`] with an explicit device profile.
    pub fn fat_tree_with(
        seed: u64,
        pods: usize,
        devs_per_leaf: usize,
        spines: usize,
        link: LinkConfig,
        ecmp: EcmpMode,
        profile: DeviceProfile,
    ) -> Topology {
        assert!(spines <= 55, "spine ip space is 10.0.0.200..=255");
        assert!(pods <= 50, "leaf ip space is 10.0.0.150..=199");
        let mut cl = Cluster::new(seed);
        let spine_ids: Vec<NodeId> = (0..spines)
            .map(|s| cl.add_switch(Switch::new(Some(DeviceIp::lan(200 + s as u8)), 600, ecmp)))
            .collect();
        let mut devices = Vec::new();
        let mut leaf_groups = Vec::new();
        let mut leaf_ips = Vec::new();
        let mut switches = spine_ids.clone();
        for p in 0..pods {
            // Leaves are SROU-addressable so aggregation trees can name
            // them as reduce waypoints (disjoint from devices <= .96,
            // hosts .101.., spines .200..).
            let leaf_ip = DeviceIp::lan(150 + p as u8);
            let leaf = cl.add_switch(Switch::new(Some(leaf_ip), 600, ecmp));
            leaf_ips.push(leaf_ip);
            switches.push(leaf);
            for &s in &spine_ids {
                cl.connect(leaf, s, link.clone());
            }
            let mut group = Vec::new();
            for d in 0..devs_per_leaf {
                let ip = Self::fat_tree_device_ip(p * devs_per_leaf + d);
                let dev = cl.add_device(profile.config(ip));
                cl.connect(leaf, dev, link.clone());
                group.push(devices.len());
                devices.push(dev);
            }
            leaf_groups.push(group);
        }
        cl.compute_routes();
        Topology {
            cluster: cl,
            devices,
            hosts: vec![],
            switches,
            leaf_groups,
            leaf_ips,
            spine_ips: (0..spines).map(|s| DeviceIp::lan(200 + s as u8)).collect(),
        }
    }

    /// Device ip of the i-th device.
    pub fn device_ip(&self, i: usize) -> DeviceIp {
        self.cluster.device(self.devices[i]).ip()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;
    use crate::sim::Engine;
    use crate::wire::{Packet, SrouHeader};

    #[test]
    fn paper_testbed_shape() {
        let t = Topology::paper_testbed(1);
        assert_eq!(t.devices.len(), 4);
        assert_eq!(t.hosts.len(), 1);
        // 5 endpoints × 2 directions.
        assert_eq!(t.cluster.links.len(), 10);
        assert_eq!(t.leaf_groups, vec![vec![0, 1, 2, 3]]);
    }

    #[test]
    fn dual_spine_has_two_equal_paths() {
        let t = Topology::dual_spine(1, 1, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        let d0 = t.devices[0]; // leaf1
        let ip1 = t.device_ip(1); // leaf2
        let cands = &t.cluster.fib_of(d0)[&ip1];
        assert_eq!(cands.len(), 1, "device has one uplink");
        // The leaf switch sees two equal-cost spine links.
        let leaf1 = t.switches[0];
        assert_eq!(t.cluster.fib_of(leaf1)[&ip1].len(), 2);
    }

    #[test]
    fn fat_tree_cross_pod_reachability() {
        let t = Topology::fat_tree(5, 3, 2, 2, LinkConfig::dc_100g(), EcmpMode::FlowHash);
        assert_eq!(t.devices.len(), 6);
        assert_eq!(t.leaf_groups, vec![vec![0, 1], vec![2, 3], vec![4, 5]]);
        let mut cl = t.cluster;
        let mut eng: Engine<Cluster> = Engine::new();
        // Device 0 (pod 0) reads from device 5 (pod 2).
        let from = t.devices[0];
        let seq = cl.alloc_seq(from);
        let target = DeviceIp::lan(6);
        let pkt = Packet::new(
            DeviceIp::lan(1),
            seq,
            SrouHeader::direct(target),
            Instruction::Read { addr: 0, len: 64 },
        );
        cl.inject(&mut eng, from, pkt);
        eng.run(&mut cl);
        // The response lands in device 0's completion queue.
        let comps = cl.device_mut(from).drain_completions();
        assert_eq!(comps.len(), 1);
        assert_eq!(cl.total_drops(), 0);
    }

    #[test]
    fn fat_tree_scales_past_96_devices() {
        // 8 pods × 16 devices = 128 > the 8-bit 10.0.0.x space; the wide
        // 10.1.x.y range takes over at index 96 without colliding with
        // spines (10.0.0.200+).
        let t = Topology::fat_tree_with(
            11,
            8,
            16,
            2,
            LinkConfig::dc_100g(),
            EcmpMode::FlowHash,
            DeviceProfile::TimingOnly,
        );
        assert_eq!(t.devices.len(), 128);
        assert_eq!(t.device_ip(0), DeviceIp::lan(1));
        assert_eq!(t.device_ip(95), DeviceIp::lan(96));
        assert_eq!(t.device_ip(96), DeviceIp(0x0A01_0000));
        assert_eq!(t.device_ip(127), DeviceIp(0x0A01_001F));
        // All addresses are distinct and routable.
        let mut ips: Vec<_> = (0..128).map(|i| t.device_ip(i)).collect();
        ips.sort();
        ips.dedup();
        assert_eq!(ips.len(), 128);
        let d0 = t.devices[0];
        assert!(t.cluster.fib_of(d0).contains_key(&t.device_ip(127)));
    }

    #[test]
    fn timing_profile_builds_phantom_devices() {
        let t = Topology::star_with(
            2,
            2,
            0,
            LinkConfig::dc_100g(),
            DeviceProfile::TimingOnly,
        );
        for &d in &t.devices {
            assert!(t.cluster.device(d).mem_ref().is_phantom());
        }
        let t = Topology::star(2, 2, 0, LinkConfig::dc_100g());
        for &d in &t.devices {
            assert!(!t.cluster.device(d).mem_ref().is_phantom());
        }
    }

    use super::super::cluster::Cluster;
}
