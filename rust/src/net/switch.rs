//! Switch model: store-and-forward pipeline + ECMP next-hop selection.
//!
//! The paper's testbed switch (Nexus 93180FX) is modeled as a fixed
//! forwarding latency plus per-egress-port queues (the queues live in
//! [`super::link::Link`]). The FIB is computed by the topology builder
//! (BFS equal-cost sets); selection is either per-flow hashing (classic
//! ECMP) or per-packet spray — the paper's SROU multipath argument (E4)
//! compares exactly these two against source-pinned waypoints.

use std::collections::HashMap;

use crate::net::aggregate::AggEngine;
use crate::pool::TenantId;
use crate::sim::SimTime;
use crate::wire::{DeviceIp, Packet};

/// How a switch picks among equal-cost egress links.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EcmpMode {
    /// Hash (src, dst) — one path per flow, collisions possible.
    FlowHash,
    /// Per-packet round-robin spray — maximal utilization, reorders.
    Spray,
}

#[derive(Debug)]
pub struct Switch {
    /// Optional address so SROU segments can name this switch as a
    /// waypoint (§2.3 "source node could select dedicated path").
    pub ip: Option<DeviceIp>,
    /// Forwarding pipeline latency (cut-through ASIC ~ 300–900 ns).
    pub latency_ns: SimTime,
    pub ecmp: EcmpMode,
    /// Per-packet spray round-robin cursor.
    rr: usize,
    pub forwarded: u64,
    pub no_route_drops: u64,
    /// In-network reduction table (PR 7, paper §2.5 "or in datacenter
    /// switch"): aggregation-marked packets naming this switch as an
    /// SROU waypoint are folded here instead of forwarded.
    pub agg: AggEngine,
    /// §2.5 tenant ACL: requester → tenant, mirroring the device-side
    /// `IommuDirectory` programming. Empty table = not enforcing.
    pub acl: HashMap<DeviceIp, TenantId>,
    /// Aggregation packets dropped because the requester is unbound.
    pub acl_drops_unbound: u64,
    /// Aggregation packets dropped because the requester is bound to a
    /// different tenant than the packet claims.
    pub acl_drops_foreign: u64,
}

impl Switch {
    pub fn new(ip: Option<DeviceIp>, latency_ns: SimTime, ecmp: EcmpMode) -> Self {
        Self {
            ip,
            latency_ns,
            ecmp,
            rr: 0,
            forwarded: 0,
            no_route_drops: 0,
            agg: AggEngine::default(),
            acl: HashMap::new(),
            acl_drops_unbound: 0,
            acl_drops_foreign: 0,
        }
    }

    /// Program the §2.5 ACL: `requester` belongs to `tenant`. A switch
    /// with at least one binding enforces the table on aggregation
    /// traffic (matching how the device-side IOMMU starts enforcing
    /// once programmed).
    pub fn bind_tenant(&mut self, requester: DeviceIp, tenant: TenantId) {
        self.acl.insert(requester, tenant);
    }

    /// Run `pkt` through the ACL and the aggregation table; returns the
    /// packets the switch must actually forward (empty if absorbed or
    /// dropped). `was_waypoint`/`fanin` come from the SROU segment the
    /// packet consumed at this switch.
    pub fn offer_agg(
        &mut self,
        now: SimTime,
        was_waypoint: bool,
        fanin: u16,
        pkt: Packet,
    ) -> Vec<Packet> {
        if pkt.flags.agg() && !self.acl.is_empty() {
            if let Some(meta) = pkt.agg.as_ref() {
                match self.acl.get(&pkt.src) {
                    None => {
                        self.acl_drops_unbound += 1;
                        return self.agg.expire(now);
                    }
                    Some(&t) if t != meta.tenant => {
                        self.acl_drops_foreign += 1;
                        return self.agg.expire(now);
                    }
                    Some(_) => {}
                }
            }
        }
        self.agg.offer(now, was_waypoint, fanin, pkt)
    }

    /// Nexus-class ToR: ~600 ns forwarding, flow-hash ECMP.
    pub fn tor(ip: Option<DeviceIp>) -> Self {
        Self::new(ip, 600, EcmpMode::FlowHash)
    }

    /// Pick one index among `n` equal-cost candidates for `pkt`.
    pub fn pick(&mut self, pkt: &Packet, dst: DeviceIp, n: usize) -> usize {
        debug_assert!(n > 0);
        match self.ecmp {
            EcmpMode::FlowHash => flow_hash(pkt.src, dst, n),
            EcmpMode::Spray => {
                self.rr = (self.rr + 1) % n;
                self.rr
            }
        }
    }
}

/// The deterministic per-flow ECMP hash: (src, dst) only — sequence is
/// deliberately excluded so a flow sticks to one path. Public so
/// experiments can *predict* collisions (E4 picks a colliding flow set
/// the way an unlucky production workload would encounter one).
pub fn flow_hash(src: DeviceIp, dst: DeviceIp, n: usize) -> usize {
    let mut h = src.0 as u64 ^ ((dst.0 as u64) << 32);
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    h ^= h >> 33;
    (h % n as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instruction;
    use crate::wire::SrouHeader;

    fn pkt(src: u8, dst: u8) -> Packet {
        Packet::new(
            DeviceIp::lan(src),
            1,
            SrouHeader::direct(DeviceIp::lan(dst)),
            Instruction::Nop,
        )
    }

    #[test]
    fn flow_hash_is_sticky_per_flow() {
        let mut sw = Switch::tor(None);
        let p = pkt(1, 2);
        let first = sw.pick(&p, DeviceIp::lan(2), 4);
        for _ in 0..100 {
            assert_eq!(sw.pick(&p, DeviceIp::lan(2), 4), first);
        }
    }

    #[test]
    fn flow_hash_spreads_across_flows() {
        let mut sw = Switch::tor(None);
        let mut seen = std::collections::HashSet::new();
        for s in 1..64 {
            for d in 64..72 {
                seen.insert(sw.pick(&pkt(s, d), DeviceIp::lan(d), 4));
            }
        }
        assert_eq!(seen.len(), 4, "all 4 paths used across many flows");
    }

    #[test]
    fn spray_round_robins() {
        let mut sw = Switch::new(None, 600, EcmpMode::Spray);
        let p = pkt(1, 2);
        let picks: Vec<usize> = (0..8).map(|_| sw.pick(&p, DeviceIp::lan(2), 4)).collect();
        assert_eq!(picks, vec![1, 2, 3, 0, 1, 2, 3, 0]);
    }
}
