//! E5 — multi-tenant serving isolation A/B (PR 10).
//!
//! The serving subsystem ([`crate::serve`]) can run any fleet; this
//! harness pins the experiment the paper's pooled-memory story implies
//! but never measures: *does one misbehaving tenant move a neighbor's
//! tail?* Two congestion-control arms run the same seeded fleet, each
//! as a full aggressor A/B ([`crate::serve::isolation_check`]):
//!
//! * **static** — fixed token-bucket budgets only; isolation rests on
//!   per-plan windows and per-plan NAK cancellation.
//! * **dcqcn** — the closed loop: the aggressor's incast burst earns CE
//!   marks, its slots get rate-controlled, neighbors keep their share.
//!
//! Reported per arm: the fleet's worst p99 without/with the aggressor,
//! the worst per-tenant inflation ratio, the aggressor's NAK/cancel
//! counts, CNPs, and the verdict against the 2x bound.

use anyhow::Result;

use crate::metrics::Table;
use crate::roce::DcqcnConfig;
use crate::serve::{isolation_check, IsolationVerdict, ServeConfig};
use crate::sim::fmt_ns;
use crate::transport::CcMode;

#[derive(Debug, Clone)]
pub struct E5Config {
    pub tenants: usize,
    pub skew: f64,
    pub waves: usize,
    pub ops_per_wave: usize,
    pub seed: u64,
    /// Allowed p99 inflation in thousandths (2000 = "at most 2x").
    pub bound_milli: u64,
}

impl Default for E5Config {
    fn default() -> Self {
        Self {
            tenants: 4,
            skew: 0.99,
            waves: 4,
            ops_per_wave: 24,
            seed: 0xE5,
            bound_milli: 2_000,
        }
    }
}

/// One congestion-control arm's A/B outcome.
#[derive(Debug, Clone)]
pub struct E5Arm {
    pub label: String,
    pub verdict: IsolationVerdict,
}

#[derive(Debug)]
pub struct E5Result {
    /// `static` then `dcqcn`, each a full aggressor A/B.
    pub arms: Vec<E5Arm>,
    pub table: Table,
}

fn serve_cfg(cfg: &E5Config, cc: CcMode) -> ServeConfig {
    ServeConfig {
        tenants: cfg.tenants,
        skew: cfg.skew,
        waves: cfg.waves,
        ops_per_wave: cfg.ops_per_wave,
        seed: cfg.seed,
        cc,
        ..Default::default()
    }
}

pub fn run_e5(cfg: &E5Config) -> Result<E5Result> {
    let arms_spec = [
        ("static", CcMode::Static),
        ("dcqcn", CcMode::Dcqcn(DcqcnConfig::default())),
    ];
    let mut arms = Vec::with_capacity(arms_spec.len());
    let mut table = Table::new(&[
        "arm",
        "p99 (quiet)",
        "p99 (aggressed)",
        "worst inflation",
        "agg naks",
        "agg cancelled",
        "cnps",
        "verdict",
    ]);
    for (label, cc) in arms_spec {
        let v = isolation_check(&serve_cfg(cfg, cc), cfg.bound_milli)?;
        let agg = v
            .contended
            .aggressor
            .as_ref()
            .expect("contended arm always carries the aggressor");
        table.row(&[
            label.to_string(),
            fmt_ns(v.baseline.worst_p99()),
            fmt_ns(v.contended.worst_p99()),
            format!("{:.2}x", v.worst_ratio_milli as f64 / 1000.0),
            agg.naks.to_string(),
            agg.cancelled.to_string(),
            v.contended.cnps.to_string(),
            if v.ok { "isolated ✓" } else { "VIOLATED" }.to_string(),
        ]);
        arms.push(E5Arm {
            label: label.to_string(),
            verdict: v,
        });
    }
    Ok(E5Result { arms, table })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_arms_report_a_complete_ab() {
        let cfg = E5Config {
            tenants: 3,
            waves: 2,
            ops_per_wave: 12,
            ..Default::default()
        };
        let r = run_e5(&cfg).unwrap();
        assert_eq!(r.arms.len(), 2);
        for arm in &r.arms {
            let v = &arm.verdict;
            // The aggressor genuinely misbehaved in the contended run...
            let agg = v.contended.aggressor.as_ref().unwrap();
            assert!(agg.naks > 0 && agg.cancelled > 0, "{}: storm never fired", arm.label);
            // ...and the quiet run had none of it.
            assert!(v.baseline.aggressor.is_none());
            // Well-behaved tenants complete NAK-free in both runs.
            for t in v.baseline.tenants.iter().chain(&v.contended.tenants) {
                assert_eq!(t.naks, 0);
                assert_eq!(t.done, t.ops);
            }
            assert!(v.worst_ratio_milli > 0);
        }
        // The DCQCN arm's closed loop actually closed under the burst.
        let dcqcn = &r.arms[1].verdict;
        assert!(dcqcn.contended.cnps > 0, "no CNPs under the incast burst");
    }
}
