//! E1 — wire-to-wire READ latency, NetDAM vs RoCE (paper §2.3).
//!
//! The paper measures a SIMD READ of 32 × f32 from DRAM through the
//! NetDAM pipeline: **avg 618 ns, jitter 39 ns, max 920 ns**, "much
//! faster than RoCE". Two measurement points are reported:
//!
//! * `device_service_ns` — wire-to-wire at the device MAC (the paper's
//!   number: request-in to response-out);
//! * `rtt_*` — end-to-end at the client through the shared fabric, for
//!   the apples-to-apples NetDAM-vs-RoCE comparison.

use crate::device::DeviceConfig;
use crate::isa::Instruction;
use crate::metrics::Table;
use crate::net::{App, AppCtx, Cluster, LinkConfig, Switch};
use crate::roce::RoceResponder;
use crate::sim::Engine;
use crate::wire::{DeviceIp, Packet, SrouHeader};

#[derive(Debug, Clone)]
pub struct E1Config {
    /// READ length in bytes (paper: 32 × f32 = 128 B).
    pub read_len: u32,
    /// Samples per target.
    pub samples: usize,
    pub seed: u64,
}

impl Default for E1Config {
    fn default() -> Self {
        Self {
            read_len: 128,
            samples: 20_000,
            seed: 0xE1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct E1Stats {
    pub mean: f64,
    pub jitter: f64,
    pub p99: u64,
    pub max: u64,
}

#[derive(Debug)]
pub struct E1Result {
    /// Wire-to-wire at the NetDAM device (the paper's 618/39/920).
    pub device: E1Stats,
    /// Client-observed RTT to the NetDAM device.
    pub netdam_rtt: E1Stats,
    /// Client-observed RTT to the RoCE host.
    pub roce_rtt: E1Stats,
    pub table: Table,
}

/// Sequential READ prober: one outstanding request, `count` total.
struct Probe {
    target: DeviceIp,
    len: u32,
    remaining: usize,
    sent_at: u64,
    metric: &'static str,
}

impl Probe {
    fn fire(&mut self, ctx: &mut AppCtx) {
        let seq = ctx.alloc_seq();
        self.sent_at = ctx.now;
        ctx.send(Packet::new(
            ctx.self_ip,
            seq,
            SrouHeader::direct(self.target),
            Instruction::Read {
                addr: 4096,
                len: self.len,
            },
        ));
    }
}

impl App for Probe {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        self.fire(ctx);
    }
    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        debug_assert!(matches!(pkt.instr, Instruction::ReadResp { .. }));
        ctx.record(self.metric, ctx.now - self.sent_at);
        self.remaining -= 1;
        if self.remaining > 0 {
            self.fire(ctx);
        }
    }
}

fn stats(cl: &Cluster, name: &str) -> E1Stats {
    let h = cl.metrics.hist(name).expect(name);
    E1Stats {
        mean: h.mean(),
        jitter: h.jitter(),
        p99: h.percentile(99.0),
        max: h.max(),
    }
}

pub fn run_e1(cfg: &E1Config) -> E1Result {
    // One fabric, two targets: NetDAM device + RoCE host, one prober each
    // (separate clients so queues don't interact).
    let mut cl = Cluster::new(cfg.seed);
    cl.trace_device_service = true;
    let sw = cl.add_switch(Switch::tor(None));
    let dev = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
    let roce = cl.add_host(DeviceIp::lan(50), Some(Box::new(RoceResponder::new(cfg.seed))));
    let c1 = cl.add_host(
        DeviceIp::lan(101),
        Some(Box::new(Probe {
            target: DeviceIp::lan(1),
            len: cfg.read_len,
            remaining: cfg.samples,
            sent_at: 0,
            metric: "rtt_netdam",
        })),
    );
    let c2 = cl.add_host(
        DeviceIp::lan(102),
        Some(Box::new(Probe {
            target: DeviceIp::lan(50),
            len: cfg.read_len,
            remaining: cfg.samples,
            sent_at: 0,
            metric: "rtt_roce",
        })),
    );
    for n in [dev, roce, c1, c2] {
        cl.connect(sw, n, LinkConfig::dc_100g());
    }
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    cl.start_apps(&mut eng);
    eng.run(&mut cl);

    let device = stats(&cl, "device_service_ns");
    let netdam_rtt = stats(&cl, "rtt_netdam");
    let roce_rtt = stats(&cl, "rtt_roce");

    let mut table = Table::new(&["measurement", "avg ns", "jitter ns", "p99 ns", "max ns"]);
    let row = |t: &mut Table, name: &str, s: &E1Stats| {
        t.row(&[
            name.to_string(),
            format!("{:.0}", s.mean),
            format!("{:.0}", s.jitter),
            s.p99.to_string(),
            s.max.to_string(),
        ]);
    };
    row(&mut table, "NetDAM device wire-to-wire (paper: 618/39/920)", &device);
    row(&mut table, "NetDAM client RTT", &netdam_rtt);
    row(&mut table, "RoCE client RTT", &roce_rtt);

    E1Result {
        device,
        netdam_rtt,
        roce_rtt,
        table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e1_reproduces_paper_numbers() {
        let r = run_e1(&E1Config {
            samples: 5_000,
            ..Default::default()
        });
        // Paper band ±15%: avg 618, jitter 39, max 920.
        assert!(
            (r.device.mean - 618.0).abs() < 0.15 * 618.0,
            "avg {}",
            r.device.mean
        );
        assert!(
            (r.device.jitter - 39.0).abs() < 0.5 * 39.0,
            "jitter {}",
            r.device.jitter
        );
        assert!(r.device.max < 1100, "max {}", r.device.max);
        assert!(r.device.max > 700, "max {}", r.device.max);
        // "much faster than RoCE": the shared fabric adds ~2.6 us to both
        // RTTs, so the honest comparison is the *service margin* and the
        // jitter/tail, where the host path loses badly.
        assert!(
            r.roce_rtt.mean - r.netdam_rtt.mean > 700.0,
            "PCIe margin: roce {} vs netdam {}",
            r.roce_rtt.mean,
            r.netdam_rtt.mean
        );
        assert!(r.roce_rtt.jitter > 4.0 * r.netdam_rtt.jitter);
        assert!(r.roce_rtt.max > 2 * r.netdam_rtt.max);
    }
}
