//! The experiment coordinator: reusable drivers for every paper
//! experiment (E1–E4) plus the serving-isolation A/B (E5), shared by
//! the `netdam` CLI, the benches, and the examples. Each driver builds
//! a cluster, runs the DES, and returns a rendered table plus
//! structured numbers for assertions.

pub mod e1_latency;
pub mod e2_allreduce;
pub mod e3_incast;
pub mod e4_multipath;
pub mod e5_serving;
pub mod incast_cc;

pub use e1_latency::{run_e1, E1Config, E1Result};
pub use e2_allreduce::{run_e2, E2Config, E2Result};
pub use e3_incast::{run_e3, E3Config, E3Result};
pub use e4_multipath::{run_e4, E4Config, E4Mode, E4Result};
pub use e5_serving::{run_e5, E5Arm, E5Config, E5Result};
pub use incast_cc::{run_incast_cc, ArmStats, IncastCcConfig, IncastCcResult};
