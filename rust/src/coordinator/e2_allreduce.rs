//! E2 — the §3.3 allreduce comparison, data-driven over the algorithm
//! menu.
//!
//! Paper (536,870,912 × f32, 4 nodes, 100G): native MPI 2.8 s, ring
//! (Horovod-style) 2.1 s, NetDAM ≈ 0.4 s. We reproduce the *shape*:
//! ordering NetDAM ≪ ring < native, NetDAM ≥ 4× vs ring, with the
//! absolute NetDAM time approaching the ring-allreduce line-rate floor
//! `2·(N−1)/N · V / 100G`. Since the collective layer became a shared
//! driver (`collectives::driver`), the comparison set is just a list of
//! [`AlgoKind`]s — `--algo` on the CLI swaps algorithms in and out
//! without touching this coordinator.
//!
//! Since PR 5 the device arms run on the **session API**: one long-lived
//! [`Fabric`] per topology (a star, plus a fat-tree when hierarchical is
//! in the menu), one communicator, every algorithm timed as a
//! collective on the shared engine — no fabric rebuild between runs.
//! The host baselines still model their own RoCE fabric through the
//! `run_collective` shim.

use anyhow::{ensure, Result};

use crate::collectives::{run_collective, AlgoKind, CollectiveReport, RunOpts};
use crate::comm::{Communicator, Fabric};
use crate::metrics::Table;
use crate::sim::{fmt_ns, SimTime};
use crate::transport::CcMode;

#[derive(Debug, Clone)]
pub struct E2Config {
    pub elements: usize,
    pub ranks: usize,
    /// Timing-only payloads (needed for the full 2^29 paper scale).
    pub timing_only: bool,
    pub window: usize,
    pub seed: u64,
    /// Also run the host baselines (slow at paper scale).
    pub with_baselines: bool,
    /// Which collectives to run; the classic paper triple by default.
    pub algos: Vec<AlgoKind>,
    /// Congestion control for the device arms ([`CcMode::Dcqcn`] turns
    /// on closed-loop per-slot pacing; host baselines ignore it).
    pub cc: CcMode,
}

impl Default for E2Config {
    fn default() -> Self {
        Self {
            elements: 1 << 20,
            ranks: 4,
            timing_only: false,
            window: 16,
            seed: 0xE2,
            with_baselines: true,
            algos: vec![
                AlgoKind::NetdamRing,
                AlgoKind::RingRoce,
                AlgoKind::MpiNative,
            ],
            cc: CcMode::Static,
        }
    }
}

#[derive(Debug)]
pub struct E2Result {
    pub netdam_ns: SimTime,
    pub ring_roce_ns: SimTime,
    pub mpi_native_ns: SimTime,
    pub line_rate_floor_ns: SimTime,
    /// One report per algorithm actually run, menu order.
    pub reports: Vec<CollectiveReport>,
    pub table: Table,
}

/// The ring-allreduce line-rate floor `2·(N−1)/N · V / 100G` in ns —
/// the single source for the coordinator table and the bench grid.
pub fn line_rate_floor_ns(ranks: usize, elements: usize) -> SimTime {
    let v_bytes = elements as f64 * 4.0;
    (2.0 * (ranks as f64 - 1.0) / ranks as f64 * v_bytes / 12.5) as SimTime
}

/// Paper-measured reference time at the 2 GiB scale, where known.
fn paper_ref(kind: AlgoKind) -> &'static str {
    match kind {
        AlgoKind::NetdamRing => "~0.4 s",
        AlgoKind::RingRoce => "2.1 s",
        AlgoKind::MpiNative => "2.8 s",
        _ => "-",
    }
}

pub fn run_e2(cfg: &E2Config) -> Result<E2Result> {
    let n = cfg.ranks;
    // Lazily-built long-lived fabrics shared by every device arm of the
    // comparison (topology decides which one an algorithm runs on).
    let mut star: Option<(Fabric, Communicator)> = None;
    let mut tree: Option<(Fabric, Communicator)> = None;
    // Keep each report paired with its kind so the table can never
    // mislabel a row if the skip logic changes.
    let mut runs: Vec<(AlgoKind, CollectiveReport)> = Vec::new();
    for &kind in &cfg.algos {
        if kind.is_host_baseline() {
            if !cfg.with_baselines {
                continue;
            }
            // Host baselines model phantom traffic regardless; the
            // NetDAM arms honor `timing_only`.
            let opts = RunOpts {
                elements: cfg.elements,
                ranks: n,
                seed: cfg.seed,
                window: cfg.window,
                timing_only: cfg.timing_only,
                cc: cfg.cc.clone(),
                ..Default::default()
            };
            runs.push((kind, run_collective(kind, &opts)?));
            continue;
        }
        // Topology-hungry algorithms (leaf groups / addressed switches)
        // share the fat-tree fabric; everything else runs on the star.
        let slot = if matches!(kind, AlgoKind::Hierarchical | AlgoKind::SwitchReduce) {
            &mut tree
        } else {
            &mut star
        };
        if slot.is_none() {
            let mut fabric = Fabric::builder()
                .seed(cfg.seed)
                .window(cfg.window)
                .timing_only(cfg.timing_only)
                .with_congestion_control(cfg.cc.clone())
                .for_algo(kind, n)?
                .build()?;
            let comm = fabric.communicator(cfg.elements as u64 * 4)?;
            if !cfg.timing_only {
                comm.seed_gradients(&mut fabric, cfg.elements, cfg.seed);
            }
            *slot = Some((fabric, comm));
        }
        let (fabric, comm) = slot.as_mut().expect("fabric just built");
        let h = comm.icollective(fabric, kind, cfg.elements, 0)?;
        let out = fabric.wait(h)?;
        ensure!(
            out.complete(),
            "{} incomplete: {}/{} ops",
            kind.name(),
            out.ops_done,
            out.ops
        );
        runs.push((kind, fabric.report(&out)));
    }

    let elapsed_of = |kind: AlgoKind| {
        runs.iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, r)| r.elapsed_ns)
            .unwrap_or(0)
    };
    let netdam_ns = elapsed_of(AlgoKind::NetdamRing);
    let ring_ns = elapsed_of(AlgoKind::RingRoce);
    let native_ns = elapsed_of(AlgoKind::MpiNative);

    let floor = line_rate_floor_ns(n, cfg.elements);

    let mut table = Table::new(&["algorithm", "time", "vs NetDAM", "paper (2GiB)"]);
    let speed = |t: SimTime| {
        if t == 0 || netdam_ns == 0 {
            "-".to_string()
        } else {
            format!("{:.2}x", t as f64 / netdam_ns as f64)
        }
    };
    for (kind, r) in &runs {
        table.row(&[
            r.algorithm.to_string(),
            fmt_ns(r.elapsed_ns),
            speed(r.elapsed_ns),
            paper_ref(*kind).to_string(),
        ]);
    }
    table.row(&[
        "line-rate floor 2(N-1)/N.V".into(),
        fmt_ns(floor),
        speed(floor),
        "0.26 s".into(),
    ]);

    Ok(E2Result {
        netdam_ns,
        ring_roce_ns: ring_ns,
        mpi_native_ns: native_ns,
        line_rate_floor_ns: floor,
        reports: runs.into_iter().map(|(_, r)| r).collect(),
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2_shape_holds_at_reduced_scale() {
        // 2^20 elements (4 MiB): the ordering and ratios of the paper's
        // table must already hold.
        let r = run_e2(&E2Config {
            elements: 1 << 20,
            timing_only: true,
            ..Default::default()
        })
        .unwrap();
        assert!(r.netdam_ns < r.ring_roce_ns, "NetDAM beats ring");
        assert!(r.ring_roce_ns < r.mpi_native_ns, "ring beats native");
        let speedup = r.ring_roce_ns as f64 / r.netdam_ns as f64;
        assert!(speedup > 3.0, "paper shows ~5x, got {speedup:.2}x");
        // NetDAM within 3× of the line-rate floor.
        assert!(r.netdam_ns < 3 * r.line_rate_floor_ns);
    }

    #[test]
    fn e2_runs_the_extended_menu() {
        // Every algorithm produces a report on the same config/grid.
        let r = run_e2(&E2Config {
            elements: 4 * 2048 * 2,
            timing_only: true,
            window: 4,
            algos: AlgoKind::ALL.to_vec(),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(r.reports.len(), AlgoKind::ALL.len());
        for rep in &r.reports {
            assert!(rep.elapsed_ns > 0, "{} produced no timing", rep.algorithm);
        }
    }
}
