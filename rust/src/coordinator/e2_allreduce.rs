//! E2 — the §3.3 allreduce comparison.
//!
//! Paper (536,870,912 × f32, 4 nodes, 100G): native MPI 2.8 s, ring
//! (Horovod-style) 2.1 s, NetDAM ≈ 0.4 s. We reproduce the *shape*:
//! ordering NetDAM ≪ ring < native, NetDAM ≥ 4× vs ring, with the
//! absolute NetDAM time approaching the ring-allreduce line-rate floor
//! `2·(N−1)/N · V / 100G`.

use anyhow::Result;

use crate::collectives::mpi_native::run_mpi_native;
use crate::collectives::ring_roce::run_ring_roce;
use crate::collectives::{run_ring_allreduce, RingSpec};
use crate::device::DeviceConfig;
use crate::metrics::Table;
use crate::net::{Cluster, LinkConfig, Switch, Topology};
use crate::sim::{fmt_ns, Engine, SimTime};
use crate::wire::DeviceIp;

#[derive(Debug, Clone)]
pub struct E2Config {
    pub elements: usize,
    pub ranks: usize,
    /// Timing-only payloads (needed for the full 2^29 paper scale).
    pub timing_only: bool,
    pub window: usize,
    pub seed: u64,
    /// Also run the host baselines (slow at paper scale).
    pub with_baselines: bool,
}

impl Default for E2Config {
    fn default() -> Self {
        Self {
            elements: 1 << 20,
            ranks: 4,
            timing_only: false,
            window: 16,
            seed: 0xE2,
            with_baselines: true,
        }
    }
}

#[derive(Debug)]
pub struct E2Result {
    pub netdam_ns: SimTime,
    pub ring_roce_ns: SimTime,
    pub mpi_native_ns: SimTime,
    pub line_rate_floor_ns: SimTime,
    pub table: Table,
}

pub fn run_e2(cfg: &E2Config) -> Result<E2Result> {
    let n = cfg.ranks;
    // --- NetDAM -----------------------------------------------------
    let (mut cl, devices) = if cfg.timing_only {
        let mut cl = Cluster::new(cfg.seed);
        let sw = cl.add_switch(Switch::tor(None));
        let mut devices = Vec::new();
        for i in 0..n {
            let d = cl.add_device(
                DeviceConfig::paper_default(DeviceIp::lan(1 + i as u8)).timing_only(),
            );
            cl.connect(sw, d, LinkConfig::dc_100g());
            devices.push(d);
        }
        cl.compute_routes();
        (cl, devices)
    } else {
        let t = Topology::star(cfg.seed, n, 0, LinkConfig::dc_100g());
        (t.cluster, t.devices)
    };
    if !cfg.timing_only {
        crate::collectives::seed_gradients(&mut cl, &devices, cfg.elements, 0, cfg.seed);
    }
    let spec = RingSpec {
        elements: cfg.elements,
        window: cfg.window,
        ..Default::default()
    };
    let mut eng: Engine<Cluster> = Engine::new();
    let out = run_ring_allreduce(&mut cl, &mut eng, &devices, &spec)?;
    anyhow::ensure!(out.blocks_done == out.blocks, "netdam allreduce incomplete");
    let netdam_ns = out.elapsed_ns;

    // --- baselines ----------------------------------------------------
    let (ring_ns, native_ns) = if cfg.with_baselines {
        let ring = run_ring_roce(cfg.seed, n, cfg.elements);
        let native = run_mpi_native(cfg.seed, n, cfg.elements);
        (ring.elapsed_ns, native.elapsed_ns)
    } else {
        (0, 0)
    };

    let v_bytes = cfg.elements as f64 * 4.0;
    let floor = (2.0 * (n as f64 - 1.0) / n as f64 * v_bytes / 12.5) as SimTime;

    let mut table = Table::new(&["algorithm", "time", "vs NetDAM", "paper (2GiB)"]);
    let speed = |t: SimTime| {
        if t == 0 {
            "-".to_string()
        } else {
            format!("{:.2}x", t as f64 / netdam_ns as f64)
        }
    };
    table.row(&[
        "NetDAM ring (in-memory ALU)".into(),
        fmt_ns(netdam_ns),
        "1.00x".into(),
        "~0.4 s".into(),
    ]);
    table.row(&[
        "Ring allreduce over RoCE".into(),
        fmt_ns(ring_ns),
        speed(ring_ns),
        "2.1 s".into(),
    ]);
    table.row(&[
        "Native MPI (recursive doubling)".into(),
        fmt_ns(native_ns),
        speed(native_ns),
        "2.8 s".into(),
    ]);
    table.row(&[
        "line-rate floor 2(N-1)/N.V".into(),
        fmt_ns(floor),
        speed(floor),
        "0.26 s".into(),
    ]);

    Ok(E2Result {
        netdam_ns,
        ring_roce_ns: ring_ns,
        mpi_native_ns: native_ns,
        line_rate_floor_ns: floor,
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2_shape_holds_at_reduced_scale() {
        // 2^20 elements (4 MiB): the ordering and ratios of the paper's
        // table must already hold.
        let r = run_e2(&E2Config {
            elements: 1 << 20,
            timing_only: true,
            ..Default::default()
        })
        .unwrap();
        assert!(r.netdam_ns < r.ring_roce_ns, "NetDAM beats ring");
        assert!(r.ring_roce_ns < r.mpi_native_ns, "ring beats native");
        let speedup = r.ring_roce_ns as f64 / r.netdam_ns as f64;
        assert!(speedup > 3.0, "paper shows ~5x, got {speedup:.2}x");
        // NetDAM within 3× of the line-rate floor.
        assert!(r.netdam_ns < 3 * r.line_rate_floor_ns);
    }
}
