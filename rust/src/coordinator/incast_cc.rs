//! Incast A/B harness for closed-loop congestion control (PR 8).
//!
//! E3 showed the paper's §2.5 cure: scatter over the pool and pull back
//! with a *static* token-bucket budget. A static budget needs the
//! operator to know the fan-in; under mixed tenants or shifting fan-in
//! it is either too timid (wasted goodput) or too brave (incast
//! collapse). This harness pits three arms against the same many-to-one
//! write storm on one switch port:
//!
//! * **unpaced** — every sender blasts at line rate; the 500 KB egress
//!   buffer overruns, tail drops trigger 300 µs timeout stalls, and p99
//!   latency explodes (classic incast collapse).
//! * **static** — each sender's plan carries a plan-private
//!   [`TokenBucket`] from a fixed per-sender budget grid; the best grid
//!   point is reported (the operator's oracle).
//! * **dcqcn** — the session runs [`CcMode::Dcqcn`]: switch RED marks
//!   CE past the ramp, the device echoes CE on completions, and each
//!   sender's slot controller cuts multiplicatively then recovers —
//!   no budget knob, the loop *finds* the fair share.
//!
//! Reported per arm: aggregate goodput, p50/p99 completion latency,
//! Jain fairness across senders, drops/retransmits/CNPs. All senders
//! ride the shared [`EngineSession`] — the same engine every collective
//! and pooled-memory plan uses, so what this harness measures is the
//! production data path, not a model of it.

use anyhow::{ensure, Result};

use crate::isa::{Flags, Instruction};
use crate::metrics::Table;
use crate::net::{Cluster, DeviceProfile, LinkConfig, Topology};
use crate::roce::DcqcnConfig;
use crate::sim::{fmt_ns, Engine, SimTime};
use crate::transport::{
    CcMode, CompletionKey, EngineSession, PlanId, ReliabilityTable, TokenBucket, WindowedOp,
};
use crate::util::stats::{jain_fairness, percentile_ns};
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};

/// The pool interleave block — every sender moves whole blocks.
const BLOCK: usize = 8192;

#[derive(Debug, Clone)]
pub struct IncastCcConfig {
    /// Senders converging on the one receiver device.
    pub fanin: usize,
    /// 8 KiB blocks each sender writes.
    pub blocks_per_sender: usize,
    /// Per-sender in-flight window.
    pub window: usize,
    pub seed: u64,
    /// Per-sender budgets (Gbps) the static arm sweeps; the best grid
    /// point by goodput is reported as `best_static`.
    pub static_grid_gbps: Vec<f64>,
}

impl Default for IncastCcConfig {
    fn default() -> Self {
        Self {
            fanin: 16,
            blocks_per_sender: 32,
            window: 8,
            seed: 0x1CA5,
            static_grid_gbps: vec![2.0, 5.0, 10.0, 25.0],
        }
    }
}

/// One arm's scoreboard.
#[derive(Debug, Clone)]
pub struct ArmStats {
    pub label: String,
    /// Delivered blocks / completion time, all senders pooled (Gbit/s).
    pub goodput_gbps: f64,
    pub lat_p50_ns: SimTime,
    pub lat_p99_ns: SimTime,
    /// Jain fairness over per-sender goodputs (1.0 = equal shares).
    pub jain: f64,
    pub link_drops: u64,
    pub retransmits: u64,
    /// CE-marked completions absorbed by slot controllers (DCQCN only).
    pub cnps: usize,
    pub elapsed_ns: SimTime,
    /// Blocks retired / blocks offered — < 1.0 when retry exhaustion
    /// stranded ops (the collapse the closed loop is meant to prevent).
    pub delivered_fraction: f64,
}

#[derive(Debug)]
pub struct IncastCcResult {
    pub unpaced: ArmStats,
    /// Every static grid point, in grid order.
    pub statics: Vec<ArmStats>,
    /// The grid point with the best goodput (the operator's oracle).
    pub best_static: ArmStats,
    pub dcqcn: ArmStats,
    pub table: Table,
}

enum Arm {
    Unpaced,
    /// Per-sender budget in Gbps.
    Static(f64),
    Dcqcn,
}

impl Arm {
    fn label(&self) -> String {
        match self {
            Arm::Unpaced => "unpaced".into(),
            Arm::Static(g) => format!("static {g} Gbps/sender"),
            Arm::Dcqcn => "dcqcn".into(),
        }
    }
}

/// Run one arm: fresh star fabric (1 device, `fanin` sender hosts), one
/// shared session, one plan per sender (plan-local slot 0 maps to a
/// distinct session slot, so per-slot DCQCN state is per-sender).
fn run_arm(cfg: &IncastCcConfig, arm: &Arm) -> Result<ArmStats> {
    ensure!(cfg.fanin >= 1 && cfg.fanin <= 128, "fanin must be 1..=128");
    let t = Topology::star_with(
        cfg.seed,
        1,
        cfg.fanin,
        LinkConfig::dc_100g(),
        DeviceProfile::TimingOnly,
    );
    let mut cl = t.cluster;
    // Shallow-timeout table: tail drops become 300 us stalls, the incast
    // failure mode the closed loop is supposed to prevent (E3's table).
    cl.xport = ReliabilityTable::new(300_000, 40);
    let mut eng: Engine<Cluster> = Engine::new();
    let dev_ip = DeviceIp::lan(1);
    let mut session = EngineSession::new(cfg.window);
    if let Arm::Dcqcn = arm {
        session = session.with_congestion_control(CcMode::Dcqcn(DcqcnConfig::default()));
    }
    let mut plans: Vec<PlanId> = Vec::with_capacity(cfg.fanin);
    for s in 0..cfg.fanin {
        let host = t.hosts[s];
        let host_ip = DeviceIp::lan(101 + s as u8);
        let base = (s * cfg.blocks_per_sender * BLOCK) as u64;
        let ops: Vec<WindowedOp> = (0..cfg.blocks_per_sender)
            .map(|b| {
                let seq = cl.alloc_seq(host);
                let pkt = Packet::new(
                    host_ip,
                    seq,
                    SrouHeader::direct(dev_ip),
                    Instruction::Write {
                        addr: base + (b * BLOCK) as u64,
                    },
                )
                .with_flags(Flags(Flags::RELIABLE))
                .with_payload(Payload::phantom(BLOCK));
                let pace_bytes = pkt.wire_bytes();
                WindowedOp {
                    slot: 0,
                    origin: host,
                    key: CompletionKey::Seq(seq),
                    tag: b as u64,
                    reliable: true,
                    pace_bytes,
                    pkt,
                }
            })
            .collect();
        let plan = match arm {
            Arm::Static(gbps) => session.submit_paced(
                &mut cl,
                &mut eng,
                ops,
                false,
                cfg.window,
                TokenBucket::new(*gbps, 2 * BLOCK),
            )?,
            _ => session.submit(&mut cl, &mut eng, ops, false, cfg.window)?,
        };
        plans.push(plan);
    }
    session.drive(&mut cl, &mut eng);
    let cnps = session.cnps();
    let mut latencies: Vec<SimTime> = Vec::new();
    let mut per_sender_goodput: Vec<f64> = Vec::with_capacity(cfg.fanin);
    let mut done_total = 0usize;
    let mut last = 0u64;
    for &p in &plans {
        let out = session.outcome(p);
        done_total += out.done;
        last = last.max(out.last_done);
        let span = out.last_done.saturating_sub(out.submitted_at);
        per_sender_goodput.push(if span == 0 {
            0.0
        } else {
            out.done as f64 * BLOCK as f64 * 8.0 / span as f64
        });
        latencies.extend(out.latencies);
    }
    session.close(&mut cl);
    let offered = cfg.fanin * cfg.blocks_per_sender;
    let elapsed = last.max(1);
    Ok(ArmStats {
        label: arm.label(),
        goodput_gbps: done_total as f64 * BLOCK as f64 * 8.0 / elapsed as f64,
        lat_p50_ns: percentile_ns(&latencies, 50.0),
        lat_p99_ns: percentile_ns(&latencies, 99.0),
        jain: jain_fairness(&per_sender_goodput),
        link_drops: cl.metrics.counter("link_drops"),
        retransmits: cl.xport.retransmits,
        cnps,
        elapsed_ns: last,
        delivered_fraction: done_total as f64 / offered.max(1) as f64,
    })
}

pub fn run_incast_cc(cfg: &IncastCcConfig) -> Result<IncastCcResult> {
    ensure!(
        !cfg.static_grid_gbps.is_empty(),
        "the static arm needs at least one budget grid point"
    );
    let unpaced = run_arm(cfg, &Arm::Unpaced)?;
    let mut statics = Vec::with_capacity(cfg.static_grid_gbps.len());
    for &g in &cfg.static_grid_gbps {
        statics.push(run_arm(cfg, &Arm::Static(g))?);
    }
    let best_static = statics
        .iter()
        .max_by(|a, b| a.goodput_gbps.total_cmp(&b.goodput_gbps))
        .expect("non-empty grid")
        .clone();
    let dcqcn = run_arm(cfg, &Arm::Dcqcn)?;

    let mut table = Table::new(&[
        "arm",
        "goodput",
        "p50 lat",
        "p99 lat",
        "jain",
        "drops",
        "retx",
        "cnps",
    ]);
    let mut row = |s: &ArmStats| {
        table.row(&[
            s.label.clone(),
            format!("{:.1} Gbps", s.goodput_gbps),
            fmt_ns(s.lat_p50_ns),
            fmt_ns(s.lat_p99_ns),
            format!("{:.3}", s.jain),
            s.link_drops.to_string(),
            s.retransmits.to_string(),
            s.cnps.to_string(),
        ]);
    };
    row(&unpaced);
    for s in &statics {
        row(s);
    }
    row(&dcqcn);

    Ok(IncastCcResult {
        unpaced,
        statics,
        best_static,
        dcqcn,
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcqcn_closes_the_loop_under_incast() {
        let cfg = IncastCcConfig {
            fanin: 8,
            blocks_per_sender: 24,
            window: 16,
            static_grid_gbps: vec![5.0, 12.0],
            ..Default::default()
        };
        let r = run_incast_cc(&cfg).unwrap();
        // The closed loop actually closed: RED marks were echoed back and
        // absorbed as CNPs.
        assert!(r.dcqcn.cnps > 0, "no CNPs — the feedback loop never fired");
        assert_eq!(r.unpaced.cnps, 0, "unpaced arm has no controllers");
        // DCQCN delivers everything (the fair share keeps queues under
        // the drop point once the loop converges).
        assert!(
            r.dcqcn.delivered_fraction == 1.0,
            "dcqcn stranded {:.2}% of blocks",
            (1.0 - r.dcqcn.delivered_fraction) * 100.0
        );
        // Adaptive pacing never drops more than the uncontrolled blast.
        assert!(
            r.dcqcn.link_drops <= r.unpaced.link_drops,
            "dcqcn dropped {} > unpaced {}",
            r.dcqcn.link_drops,
            r.unpaced.link_drops
        );
        // Converged senders share fairly.
        assert!(r.dcqcn.jain >= 0.9, "jain {:.3} < 0.9", r.dcqcn.jain);
        // Sanity on the lens itself.
        assert!(r.dcqcn.lat_p99_ns >= r.dcqcn.lat_p50_ns);
    }

    #[test]
    fn static_grid_reports_every_point_and_picks_the_best() {
        let cfg = IncastCcConfig {
            fanin: 4,
            blocks_per_sender: 8,
            window: 4,
            static_grid_gbps: vec![2.0, 20.0],
            ..Default::default()
        };
        let r = run_incast_cc(&cfg).unwrap();
        assert_eq!(r.statics.len(), 2);
        let best = r
            .statics
            .iter()
            .map(|s| s.goodput_gbps)
            .fold(f64::MIN, f64::max);
        assert_eq!(r.best_static.goodput_gbps, best);
        // A 4-way fan-in at 2 Gbps/sender can't beat 20 Gbps/sender on
        // an uncongested 100G port.
        assert!(r.statics[1].goodput_gbps > r.statics[0].goodput_gbps);
    }
}
