//! E3 — incast avoidance via the block-interleaved pool (paper §2.5).
//!
//! "many-to-one communication could be equally load balance to multiple
//! NetDAM device, the receiving host could pull them back from global
//! memory pool based sequencing and rate-limited READ command, the
//! incast problem can be easily avoid without complex congestion control
//! mechanism."
//!
//! Three arms:
//! * **direct** — N senders blast their result straight at one device:
//!   classic incast, buffer overrun, retransmit storm.
//! * **pool** — senders scatter over the interleaved pool (balanced, no
//!   hot link), receiver pulls back with token-bucket-paced READs.
//! * The numbers contrast completion time, drops and retransmits.
//!
//! The pool arm runs on the **real memory plane**: one job tenant
//! `malloc_mapped`s the aggregate through the [`SdnController`] (which
//! programs every device IOMMU with the lease and binds the sender/
//! receiver hosts to the tenant), the senders' block plans are compiled
//! from the controller's GVA translation, and the paced pull-back is a
//! [`MemClient`] **paced read**: the same shared window engine that
//! drives every pooled op, with the token bucket wired into its refill
//! decision — no hand-rolled pacing loop, and every read is translated
//! and fenced by the device IOMMUs on the way in.

use anyhow::Result;

use crate::isa::{Flags, Instruction};
use crate::mem::MemClient;
use crate::metrics::Table;
use crate::net::{App, AppCtx, Cluster, LinkConfig, Topology};
use crate::pool::{SdnController, TenantId};
use crate::sim::{fmt_ns, Engine, SimTime};
use crate::transport::ReliabilityTable;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};

#[derive(Debug, Clone)]
pub struct E3Config {
    pub senders: usize,
    pub devices: usize,
    /// Bytes each sender contributes.
    pub bytes_per_sender: usize,
    /// READ-pull pacing as a fraction of line rate.
    pub pull_fraction: f64,
    pub seed: u64,
}

impl Default for E3Config {
    fn default() -> Self {
        Self {
            senders: 4,
            devices: 4,
            bytes_per_sender: 2 << 20,
            pull_fraction: 0.92,
            seed: 0xE3,
        }
    }
}

#[derive(Debug)]
pub struct E3Result {
    pub direct_ns: SimTime,
    pub direct_drops: u64,
    pub direct_retransmits: u64,
    pub pool_scatter_ns: SimTime,
    /// Duration of the MemClient paced READ pull-back (runs after the
    /// scatter completes).
    pub pool_pull_ns: SimTime,
    pub pool_drops: u64,
    pub pool_retransmits: u64,
    pub table: Table,
}

const BLOCK: usize = 8192;

/// A sender blasting `blocks` reliable writes toward its targets as fast
/// as its NIC allows (no congestion control — the incast stressor).
struct BurstSender {
    /// (target, device-local addr) per block, precomputed.
    plan: Vec<(DeviceIp, u64)>,
    next: usize,
    gap_ns: SimTime,
    metric: &'static str,
    acked: usize,
}

impl App for BurstSender {
    fn on_start(&mut self, ctx: &mut AppCtx) {
        ctx.timer(1, 0);
    }
    fn on_timer(&mut self, _t: u64, ctx: &mut AppCtx) {
        if self.next >= self.plan.len() {
            return;
        }
        let (dst, addr) = self.plan[self.next];
        self.next += 1;
        let seq = ctx.alloc_seq();
        let pkt = Packet::new(
            ctx.self_ip,
            seq,
            SrouHeader::direct(dst),
            Instruction::Write { addr },
        )
        .with_flags(Flags(Flags::RELIABLE))
        .with_payload(Payload::phantom(BLOCK));
        ctx.send_reliable(pkt);
        ctx.timer(self.gap_ns, 0);
    }
    fn on_packet(&mut self, pkt: Packet, ctx: &mut AppCtx) {
        if matches!(pkt.instr, Instruction::WriteAck { .. }) {
            self.acked += 1;
            if self.acked == self.plan.len() {
                ctx.record(self.metric, ctx.now);
            }
        }
    }
}

fn build_cluster(cfg: &E3Config, timing: bool) -> (Cluster, Vec<DeviceIp>) {
    let t = Topology::star(cfg.seed, cfg.devices, 0, LinkConfig::dc_100g());
    let mut cl = t.cluster;
    if timing {
        // Writes use phantom payloads anyway; devices stay data-bearing
        // (addresses matter, contents don't).
    }
    cl.xport = ReliabilityTable::new(300_000, 40);
    let ips = (0..cfg.devices)
        .map(|i| DeviceIp::lan(1 + i as u8))
        .collect();
    (cl, ips)
}

pub fn run_e3(cfg: &E3Config) -> Result<E3Result> {
    // Validate up front: both arms move whole blocks, and failing after
    // the direct arm has simulated would waste minutes of wallclock.
    anyhow::ensure!(
        cfg.bytes_per_sender % BLOCK == 0,
        "bytes_per_sender must be a whole number of {BLOCK}-byte blocks"
    );
    let blocks_each = cfg.bytes_per_sender / BLOCK;
    let gap = ((BLOCK + 96) as f64 * 8.0 / 100.0).ceil() as SimTime; // line rate

    // --- arm 1: direct incast onto device 0 ---------------------------
    let (mut cl, ips) = build_cluster(cfg, true);
    for s in 0..cfg.senders {
        // Each sender writes its own region of device 0.
        let base = (s * cfg.bytes_per_sender) as u64;
        let plan: Vec<(DeviceIp, u64)> = (0..blocks_each)
            .map(|b| (ips[0], base + (b * BLOCK) as u64))
            .collect();
        let h = cl.add_host(
            DeviceIp::lan(101 + s as u8),
            Some(Box::new(BurstSender {
                plan,
                next: 0,
                gap_ns: gap,
                metric: "direct_done_ns",
                acked: 0,
            })),
        );
        cl.connect(0, h, LinkConfig::dc_100g()); // node 0 = switch
    }
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    cl.start_apps(&mut eng);
    eng.run(&mut cl);
    let direct_ns = cl
        .metrics
        .hist("direct_done_ns")
        .map(|h| h.max())
        .unwrap_or(0);
    anyhow::ensure!(
        cl.metrics.hist("direct_done_ns").map(|h| h.count()).unwrap_or(0) as usize
            == cfg.senders,
        "direct arm incomplete"
    );
    let direct_drops = cl.metrics.counter("link_drops");
    let direct_retx = cl.metrics.counter("retransmits");

    // --- arm 2: interleaved scatter + paced pull ----------------------
    // This arm rides the real memory plane: the SDN controller leases the
    // aggregate to one job tenant, programs every device IOMMU, and the
    // hosts' plans come from the controller's GVA translation.
    const JOB: TenantId = 1;
    let (mut cl, ips) = build_cluster(cfg, true);
    let map = crate::pool::InterleaveMap::paper_default(ips.clone());
    let mut ctl = SdnController::new(map, 2 << 30);
    let total = cfg.senders * cfg.bytes_per_sender;
    let agg = ctl
        .malloc_mapped(&mut cl, JOB, total as u64, true)
        .map_err(|e| anyhow::anyhow!("pool lease failed: {e}"))?;
    for s in 0..cfg.senders {
        let host_ip = DeviceIp::lan(101 + s as u8);
        ctl.grant_host(&mut cl, JOB, host_ip);
        let gva0 = agg.gva + (s * cfg.bytes_per_sender) as u64;
        let plan: Vec<(DeviceIp, u64)> = ctl
            .access(JOB, gva0, cfg.bytes_per_sender as u64, true)
            .map_err(|e| anyhow::anyhow!("sender {s} plan denied: {e}"))?
            .into_iter()
            .map(|e| (e.device, e.local_addr))
            .collect();
        let h = cl.add_host(
            host_ip,
            Some(Box::new(BurstSender {
                plan,
                next: 0,
                gap_ns: gap,
                metric: "scatter_done_ns",
                acked: 0,
            })),
        );
        cl.connect(0, h, LinkConfig::dc_100g());
    }
    // Receiver: a plain host — its pull-back runs through the memory
    // plane (a MemClient paced read) once the scatter lands.
    ctl.grant_host(&mut cl, JOB, DeviceIp::lan(99));
    let recv = cl.add_host(DeviceIp::lan(99), None);
    cl.connect(0, recv, LinkConfig::dc_100g());
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    cl.start_apps(&mut eng);
    eng.run(&mut cl);
    let scatter_ns = cl
        .metrics
        .hist("scatter_done_ns")
        .map(|h| h.max())
        .unwrap_or(0);
    anyhow::ensure!(
        cl.metrics
            .hist("scatter_done_ns")
            .map(|h| h.count())
            .unwrap_or(0) as usize
            == cfg.senders,
        "scatter incomplete"
    );
    // Paced READ pull-back through MemClient: sequenced, token-bucket
    // rate-limited in the shared window engine's refill decision — the
    // paper's incast cure, on the production data path.
    let puller = MemClient::new(recv, DeviceIp::lan(99), JOB, ctl.map().clone())
        .with_window(8)
        .with_pace(100.0 * cfg.pull_fraction, 2 * BLOCK);
    let t0 = eng.now();
    let pulled = puller
        .read(&mut cl, &mut eng, agg.gva, total)
        .map_err(|e| anyhow::anyhow!("paced pull-back failed: {e}"))?;
    anyhow::ensure!(pulled.len() == total, "pull incomplete");
    let pull_ns = eng.now().saturating_sub(t0).max(1);
    let pool_drops = cl.metrics.counter("link_drops");
    let pool_retx = cl.metrics.counter("retransmits");
    // Every pool access was translated by a programmed (non-identity)
    // device IOMMU, and the in-lease plan drew no NAKs.
    for &ip in &ips {
        let node = cl.node_by_ip(ip).expect("pool device");
        let dev = cl.device(node);
        anyhow::ensure!(
            !dev.iommu_ref().is_identity(),
            "pool device {ip} must run a controller-programmed IOMMU"
        );
        anyhow::ensure!(
            dev.iommu_naks == 0,
            "in-lease pool traffic must not fault ({} NAKs at {ip})",
            dev.iommu_naks
        );
    }

    let mut table = Table::new(&[
        "arm",
        "completion",
        "link drops",
        "retransmits",
    ]);
    table.row(&[
        format!("direct {}->1 incast", cfg.senders),
        fmt_ns(direct_ns),
        direct_drops.to_string(),
        direct_retx.to_string(),
    ]);
    table.row(&[
        "pool scatter (interleaved)".into(),
        fmt_ns(scatter_ns),
        pool_drops.to_string(),
        pool_retx.to_string(),
    ]);
    table.row(&[
        "paced READ pull-back".into(),
        fmt_ns(pull_ns),
        "-".into(),
        "-".into(),
    ]);

    Ok(E3Result {
        direct_ns,
        direct_drops,
        direct_retransmits: direct_retx,
        pool_scatter_ns: scatter_ns,
        pool_pull_ns: pull_ns,
        pool_drops,
        pool_retransmits: pool_retx,
        table,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn incast_hurts_and_pool_cures_it() {
        let cfg = E3Config {
            bytes_per_sender: 512 << 10,
            ..Default::default()
        };
        let r = run_e3(&cfg).unwrap();
        // Direct incast: drops and retransmissions; pool: clean.
        assert!(r.direct_drops > 0, "incast must overrun the buffer");
        assert!(r.direct_retransmits > 0);
        assert_eq!(r.pool_drops, 0, "interleaving balances the load");
        assert_eq!(r.pool_retransmits, 0);
        // Pool scatter finishes much faster than the incast storm.
        assert!(
            r.pool_scatter_ns * 2 < r.direct_ns,
            "scatter {} vs direct {}",
            r.pool_scatter_ns,
            r.direct_ns
        );
        // The MemClient paced pull-back (arm 2) moves the same aggregate
        // at better goodput than the incast storm (arm 1): the §2.5 cure
        // still holds on the shared window engine's paced read path.
        let total = (cfg.senders * cfg.bytes_per_sender) as f64;
        let direct_goodput = total / r.direct_ns.max(1) as f64;
        let pull_goodput = total / r.pool_pull_ns.max(1) as f64;
        assert!(
            pull_goodput >= direct_goodput,
            "paced pull-back goodput {pull_goodput:.3} B/ns must beat the \
             incast storm's {direct_goodput:.3} B/ns"
        );
    }
}
