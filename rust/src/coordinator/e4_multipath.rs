//! E4 — SROU multipath vs classic ECMP (paper §2.3).
//!
//! "NetDAM design Segment Routing Header in UDP (SROU) enable topology
//! independent transport, source node could select dedicated path to
//! avoid switch buffer overrun and fully utilize the fabric bandwidth."
//!
//! Topology: two leaves × two spines, capacity-matched: as many
//! cross-leaf elephant flows as spines, so perfect placement runs at
//! full line rate. Arms:
//! * **FlowHash ECMP** — per-flow hashing. The flow set is chosen (by
//!   predicting the hash, as an unlucky production pairing would) so two
//!   elephants **collide** on a spine: effective bandwidth halves.
//! * **SROU spray** — each *source* alternates spine waypoints per
//!   packet: both spines loaded evenly by construction, line rate.

use anyhow::Result;

use crate::comm::Fabric;
use crate::isa::Instruction;
use crate::metrics::Table;
use crate::net::switch::flow_hash;
use crate::net::{Cluster, EcmpMode, Node};
use crate::sim::{fmt_ns, SimTime};
use crate::srou::SprayPlan;
use crate::wire::{DeviceIp, Packet, Payload, SrouHeader};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum E4Mode {
    EcmpFlowHash,
    SrouSpray,
}

#[derive(Debug, Clone)]
pub struct E4Config {
    /// Devices per leaf (= max concurrent flows; 2 spines ⇒ use 2).
    pub devs_per_leaf: usize,
    pub bytes_per_flow: usize,
    pub seed: u64,
}

impl Default for E4Config {
    fn default() -> Self {
        Self {
            devs_per_leaf: 2,
            bytes_per_flow: 4 << 20,
            seed: 0xE4,
        }
    }
}

#[derive(Debug)]
pub struct E4Result {
    pub mode: E4Mode,
    pub completion_ns: SimTime,
    pub drops: u64,
    /// Fraction of offered blocks that actually arrived (unreliable
    /// writes: ECMP collisions shed load at the hot spine).
    pub delivered_pct: f64,
    /// Delivered payload bandwidth over the run (Gbit/s).
    pub goodput_gbps: f64,
    /// Bytes forwarded per spine (imbalance indicator).
    pub spine_bytes: Vec<u64>,
    /// Predicted hash collisions in the flow set (ECMP arm).
    pub predicted_collisions: usize,
}

const BLOCK: usize = 8192;

/// Pick a dst rotation whose flow set collides under the ECMP hash —
/// the pairing an unlucky tenant gets. Returns (pairs, collisions).
fn colliding_pairs(cfg: &E4Config) -> (Vec<(DeviceIp, DeviceIp)>, usize) {
    let n = cfg.devs_per_leaf;
    let mut best: (Vec<(DeviceIp, DeviceIp)>, usize) = (Vec::new(), 0);
    for rot in 0..n {
        let pairs: Vec<(DeviceIp, DeviceIp)> = (0..n)
            .map(|f| {
                (
                    DeviceIp::lan(1 + f as u8),
                    DeviceIp::lan(1 + (n + (f + rot) % n) as u8),
                )
            })
            .collect();
        let picks: Vec<usize> = pairs.iter().map(|&(s, d)| flow_hash(s, d, 2)).collect();
        let on_zero = picks.iter().filter(|&&p| p == 0).count();
        let collisions = on_zero.max(n - on_zero) - n.div_ceil(2);
        if collisions >= best.1 {
            best = (pairs, collisions);
        }
    }
    best
}

fn run_mode(cfg: &E4Config, mode: E4Mode) -> Result<E4Result> {
    // The dual-spine fabric comes from the session builder now; E4's
    // open-loop elephant flows predate the windowed engine, so they use
    // the same Fabric's raw injection surface instead of hand-assembling
    // a Cluster.
    let mut fabric = Fabric::builder()
        .dual_spine(cfg.devs_per_leaf)
        .seed(cfg.seed)
        .ecmp(EcmpMode::FlowHash)
        .build()?;
    let devices = fabric.devices().to_vec();
    let spine_ips = [DeviceIp::lan(201), DeviceIp::lan(202)];
    let (cl, eng) = fabric.raw_parts();

    let (pairs, predicted) = colliding_pairs(cfg);
    let blocks = cfg.bytes_per_flow / BLOCK;
    let gap = ((BLOCK + 96) as f64 * 8.0 / 100.0).ceil() as SimTime; // line rate
    for (f, &(src_ip, dst_ip)) in pairs.iter().enumerate() {
        let src_node = devices[f];
        let mut spray = SprayPlan::new(spine_ips.to_vec());
        for b in 0..blocks {
            let srou = match mode {
                E4Mode::EcmpFlowHash => SrouHeader::direct(dst_ip),
                E4Mode::SrouSpray => spray.path(dst_ip),
            };
            let seq = cl.alloc_seq(src_node);
            let pkt = Packet::new(
                src_ip,
                seq,
                srou,
                Instruction::Write {
                    addr: (b * BLOCK) as u64,
                },
            )
            .with_payload(Payload::phantom(BLOCK));
            let at = b as u64 * gap;
            eng.schedule_at(at, move |cl: &mut Cluster, eng| {
                cl.send_from(eng, src_node, pkt);
            });
        }
    }
    eng.run(cl);

    // All devices idle once the engine drains: end time = last delivery.
    let completion = eng.now();
    let drops = cl.metrics.counter("link_drops");
    // Goodput: blocks that actually landed at the leaf-2 devices.
    let offered_blocks = (cfg.devs_per_leaf * blocks) as u64;
    let delivered: u64 = (cfg.devs_per_leaf..2 * cfg.devs_per_leaf)
        .map(|i| cl.device(devices[i]).pkts_in)
        .sum();
    let delivered_pct = 100.0 * delivered as f64 / offered_blocks as f64;
    let goodput_gbps = (delivered * BLOCK as u64 * 8) as f64 / completion.max(1) as f64;
    let mut spine_bytes = Vec::new();
    for (i, node) in cl.nodes.iter().enumerate() {
        if let Node::Switch(sw) = node {
            if sw.ip.is_some() {
                let bytes: u64 = cl
                    .links
                    .iter()
                    .filter(|l| l.from == i)
                    .map(|l| l.tx_bytes)
                    .sum();
                spine_bytes.push(bytes);
            }
        }
    }
    Ok(E4Result {
        mode,
        completion_ns: completion,
        drops,
        delivered_pct,
        goodput_gbps,
        spine_bytes,
        predicted_collisions: predicted,
    })
}

pub fn run_e4(cfg: &E4Config) -> Result<(Vec<E4Result>, Table)> {
    let ecmp = run_mode(cfg, E4Mode::EcmpFlowHash)?;
    let spray = run_mode(cfg, E4Mode::SrouSpray)?;
    let mut table = Table::new(&[
        "mode",
        "completion",
        "delivered",
        "goodput",
        "drops",
        "spine bytes (balance)",
    ]);
    for r in [&ecmp, &spray] {
        table.row(&[
            match r.mode {
                E4Mode::EcmpFlowHash => {
                    format!("ECMP flow-hash ({} collisions)", r.predicted_collisions)
                }
                E4Mode::SrouSpray => "SROU source spray".into(),
            },
            fmt_ns(r.completion_ns),
            format!("{:.1}%", r.delivered_pct),
            format!("{:.1} Gbps", r.goodput_gbps),
            r.drops.to_string(),
            format!("{:?}", r.spine_bytes),
        ]);
    }
    Ok((vec![ecmp, spray], table))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srou_spray_balances_and_finishes_faster() {
        let cfg = E4Config {
            bytes_per_flow: 1 << 20,
            ..Default::default()
        };
        let (results, _) = run_e4(&cfg).unwrap();
        let ecmp = &results[0];
        let spray = &results[1];
        assert!(
            ecmp.predicted_collisions >= 1,
            "flow set must contain a hash collision"
        );
        // Spray balances the spines nearly perfectly.
        let imb = |r: &E4Result| {
            let a = r.spine_bytes[0] as f64;
            let b = r.spine_bytes[1] as f64;
            (a - b).abs() / (a + b).max(1.0)
        };
        assert!(imb(spray) < 0.05, "spray imbalance {}", imb(spray));
        // Spray delivers everything at full fabric bandwidth; the
        // collision arm either sheds load (drops) or crawls.
        assert!(
            spray.delivered_pct > 99.9,
            "spray delivered {}",
            spray.delivered_pct
        );
        assert_eq!(spray.drops, 0);
        assert!(
            ecmp.delivered_pct < 95.0 || ecmp.completion_ns > spray.completion_ns * 13 / 10,
            "collision must cost goodput or time: {} % in {} ns",
            ecmp.delivered_pct,
            ecmp.completion_ns
        );
        assert!(
            spray.goodput_gbps > 1.2 * ecmp.goodput_gbps * ecmp.delivered_pct / 100.0
                || spray.goodput_gbps > 1.2 * ecmp.goodput_gbps,
            "spray {} vs ecmp {} Gbps",
            spray.goodput_gbps,
            ecmp.goodput_gbps
        );
        assert!(imb(ecmp) > 0.3, "collision shows as imbalance: {}", imb(ecmp));
    }
}
