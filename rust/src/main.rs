//! `netdam` — the experiment launcher.
//!
//! ```text
//! netdam latency    [--samples N] [--len BYTES]          # E1 (§2.3)
//! netdam allreduce  [--elements N] [--algo LIST|all] ... # E2 (§3.3)
//! netdam incast     [--senders N] [--bytes B]            # E3 (§2.5)
//! netdam multipath  [--bytes B]                          # E4 (§2.3)
//! netdam alu        [--lanes N]                          # E6: native vs Pallas/PJRT
//! netdam serve      [--tenants N] [--aggressor] ...      # E5: serving fleet (§2.5/§2.6)
//! netdam train      [--steps N] [--workers N]            # e2e data-parallel MLP
//! netdam info                                            # artifact inventory
//! ```
//!
//! Every subcommand accepts `--config FILE` (mini-TOML, see
//! `rust/src/config.rs`) plus `--set key=value` overrides.

use anyhow::{bail, Result};

use netdam::cli::Args;
use netdam::config::Config;
use netdam::coordinator::{run_e1, run_e2, run_e3, run_e4, E1Config, E2Config, E3Config, E4Config};

/// `--cc dcqcn|static` — closed-loop DCQCN vs the static pacing default.
fn parse_cc(args: &Args) -> Result<netdam::transport::CcMode> {
    match args.opt("cc") {
        Some(mode) => netdam::transport::CcMode::parse(mode),
        None => Ok(netdam::transport::CcMode::Static),
    }
}

fn load_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.opt("config") {
        Some(path) => Config::load(std::path::Path::new(path))?,
        None => Config::parse("")?,
    };
    for (k, v) in &args.sets {
        cfg.set(k, v)?;
    }
    Ok(cfg)
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(rest)?;
    let cfg = load_config(&args)?;

    match cmd.as_str() {
        "latency" => {
            let c = E1Config {
                read_len: args.opt_u64("len", cfg.u64("latency.len", 128))? as u32,
                samples: args.opt_usize("samples", cfg.usize("latency.samples", 20_000))?,
                seed: args.opt_u64("seed", cfg.u64("seed", 0xE1))?,
            };
            let r = run_e1(&c);
            println!("E1 — wire-to-wire READ of {} B, {} samples", c.read_len, c.samples);
            print!("{}", r.table.render());
        }
        "allreduce" => {
            use netdam::collectives::AlgoKind;
            // `--algo ring,hd,...` (or `--algo all`) selects the
            // collective menu; default is the classic paper triple.
            let algos = match args.opt_list("algo") {
                None => E2Config::default().algos,
                Some(names) if names.is_empty() => {
                    bail!("--algo requires at least one algorithm name (or `all`)")
                }
                Some(names) if names.iter().any(|n| n.eq_ignore_ascii_case("all")) => {
                    AlgoKind::ALL.to_vec()
                }
                Some(names) => names
                    .iter()
                    .map(|n| AlgoKind::parse(n))
                    .collect::<Result<Vec<_>>>()?,
            };
            let c = E2Config {
                elements: args.opt_usize("elements", cfg.usize("allreduce.elements", 1 << 20))?,
                ranks: args.opt_usize("ranks", cfg.usize("allreduce.ranks", 4))?,
                timing_only: args.flag("timing-only") || cfg.bool("allreduce.timing_only", false),
                window: args.opt_usize("window", cfg.usize("allreduce.window", 16))?,
                seed: args.opt_u64("seed", cfg.u64("seed", 0xE2))?,
                with_baselines: !args.flag("no-baselines"),
                algos,
                cc: parse_cc(&args)?,
            };
            println!(
                "E2 — {} x f32 allreduce over {} ranks ({}, cc {})",
                c.elements,
                c.ranks,
                if c.timing_only { "timing-only" } else { "data-bearing" },
                if matches!(c.cc, netdam::transport::CcMode::Dcqcn(_)) {
                    "dcqcn"
                } else {
                    "static"
                }
            );
            let r = run_e2(&c)?;
            print!("{}", r.table.render());
        }
        "incast" => {
            let c = E3Config {
                senders: args.opt_usize("senders", cfg.usize("incast.senders", 4))?,
                devices: args.opt_usize("devices", cfg.usize("incast.devices", 4))?,
                bytes_per_sender: args
                    .opt_usize("bytes", cfg.usize("incast.bytes_per_sender", 2 << 20))?,
                pull_fraction: args.opt_f64("pull-fraction", 0.92)?,
                seed: args.opt_u64("seed", cfg.u64("seed", 0xE3))?,
            };
            println!(
                "E3 — {} senders x {} B, direct incast vs interleaved pool",
                c.senders, c.bytes_per_sender
            );
            let r = run_e3(&c)?;
            print!("{}", r.table.render());
        }
        "multipath" => {
            let c = E4Config {
                devs_per_leaf: args.opt_usize("devs", 2)?,
                bytes_per_flow: args.opt_usize("bytes", cfg.usize("multipath.bytes", 4 << 20))?,
                seed: args.opt_u64("seed", cfg.u64("seed", 0xE4))?,
            };
            println!("E4 — elephant flows across dual spines");
            let (_, table) = run_e4(&c)?;
            print!("{}", table.render());
        }
        "alu" => {
            run_alu_compare(&args)?;
        }
        "prog" => {
            run_prog_demo(&args)?;
        }
        "mem" => {
            run_mem_demo(&args)?;
        }
        "comm" => {
            run_comm_demo(&args)?;
        }
        "serve" => {
            run_serve(&args, &cfg)?;
        }
        "train" => {
            let steps = args.opt_usize("steps", 50)?;
            let workers = args.opt_usize("workers", 4)?;
            let curve = netdam::examples_support::train_dataparallel(steps, workers, true)?;
            println!(
                "final loss after {steps} steps: {:.6}",
                curve.last().copied().unwrap_or(f32::NAN)
            );
        }
        "info" => {
            let rt = netdam::runtime::Runtime::open_default()?;
            println!("artifacts:");
            for name in rt.artifact_names()? {
                println!("  {name}");
            }
        }
        other => {
            print_usage();
            bail!("unknown subcommand {other:?}");
        }
    }
    Ok(())
}

/// Packet-program demo: build → verify → execute the programmable ISA.
fn run_prog_demo(args: &Args) -> Result<()> {
    use std::sync::Arc;

    use netdam::collectives::{run_collective, AlgoKind, RunOpts};
    use netdam::device::DeviceConfig;
    use netdam::isa::dpu::{register_dpu_instructions, OP_CRC32, OP_CRYPTO_WRITE};
    use netdam::isa::registry::{InstructionRegistry, MemAccess};
    use netdam::isa::{Instruction, ProgramBuilder, SimdOp, VerifyEnv};
    use netdam::net::{Cluster, LinkConfig, Switch};
    use netdam::sim::{fmt_ns, Engine};
    use netdam::wire::{DeviceIp, Packet, Payload, SrouHeader};

    println!("== NetDAM packet programs: build -> verify -> execute ==\n");

    // 1. A chained DPU offload in ONE packet: encrypt-write the payload
    //    into device memory, then CRC the ciphertext region (operand
    //    forwarding between the fused steps), reply with the receipt.
    let mut reg = InstructionRegistry::new();
    register_dpu_instructions(&mut reg, 0x5EC_0E7)?;
    let mut cl = Cluster::with_registry(7, Arc::new(reg));
    let sw = cl.add_switch(Switch::tor(None));
    let host = cl.add_host(DeviceIp::lan(101), None);
    let dev = cl.add_device(DeviceConfig::paper_default(DeviceIp::lan(1)));
    cl.connect(sw, host, LinkConfig::dc_100g());
    cl.connect(sw, dev, LinkConfig::dc_100g());
    cl.compute_routes();
    let mut eng: Engine<Cluster> = Engine::new();
    let message = b"in-network compute, one packet".to_vec();
    let prog = netdam::isa::ProgramBuilder::new()
        .hop(Instruction::User {
            opcode: OP_CRYPTO_WRITE,
            a: 4096,
            b: 0,
            c: 0,
        })
        .then(Instruction::User {
            opcode: OP_CRC32,
            a: 0,
            b: 0,
            c: 0,
        })
        .build_unchecked();
    let seq = cl.alloc_seq(host);
    let pkt = Packet::new(
        DeviceIp::lan(101),
        seq,
        SrouHeader::direct(DeviceIp::lan(1)),
        Instruction::Program(std::sync::Arc::new(prog)),
    )
    .with_payload(Payload::from_bytes(message.clone()));
    cl.inject(&mut eng, host, pkt);
    eng.run(&mut cl);
    let (t, resp) = cl
        .host_mut(host)
        .mailbox
        .pop()
        .ok_or_else(|| anyhow::anyhow!("no program reply"))?;
    let Instruction::User { opcode, a, b, c } = resp.instr else {
        bail!("unexpected program reply {:?}", resp.instr);
    };
    anyhow::ensure!(opcode == OP_CRC32, "reply opcode {opcode:#06x}");
    let ct = cl.device_mut(dev).mem().read(a, b as usize)?;
    anyhow::ensure!(
        c == netdam::util::crc32::hash(&ct) as u64,
        "CRC receipt does not match the stored ciphertext"
    );
    println!(
        "crypto_write -> crc32 chain: {b} B encrypted at {a:#x}, CRC {:08x}, RTT {}",
        c as u32,
        fmt_ns(t)
    );

    // 2. The verifier as a safety net: the §2.3 relaxed-ordering rule is
    //    a machine-checked property, not a comment.
    let env = VerifyEnv {
        capacity: 1 << 20,
        payload_len: 8192,
        ordered: false,
        lossless: true,
        srou_hops: 3,
        registry: None,
    };
    let err = ProgramBuilder::new()
        .reduce(SimdOp::Sub, 0, 3)
        .build(&env)
        .unwrap_err();
    println!("\nverify() rejects unsafe chains: {err}");

    // 3. The §3 fused allreduce running as device-executed programs.
    let elements = args.opt_usize("elements", 1 << 16)?;
    let ranks = args.opt_usize("ranks", 4)?;
    let r = run_collective(
        AlgoKind::NetdamRing,
        &RunOpts {
            elements,
            ranks,
            seed: 0x9806,
            window: 16,
            timing_only: false,
            ..Default::default()
        },
    )?;
    println!(
        "\nring allreduce of {elements} x f32 over {ranks} ranks as packet programs: {} ({:.1} Gbit/s bus bw)",
        fmt_ns(r.elapsed_ns),
        r.bus_bw_gbps(AlgoKind::NetdamRing.bw_fraction(ranks))
    );
    Ok(())
}

/// Pooled-memory demo on the session API: one `Fabric` owns topology +
/// SDN controller + the shared engine; lease → IOMMU program → batch
/// plan → device enforcement, plus the near-memory embedding gather,
/// pipelined batches, and (with `--paced`) token-bucket READ pacing.
fn run_mem_demo(args: &Args) -> Result<()> {
    use netdam::comm::Fabric;
    use netdam::mem::MemError;
    use netdam::sim::fmt_ns;
    use netdam::util::bytes::{bytes_to_f32s, f32s_to_bytes};

    let n_devices = args.opt_usize("devices", 4)?.clamp(1, 64);
    let bytes = args.opt_usize("bytes", 256 << 10)?.max(8192);
    // Per-device in-flight window and optional token-bucket pacing —
    // both plumb straight into the shared transport window engine.
    let window = args.opt_usize("window", 4)?.max(1);
    let paced_gbps = args.opt_f64("paced", 0.0)?;
    println!("== NetDAM memory plane: GVA data path over {n_devices} devices (window {window}) ==\n");

    // One Fabric replaces the hand-assembled Cluster + SdnController.
    let mut fabric = Fabric::builder()
        .star(n_devices)
        .hosts(1)
        .seed(0x3E3D)
        .window(window)
        .with_pool(1 << 30)
        .with_congestion_control(parse_cc(args)?)
        .build()?;
    let client = fabric.mem_client()?;
    let tenant = client.tenant;
    let lease = fabric.malloc(tenant, bytes as u64, true)?;

    // Scatter-gather bandwidth through the pool, driven as session plans.
    let data: Vec<u8> = (0..bytes).map(|i| (i % 249) as u8).collect();
    let t0 = fabric.now();
    fabric.mem_write(&client, lease.gva, &data)?;
    let tw = fabric.now() - t0;
    let t0 = fabric.now();
    let back = fabric.mem_read(&client, lease.gva, bytes)?;
    let tr = fabric.now() - t0;
    anyhow::ensure!(back == data, "read-back mismatch");
    let gbps = |ns: u64| bytes as f64 * 8.0 / ns.max(1) as f64;
    println!(
        "write {bytes} B in {} ({:.1} Gbit/s), read back in {} ({:.1} Gbit/s), verified",
        fmt_ns(tw),
        gbps(tw),
        fmt_ns(tr),
        gbps(tr)
    );

    // Device-enforced denial: a read-only lease NAKs the write on the
    // wire — and cancels only this plan on the shared session.
    let ro = fabric.malloc(tenant, 8192, false)?;
    match fabric.mem_write(&client, ro.gva, &[9u8; 64]) {
        Err(MemError::Nak { device, reason, .. }) => {
            println!("read-only lease: write NAK'd by device {device} ({reason})")
        }
        Err(e) => anyhow::bail!("expected a device NAK, got {e}"),
        Ok(()) => anyhow::bail!("expected a device NAK, got a completed write"),
    }

    // Pipelined batch: several logical ops in one windowed engine run —
    // two reads of disjoint halves plus a CAS on a scratch word, all in
    // flight together.
    let scratch = fabric.malloc(tenant, 8192, true)?;
    let mut batch = client.batch();
    let h_lo = batch.read(fabric.cluster_mut(), lease.gva, bytes / 2);
    let h_hi = batch.read(fabric.cluster_mut(), lease.gva + (bytes / 2) as u64, bytes / 2);
    let h_cas = batch
        .cas(fabric.cluster_mut(), scratch.gva, 0, 7)?;
    let n_pkts = batch.len();
    let t0 = fabric.now();
    let h = fabric.submit_mem(batch)?;
    let mut res = fabric.wait_mem(h)?;
    let tb = fabric.now() - t0;
    let lo = res.take_read(h_lo).expect("low half");
    let hi = res.take_read(h_hi).expect("high half");
    anyhow::ensure!(lo == data[..bytes / 2] && hi == data[bytes / 2..], "batch read mismatch");
    let (_, cas_swapped) = res.cas_outcome(h_cas).expect("cas outcome");
    anyhow::ensure!(cas_swapped, "batched CAS must win on the zeroed scratch word");
    println!(
        "pipelined batch: 2 reads + 1 CAS ({n_pkts} packets) in {} ({:.1} Gbit/s) ✓",
        fmt_ns(tb),
        gbps(tb)
    );

    // Optional paced pull-back (the §2.5 incast cure): re-read the lease
    // through a token-bucket-paced client and show the throttled rate.
    // The paced client runs standalone; the idle session has released
    // its completion hook.
    if paced_gbps > 0.0 {
        let paced = client.clone_with_pace(paced_gbps, 16 << 10);
        let (cl, eng) = fabric.raw_parts();
        let t0 = eng.now();
        let back = paced
            .read(cl, eng, lease.gva, bytes)?;
        let tp = eng.now() - t0;
        anyhow::ensure!(back == data, "paced read mismatch");
        println!(
            "paced pull-back at {paced_gbps} Gbit/s budget: {bytes} B in {} ({:.1} Gbit/s achieved)",
            fmt_ns(tp),
            gbps(tp)
        );
    }

    // Near-memory gather: fold 2 bags of 4 rows each with on-device Simd
    // adds — both bags pipelined through one batch on the session.
    let rows = fabric.malloc(tenant, 32 * 1024, true)?;
    let dst = fabric.malloc(tenant, 2048, true)?;
    let mut table = Vec::new();
    for r in 0..32 {
        table.extend_from_slice(&f32s_to_bytes(&vec![r as f32; 256]));
    }
    fabric.mem_write(&client, rows.gva, &table)?;
    let bags = [[1u64, 2, 8, 21], [3, 5, 7, 11]];
    let mut gb = client.batch();
    for (b, picks) in bags.iter().enumerate() {
        let gvas: Vec<u64> = picks.iter().map(|&r| rows.gva + r * 1024).collect();
        gb.gather_sum(fabric.cluster_mut(), &gvas, 1024, dst.gva + (b * 1024) as u64)?;
    }
    let h = fabric.submit_mem(gb)?;
    fabric.wait_mem(h)?;
    for (b, picks) in bags.iter().enumerate() {
        let want = picks.iter().sum::<u64>() as f32;
        let row = fabric.mem_read(&client, dst.gva + (b * 1024) as u64, 1024)?;
        let sum = bytes_to_f32s(&row)?;
        anyhow::ensure!(
            sum.iter().all(|&v| v == want),
            "bag {b} gather sum wrong: {} != {want}",
            sum[0]
        );
        println!("gather_sum bag {b} {picks:?} -> {want} per lane (on-device reduce) ✓");
    }
    Ok(())
}

/// Session-API demo: one fabric, two tenant jobs with overlapping
/// nonblocking allreduces, a pooled-memory plan sharing the same
/// engine, and the gradient-bucketing fusion layer (fused vs unfused).
fn run_comm_demo(args: &Args) -> Result<()> {
    use netdam::collectives::naive_sum;
    use netdam::comm::{buckets_total_elems, plan_buckets, Fabric};
    use netdam::sim::fmt_ns;

    let ranks = args.opt_usize("ranks", 4)?.max(2);
    let elements = args.opt_usize("elements", 4 * 2048)?.max(ranks);
    // Scaling the simulator: `--shards N` runs the DES on the sharded
    // parallel core (N event heaps under conservative lookahead) —
    // same seed, bit-identical results, built for 1024-rank fabrics.
    let shards = args.opt_usize("shards", 0)?;
    let shard_threads = args.opt_usize("shard-threads", 0)?;
    println!("== NetDAM session API: two jobs, one fabric ==\n");

    let mut builder = Fabric::builder()
        .star(ranks)
        .hosts(1)
        .seed(0xC033)
        .with_pool(1 << 20)
        .with_congestion_control(parse_cc(args)?);
    if shards > 0 {
        builder = builder.with_shards(shards).shard_threads(shard_threads);
    }
    let mut fabric = builder.build()?;
    let job_a = fabric.communicator(elements as u64 * 4)?;
    let job_b = fabric.communicator(elements as u64 * 4)?;
    let ga = job_a.seed_gradients_exact(&mut fabric, elements, 0xA);
    let gb = job_b.seed_gradients_exact(&mut fabric, elements, 0xB);

    // A third tenant streams pooled-memory I/O over the same session.
    let mem = fabric.mem_client()?;
    let lease = fabric.malloc(mem.tenant, 64 << 10, true)?;
    let payload: Vec<u8> = (0..64 << 10).map(|i| (i % 251) as u8).collect();
    let mut batch = mem.batch();
    batch.write(fabric.cluster_mut(), lease.gva, &payload);

    // Everything in flight before anything completes: two tenant
    // allreduces + the memory plan, multiplexed on one window engine.
    let ha = job_a.iallreduce(&mut fabric, elements)?;
    let hb = job_b.iallreduce(&mut fabric, elements)?;
    let hm = fabric.submit_mem(batch)?;
    let oa = fabric.wait(ha)?;
    let ob = fabric.wait(hb)?;
    fabric.wait_mem(hm)?;
    anyhow::ensure!(oa.complete() && ob.complete(), "a job stopped short");
    let overlap = fabric.max_concurrent_plans();
    println!(
        "job A allreduce {} | job B allreduce {} | mem write 64 KiB | {overlap} plans in flight at peak",
        fmt_ns(oa.elapsed_ns()),
        fmt_ns(ob.elapsed_ns()),
    );
    anyhow::ensure!(overlap >= 3, "expected overlapping tenants, got {overlap}");
    // Both tenants' results match the host oracle bit-for-bit.
    for (job, grads) in [(&job_a, &ga), (&job_b, &gb)] {
        let oracle = naive_sum(grads);
        for r in 0..ranks {
            anyhow::ensure!(
                job.read_vector(&mut fabric, r, elements)? == oracle,
                "tenant result diverged from the oracle at rank {r}"
            );
        }
    }
    println!("both tenants bit-exact vs the host oracle ✓\n");

    // Gradient bucketing: a stream of small tensors, fused into
    // interleave-block buckets vs one collective per tensor.
    let sizes: Vec<usize> = (0..24).map(|i| 192 + (i * 37) % 512).collect();
    let fused = plan_buckets(&sizes, ranks * 2048, ranks);
    let unfused = plan_buckets(&sizes, 0, ranks);
    let footprint = buckets_total_elems(&fused).max(buckets_total_elems(&unfused));
    let stream = fabric.communicator(footprint as u64 * 4)?;
    stream.seed_gradients_exact(&mut fabric, footprint, 0xF);
    let t0 = fabric.now();
    for h in stream.iallreduce_buckets(&mut fabric, &fused)? {
        let o = fabric.wait(h)?;
        anyhow::ensure!(o.complete(), "fused bucket stopped short");
    }
    let t_fused = fabric.now() - t0;
    stream.seed_gradients_exact(&mut fabric, footprint, 0xF);
    let t0 = fabric.now();
    for h in stream.iallreduce_buckets(&mut fabric, &unfused)? {
        let o = fabric.wait(h)?;
        anyhow::ensure!(o.complete(), "unfused tensor stopped short");
    }
    let t_unfused = fabric.now() - t0;
    println!(
        "{} tensors ({} elems): fused into {} buckets in {} vs {} unfused ops in {} ({:.2}x)",
        sizes.len(),
        sizes.iter().sum::<usize>(),
        fused.len(),
        fmt_ns(t_fused),
        unfused.len(),
        fmt_ns(t_unfused),
        t_unfused as f64 / t_fused.max(1) as f64,
    );
    if shards > 0 {
        println!(
            "sharded DES core: {} shards, {} events executed",
            fabric.shard_count(),
            fabric.sharded_events()
        );
    }
    Ok(())
}

/// The serving tier: a multi-tenant KV/embedding fleet on one pooled
/// fabric, with per-tenant tail reporting and (with `--isolation`) the
/// full aggressor A/B verdict.
fn run_serve(args: &Args, cfg: &Config) -> Result<()> {
    use netdam::serve::{isolation_check, run, Mix, ServeConfig};

    let d = ServeConfig::default();
    let mix = match args.opt("mix") {
        Some(s) => Mix::parse(s).ok_or_else(|| {
            anyhow::anyhow!("--mix wants get/put/cas/gather weights, e.g. 60/25/10/5")
        })?,
        None => d.mix,
    };
    let c = ServeConfig {
        tenants: args.opt_usize("tenants", cfg.usize("serve.tenants", d.tenants))?,
        devices: args.opt_usize("devices", cfg.usize("serve.devices", d.devices))?,
        keys_per_tenant: args.opt_u64("keys", d.keys_per_tenant)?,
        waves: args.opt_usize("waves", d.waves)?,
        ops_per_wave: args.opt_usize("ops", d.ops_per_wave)?,
        skew: args.opt_f64("skew", d.skew)?,
        churn: args.opt_f64("churn", d.churn)?,
        burst_bytes: args.opt_usize("burst", d.burst_bytes)?,
        aggressor: args.flag("aggressor"),
        seed: args.opt_u64("seed", cfg.u64("seed", d.seed))?,
        shards: args.opt_usize("shards", d.shards)?,
        shard_threads: args.opt_usize("shard-threads", 0)?,
        cc: parse_cc(args)?,
        mix,
        ..d
    };
    println!(
        "serve — {} tenants x {} waves x {} ops, zipf θ={}, churn {:.0}%, {} core, cc {}",
        c.tenants,
        c.waves,
        c.ops_per_wave,
        c.skew,
        c.churn * 100.0,
        if c.shards > 0 { "sharded" } else { "classic" },
        if matches!(c.cc, netdam::transport::CcMode::Dcqcn(_)) {
            "dcqcn"
        } else {
            "static"
        }
    );
    if args.flag("isolation") {
        // The full A/B: same fleet without, then with the aggressor.
        let v = isolation_check(&c, args.opt_u64("bound-milli", 2_000)?)?;
        println!("\n-- quiet fleet --\n{}", v.baseline.render());
        println!("-- aggressed fleet --\n{}", v.contended.render());
        println!(
            "isolation: worst p99 inflation {:.2}x vs bound {:.2}x -> {}",
            v.worst_ratio_milli as f64 / 1000.0,
            v.bound_milli as f64 / 1000.0,
            if v.ok { "isolated ✓" } else { "VIOLATED" }
        );
        anyhow::ensure!(v.ok, "isolation bound violated");
    } else {
        let r = run(&c)?;
        println!("\n{}", r.render());
    }
    Ok(())
}

/// E6: ALU backend comparison — native rust vs the compiled Pallas kernel.
fn run_alu_compare(args: &Args) -> Result<()> {
    use netdam::alu::{AluBackend, NativeAlu};
    use netdam::isa::SimdOp;
    use netdam::runtime::XlaAlu;
    use netdam::util::Xoshiro256;

    let lanes = args.opt_usize("lanes", 1 << 20)?;
    let mut rng = Xoshiro256::seed_from(7);
    let a = rng.f32_vec(lanes, -100.0, 100.0);
    let b = rng.f32_vec(lanes, -100.0, 100.0);
    let mut xla = XlaAlu::open_default()?;
    println!("| op | native | xla-pallas | bitwise equal |");
    println!("|---|---|---|---|");
    for op in SimdOp::ALL {
        let t0 = std::time::Instant::now();
        let mut acc_n = a.clone();
        NativeAlu::new().apply(op, &mut acc_n, &b);
        let native_t = t0.elapsed();
        let t1 = std::time::Instant::now();
        let mut acc_x = a.clone();
        xla.apply(op, &mut acc_x, &b);
        let xla_t = t1.elapsed();
        let equal = acc_n
            .iter()
            .zip(acc_x.iter())
            .all(|(x, y)| x.to_bits() == y.to_bits());
        println!(
            "| {} | {:.2?} | {:.2?} | {} |",
            op.name(),
            native_t,
            xla_t,
            equal
        );
        if !equal {
            bail!("backend mismatch on {op:?}");
        }
    }
    Ok(())
}

fn print_usage() {
    println!(
        "netdam — NetDAM reproduction launcher\n\
         subcommands: latency | allreduce | incast | multipath | alu | prog | mem | comm | serve | train | info\n\
         common flags: --config FILE, --set key=value, --seed N\n\
         allreduce: --algo netdam-ring|halving-doubling|hierarchical|switch-reduce|\n\
                    reduce-scatter|all-gather|broadcast|tree-bcast|reduce|ring-roce|\n\
                    mpi-native (comma list, or `all`); switch-reduce folds contributions\n\
                    IN the fat-tree switches (§2.5 in-network aggregation)\n\
         congestion control: allreduce/mem/comm take --cc dcqcn|static — dcqcn turns on\n\
                    closed-loop per-slot rate control (ECN CE -> CNP -> multiplicative\n\
                    cut + fast recovery) in the shared transport engine; static (default)\n\
                    keeps the fixed token-bucket budgets\n\
         prog:      packet-program demo (build -> verify -> execute); --elements N --ranks N\n\
         mem:       pooled-memory demo on the session API (lease -> IOMMU -> scatter-gather ->\n\
                    NAK -> pipelined batch -> multi-bag gather); --devices N --bytes B\n\
                    --window W (per-device in-flight window) --paced GBPS (READ pull-back)\n\
         comm:      session-API demo — two tenant jobs' allreduces + a pooled-memory plan\n\
                    overlapping on ONE fabric, then gradient bucketing fused vs unfused;\n\
                    --ranks N --elements N\n\
         serve:     multi-tenant KV/embedding serving fleet on the pooled fabric with\n\
                    per-tenant p50/p99/p99.9 + goodput reporting; --tenants N --skew θ\n\
                    --mix G/P/C/B --churn P --waves N --ops N --aggressor (add the\n\
                    misbehaving tenant: NAK storm + incast burst) --isolation (full A/B,\n\
                    asserts every neighbor's p99 within --bound-milli of baseline)\n\
         scaling the simulator: comm also takes --shards N (run the DES on N parallel\n\
                    event shards under conservative lookahead; same seed => bit-identical\n\
                    results at any shard count) and --shard-threads T (0 = auto)"
    );
}
