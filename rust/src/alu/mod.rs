//! The NetDAM ALU array (paper §2.4/§3.1).
//!
//! "Traditional CPU may only has AVX512 instruction support, each cycle may
//! only support 32× float32 value add operation. NetDAM could leverage
//! directly memory access and implement multiple ALUs to support 2048 ×
//! float32 add operation with single instruction."
//!
//! Two concerns live here, deliberately separated:
//!
//! * **Semantics** — [`AluBackend`]: apply a [`SimdOp`] lane-wise over f32
//!   vectors, and compute the block hash. Implementations:
//!   [`native::NativeAlu`] (pure rust, used inside the per-packet DES hot
//!   path) and `runtime::XlaAlu` (executes the AOT-compiled Pallas kernel
//!   through PJRT — the compute plane the three-layer design mandates; it
//!   lives in [`crate::runtime`] to keep this module xla-free).
//!   Both are verified against each other and against the python oracle.
//! * **Timing** — [`AluCostModel`]: how many ns the device pipeline charges
//!   for one instruction, as a function of lanes-per-cycle and clock. The
//!   DES uses this regardless of which backend computed the numbers.

pub mod hash;
pub mod native;

pub use hash::block_hash;
pub use native::NativeAlu;

use crate::isa::SimdOp;
use crate::sim::SimTime;

/// Lane-wise SIMD execution over f32.
///
/// `Send` because the sharded DES runtime (`net::shard`) migrates device
/// nodes across worker threads at window barriers. Both backends in this
/// offline build (`NativeAlu`, the chunked `XlaAlu` stub) are plain data;
/// a future PJRT-client-backed implementation would either hold a
/// thread-safe client handle or pin its devices to one shard.
pub trait AluBackend: Send {
    /// `acc[i] = op(acc[i], operand[i])` for all lanes.
    /// Lengths must match; implementations may process in blocks.
    fn apply(&mut self, op: SimdOp, acc: &mut [f32], operand: &[f32]);

    /// Block hash of raw bytes (idempotency guard, §3.1).
    fn hash(&mut self, block: &[u8]) -> u64 {
        block_hash(block)
    }

    fn name(&self) -> &'static str;
}

/// Time model of the ALU array + memory path on the device.
#[derive(Debug, Clone)]
pub struct AluCostModel {
    /// f32 lanes processed per fabric cycle (paper: 2048).
    pub lanes: usize,
    /// Fabric clock in GHz (Alveo U55N fabric ≈ 0.25–0.45 GHz).
    pub clock_ghz: f64,
    /// Fixed instruction issue overhead (decode, operand fetch setup).
    pub issue_ns: SimTime,
}

impl AluCostModel {
    /// The paper's device: 2048 lanes at 250 MHz fabric clock.
    pub fn paper_default() -> Self {
        Self {
            lanes: 2048,
            clock_ghz: 0.25,
            issue_ns: 8,
        }
    }

    /// An AVX-512 host core for the RoCE baseline: 32 lanes, 3 GHz.
    pub fn avx512_host() -> Self {
        Self {
            lanes: 32,
            clock_ghz: 3.0,
            issue_ns: 0,
        }
    }

    /// Nanoseconds to run one SIMD instruction over `n_lanes` f32 values.
    pub fn exec_ns(&self, n_lanes: usize) -> SimTime {
        let cycles = n_lanes.div_ceil(self.lanes) as f64;
        self.issue_ns + (cycles / self.clock_ghz).round() as SimTime
    }

    /// Effective f32 throughput in lanes/ns (for roofline reporting).
    pub fn lanes_per_ns(&self) -> f64 {
        self.lanes as f64 * self.clock_ghz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_alu_one_block_is_one_cycle() {
        let m = AluCostModel::paper_default();
        // 2048 lanes at 250MHz: one cycle = 4ns (+8ns issue).
        assert_eq!(m.exec_ns(2048), 12);
        assert_eq!(m.exec_ns(1), 12);
        // two blocks = two cycles
        assert_eq!(m.exec_ns(4096), 16);
    }

    #[test]
    fn netdam_alu_outruns_avx512_per_instruction() {
        // The paper's comparison: one NetDAM instruction covers 2048 lanes;
        // an AVX-512 core needs 64 cycles for the same block.
        let nd = AluCostModel::paper_default();
        let host = AluCostModel::avx512_host();
        let nd_t = nd.exec_ns(2048);
        let host_t = host.exec_ns(2048);
        assert!(
            (host_t as f64) > 1.5 * nd_t as f64,
            "netdam {nd_t}ns vs host {host_t}ns"
        );
    }

    #[test]
    fn throughput_scales_with_lanes() {
        let a = AluCostModel {
            lanes: 512,
            clock_ghz: 0.25,
            issue_ns: 0,
        };
        let b = AluCostModel {
            lanes: 2048,
            clock_ghz: 0.25,
            issue_ns: 0,
        };
        assert!(b.lanes_per_ns() > 3.9 * a.lanes_per_ns());
    }
}
