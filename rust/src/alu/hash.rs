//! The block hash (paper §3.1).
//!
//! "we defined a block based hash algorithm to keep the last hop
//! idempotent. *block-hash* instruction added to calculate block-hash,
//! each blocks may contains 2048 x float32 data."
//!
//! The hash must be computable by a wide SIMD datapath in one pass, so it
//! is an order-sensitive weighted sum over u32 lanes rather than a serial
//! chain: `h = Σ_i (lane_i ⊕ C1) · (2i+1)  (mod 2^32)`. Odd multipliers
//! keep each term invertible; the position weight makes permutations
//! collide with probability ~2^-32 like any 32-bit hash. **This exact
//! definition is mirrored by the Pallas kernel** (`kernels/block_hash.py`)
//! and asserted equal in the integration tests — the FPGA, the rust
//! simulator and the compiled XLA artifact must all agree or the
//! idempotency guard would mis-fire.

/// Lane whitening constant (golden ratio, same as the Pallas kernel).
pub const HASH_C1: u32 = 0x9E37_79B9;

/// Hash a block of bytes. Length is padded conceptually with zeros to a
/// multiple of 4 (the FPGA datapath always sees whole u32 lanes).
pub fn block_hash(block: &[u8]) -> u64 {
    let mut h: u32 = 0;
    let mut chunks = block.chunks_exact(4);
    let mut i: u32 = 0;
    for c in &mut chunks {
        let lane = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        h = h.wrapping_add((lane ^ HASH_C1).wrapping_mul(2 * i + 1));
        i += 1;
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut last = [0u8; 4];
        last[..rem.len()].copy_from_slice(rem);
        let lane = u32::from_le_bytes(last);
        h = h.wrapping_add((lane ^ HASH_C1).wrapping_mul(2 * i + 1));
    }
    h as u64
}

/// Hash f32 lanes directly (collectives call this on payload vectors).
pub fn block_hash_f32(lanes: &[f32]) -> u64 {
    let mut h: u32 = 0;
    for (i, x) in lanes.iter().enumerate() {
        h = h.wrapping_add((x.to_bits() ^ HASH_C1).wrapping_mul(2 * i as u32 + 1));
    }
    h as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_fits_u32() {
        let b = vec![7u8; 8192];
        let h1 = block_hash(&b);
        assert_eq!(h1, block_hash(&b));
        assert!(h1 <= u32::MAX as u64);
    }

    #[test]
    fn sensitive_to_content_and_position() {
        let a = vec![1u8, 2, 3, 4, 5, 6, 7, 8];
        let mut flipped = a.clone();
        flipped[0] ^= 1;
        assert_ne!(block_hash(&a), block_hash(&flipped));
        // Swap the two u32 lanes — a pure permutation must change the hash.
        let swapped = vec![5u8, 6, 7, 8, 1, 2, 3, 4];
        assert_ne!(block_hash(&a), block_hash(&swapped));
    }

    #[test]
    fn byte_and_f32_views_agree() {
        let xs = vec![1.5f32, -2.0, 3.25, 0.0, f32::INFINITY];
        let bytes = crate::util::bytes::f32s_to_bytes(&xs);
        assert_eq!(block_hash(&bytes), block_hash_f32(&xs));
    }

    #[test]
    fn ragged_tail_zero_pads() {
        // [1,0,0,0] as one lane == [1] padded
        assert_eq!(block_hash(&[1, 0, 0, 0]), block_hash(&[1]));
        // but an extra zero *lane* changes the hash (length-extension
        // distinct blocks) — position weight covers it only if nonzero:
        // here lane value 0^C1 * weight ≠ 0, so lengths differ.
        assert_ne!(block_hash(&[1, 0, 0, 0]), block_hash(&[1, 0, 0, 0, 0]));
    }

    #[test]
    fn known_vector_matches_python_kernel() {
        // This constant is asserted on the python side too
        // (python/tests/test_block_hash.py::test_known_vector).
        let xs: Vec<f32> = (0..8).map(|i| i as f32).collect();
        assert_eq!(block_hash_f32(&xs), 0xB5DE_6E40);
    }
}
