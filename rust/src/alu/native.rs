//! Pure-rust ALU backend — the per-packet hot path of the DES.
//!
//! The inner loops are written as exact-length zipped slices so LLVM
//! auto-vectorizes them (checked in § Perf; on this CPU `add` saturates
//! memory bandwidth). Semantics must match the Pallas kernel bit-for-bit
//! for Add/Sub/Mul/Min/Max/Xor on finite and non-finite inputs — the
//! integration test `runtime_alu_agrees` asserts it.

use super::AluBackend;
use crate::isa::SimdOp;

/// The native backend is stateless; the struct exists so callers hold a
/// `dyn AluBackend` uniformly with `XlaAlu`.
#[derive(Debug, Default, Clone)]
pub struct NativeAlu;

impl NativeAlu {
    pub fn new() -> Self {
        Self
    }
}

#[inline]
fn zip_apply(acc: &mut [f32], operand: &[f32], f: impl Fn(f32, f32) -> f32) {
    // Exact-length zip: the bounds checks hoist and LLVM vectorizes.
    for (a, b) in acc.iter_mut().zip(operand.iter()) {
        *a = f(*a, *b);
    }
}

impl AluBackend for NativeAlu {
    fn apply(&mut self, op: SimdOp, acc: &mut [f32], operand: &[f32]) {
        assert_eq!(
            acc.len(),
            operand.len(),
            "SIMD lane count mismatch: {} vs {}",
            acc.len(),
            operand.len()
        );
        match op {
            SimdOp::Add => zip_apply(acc, operand, |a, b| a + b),
            SimdOp::Sub => zip_apply(acc, operand, |a, b| a - b),
            SimdOp::Mul => zip_apply(acc, operand, |a, b| a * b),
            // min/max match jnp.minimum/jnp.maximum: NaN propagates from
            // either operand (f32::min/max would *suppress* NaN).
            SimdOp::Min => zip_apply(acc, operand, |a, b| {
                if a.is_nan() || b.is_nan() {
                    f32::NAN
                } else {
                    a.min(b)
                }
            }),
            SimdOp::Max => zip_apply(acc, operand, |a, b| {
                if a.is_nan() || b.is_nan() {
                    f32::NAN
                } else {
                    a.max(b)
                }
            }),
            SimdOp::Xor => {
                zip_apply(acc, operand, |a, b| f32::from_bits(a.to_bits() ^ b.to_bits()))
            }
        }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Convenience: out-of-place apply returning a fresh vector.
pub fn apply_simd(op: SimdOp, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut acc = a.to_vec();
    NativeAlu::new().apply(op, &mut acc, b);
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn all_ops_elementwise() {
        let a = [1.0f32, -2.0, 3.5, 0.0];
        let b = [4.0f32, 5.0, -1.5, 0.0];
        assert_eq!(apply_simd(SimdOp::Add, &a, &b), vec![5.0, 3.0, 2.0, 0.0]);
        assert_eq!(apply_simd(SimdOp::Sub, &a, &b), vec![-3.0, -7.0, 5.0, 0.0]);
        assert_eq!(apply_simd(SimdOp::Mul, &a, &b), vec![4.0, -10.0, -5.25, 0.0]);
        assert_eq!(apply_simd(SimdOp::Min, &a, &b), vec![1.0, -2.0, -1.5, 0.0]);
        assert_eq!(apply_simd(SimdOp::Max, &a, &b), vec![4.0, 5.0, 3.5, 0.0]);
        let x = apply_simd(SimdOp::Xor, &a, &a);
        assert_eq!(x, vec![0.0; 4]);
    }

    #[test]
    fn nan_propagates_in_min_max() {
        let a = [f32::NAN, 1.0];
        let b = [2.0f32, f32::NAN];
        let mn = apply_simd(SimdOp::Min, &a, &b);
        let mx = apply_simd(SimdOp::Max, &a, &b);
        assert!(mn.iter().all(|v| v.is_nan()));
        assert!(mx.iter().all(|v| v.is_nan()));
    }

    #[test]
    fn xor_is_involution() {
        let mut rng = Xoshiro256::seed_from(17);
        let a = rng.f32_vec(2048, -10.0, 10.0);
        let b = rng.f32_vec(2048, -10.0, 10.0);
        let x = apply_simd(SimdOp::Xor, &a, &b);
        let back = apply_simd(SimdOp::Xor, &x, &b);
        assert_eq!(back, a);
    }

    #[test]
    fn add_matches_scalar_reference_on_random_blocks() {
        let mut rng = Xoshiro256::seed_from(23);
        for _ in 0..16 {
            let n = 1 + rng.next_below(4096) as usize;
            let a = rng.f32_vec(n, -1e6, 1e6);
            let b = rng.f32_vec(n, -1e6, 1e6);
            let got = apply_simd(SimdOp::Add, &a, &b);
            for i in 0..n {
                assert_eq!(got[i], a[i] + b[i]);
            }
        }
    }

    #[test]
    #[should_panic(expected = "lane count mismatch")]
    fn length_mismatch_panics() {
        let mut acc = vec![0.0f32; 4];
        NativeAlu::new().apply(SimdOp::Add, &mut acc, &[1.0; 5]);
    }
}
