//! The PJRT runtime: loads the AOT-compiled HLO artifacts and executes
//! them from rust. Python never runs at request time — `make artifacts`
//! is the only python step, and the `netdam` binary is self-contained
//! afterwards.
//!
//! * [`Runtime`] — PJRT CPU client + a compile-once executable cache over
//!   `artifacts/*.hlo.txt` (manifest-driven).
//! * [`XlaAlu`] — an [`crate::alu::AluBackend`] that runs the device ALU
//!   through the compiled Pallas kernels (the L1→L3 integration).
//! * [`mlp`] — the training-step harness for the data-parallel example.

pub mod mlp;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::alu::{AluBackend, NativeAlu};
use crate::isa::SimdOp;

/// Lanes per Pallas block (must match `kernels.LANES`; checked vs abi.txt).
pub const LANES: usize = 2048;
/// Blocks per ALU artifact invocation (`aot.ALU_BLOCKS`).
pub const ALU_BLOCKS: usize = 8;
/// Flat element count per ALU artifact call.
pub const ALU_CHUNK: usize = LANES * ALU_BLOCKS;

/// Compile-once, execute-many PJRT wrapper.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (validates `abi.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let abi = std::fs::read_to_string(dir.join("abi.txt"))
            .with_context(|| format!("reading {}/abi.txt — run `make artifacts`", dir.display()))?;
        for line in abi.lines() {
            let mut it = line.split_whitespace();
            match (it.next(), it.next()) {
                (Some("lanes"), Some(v)) => {
                    let v: usize = v.parse()?;
                    if v != LANES {
                        bail!("artifact lanes {v} != runtime LANES {LANES}");
                    }
                }
                (Some("alu_blocks"), Some(v)) => {
                    let v: usize = v.parse()?;
                    if v != ALU_BLOCKS {
                        bail!("artifact alu_blocks {v} != runtime ALU_BLOCKS {ALU_BLOCKS}");
                    }
                }
                _ => {}
            }
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            cache: HashMap::new(),
        })
    }

    /// Default location relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        Self::open("artifacts")
    }

    /// Compile (or fetch) the named artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute `name` over the given literals; returns the untupled
    /// outputs (artifacts are lowered with `return_tuple=True`).
    pub fn exec(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Convenience: run a flat-f32 → flat-f32 artifact.
    pub fn exec_f32(&mut self, name: &str, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = args.iter().map(|a| xla::Literal::vec1(a)).collect();
        let outs = self.exec(name, &lits)?;
        outs.iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }

    pub fn artifact_names(&self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))?;
        Ok(manifest
            .lines()
            .filter_map(|l| l.split_whitespace().next())
            .map(str::to_string)
            .collect())
    }
}

/// ALU backend executing the compiled Pallas kernels through PJRT.
///
/// Arbitrary lane counts are processed in `ALU_CHUNK` slices; the ragged
/// tail is zero-padded (padding lanes are discarded on the way out).
pub struct XlaAlu {
    rt: Runtime,
    /// Artifact invocations served (perf counter for the simd bench).
    pub calls: u64,
}

impl XlaAlu {
    pub fn new(rt: Runtime) -> Self {
        Self { rt, calls: 0 }
    }

    pub fn open_default() -> Result<Self> {
        Ok(Self::new(Runtime::open_default()?))
    }

    fn artifact(op: SimdOp) -> &'static str {
        match op {
            SimdOp::Add => "simd_add",
            SimdOp::Sub => "simd_sub",
            SimdOp::Mul => "simd_mul",
            SimdOp::Min => "simd_min",
            SimdOp::Max => "simd_max",
            SimdOp::Xor => "simd_xor",
        }
    }

    /// Block hash through the compiled kernel (whole chunks only).
    pub fn hash_blocks(&mut self, x: &[f32]) -> Result<Vec<u32>> {
        anyhow::ensure!(x.len() == ALU_CHUNK, "hash_blocks wants one full chunk");
        let outs = self.rt.exec("block_hash", &[xla::Literal::vec1(x)])?;
        outs[0]
            .to_vec::<u32>()
            .map_err(|e| anyhow!("hash result: {e:?}"))
    }
}

impl AluBackend for XlaAlu {
    fn apply(&mut self, op: SimdOp, acc: &mut [f32], operand: &[f32]) {
        assert_eq!(acc.len(), operand.len(), "SIMD lane count mismatch");
        let name = Self::artifact(op);
        let mut off = 0;
        while off < acc.len() {
            let n = (acc.len() - off).min(ALU_CHUNK);
            let mut a = vec![0f32; ALU_CHUNK];
            let mut b = vec![0f32; ALU_CHUNK];
            a[..n].copy_from_slice(&acc[off..off + n]);
            b[..n].copy_from_slice(&operand[off..off + n]);
            let out = self
                .rt
                .exec_f32(name, &[&a, &b])
                .unwrap_or_else(|e| panic!("XlaAlu {name}: {e}"));
            acc[off..off + n].copy_from_slice(&out[0][..n]);
            self.calls += 1;
            off += n;
        }
    }

    fn name(&self) -> &'static str {
        "xla-pallas"
    }
}

/// Cross-backend equivalence: the integration seal between L1 and L3.
/// Bitwise equality is demanded except NaN-vs-NaN (any payload accepted).
pub fn backends_agree(op: SimdOp, a: &[f32], b: &[f32], xla_alu: &mut XlaAlu) -> bool {
    let mut native = a.to_vec();
    NativeAlu::new().apply(op, &mut native, b);
    let mut xla_v = a.to_vec();
    xla_alu.apply(op, &mut xla_v, b);
    native
        .iter()
        .zip(xla_v.iter())
        .all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}
