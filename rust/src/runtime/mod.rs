//! The compute-plane runtime.
//!
//! In the full three-layer build this module loads AOT-compiled HLO
//! artifacts (Pallas kernels lowered by `python/compile/aot.py`) and
//! executes them through the PJRT C API. This repository is built and
//! tested **offline**, without the `xla` bindings or a PJRT plugin on the
//! box, so the module ships the paper-faithful *stub*:
//!
//! * [`Runtime`] keeps the artifact-directory handling (abi/manifest
//!   validation) but reports "backend unavailable" on [`Runtime::exec`];
//! * [`XlaAlu`] keeps the [`AluBackend`] contract — chunked 2048-lane
//!   blocks, per-call accounting — and computes through [`NativeAlu`],
//!   which is pinned bit-for-bit against the Pallas kernels by the python
//!   test suite. Simulation results are therefore identical with either
//!   backend; only wall-clock differs.
//!
//! The public surface (types, constants, [`backends_agree`]) is the same
//! as the PJRT-backed build so callers never branch on the backend.

pub mod mlp;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::alu::{block_hash, AluBackend, NativeAlu};
use crate::isa::SimdOp;
use crate::util::bytes::f32s_to_bytes;

/// Lanes per Pallas block (must match `kernels.LANES`; checked vs abi.txt).
pub const LANES: usize = 2048;
/// Blocks per ALU artifact invocation (`aot.ALU_BLOCKS`).
pub const ALU_BLOCKS: usize = 8;
/// Flat element count per ALU artifact call.
pub const ALU_CHUNK: usize = LANES * ALU_BLOCKS;

/// Minimal stand-in for a PJRT literal: a flat f32 buffer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Literal(pub Vec<f32>);

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal(data.to_vec())
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.0.clone()
    }
}

/// Artifact-directory handle. Validates the ABI contract on open; actual
/// execution requires the PJRT backend and reports unavailable here.
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (validates `abi.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let abi = std::fs::read_to_string(dir.join("abi.txt"))
            .with_context(|| format!("reading {}/abi.txt — run `make artifacts`", dir.display()))?;
        for line in abi.lines() {
            let mut it = line.split_whitespace();
            match (it.next(), it.next()) {
                (Some("lanes"), Some(v)) => {
                    let v: usize = v.parse()?;
                    if v != LANES {
                        bail!("artifact lanes {v} != runtime LANES {LANES}");
                    }
                }
                (Some("alu_blocks"), Some(v)) => {
                    let v: usize = v.parse()?;
                    if v != ALU_BLOCKS {
                        bail!("artifact alu_blocks {v} != runtime ALU_BLOCKS {ALU_BLOCKS}");
                    }
                }
                _ => {}
            }
        }
        Ok(Runtime { dir })
    }

    /// Default location relative to the repo root.
    pub fn open_default() -> Result<Runtime> {
        Self::open("artifacts")
    }

    /// Execute the named artifact. Unavailable in the offline build.
    pub fn exec(&mut self, name: &str, _args: &[Literal]) -> Result<Vec<Literal>> {
        bail!(
            "PJRT backend unavailable in this offline build: cannot execute \
             artifact {name:?} from {} (the simulated datapath uses the \
             bit-identical native ALU instead)",
            self.dir.display()
        );
    }

    /// Convenience: run a flat-f32 → flat-f32 artifact.
    pub fn exec_f32(&mut self, name: &str, args: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<Literal> = args.iter().map(|a| Literal::vec1(a)).collect();
        let outs = self.exec(name, &lits)?;
        Ok(outs.iter().map(|l| l.to_vec()).collect())
    }

    pub fn artifact_names(&self) -> Result<Vec<String>> {
        let manifest = std::fs::read_to_string(self.dir.join("manifest.txt"))?;
        Ok(manifest
            .lines()
            .filter_map(|l| l.split_whitespace().next())
            .map(str::to_string)
            .collect())
    }
}

/// ALU backend with the compiled-Pallas calling convention (chunked
/// `ALU_CHUNK` slices, per-call accounting), computing through the native
/// ALU in this offline build.
pub struct XlaAlu {
    native: NativeAlu,
    /// Artifact-shaped invocations served (perf counter for the simd bench).
    pub calls: u64,
}

impl XlaAlu {
    pub fn new(_rt: Runtime) -> Self {
        Self {
            native: NativeAlu::new(),
            calls: 0,
        }
    }

    /// The stub backend needs no artifacts; always succeeds.
    pub fn open_default() -> Result<Self> {
        Ok(Self {
            native: NativeAlu::new(),
            calls: 0,
        })
    }

    /// Block hash with the artifact ABI (whole chunks only, one u32 hash
    /// per 2048-lane block).
    pub fn hash_blocks(&mut self, x: &[f32]) -> Result<Vec<u32>> {
        anyhow::ensure!(x.len() == ALU_CHUNK, "hash_blocks wants one full chunk");
        Ok((0..ALU_BLOCKS)
            .map(|i| {
                let block = &x[i * LANES..(i + 1) * LANES];
                block_hash(&f32s_to_bytes(block)) as u32
            })
            .collect())
    }
}

impl AluBackend for XlaAlu {
    fn apply(&mut self, op: SimdOp, acc: &mut [f32], operand: &[f32]) {
        assert_eq!(acc.len(), operand.len(), "SIMD lane count mismatch");
        let mut off = 0;
        while off < acc.len() {
            let n = (acc.len() - off).min(ALU_CHUNK);
            self.native
                .apply(op, &mut acc[off..off + n], &operand[off..off + n]);
            self.calls += 1;
            off += n;
        }
    }

    fn name(&self) -> &'static str {
        "xla-pallas-stub"
    }
}

/// Cross-backend equivalence: the integration seal between L1 and L3.
/// Bitwise equality is demanded except NaN-vs-NaN (any payload accepted).
pub fn backends_agree(op: SimdOp, a: &[f32], b: &[f32], xla_alu: &mut XlaAlu) -> bool {
    let mut native = a.to_vec();
    NativeAlu::new().apply(op, &mut native, b);
    let mut xla_v = a.to_vec();
    xla_alu.apply(op, &mut xla_v, b);
    native
        .iter()
        .zip(xla_v.iter())
        .all(|(x, y)| x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()))
}
