//! Data-parallel MLP training harness over the AOT artifacts.
//!
//! Each worker executes the `mlp_grad` artifact (fwd/bwd through PJRT);
//! the *gradient allreduce* between workers is the part NetDAM
//! accelerates, and `examples/train_dataparallel.rs` routes it through
//! the simulated fabric. In this offline build the PJRT backend is
//! stubbed (see [`super`]): the shape/ABI plumbing works, but
//! [`MlpTrainer::open`] fails with a clear message unless artifacts and a
//! PJRT plugin are present.

use anyhow::Result;

use super::{Literal, Runtime, LANES};

/// MLP geometry, read from `abi.txt` at open time.
#[derive(Debug, Clone, Copy)]
pub struct MlpShape {
    pub d_in: usize,
    pub d_h: usize,
    pub d_out: usize,
    pub batch: usize,
}

impl MlpShape {
    /// Flat lengths of (w1, b1, w2, b2).
    pub fn param_lens(&self) -> [usize; 4] {
        [
            self.d_in * self.d_h,
            self.d_h,
            self.d_h * self.d_out,
            self.d_out,
        ]
    }

    pub fn n_params(&self) -> usize {
        self.param_lens().iter().sum()
    }
}

/// One training worker (or the leader applying updates).
pub struct MlpTrainer {
    rt: Runtime,
    pub shape: MlpShape,
    /// Flat parameters in (w1, b1, w2, b2) order.
    pub params: Vec<Vec<f32>>,
}

impl MlpTrainer {
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<MlpTrainer> {
        let dir = dir.as_ref();
        let abi = std::fs::read_to_string(dir.join("abi.txt"))?;
        let mut shape = MlpShape {
            d_in: 0,
            d_h: 0,
            d_out: 0,
            batch: 0,
        };
        for line in abi.lines() {
            let f: Vec<&str> = line.split_whitespace().collect();
            match f.as_slice() {
                ["mlp", a, b, c] => {
                    shape.d_in = a.parse()?;
                    shape.d_h = b.parse()?;
                    shape.d_out = c.parse()?;
                }
                ["train_batch", v] => shape.batch = v.parse()?,
                _ => {}
            }
        }
        anyhow::ensure!(shape.d_in > 0 && shape.batch > 0, "abi.txt missing mlp/batch");
        let mut rt = Runtime::open(dir)?;
        // Initialize parameters from the artifact (identical to python).
        let outs = rt.exec("mlp_init", &[])?;
        anyhow::ensure!(outs.len() == 4, "mlp_init must return 4 params");
        let params = outs.iter().map(|l| l.to_vec()).collect();
        Ok(MlpTrainer { rt, shape, params })
    }

    /// Generate the deterministic batch for `step` (same stream the
    /// python oracle trains on).
    pub fn batch(&mut self, step: u32) -> Result<(Literal, Literal)> {
        let step_lit = Literal::vec1(&[step as f32]);
        let mut outs = self.rt.exec("mlp_batch", &[step_lit])?;
        anyhow::ensure!(outs.len() == 2, "mlp_batch returns (x, y)");
        let y = outs.pop().unwrap();
        let x = outs.pop().unwrap();
        Ok((x, y))
    }

    /// Forward/backward on the worker's current params; returns flat
    /// gradients in param order + the scalar loss.
    pub fn grad_step(&mut self, x: &Literal, y: &Literal) -> Result<(Vec<Vec<f32>>, f32)> {
        let lens = self.shape.param_lens();
        let args = vec![
            Literal::vec1(&self.params[0]),
            Literal::vec1(&self.params[1]),
            Literal::vec1(&self.params[2]),
            Literal::vec1(&self.params[3]),
            x.clone(),
            y.clone(),
        ];
        let outs = self.rt.exec("mlp_grad", &args)?;
        anyhow::ensure!(outs.len() == 5, "mlp_grad returns 4 grads + loss");
        let mut grads = Vec::with_capacity(4);
        for (i, l) in outs[..4].iter().enumerate() {
            let g = l.to_vec();
            anyhow::ensure!(g.len() == lens[i], "grad {i} length");
            grads.push(g);
        }
        let loss = outs[4].to_vec()[0];
        Ok((grads, loss))
    }

    /// Apply `p ← p − lr·g` through the `sgd_apply` artifact. Parameters
    /// shorter than the artifact's block count are zero-padded.
    pub fn sgd_apply(&mut self, grads: &[Vec<f32>], lr: f32) -> Result<()> {
        let sgd_len = {
            // artifact is sized for the largest parameter (w1).
            let w1 = self.shape.d_in * self.shape.d_h;
            w1.div_ceil(LANES) * LANES
        };
        let neg_lr = vec![-lr; LANES];
        for (p, g) in self.params.iter_mut().zip(grads.iter()) {
            let mut pw = vec![0f32; sgd_len];
            let mut gw = vec![0f32; sgd_len];
            pw[..p.len()].copy_from_slice(p);
            gw[..g.len()].copy_from_slice(g);
            let args = vec![
                Literal::vec1(&pw),
                Literal::vec1(&gw),
                Literal::vec1(&neg_lr),
            ];
            let outs = self.rt.exec("sgd_apply", &args)?;
            let new_p = outs[0].to_vec();
            let n = p.len();
            p.copy_from_slice(&new_p[..n]);
        }
        Ok(())
    }

    /// The python oracle's loss curve (written at `make artifacts` time).
    pub fn reference_curve(dir: impl AsRef<std::path::Path>) -> Result<Vec<f32>> {
        let text = std::fs::read_to_string(dir.as_ref().join("reference_curve.txt"))?;
        text.lines()
            .map(|l| l.trim().parse::<f32>().map_err(Into::into))
            .collect()
    }
}
