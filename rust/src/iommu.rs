//! Per-device IOMMU (paper §2.5).
//!
//! "IOMMU may implement on NetDAM for Virtual Address and Physical Address
//! translation. Remote Memory could also mapping to local Virtual Address
//! by this IOMMU."
//!
//! The model is a flat page table over 2 MiB pages with R/W permission
//! bits. Identity mapping (the FPGA prototype's default) is the fast path:
//! an empty table translates 1:1 with full access — so simulations that
//! don't exercise virtualization pay nothing.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// 2 MiB translation granule.
pub const IOMMU_PAGE_BITS: u32 = 21;
pub const IOMMU_PAGE_SIZE: u64 = 1 << IOMMU_PAGE_BITS;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Perms {
    pub read: bool,
    pub write: bool,
}

impl Perms {
    pub const RW: Perms = Perms {
        read: true,
        write: true,
    };
    pub const RO: Perms = Perms {
        read: true,
        write: false,
    };
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    pa_page: u64,
    perms: Perms,
}

/// The translation table. `Access::Read`/`Write` select the permission bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

#[derive(Debug, Default)]
pub struct Iommu {
    table: HashMap<u64, Entry>,
}

impl Iommu {
    /// Identity-mapping IOMMU (empty table).
    pub fn identity() -> Self {
        Self::default()
    }

    pub fn is_identity(&self) -> bool {
        self.table.is_empty()
    }

    /// Map `va..va+len` → `pa..pa+len`. All three must be page-aligned.
    pub fn map(&mut self, va: u64, pa: u64, len: u64, perms: Perms) -> Result<()> {
        if va % IOMMU_PAGE_SIZE != 0 || pa % IOMMU_PAGE_SIZE != 0 || len % IOMMU_PAGE_SIZE != 0 {
            bail!("IOMMU mappings must be 2MiB-aligned (va={va:#x} pa={pa:#x} len={len:#x})");
        }
        for i in 0..len / IOMMU_PAGE_SIZE {
            let vp = (va >> IOMMU_PAGE_BITS) + i;
            if self.table.contains_key(&vp) {
                bail!("VA page {:#x} already mapped", vp << IOMMU_PAGE_BITS);
            }
            self.table.insert(
                vp,
                Entry {
                    pa_page: (pa >> IOMMU_PAGE_BITS) + i,
                    perms,
                },
            );
        }
        Ok(())
    }

    pub fn unmap(&mut self, va: u64, len: u64) -> Result<()> {
        if va % IOMMU_PAGE_SIZE != 0 || len % IOMMU_PAGE_SIZE != 0 {
            bail!("IOMMU unmap must be 2MiB-aligned");
        }
        for i in 0..len / IOMMU_PAGE_SIZE {
            let vp = (va >> IOMMU_PAGE_BITS) + i;
            if self.table.remove(&vp).is_none() {
                bail!("VA page {:#x} not mapped", vp << IOMMU_PAGE_BITS);
            }
        }
        Ok(())
    }

    /// Translate one address for an access of `len` bytes. The access must
    /// not cross a page boundary into a differently-mapped page unless the
    /// mapping is contiguous (checked).
    pub fn translate(&self, va: u64, len: usize, access: Access) -> Result<u64> {
        if self.table.is_empty() {
            return Ok(va); // identity fast path
        }
        let first = va >> IOMMU_PAGE_BITS;
        let last = (va + len.max(1) as u64 - 1) >> IOMMU_PAGE_BITS;
        let Some(e0) = self.table.get(&first) else {
            bail!("IOMMU fault: VA {va:#x} not mapped");
        };
        let ok = match access {
            Access::Read => e0.perms.read,
            Access::Write => e0.perms.write,
        };
        if !ok {
            bail!("IOMMU permission fault at VA {va:#x} ({access:?})");
        }
        // Verify spanned pages are mapped contiguously with same perms.
        for (k, vp) in (first..=last).enumerate() {
            let Some(e) = self.table.get(&vp) else {
                bail!("IOMMU fault: VA page {:#x} not mapped", vp << IOMMU_PAGE_BITS);
            };
            if e.pa_page != e0.pa_page + k as u64 || e.perms != e0.perms {
                bail!("IOMMU: access at {va:#x}+{len} crosses a mapping break");
            }
        }
        Ok((e0.pa_page << IOMMU_PAGE_BITS) + (va & (IOMMU_PAGE_SIZE - 1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_passes_through() {
        let m = Iommu::identity();
        assert_eq!(m.translate(0x1234_5678, 64, Access::Read).unwrap(), 0x1234_5678);
        assert_eq!(m.translate(0, 1, Access::Write).unwrap(), 0);
    }

    #[test]
    fn mapped_translation() {
        let mut m = Iommu::identity();
        m.map(0, 4 * IOMMU_PAGE_SIZE, 2 * IOMMU_PAGE_SIZE, Perms::RW)
            .unwrap();
        assert_eq!(
            m.translate(100, 8, Access::Read).unwrap(),
            4 * IOMMU_PAGE_SIZE + 100
        );
        // Second page maps contiguously.
        assert_eq!(
            m.translate(IOMMU_PAGE_SIZE + 8, 8, Access::Write).unwrap(),
            5 * IOMMU_PAGE_SIZE + 8
        );
    }

    #[test]
    fn unmapped_va_faults_once_table_nonempty() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        assert!(m.translate(IOMMU_PAGE_SIZE * 10, 4, Access::Read).is_err());
    }

    #[test]
    fn write_to_readonly_faults() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RO).unwrap();
        assert!(m.translate(0, 4, Access::Read).is_ok());
        assert!(m.translate(0, 4, Access::Write).is_err());
    }

    #[test]
    fn cross_page_contiguous_ok_break_faults() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        // Map second VA page to a NON-contiguous PA page.
        m.map(IOMMU_PAGE_SIZE, 8 * IOMMU_PAGE_SIZE, IOMMU_PAGE_SIZE, Perms::RW)
            .unwrap();
        let straddle = IOMMU_PAGE_SIZE - 8;
        assert!(m.translate(straddle, 16, Access::Read).is_err());
    }

    #[test]
    fn double_map_and_misalignment_rejected() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        assert!(m.map(0, IOMMU_PAGE_SIZE, IOMMU_PAGE_SIZE, Perms::RW).is_err());
        assert!(m.map(123, 0, IOMMU_PAGE_SIZE, Perms::RW).is_err());
        assert!(m.unmap(4096, IOMMU_PAGE_SIZE).is_err());
    }

    #[test]
    fn unmap_restores_fault() {
        let mut m = Iommu::identity();
        m.map(0, 0, 2 * IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        m.unmap(0, IOMMU_PAGE_SIZE).unwrap();
        assert!(m.translate(0, 4, Access::Read).is_err());
        assert!(m.translate(IOMMU_PAGE_SIZE, 4, Access::Read).is_ok());
    }
}
