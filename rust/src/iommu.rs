//! Per-device IOMMU (paper §2.5/§2.6).
//!
//! "IOMMU may implement on NetDAM for Virtual Address and Physical Address
//! translation. Remote Memory could also mapping to local Virtual Address
//! by this IOMMU."
//!
//! The model is a flat page table with R/W permission bits and an optional
//! **tenant lease** per entry — the device-side half of the SDN
//! controller's ACL (§2.6): the controller translates malloc/free into
//! page mappings *on each device*, so access control is enforced where the
//! paper enforces it, at the memory, not in host software. A denied
//! translation is a typed [`IommuFault`]; the device surfaces it on the
//! wire as a `Nack` carrying the matching [`NakReason`].
//!
//! The page size is configurable per instance ([`Iommu::with_page_bits`]):
//! the default 2 MiB granule suits host-style virtualization, while the
//! pool controller programs leases at the interleave-block granule (8 KiB).
//! Identity mapping (the FPGA prototype's default) is the fast path: an
//! empty table translates 1:1 with full access — so simulations that don't
//! exercise virtualization pay nothing.

use anyhow::{bail, Result};
use std::collections::HashMap;
use std::fmt;

/// Default translation granule: 2 MiB.
pub const IOMMU_PAGE_BITS: u32 = 21;
pub const IOMMU_PAGE_SIZE: u64 = 1 << IOMMU_PAGE_BITS;

/// A pool tenant (the controller's lease owner identity).
pub type TenantId = u32;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Perms {
    pub read: bool,
    pub write: bool,
}

impl Perms {
    pub const RW: Perms = Perms {
        read: true,
        write: true,
    };
    pub const RO: Perms = Perms {
        read: true,
        write: false,
    };
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    pa_page: u64,
    perms: Perms,
    /// `Some(t)` restricts this page to requests attributed to tenant `t`.
    lease: Option<TenantId>,
}

/// The translation table. `Access::Read`/`Write` select the permission bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    Read,
    Write,
}

/// Wire-level NAK reason codes (the `reason` byte of `Instruction::Nack`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum NakReason {
    Unspecified = 0,
    /// Translation fault: the page is not mapped (out of lease).
    Unmapped = 1,
    /// The lease does not grant read permission.
    ReadDenied = 2,
    /// The lease does not grant write permission.
    WriteDenied = 3,
    /// The page belongs to a different tenant's lease.
    ForeignLease = 4,
    /// The access spans a translation discontinuity.
    MappingBreak = 5,
}

impl NakReason {
    pub fn from_u8(v: u8) -> NakReason {
        match v {
            1 => NakReason::Unmapped,
            2 => NakReason::ReadDenied,
            3 => NakReason::WriteDenied,
            4 => NakReason::ForeignLease,
            5 => NakReason::MappingBreak,
            _ => NakReason::Unspecified,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NakReason::Unspecified => "unspecified",
            NakReason::Unmapped => "unmapped",
            NakReason::ReadDenied => "read-denied",
            NakReason::WriteDenied => "write-denied",
            NakReason::ForeignLease => "foreign-lease",
            NakReason::MappingBreak => "mapping-break",
        }
    }
}

impl fmt::Display for NakReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed translation failure — what the device turns into a wire NAK.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IommuFault {
    /// No mapping covers `va`.
    Unmapped { va: u64 },
    /// The mapping exists but does not grant the access.
    Denied { va: u64, write: bool },
    /// The mapping is leased to another tenant.
    ForeignLease { va: u64 },
    /// The access spans pages that are not contiguously mapped.
    MappingBreak { va: u64, len: usize },
}

impl IommuFault {
    /// The NAK reason byte this fault puts on the wire.
    pub fn reason(&self) -> NakReason {
        match self {
            IommuFault::Unmapped { .. } => NakReason::Unmapped,
            IommuFault::Denied { write: false, .. } => NakReason::ReadDenied,
            IommuFault::Denied { write: true, .. } => NakReason::WriteDenied,
            IommuFault::ForeignLease { .. } => NakReason::ForeignLease,
            IommuFault::MappingBreak { .. } => NakReason::MappingBreak,
        }
    }
}

impl fmt::Display for IommuFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            IommuFault::Unmapped { va } => write!(f, "IOMMU fault: VA {va:#x} not mapped"),
            IommuFault::Denied { va, write } => write!(
                f,
                "IOMMU permission fault at VA {va:#x} ({})",
                if *write { "write" } else { "read" }
            ),
            IommuFault::ForeignLease { va } => {
                write!(f, "IOMMU lease fault: VA {va:#x} belongs to another tenant")
            }
            IommuFault::MappingBreak { va, len } => {
                write!(f, "IOMMU: access at {va:#x}+{len} crosses a mapping break")
            }
        }
    }
}

impl std::error::Error for IommuFault {}

#[derive(Debug)]
pub struct Iommu {
    table: HashMap<u64, Entry>,
    page_bits: u32,
    /// Latched on the first mapping: once a device has been programmed,
    /// an empty table means "nothing mapped" (fault), not identity —
    /// freeing the last lease must not reopen the whole address space.
    enforcing: bool,
}

impl Default for Iommu {
    fn default() -> Self {
        Self {
            table: HashMap::new(),
            page_bits: IOMMU_PAGE_BITS,
            enforcing: false,
        }
    }
}

impl Iommu {
    /// Identity-mapping IOMMU (empty table).
    pub fn identity() -> Self {
        Self::default()
    }

    /// An empty IOMMU with a custom translation granule of `2^bits` bytes
    /// (the pool controller uses the interleave-block granule).
    pub fn with_page_bits(bits: u32) -> Self {
        assert!((6..=30).contains(&bits), "page bits {bits} out of range");
        Self {
            table: HashMap::new(),
            page_bits: bits,
            enforcing: false,
        }
    }

    /// Change the granule. Only legal while the table is empty.
    pub fn set_page_bits(&mut self, bits: u32) -> Result<()> {
        if !self.table.is_empty() {
            bail!("cannot change IOMMU page size with live mappings");
        }
        if !(6..=30).contains(&bits) {
            bail!("page bits {bits} out of range");
        }
        self.page_bits = bits;
        Ok(())
    }

    pub fn page_size(&self) -> u64 {
        1 << self.page_bits
    }

    pub fn is_identity(&self) -> bool {
        self.table.is_empty() && !self.enforcing
    }

    /// Map `va..va+len` → `pa..pa+len` with no tenant restriction. All
    /// three must be page-aligned.
    pub fn map(&mut self, va: u64, pa: u64, len: u64, perms: Perms) -> Result<()> {
        self.map_leased(va, pa, len, perms, None)
    }

    /// Map a tenant lease: like [`map`](Self::map), but the pages only
    /// translate for requests attributed to `lease` (when `Some`).
    pub fn map_leased(
        &mut self,
        va: u64,
        pa: u64,
        len: u64,
        perms: Perms,
        lease: Option<TenantId>,
    ) -> Result<()> {
        let psz = self.page_size();
        if va % psz != 0 || pa % psz != 0 || len % psz != 0 {
            bail!(
                "IOMMU mappings must be {psz}-byte aligned (va={va:#x} pa={pa:#x} len={len:#x})"
            );
        }
        for i in 0..len / psz {
            let vp = (va >> self.page_bits) + i;
            if self.table.contains_key(&vp) {
                bail!("VA page {:#x} already mapped", vp << self.page_bits);
            }
            self.table.insert(
                vp,
                Entry {
                    pa_page: (pa >> self.page_bits) + i,
                    perms,
                    lease,
                },
            );
        }
        self.enforcing = true;
        Ok(())
    }

    pub fn unmap(&mut self, va: u64, len: u64) -> Result<()> {
        let psz = self.page_size();
        if va % psz != 0 || len % psz != 0 {
            bail!("IOMMU unmap must be {psz}-byte aligned");
        }
        for i in 0..len / psz {
            let vp = (va >> self.page_bits) + i;
            if self.table.remove(&vp).is_none() {
                bail!("VA page {:#x} not mapped", vp << self.page_bits);
            }
        }
        Ok(())
    }

    /// Translate one request-attributed access of `len` bytes. `tenant` is
    /// the requester identity the device resolved from the packet source
    /// (None = unattributed). The access must not cross a page boundary
    /// into a differently-mapped page unless the mapping is contiguous
    /// with identical perms and lease (checked).
    pub fn translate_req(
        &self,
        va: u64,
        len: usize,
        access: Access,
        tenant: Option<TenantId>,
    ) -> Result<u64, IommuFault> {
        if self.table.is_empty() {
            if self.enforcing {
                return Err(IommuFault::Unmapped { va });
            }
            return Ok(va); // identity fast path
        }
        let first = va >> self.page_bits;
        let last = (va + len.max(1) as u64 - 1) >> self.page_bits;
        let Some(e0) = self.table.get(&first) else {
            return Err(IommuFault::Unmapped { va });
        };
        if let Some(owner) = e0.lease {
            if tenant != Some(owner) {
                return Err(IommuFault::ForeignLease { va });
            }
        }
        let ok = match access {
            Access::Read => e0.perms.read,
            Access::Write => e0.perms.write,
        };
        if !ok {
            return Err(IommuFault::Denied {
                va,
                write: matches!(access, Access::Write),
            });
        }
        // Verify spanned pages are mapped contiguously with same rights.
        for (k, vp) in (first..=last).enumerate() {
            let Some(e) = self.table.get(&vp) else {
                return Err(IommuFault::Unmapped {
                    va: vp << self.page_bits,
                });
            };
            if e.pa_page != e0.pa_page + k as u64 || e.perms != e0.perms || e.lease != e0.lease {
                return Err(IommuFault::MappingBreak { va, len });
            }
        }
        Ok((e0.pa_page << self.page_bits) + (va & (self.page_size() - 1)))
    }

    /// Unattributed translation (compat wrapper): leased pages reject it.
    pub fn translate(&self, va: u64, len: usize, access: Access) -> Result<u64> {
        Ok(self.translate_req(va, len, access, None)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_passes_through() {
        let m = Iommu::identity();
        assert_eq!(m.translate(0x1234_5678, 64, Access::Read).unwrap(), 0x1234_5678);
        assert_eq!(m.translate(0, 1, Access::Write).unwrap(), 0);
    }

    #[test]
    fn mapped_translation() {
        let mut m = Iommu::identity();
        m.map(0, 4 * IOMMU_PAGE_SIZE, 2 * IOMMU_PAGE_SIZE, Perms::RW)
            .unwrap();
        assert_eq!(
            m.translate(100, 8, Access::Read).unwrap(),
            4 * IOMMU_PAGE_SIZE + 100
        );
        // Second page maps contiguously.
        assert_eq!(
            m.translate(IOMMU_PAGE_SIZE + 8, 8, Access::Write).unwrap(),
            5 * IOMMU_PAGE_SIZE + 8
        );
    }

    #[test]
    fn unmapped_va_faults_once_table_nonempty() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        assert_eq!(
            m.translate_req(IOMMU_PAGE_SIZE * 10, 4, Access::Read, None),
            Err(IommuFault::Unmapped {
                va: IOMMU_PAGE_SIZE * 10
            })
        );
    }

    #[test]
    fn write_to_readonly_faults() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RO).unwrap();
        assert!(m.translate(0, 4, Access::Read).is_ok());
        let f = m.translate_req(0, 4, Access::Write, None).unwrap_err();
        assert_eq!(f, IommuFault::Denied { va: 0, write: true });
        assert_eq!(f.reason(), NakReason::WriteDenied);
    }

    #[test]
    fn cross_page_contiguous_ok_break_faults() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        // Map second VA page to a NON-contiguous PA page.
        m.map(IOMMU_PAGE_SIZE, 8 * IOMMU_PAGE_SIZE, IOMMU_PAGE_SIZE, Perms::RW)
            .unwrap();
        let straddle = IOMMU_PAGE_SIZE - 8;
        assert!(matches!(
            m.translate_req(straddle, 16, Access::Read, None),
            Err(IommuFault::MappingBreak { .. })
        ));
    }

    #[test]
    fn double_map_and_misalignment_rejected() {
        let mut m = Iommu::identity();
        m.map(0, 0, IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        assert!(m.map(0, IOMMU_PAGE_SIZE, IOMMU_PAGE_SIZE, Perms::RW).is_err());
        assert!(m.map(123, 0, IOMMU_PAGE_SIZE, Perms::RW).is_err());
        assert!(m.unmap(4096, IOMMU_PAGE_SIZE).is_err());
    }

    #[test]
    fn unmap_restores_fault() {
        let mut m = Iommu::identity();
        m.map(0, 0, 2 * IOMMU_PAGE_SIZE, Perms::RW).unwrap();
        m.unmap(0, IOMMU_PAGE_SIZE).unwrap();
        assert!(m.translate(0, 4, Access::Read).is_err());
        assert!(m.translate(IOMMU_PAGE_SIZE, 4, Access::Read).is_ok());
    }

    #[test]
    fn leased_pages_admit_only_their_tenant() {
        let mut m = Iommu::with_page_bits(13); // 8 KiB pool granule
        assert_eq!(m.page_size(), 8192);
        m.map_leased(0, 0, 8192, Perms::RW, Some(7)).unwrap();
        assert_eq!(m.translate_req(64, 8, Access::Read, Some(7)), Ok(64));
        assert_eq!(
            m.translate_req(64, 8, Access::Read, Some(8)),
            Err(IommuFault::ForeignLease { va: 64 })
        );
        assert_eq!(
            m.translate_req(64, 8, Access::Read, None),
            Err(IommuFault::ForeignLease { va: 64 })
        );
        // Contiguity check also refuses to cross into another lease.
        m.map_leased(8192, 8192, 8192, Perms::RW, Some(9)).unwrap();
        assert!(matches!(
            m.translate_req(8192 - 4, 8, Access::Read, Some(7)),
            Err(IommuFault::MappingBreak { .. })
        ));
    }

    #[test]
    fn page_size_only_changes_while_empty() {
        let mut m = Iommu::identity();
        m.set_page_bits(13).unwrap();
        m.map(0, 0, 8192, Perms::RW).unwrap();
        assert!(m.set_page_bits(21).is_err());
        assert!(!m.is_identity());
    }

    #[test]
    fn unmapping_everything_does_not_reopen_identity() {
        let mut m = Iommu::with_page_bits(13);
        m.map(0, 0, 8192, Perms::RW).unwrap();
        m.unmap(0, 8192).unwrap();
        // Once programmed, an empty table means "no leases", not identity.
        assert!(!m.is_identity());
        assert_eq!(
            m.translate_req(64, 8, Access::Read, None),
            Err(IommuFault::Unmapped { va: 64 })
        );
    }

    #[test]
    fn fault_reasons_round_trip_the_wire_byte() {
        let faults = [
            IommuFault::Unmapped { va: 0 },
            IommuFault::Denied { va: 0, write: false },
            IommuFault::Denied { va: 0, write: true },
            IommuFault::ForeignLease { va: 0 },
            IommuFault::MappingBreak { va: 0, len: 8 },
        ];
        for f in faults {
            let r = f.reason();
            assert_eq!(NakReason::from_u8(r as u8), r, "{f}");
            assert_ne!(r, NakReason::Unspecified);
        }
        assert_eq!(NakReason::from_u8(0xEE), NakReason::Unspecified);
    }
}
